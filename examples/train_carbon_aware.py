"""End-to-end driver: train a language model with carbon-aware step gating.

The training run is divided into step chunks; CaWoSched (the paper's
scheduler) assigns each chunk a start time inside the site's green-energy
windows, and the loop gates on that plan (simulated clock: 1 step = 1 s).
Checkpoints + deterministic data make the run restartable at any point.

    PYTHONPATH=src python examples/train_carbon_aware.py \
        --steps 120 --chunk 10 [--model-size 100m] [--inject-failure]

Default is a ~10M-param SmolLM-family config so the example finishes on a
laptop CPU in minutes; ``--model-size 100m`` trains the real ~100M-class
config (hours on CPU, minutes on accelerators).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS
from repro.configs.base import ShapeConfig, reduced
from repro.core import generate_profile
from repro.data import SyntheticTokens
from repro.models import build_model, param_count
from repro.runtime import FailureInjector, run_with_restarts
from repro.runtime.carbon_gate import CarbonGate, fleet_platform
from repro.runtime.fault import SimulatedFailure
from repro.train.step import init_state, make_train_step


def model_config(size: str):
    base = ARCHS["smollm-360m"]
    if size == "100m":
        return dataclasses.replace(
            base, name="smollm-100m", num_layers=12, d_model=768,
            num_heads=12, kv_heads=4, d_ff=2048, head_dim=64,
            vocab=49152, dtype="float32")
    r = reduced(base)
    return dataclasses.replace(r, d_model=256, num_layers=6, d_ff=1024,
                               vocab=8192, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--chunk", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-size", default="10m", choices=["10m", "100m"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--variant", default="pressWR-LS")
    args = ap.parse_args()

    cfg = model_config(args.model_size)
    model = build_model(cfg, tp=16)
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    data = SyntheticTokens(cfg, shape, seed=0)
    step_fn = jax.jit(make_train_step(model, microbatches=1, warmup=20))

    # --- carbon plan: chunks of `chunk` steps, ~1 s per step (simulated)
    n_chunks = -(-args.steps // args.chunk)
    plat = fleet_platform(pods=1, chip_watts_idle=60, chip_watts_work=200,
                          chips_per_pod=8)
    horizon = 3 * args.steps
    profile = generate_profile("S1", horizon, plat, J=24, seed=7,
                               work_capacity=plat.p_work[0])
    gate = CarbonGate(profile, plat, variant=args.variant)
    plan = gate.make_plan([[args.chunk] * n_chunks])
    print(f"carbon plan: cost={plan.cost} vs ASAP={plan.asap_cost} "
          f"({plan.cost / max(plan.asap_cost, 1):.2f}x)")

    mgr = CheckpointManager(args.ckpt_dir, keep=2, every=args.chunk)
    injector = (FailureInjector(0.02, seed=1)
                if args.inject_failure else None)
    clock = {"now": 0.0}

    def train(state, start, stop):
        t_wall = time.time()
        for s in range(start, stop):
            if s % args.chunk == 0:
                wait = gate.wait_time(0, s // args.chunk, clock["now"])
                if wait > 0:
                    print(f"  [gate] chunk {s // args.chunk}: waiting "
                          f"{wait:.0f}s (simulated) for green window")
                    clock["now"] += wait
            if injector is not None:
                injector.maybe_fail(s)
            state, metrics = step_fn(state, data.batch(s))
            clock["now"] += 1.0
            if s % 10 == 0:
                print(f"step {s:4d} loss={float(metrics['loss']):.4f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({time.time() - t_wall:.1f}s wall)")
            mgr.maybe_save(state, s)
        return state

    def init():
        state = init_state(model, jax.random.PRNGKey(0))
        print(f"model {cfg.name}: {param_count(state['params'])/1e6:.1f}M "
              f"params")
        return state

    state, done, restarts = run_with_restarts(
        train, mgr, init, args.steps, max_restarts=20)
    print(f"\ndone: {done} steps, {restarts} restarts, "
          f"final simulated clock {clock['now']:.0f}s")


if __name__ == "__main__":
    main()
