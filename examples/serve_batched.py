"""Serve a small model with continuously batched requests.

    PYTHONPATH=src python examples/serve_batched.py --requests 12 --slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import build_model, param_count
from repro.serve import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    cfg = dataclasses.replace(reduced(ARCHS[args.arch]), dtype="float32")
    model = build_model(cfg, tp=16)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(params)/1e6:.2f}M params, "
          f"{args.slots} decode slots")

    batcher = ContinuousBatcher(model, params, batch_size=args.slots,
                                max_len=256, eos=0)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, rng.integers(2, 6)).tolist()
        batcher.submit(Request(rid=rid, prompt=prompt,
                               max_tokens=args.max_new))

    t0 = time.time()
    steps = 0
    while batcher.queue or any(r is not None and not r.done
                               for r in batcher.slots):
        batcher.step()
        steps += 1
        if steps > 10_000:
            break
    dt = time.time() - t0
    done = [r for r in batcher.slots if r is not None and r.done]
    print(f"{steps} decode steps in {dt:.1f}s "
          f"({steps * args.slots / dt:.1f} tok/s aggregate)")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:10]}...")


if __name__ == "__main__":
    main()
