"""Serve a small model with continuously batched requests.

Before serving, the decode workload is planned carbon-aware through the
Planner API: the request backlog becomes a chain of decode chunks (a
fixed-mapping workflow), and one ``Planner.plan`` call places them inside
the site's green windows (simulated — the demo prints the admission plan
and then serves immediately).

The admission planning runs with tracing enabled: the coalesced burst
plus one forced degradation (a zero-budget request that walks the
fallback ladder down to ``asap``) produce a span trace that is dumped as
Chrome trace_event JSONL — load it line by line, or wrap in ``[...]``
for ``chrome://tracing`` / Perfetto.

    PYTHONPATH=src python examples/serve_batched.py --requests 12 --slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro import obs
from repro.api import Planner, PlanRequest
from repro.configs import ARCHS, reduced
from repro.core import generate_profile
from repro.core.dag import build_instance
from repro.models import build_model, param_count
from repro.serve import ContinuousBatcher, PlanService, Request


def carbon_admission_plan(n_requests: int, slots: int, est_chunk_s: int = 5,
                          trace_out: str = "serve_trace.jsonl"):
    """Green-window admission plan of the decode backlog (one chain of
    per-batch decode chunks on a 1-pod serving platform), traced: a
    coalesced 3-caller burst plus one zero-budget request forced down
    the fallback ladder, dumped to ``trace_out`` as JSONL."""
    from repro.runtime.carbon_gate import chunk_workflow, fleet_platform

    plat = fleet_platform(pods=1, chip_watts_idle=40, chip_watts_work=120,
                          chips_per_pod=8)
    n_chunks = max(-(-n_requests // slots), 1)
    chunk = [[est_chunk_s] * n_chunks]
    wf, mapping = chunk_workflow([n_chunks], chunk)
    inst = build_instance(wf, mapping, plat, dur=wf.node_w)
    horizon = 3 * n_chunks * est_chunk_s
    profile = generate_profile("S1", horizon, plat, J=12, seed=4,
                               work_capacity=int(plat.p_work[0]))
    tracer, _ = obs.configure(tracing=True)
    # plan through the resilient serving tier: a blown budget degrades to
    # a feasible asap plan instead of failing admission
    with PlanService(Planner(plat), default_budget=10.0) as svc:
        req = PlanRequest(instances=inst, profiles=profile,
                          variants=("asap", "pressWR-LS"))
        svc.pause()                    # let the burst pile up: coalesce
        burst = [svc.submit(req) for _ in range(3)]
        svc.resume()
        res = [t.result(timeout=120) for t in burst][0]
        # forced degradation: no budget left => skip straight to asap
        degraded = svc.plan(req, budget=0.0)
        stats = svc.stats()
    n_events = tracer.dump_jsonl(trace_out)
    obs.set_tracer(None)
    plan = res.result(variant="pressWR-LS" if "pressWR-LS" in res.variants
                      else res.variants[-1])
    asap = res.result(variant="asap")
    state = (f"degraded to {res.fallback_stage}" if res.degraded
             else "full fidelity")
    print(f"carbon admission plan: {n_chunks} decode chunks, carbon "
          f"{plan.cost} vs ASAP {asap.cost} "
          f"({plan.cost / max(asap.cost, 1):.2f}x, {state}); chunk starts "
          f"{[int(s) for s in plan.start[:8]]}"
          f"{'...' if len(plan.start) > 8 else ''} (simulated)")
    rungs = [s for s in tracer.finished() if s.name.startswith("rung:")]
    walk = ", ".join(f"{s.name.split(':', 1)[1]}:"
                     f"{s.attrs.get('outcome')} {s.duration * 1e3:.1f}ms"
                     for s in sorted(rungs, key=lambda s: s.t0))
    print(f"  coalesced {stats['coalesced_requests']} requests into "
          f"{stats['batches']} launches; forced degradation served by "
          f"{degraded.fallback_stage} ({', '.join(degraded.attempts)})")
    print(f"  trace: {n_events} spans -> {trace_out} (rungs: {walk})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--trace-out", default="serve_trace.jsonl",
                    help="where the admission-planning span trace lands "
                         "(Chrome trace_event JSONL)")
    args = ap.parse_args()

    carbon_admission_plan(args.requests, args.slots,
                          trace_out=args.trace_out)

    cfg = dataclasses.replace(reduced(ARCHS[args.arch]), dtype="float32")
    model = build_model(cfg, tp=16)
    params = model.init(jax.random.PRNGKey(0))
    print(f"serving {cfg.name}: {param_count(params)/1e6:.2f}M params, "
          f"{args.slots} decode slots")

    batcher = ContinuousBatcher(model, params, batch_size=args.slots,
                                max_len=256, eos=0)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, rng.integers(2, 6)).tolist()
        batcher.submit(Request(rid=rid, prompt=prompt,
                               max_tokens=args.max_new))

    t0 = time.time()
    steps = 0
    while batcher.queue or any(r is not None and not r.done
                               for r in batcher.slots):
        batcher.step()
        steps += 1
        if steps > 10_000:
            break
    dt = time.time() - t0
    done = [r for r in batcher.slots if r is not None and r.done]
    print(f"{steps} decode steps in {dt:.1f}s "
          f"({steps * args.slots / dt:.1f} tok/s aggregate)")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:10]}...")


if __name__ == "__main__":
    main()
