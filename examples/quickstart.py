"""Quickstart: schedule a scientific workflow carbon-aware in ~20 lines.

One ``Planner.plan`` call evaluates the ASAP baseline plus all 16
CaWoSched variants (paper §5) in a single amortized pass and returns the
dense cost grid; a second call on the ``solver="exact"`` axis audits the
heuristics against a provable optimum (``PlanResult.gap``).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
)
from repro.core.dag import trivial_mapping
from repro.workflows import layered_random, make_workflow


def main():
    platform = make_cluster(nodes_per_type=2, seed=0)      # 12 machines
    workflow = make_workflow("atacseq", n_samples=8, seed=1)
    print(f"workflow: {workflow.name}  tasks={workflow.n} edges={workflow.m}")

    mapping = heft_mapping(workflow, platform)             # fixed mapping
    inst = build_instance(workflow, mapping, platform)     # + comm tasks
    print(f"enhanced DAG: {inst.num_tasks} tasks "
          f"({inst.num_tasks - workflow.n} communications)")

    deadline = deadline_from_asap(inst, factor=2.0)
    profile = generate_profile("S1", deadline, platform, J=24, seed=2)

    planner = Planner(platform)                            # engine="auto"
    res = planner.plan(PlanRequest(instances=inst, profiles=profile))

    asap = res.result(variant="asap")
    print(f"\nASAP baseline: carbon cost = {asap.cost}")
    print(f"{'variant':<12} {'cost':>10} {'vs ASAP':>8} {'ms':>7}")
    for name in res.variants:
        if name == "asap":
            continue
        r = res.result(variant=name)
        ratio = r.cost / asap.cost if asap.cost else 1.0
        print(f"{name:<12} {r.cost:>10} {ratio:>8.3f} {r.seconds*1e3:>7.1f}")
    best = res.best()
    print(f"\nbest variant: {best.variant} "
          f"({best.cost / max(asap.cost, 1):.3f}x ASAP)")

    # The HEFT mapping above is fixed before scheduling. To optimize the
    # mapping JOINTLY with the schedule, pass the raw workflow instead of
    # an instance and set mapping="search" (or "heft" for plain HEFT):
    #     res = planner.plan(PlanRequest(
    #         instances=workflow, profiles=profile, mapping="search"))
    #     res.mappings[0]       # the winning FixedMapping
    #     res.mapping_info[0]   # search provenance (rounds, candidates)
    # See examples/fleet_scheduler.py for a measured joint-vs-fixed run.

    # --- optimality audit on a small instance (the solver axis) ----------
    # solver="exact" dispatches per instance: the polynomial DP on a
    # single-processor chain, the time-indexed ILP otherwise. The same
    # Planner serves both; gap() reports best-heuristic / optimum.
    tiny_wf = layered_random(6, 3, seed=7)
    tiny_plat = make_cluster(nodes_per_type=1, seed=0)
    tiny = build_instance(
        tiny_wf, trivial_mapping(tiny_wf, tiny_plat, by="single"),
        tiny_plat)
    tiny_prof = generate_profile(
        "S1", deadline_from_asap(tiny, factor=1.5), tiny_plat, J=6,
        seed=3, work_capacity=int(tiny.task_work.max()) // 2)
    tiny_planner = Planner(tiny_plat, engine="numpy")
    req = dict(instances=tiny, profiles=tiny_prof)
    heur = tiny_planner.plan(PlanRequest(**req))
    exact = tiny_planner.plan(PlanRequest(**req, solver="exact"))
    print(f"\nexact audit ({tiny.num_tasks}-task chain): "
          f"optimum={int(exact.costs[0, 0, 0])} "
          f"best heuristic gap={float(heur.gap(exact)[0, 0]):.3f}")
    print(heur.compare(exact))


if __name__ == "__main__":
    main()
