"""Quickstart: schedule a scientific workflow carbon-aware in ~20 lines.

One ``Planner.plan`` call evaluates the ASAP baseline plus all 16
CaWoSched variants (paper §5) in a single amortized pass and returns the
dense cost grid.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
)
from repro.workflows import make_workflow


def main():
    platform = make_cluster(nodes_per_type=2, seed=0)      # 12 machines
    workflow = make_workflow("atacseq", n_samples=8, seed=1)
    print(f"workflow: {workflow.name}  tasks={workflow.n} edges={workflow.m}")

    mapping = heft_mapping(workflow, platform)             # fixed mapping
    inst = build_instance(workflow, mapping, platform)     # + comm tasks
    print(f"enhanced DAG: {inst.num_tasks} tasks "
          f"({inst.num_tasks - workflow.n} communications)")

    deadline = deadline_from_asap(inst, factor=2.0)
    profile = generate_profile("S1", deadline, platform, J=24, seed=2)

    planner = Planner(platform)                            # engine="auto"
    res = planner.plan(PlanRequest(instances=inst, profiles=profile))

    asap = res.result(variant="asap")
    print(f"\nASAP baseline: carbon cost = {asap.cost}")
    print(f"{'variant':<12} {'cost':>10} {'vs ASAP':>8} {'ms':>7}")
    for name in res.variants:
        if name == "asap":
            continue
        r = res.result(variant=name)
        ratio = r.cost / asap.cost if asap.cost else 1.0
        print(f"{name:<12} {r.cost:>10} {ratio:>8.3f} {r.seconds*1e3:>7.1f}")
    best = res.best()
    print(f"\nbest variant: {best.variant} "
          f"({best.cost / max(asap.cost, 1):.3f}x ASAP)")


if __name__ == "__main__":
    main()
