"""Quickstart: schedule a scientific workflow carbon-aware in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.cluster import make_cluster
from repro.core import (
    ALL_VARIANTS,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    schedule,
)
from repro.workflows import make_workflow


def main():
    platform = make_cluster(nodes_per_type=2, seed=0)      # 12 machines
    workflow = make_workflow("atacseq", n_samples=8, seed=1)
    print(f"workflow: {workflow.name}  tasks={workflow.n} edges={workflow.m}")

    mapping = heft_mapping(workflow, platform)             # fixed mapping
    inst = build_instance(workflow, mapping, platform)     # + comm tasks
    print(f"enhanced DAG: {inst.num_tasks} tasks "
          f"({inst.num_tasks - workflow.n} communications)")

    deadline = deadline_from_asap(inst, factor=2.0)
    profile = generate_profile("S1", deadline, platform, J=24, seed=2)

    base = schedule(inst, profile, platform, "asap")
    print(f"\nASAP baseline: carbon cost = {base.cost}")
    print(f"{'variant':<12} {'cost':>10} {'vs ASAP':>8} {'ms':>7}")
    for v in ALL_VARIANTS:
        r = schedule(inst, profile, platform, v.name)
        ratio = r.cost / base.cost if base.cost else 1.0
        print(f"{v.name:<12} {r.cost:>10} {ratio:>8.3f} {r.seconds*1e3:>7.1f}")


if __name__ == "__main__":
    main()
