"""Fleet-level carbon-aware scheduling driven by the dry-run roofline model.

The roofline table (experiments/dryrun/*.json) provides per-(arch x shape)
step-time estimates on the production mesh; each fleet of training/serving
jobs across 2 pods becomes a fixed-mapping workflow whose task durations
come from those estimates, and CaWoSched shifts the jobs into green
windows.

Carbon forecasts are uncertain, so BOTH fleets x their 8-member perturbed
forecast ensembles x all 17 variants are planned as ONE ``Planner.plan``
call — the combined (instances x profiles x variants) grid; under the jax
engine every shape bucket of the grid is a single triple-vmapped device
launch. Per fleet the ROBUST variant is executed: the one whose worst
cost across the ensemble is smallest (min-max).

Fleet 0 is then re-planned with ``mapping="search"`` — the chunk->pod
placement becomes a decision variable optimized jointly with the
schedule (candidate mappings fan out through the same batched grid) —
and a :class:`~repro.api.PlanningSession` replans it over a rolling
3-window horizon: window k+1's plan is computed on a background worker
while window k "executes".

    PYTHONPATH=src python examples/fleet_scheduler.py
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.api import Planner, PlanRequest, window_profile
from repro.core import generate_profile
from repro.core.dag import build_instance
from repro.runtime.carbon_gate import chunk_workflow, fleet_platform

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")

N_ENSEMBLE = 8
N_WINDOWS = 3


def step_seconds(arch: str, shape: str) -> float:
    """Roofline bound from the dry-run (fallback: 1s)."""
    path = os.path.join(DRYRUN, f"{arch}_{shape}_single.json")
    if os.path.exists(path):
        d = json.load(open(path))
        if "roofline" in d:
            return max(d["roofline"]["bound_s"], 0.05)
    return 1.0


# per fleet: (pod0 job mix, pod1 job mix); (arch, shape, chunks, steps)
FLEETS = {
    "train-heavy": (
        [("qwen2.5-3b", "train_4k", 10, 50),
         ("smollm-360m", "train_4k", 6, 100)],
        [("granite-34b", "train_4k", 8, 25),
         ("whisper-large-v3", "train_4k", 5, 40)],
    ),
    "mixed-serve": (
        [("qwen2.5-3b", "train_4k", 6, 30),
         ("whisper-large-v3", "train_4k", 8, 60)],
        [("smollm-360m", "train_4k", 12, 80)],
    ),
}


def chunks(jobs):
    out = []
    for arch, shape, n_chunks, steps in jobs:
        sec = step_seconds(arch, shape)
        out += [max(int(sec * steps), 1)] * n_chunks
    return out


def build_fleet(plat, jobs0, jobs1):
    c0, c1 = chunks(jobs0), chunks(jobs1)
    wf, mapping = chunk_workflow([len(c0), len(c1)], [c0, c1])
    inst = build_instance(wf, mapping, plat, dur=wf.node_w)
    horizon = int(2.5 * max(sum(c0), sum(c1)))
    return inst, horizon, wf


def main():
    plat = fleet_platform(pods=2, chip_watts_idle=100, chip_watts_work=250,
                          chips_per_pod=256)
    names, instances, ensembles, fleet_wfs = [], [], [], []
    for name, (jobs0, jobs1) in FLEETS.items():
        inst, horizon, wf = build_fleet(plat, jobs0, jobs1)
        fleet_wfs.append(wf)
        # ensemble: one nominal forecast + perturbed members (same interval
        # grid, resampled budget noise — forecast uncertainty)
        profs = [generate_profile("S3", horizon, plat, J=48, seed=3 + s,
                                  work_capacity=int(plat.p_work[:2].sum()))
                 for s in range(N_ENSEMBLE)]
        names.append(name)
        instances.append(inst)
        ensembles.append(profs)

    # ONE plan call: both fleets x 8 members x 17 variants (the combined
    # grid; per-fleet cells are bit-identical to planning each alone)
    planner = Planner(plat, engine="auto")
    res = planner.plan(PlanRequest(instances=instances, profiles=ensembles,
                                   robust=True))

    for i, name in enumerate(names):
        inst, profs = instances[i], ensembles[i]
        costs, vnames = res.cost_matrix(i)
        robust, worst_cost = res.robust(i)
        asap_worst = costs[:, vnames.index("asap")].max()
        nominal_best = res.best(i, 0).variant

        print(f"\n[{name}] horizon {profs[0].T}s, {inst.num_tasks} chunk "
              f"tasks, {N_ENSEMBLE} forecast members "
              f"(engine={res.engine})")
        print(f"  robust (min-max) variant: {robust} "
              f"(worst-member carbon {worst_cost}; ASAP worst {asap_worst},"
              f" {worst_cost / max(asap_worst, 1):.2f}x)")
        if nominal_best != robust:
            print(f"  nominal-only pick would be {nominal_best} "
                  f"(worst-member carbon "
                  f"{costs[:, vnames.index(nominal_best)].max()})")
        best = res.pick(i)
        for pod, chain in enumerate(inst.proc_chains[:2]):
            starts = [int(best.start[t]) for t in chain]
            print(f"  pod{pod} chunk starts: {starts[:10]}"
                  f"{'...' if len(starts) > 10 else ''}")

    # --- joint mapping x scheduling of fleet 0 ----------------------------
    # The chunk->pod placement above is a FIXED mapping; `mapping="search"`
    # makes it a decision variable: candidate chunk placements are fanned
    # out through the same batched grid, and the cheapest (mapping,
    # schedule) pair wins — chunks migrate to the pod whose green windows
    # fit them.
    wf0, nominal = fleet_wfs[0], ensembles[0][0]
    res_fixed = planner.plan(PlanRequest(instances=instances[0],
                                         profiles=nominal))
    res_joint = planner.plan(PlanRequest(
        instances=wf0, profiles=nominal, mapping="search",
        mapping_options={"seeds": 4, "rounds": 2, "neighbors": 6}))
    cost_fixed = res_fixed.best().cost
    cost_joint = res_joint.best().cost
    info = res_joint.mapping_info[0]
    print(f"\n[joint mapping x scheduling] fleet {names[0]}, nominal "
          f"forecast")
    print(f"  fixed chunk->pod mapping: carbon {cost_fixed}")
    print(f"  searched mapping ({info.candidates} candidates, "
          f"{info.rounds} rounds, winner {info.label!r}): "
          f"carbon {cost_joint} "
          f"({(cost_fixed - cost_joint) / max(cost_fixed, 1) * 100:.1f}% "
          f"saved)")

    # --- async rolling-horizon replanning of fleet 0 ----------------------
    inst, W = instances[0], ensembles[0][0].T
    long = generate_profile("S3", N_WINDOWS * W, plat, J=96, seed=42,
                            work_capacity=int(plat.p_work[:2].sum()))

    def wprofs(k):      # window slice + perturbed members, same horizon W
        return [window_profile(long, k * W, W)] + [
            generate_profile("S3", W, plat, J=48, seed=60 + 8 * k + j,
                             work_capacity=int(plat.p_work[:2].sum()))
            for j in range(3)]

    print(f"\n[rolling horizon] fleet {names[0]}, {N_WINDOWS} windows of "
          f"{W}s (window k+1 planned while k executes)")
    with planner.session(inst, wprofs, n_windows=N_WINDOWS) as sess:
        for k, plan in sess.windows():
            robust, worst = plan.robust(0)
            print(f"  window {k}: robust={robust} worst-member={worst} "
                  f"(planned in {plan.seconds * 1e3:.0f} ms)")


if __name__ == "__main__":
    main()
