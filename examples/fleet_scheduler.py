"""Fleet-level carbon-aware scheduling driven by the dry-run roofline model.

The roofline table (experiments/dryrun/*.json) provides per-(arch x shape)
step-time estimates on the production mesh; each fleet of training/serving
jobs across 2 pods becomes a fixed-mapping workflow whose task durations
come from those estimates, and CaWoSched shifts the jobs into green
windows.

Carbon forecasts are uncertain, so each fleet instance is planned against
an ENSEMBLE of 8 perturbed profiles through ``schedule_portfolio_multi``
(the graph precompute runs once per instance; every profile only pays its
overlay) and the ROBUST variant is picked per instance: the one whose
worst cost across the ensemble is smallest (min-max).

    PYTHONPATH=src python examples/fleet_scheduler.py
"""
from __future__ import annotations

import json
import os

import numpy as np

from repro.core import generate_profile, portfolio_cost_matrix, \
    robust_pick, schedule_portfolio_multi
from repro.core.dag import build_instance
from repro.runtime.carbon_gate import chunk_workflow, fleet_platform

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")

N_ENSEMBLE = 8


def step_seconds(arch: str, shape: str) -> float:
    """Roofline bound from the dry-run (fallback: 1s)."""
    path = os.path.join(DRYRUN, f"{arch}_{shape}_single.json")
    if os.path.exists(path):
        d = json.load(open(path))
        if "roofline" in d:
            return max(d["roofline"]["bound_s"], 0.05)
    return 1.0


# per fleet: (pod0 job mix, pod1 job mix); (arch, shape, chunks, steps)
FLEETS = {
    "train-heavy": (
        [("qwen2.5-3b", "train_4k", 10, 50),
         ("smollm-360m", "train_4k", 6, 100)],
        [("granite-34b", "train_4k", 8, 25),
         ("whisper-large-v3", "train_4k", 5, 40)],
    ),
    "mixed-serve": (
        [("qwen2.5-3b", "train_4k", 6, 30),
         ("whisper-large-v3", "train_4k", 8, 60)],
        [("smollm-360m", "train_4k", 12, 80)],
    ),
}


def chunks(jobs):
    out = []
    for arch, shape, n_chunks, steps in jobs:
        sec = step_seconds(arch, shape)
        out += [max(int(sec * steps), 1)] * n_chunks
    return out


def main():
    plat = fleet_platform(pods=2, chip_watts_idle=100, chip_watts_work=250,
                          chips_per_pod=256)
    for name, (jobs0, jobs1) in FLEETS.items():
        c0, c1 = chunks(jobs0), chunks(jobs1)
        wf, mapping = chunk_workflow([len(c0), len(c1)], [c0, c1])
        inst = build_instance(wf, mapping, plat, dur=wf.node_w)
        horizon = int(2.5 * max(sum(c0), sum(c1)))
        # ensemble: one nominal forecast + perturbed members (same interval
        # grid, resampled budget noise — forecast uncertainty)
        profiles = [generate_profile("S3", horizon, plat, J=48, seed=3 + s,
                                     work_capacity=int(plat.p_work[:2].sum()))
                    for s in range(N_ENSEMBLE)]

        # one multi-profile pass: ASAP + all 16 variants x all 8 members
        # share the per-instance graph precompute
        results = schedule_portfolio_multi(inst, profiles, plat)
        costs, names = portfolio_cost_matrix(results)
        robust, worst_cost = robust_pick(costs, names)
        asap_worst = costs[:, names.index("asap")].max()
        heur = [i for i, n in enumerate(names) if n != "asap"]
        nominal_best = names[heur[int(np.argmin(costs[0, heur]))]]

        print(f"\n[{name}] horizon {horizon}s, {inst.num_tasks} chunk tasks,"
              f" {N_ENSEMBLE} forecast members")
        print(f"  robust (min-max) variant: {robust} "
              f"(worst-member carbon {worst_cost}; ASAP worst {asap_worst},"
              f" {worst_cost / max(asap_worst, 1):.2f}x)")
        if nominal_best != robust:
            print(f"  nominal-only pick would be {nominal_best} "
                  f"(worst-member carbon "
                  f"{costs[:, names.index(nominal_best)].max()})")
        best = results[0][robust]
        for pod, chain in enumerate(inst.proc_chains[:2]):
            starts = [int(best.start[t]) for t in chain]
            print(f"  pod{pod} chunk starts: {starts[:10]}"
                  f"{'...' if len(starts) > 10 else ''}")


if __name__ == "__main__":
    main()
