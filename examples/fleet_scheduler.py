"""Fleet-level carbon-aware scheduling driven by the dry-run roofline model.

The roofline table (experiments/dryrun/*.json) provides per-(arch x shape)
step-time estimates on the production mesh; a fleet of training/serving jobs
across 2 pods becomes a fixed-mapping workflow whose task durations come
from those estimates, and CaWoSched shifts the jobs into green windows.

    PYTHONPATH=src python examples/fleet_scheduler.py
"""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.core import generate_profile, schedule_portfolio
from repro.core.dag import build_instance
from repro.runtime.carbon_gate import chunk_workflow, fleet_platform

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def step_seconds(arch: str, shape: str) -> float:
    """Roofline bound from the dry-run (fallback: 1s)."""
    path = os.path.join(DRYRUN, f"{arch}_{shape}_single.json")
    if os.path.exists(path):
        d = json.load(open(path))
        if "roofline" in d:
            return max(d["roofline"]["bound_s"], 0.05)
    return 1.0


def main():
    # job mix: (arch, shape, number of step-chunks, steps per chunk)
    jobs_pod0 = [("qwen2.5-3b", "train_4k", 10, 50),
                 ("smollm-360m", "train_4k", 6, 100)]
    jobs_pod1 = [("granite-34b", "train_4k", 8, 25),
                 ("whisper-large-v3", "train_4k", 5, 40)]

    def chunks(jobs):
        out = []
        for arch, shape, n_chunks, steps in jobs:
            sec = step_seconds(arch, shape)
            out += [max(int(sec * steps), 1)] * n_chunks
        return out

    c0, c1 = chunks(jobs_pod0), chunks(jobs_pod1)
    print("pod0 chunk seconds:", c0)
    print("pod1 chunk seconds:", c1)

    plat = fleet_platform(pods=2, chip_watts_idle=100, chip_watts_work=250,
                          chips_per_pod=256)
    wf, mapping = chunk_workflow([len(c0), len(c1)], [c0, c1])
    inst = build_instance(wf, mapping, plat, dur=wf.node_w)
    horizon = int(2.5 * max(sum(c0), sum(c1)))
    profile = generate_profile("S3", horizon, plat, J=48, seed=3,
                               work_capacity=int(plat.p_work[:2].sum()))

    # one portfolio pass: ASAP + all 16 variants share the per-instance
    # precompute and the segment-list greedy (the long-horizon fast path —
    # the candidate list here is ~J + 2N points vs T ~ 10^5 time units)
    res = schedule_portfolio(inst, profile, plat)
    base = res["asap"]
    best = min((r for v, r in res.items() if v != "asap"),
               key=lambda r: r.cost)
    print(f"\nfleet horizon {horizon}s; ASAP carbon {base.cost}, "
          f"CaWoSched carbon {best.cost} [{best.variant}] "
          f"({best.cost / max(base.cost, 1):.2f}x)")
    for pod, chain in enumerate(inst.proc_chains[:2]):
        starts = [int(best.start[t]) for t in chain]
        print(f"pod{pod} chunk starts: {starts[:12]}{'...' if len(starts) > 12 else ''}")


if __name__ == "__main__":
    main()
