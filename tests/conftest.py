import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (dryrun.py sets its own flag in-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import build_instance, heft_mapping
from repro.workflows import make_workflow


@pytest.fixture(scope="session")
def small_platform():
    return make_cluster(1, seed=0)      # 6 compute processors


@pytest.fixture(scope="session")
def medium_instance(small_platform):
    wf = make_workflow("eager", 6, seed=3)
    mp = heft_mapping(wf, small_platform)
    return build_instance(wf, mp, small_platform)


def random_instance(n_tasks=24, seed=0, platform=None, kind="atacseq"):
    platform = platform or make_cluster(1, seed=seed)
    wf = make_workflow(kind, max(n_tasks // 12, 1), seed=seed)
    mp = heft_mapping(wf, platform)
    return build_instance(wf, mp, platform), platform
