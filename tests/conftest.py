import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (dryrun.py sets its own flag in-process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import build_instance, heft_mapping
from repro.workflows import make_workflow


def pytest_addoption(parser):
    parser.addoption(
        "--chaos-workers", action="store", type=int, default=1,
        help="PlanService drain-worker count the chaos suite runs under "
             "(make test-chaos sweeps 1 and 4)")
    parser.addoption(
        "--chaos-seed", action="store", type=int, default=0,
        help="seed offset for the chaos suite's scenario generators "
             "(the flake guard repeats the suite across several seeds)")


@pytest.fixture
def chaos_workers(request):
    return request.config.getoption("--chaos-workers")


@pytest.fixture
def chaos_seed(request):
    return request.config.getoption("--chaos-seed")


@pytest.fixture(scope="session")
def small_platform():
    return make_cluster(1, seed=0)      # 6 compute processors


@pytest.fixture(scope="session")
def medium_instance(small_platform):
    wf = make_workflow("eager", 6, seed=3)
    mp = heft_mapping(wf, small_platform)
    return build_instance(wf, mp, small_platform)


def random_instance(n_tasks=24, seed=0, platform=None, kind="atacseq"):
    platform = platform or make_cluster(1, seed=seed)
    wf = make_workflow(kind, max(n_tasks // 12, 1), seed=seed)
    mp = heft_mapping(wf, platform)
    return build_instance(wf, mp, platform), platform
