"""Pluggable solver axis: registry resolution, the exact stack via
``PlanRequest(solver=...)`` (dp_poly == dp_pseudo == ILP on uniprocessor
chains, ILP lower-bounds every heuristic on multiprocessor instances),
PlanResult.gap()/compare(), the asap baseline solver, commit_k="auto",
and the longest-path-matrix memory guard."""
import numpy as np
import pytest

from repro.api import LocalSearchConfig, Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    get_solver,
    schedule_cost,
    solver_names,
    validate_schedule,
)
from repro.core.carbon import PowerProfile
from repro.core.dag import trivial_mapping
from repro.core.dp_uniproc import dp_poly, dp_pseudo, is_uniprocessor
from repro.workflows import layered_random


def _require_highs():
    opt = pytest.importorskip("scipy.optimize")
    if not hasattr(opt, "milp"):
        pytest.skip("scipy.optimize.milp (HiGHS) unavailable")


def _tight_profile(inst, plat, T, J=4, seed=0):
    """A budget so tight that scheduling decisions carry nonzero cost."""
    rng = np.random.default_rng(seed)
    bounds = np.unique(np.round(np.linspace(0, T, J + 1)).astype(np.int64))
    budget = plat.idle_total + rng.integers(
        0, max(int(inst.task_work.max()) // 2, 2), size=len(bounds) - 1)
    return PowerProfile(bounds=bounds, budget=budget)


def _uniproc(seed=7, factor=1.4):
    plat = make_cluster(1, seed=0)
    wf = layered_random(5, 3, seed=seed)
    inst = build_instance(wf, trivial_mapping(wf, plat, by="single"), plat)
    T = deadline_from_asap(inst, factor)
    return plat, inst, _tight_profile(inst, plat, T, seed=seed)


def _multiproc(seed=0, factor=1.5):
    """Tiny multiprocessor instance (short durations keep the ILP fast)."""
    rng = np.random.default_rng(seed)
    plat = make_cluster(1, seed=0)
    wf = layered_random(6, 3, seed=seed)
    inst = build_instance(wf, trivial_mapping(wf, plat), plat,
                          dur=rng.integers(1, 6, size=wf.n))
    T = deadline_from_asap(inst, factor)
    return plat, inst, _tight_profile(inst, plat, T, seed=seed)


# --- registry resolution ----------------------------------------------------

def test_solver_registry_resolution():
    from repro.kernels.backend import resolve_solver

    assert set(solver_names()) >= {"heuristic", "exact", "ilp", "dp",
                                   "asap"}
    assert resolve_solver(None).name == "heuristic"
    assert resolve_solver("auto").name == "heuristic"
    assert resolve_solver("exact") is get_solver("exact")
    with pytest.raises(ValueError, match="unknown solver"):
        resolve_solver("simplex")
    plat, inst, prof = _uniproc()
    with pytest.raises(ValueError, match="unknown solver"):
        PlanRequest(instances=inst, profiles=prof,
                    solver="simplex").resolve()
    # non-heuristic solvers serve exactly their own variant column
    with pytest.raises(ValueError, match="exactly the variant"):
        PlanRequest(instances=inst, profiles=prof, solver="exact",
                    variants=("slack",)).resolve()
    _, _, names = PlanRequest(instances=inst, profiles=prof,
                              solver="exact").resolve()
    assert names == ("exact",)


# --- exact stack on the solver axis ----------------------------------------

@pytest.mark.parametrize("seed", range(3))
def test_exact_resolves_to_dp_on_uniprocessor(seed):
    plat, inst, prof = _uniproc(seed=seed)
    assert is_uniprocessor(inst)
    planner = Planner(plat, engine="numpy")
    # check=True cross-validates every cell against the pseudo-poly oracle
    ex = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                  solver="exact",
                                  solver_options={"check": True}))
    dp = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                  solver="dp"))
    c_poly, s_poly = dp_poly(inst, prof)
    c_pseudo, _ = dp_pseudo(inst, prof)
    assert ex.solver == "exact" and ex.variants == ("exact",)
    assert int(ex.costs[0, 0, 0]) == c_poly == c_pseudo \
        == int(dp.costs[0, 0, 0])
    assert ex.lower_bound is not None \
        and int(ex.lower_bound[0, 0]) == c_poly
    got = ex.result(variant="exact")
    validate_schedule(inst, prof, got.start)
    assert schedule_cost(inst, prof, got.start) == c_poly
    assert schedule_cost(inst, prof, s_poly) == c_poly


def test_dp_solver_rejects_multiprocessor():
    plat, inst, prof = _multiproc()
    assert not is_uniprocessor(inst)
    with pytest.raises(ValueError, match="single-processor"):
        Planner(plat, engine="numpy").plan(
            PlanRequest(instances=inst, profiles=prof, solver="dp"))


@pytest.mark.ilp
@pytest.mark.parametrize("seed", range(2))
def test_ilp_equals_dp_on_uniprocessor_via_solver_axis(seed):
    _require_highs()
    plat, inst, prof = _uniproc(seed=seed + 20)
    planner = Planner(plat, engine="numpy")
    ilp = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="ilp",
                                   solver_options={"time_limit": 120}))
    dp = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                  solver="dp"))
    assert int(ilp.costs[0, 0, 0]) == int(dp.costs[0, 0, 0])
    assert int(ilp.lower_bound[0, 0]) == int(dp.costs[0, 0, 0])
    validate_schedule(inst, prof, ilp.result(variant="ilp").start)


@pytest.mark.ilp
@pytest.mark.parametrize("seed", range(2))
def test_exact_lower_bounds_heuristics_on_multiprocessor(seed):
    _require_highs()
    plat, inst, prof = _multiproc(seed=seed)
    planner = Planner(plat, engine="numpy")
    ex = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                  solver="exact",
                                  solver_options={"time_limit": 120}))
    heur = planner.plan(PlanRequest(instances=inst, profiles=prof))
    base = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                    solver="asap"))
    opt = int(ex.costs[0, 0, 0])
    validate_schedule(inst, prof, ex.result(variant="exact").start)
    # the exact optimum lower-bounds every heuristic and the baseline
    assert (heur.costs[0, 0] >= opt).all()
    assert int(base.costs[0, 0, 0]) >= opt
    gaps = heur.gap(ex)
    assert gaps.shape == (1, 1) and gaps[0, 0] >= 1.0 - 1e-12
    table = heur.compare(ex)
    assert "exact" in table and table.count("\n") >= len(heur.variants)


def test_asap_solver_matches_asap_variant():
    plat, inst, prof = _multiproc(seed=1)
    planner = Planner(plat, engine="numpy")
    base = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                    solver="asap"))
    legacy = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                      variants="asap"))
    assert base.solver == "asap" and base.variants == ("asap",)
    assert base.lower_bound is None
    a, b = base.result(variant="asap"), legacy.result(variant="asap")
    assert (a.start == b.start).all() and a.cost == b.cost


def test_gap_requires_bound_and_handles_zero_cost():
    plat, inst, prof = _uniproc(seed=3)
    planner = Planner(plat, engine="numpy")
    heur = planner.plan(PlanRequest(instances=inst, profiles=prof))
    with pytest.raises(ValueError, match="lower bound"):
        heur.gap()
    ex = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                  solver="exact"))
    assert heur.gap(ex)[0, 0] >= 1.0 - 1e-12
    # a free profile makes everything cost 0: gap convention -> exactly 1
    free = PowerProfile(
        bounds=np.asarray([0, prof.T], dtype=np.int64),
        budget=np.asarray(
            [plat.idle_total + int(inst.task_work.sum()) + 1],
            dtype=np.int64))
    h0 = planner.plan(PlanRequest(instances=inst, profiles=free))
    e0 = planner.plan(PlanRequest(instances=inst, profiles=free,
                                  solver="exact"))
    assert int(e0.costs[0, 0, 0]) == 0
    assert h0.gap(e0)[0, 0] == 1.0
    with pytest.raises(ValueError, match="grid shapes"):
        h0.gap(planner.plan(PlanRequest(
            instances=[inst, inst], profiles=free, solver="exact")))


@pytest.mark.ilp
def test_exact_solver_dispatches_per_instance_in_one_request():
    """One request mixing a uniprocessor and a multiprocessor instance:
    the exact solver must route each to its oracle (DP / ILP)."""
    _require_highs()
    plat, uni, prof_u = _uniproc(seed=4)
    _, multi, prof_m = _multiproc(seed=2)
    ex = Planner(plat, engine="numpy").plan(PlanRequest(
        instances=[uni, multi], profiles=[[prof_u], [prof_m]],
        solver="exact", solver_options={"time_limit": 120}))
    assert ex.shape == (2, 1, 1)
    c_dp, _ = dp_poly(uni, prof_u)
    assert int(ex.costs[0, 0, 0]) == c_dp
    assert (ex.lower_bound == ex.costs[:, :, 0]).all()
    validate_schedule(multi, prof_m, ex.results[1][0]["exact"].start)


# --- commit_k="auto" --------------------------------------------------------

def test_auto_commit_k_rule_and_config():
    from repro.core.local_search_jax import auto_commit_k

    assert auto_commit_k(0) == 8
    assert auto_commit_k(10**6) == 128
    assert auto_commit_k(200) == 50
    ks = [auto_commit_k(n) for n in range(0, 2000, 50)]
    assert ks == sorted(ks)                     # monotone in density
    assert LocalSearchConfig(commit_k="auto").commit_k == "auto"
    with pytest.raises(ValueError):
        LocalSearchConfig(commit_k=0)
    with pytest.raises(ValueError):
        LocalSearchConfig(commit_k="bogus")


@pytest.mark.device
def test_commit_k_auto_matches_sequential_reference():
    """commit_k='auto' must land every -LS row on a state the sequential
    reference cannot improve (same guarantee as any fixed K)."""
    from repro.core import generate_profile, heft_mapping
    from repro.core.local_search import local_search
    from repro.workflows import make_workflow

    plat = make_cluster(1, seed=4)
    wf = make_workflow("eager", 3, seed=4)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    prof = generate_profile("S1", deadline_from_asap(inst, 2.0), plat,
                            J=16, seed=4)
    res = Planner(plat, engine="jax",
                  ls=LocalSearchConfig(commit_k="auto")).plan(
        PlanRequest(instances=inst, profiles=prof))
    for name in res.variants:
        if not name.endswith("-LS"):
            continue
        got = res.results[0][0][name]
        validate_schedule(inst, prof, got.start)
        assert got.cost <= res.results[0][0][name[:-3]].cost
        polished = local_search(inst, prof, plat, got.start, max_rounds=1)
        assert (polished == got.start).all(), name


# --- longest-path matrix memory guard ---------------------------------------

def test_lp_matrix_memory_guard():
    from repro.core.greedy_jax import (
        LP_MAX_BYTES,
        longest_path_matrix,
        lp_matrix_bytes,
    )

    assert lp_matrix_bytes(4000) == 64_000_000      # the ROADMAP number
    assert lp_matrix_bytes(5000) < LP_MAX_BYTES < lp_matrix_bytes(6000)
    _, inst, _ = _multiproc(seed=3)
    lp = longest_path_matrix(inst)                  # small N: fine
    assert lp.shape == (inst.num_tasks, inst.num_tasks)
    with pytest.raises(MemoryError, match="blocked form"):
        longest_path_matrix(inst, max_bytes=8)
    (lp2,) = [longest_path_matrix(inst, max_bytes=lp_matrix_bytes(
        inst.num_tasks))]                           # exact budget passes
    assert (lp2 == lp).all()
