"""Deeper model correctness: train-mode forward == step-by-step decode,
MoE dispatch == dense mixture, attention chunking == unchunked."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.configs.base import MoEConfig
from repro.models import build_model
from repro.models import layers as L
from repro.models import moe as MOE


def _tokens_batch(r, B, S, seed=0):
    rng = np.random.default_rng(seed)
    tok = jnp.asarray(rng.integers(1, r.vocab, (B, S)), jnp.int32)
    return {"tokens": tok, "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen1.5-0.5b",
                                  "granite-moe-1b-a400m", "xlstm-125m",
                                  "jamba-v0.1-52b"])
def test_train_forward_matches_decode(arch):
    """Greedy decode over a prompt must match argmax of the train-mode
    forward logits at each position (same params, causal consistency)."""
    r = dataclasses.replace(reduced(ARCHS[arch]), dtype="float32")
    if r.moe is not None:
        # capacity effects differ between S-token and 1-token calls unless
        # capacity is generous
        r = dataclasses.replace(
            r, moe=dataclasses.replace(r.moe, capacity_factor=8.0))
    m = build_model(r, tp=16)
    key = jax.random.PRNGKey(0)
    params = m.init(key)
    B, S = 2, 8
    batch = _tokens_batch(r, B, S)
    h = m.apply(params, batch, remat=False)
    full_logits = L.unembed(h, params["embed"])          # [B,S,V]

    cache = m.init_cache(B, S + 2)
    outs = []
    for t in range(S):
        logits, cache = m.decode_step(params, cache, batch["tokens"][:, t])
        outs.append(np.asarray(logits))
    dec = np.stack(outs, axis=1)                         # [B,S,V]
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), dec, rtol=2e-2, atol=2e-2)


def test_moe_matches_dense_mixture():
    """With capacity >= tokens, sort-based dispatch == explicit mixture."""
    d, ff, E, k = 16, 32, 4, 2
    key = jax.random.PRNGKey(1)
    mcfg = MoEConfig(num_experts=E, top_k=k, d_ff_expert=ff,
                     capacity_factor=float(E))   # never drop
    p = MOE.init_moe(key, d, mcfg, layers=1)
    p = jax.tree.map(lambda a: a[0], p)          # single layer
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 6, d), jnp.float32)
    got = MOE.moe_ffn(p, x, mcfg)

    # dense reference: per token, softmax(top-k) mixture of expert MLPs
    logits = jnp.einsum("bsd,de->bse", x, p["gate"])
    topv, topi = jax.lax.top_k(logits, k)
    gates = jax.nn.softmax(topv, axis=-1)
    y = jnp.zeros_like(x)
    for e in range(E):
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"][e]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["w3"][e])
        ye = jnp.einsum("bsf,fd->bsd", h, p["w2"][e])
        w = ((topi == e) * gates).sum(-1)[..., None]
        y = y + w * ye
    np.testing.assert_allclose(np.asarray(got), np.asarray(y),
                               rtol=1e-4, atol=1e-5)


def test_moe_capacity_drops_tokens():
    """With capacity ~ 0, output collapses to (near) zero — drops happen."""
    d, ff, E, k = 8, 16, 4, 2
    mcfg = MoEConfig(num_experts=E, top_k=k, d_ff_expert=ff,
                     capacity_factor=1e-9)
    p = jax.tree.map(lambda a: a[0],
                     MOE.init_moe(jax.random.PRNGKey(3), d, mcfg, layers=1))
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 16, d), jnp.float32)
    y = MOE.moe_ffn(p, x, mcfg)
    # capacity rounds up to 8 slots/expert -> at most 32 pair slots for 64
    # pairs: some tokens must drop; norm is reduced vs generous capacity
    y_full = MOE.moe_ffn(p, x, dataclasses.replace(mcfg,
                                                   capacity_factor=8.0))
    assert float(jnp.abs(y).sum()) < float(jnp.abs(y_full).sum())


def test_attention_chunking_is_exact():
    cfg = reduced(ARCHS["qwen2.5-3b"])
    cfg = dataclasses.replace(cfg, dtype="float32")
    key = jax.random.PRNGKey(5)
    p = jax.tree.map(lambda a: a[0],
                     L.init_attn(key, cfg, 1, hq_pad=4, hkv_pad=2))
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 64, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(64), (2, 64))
    full = L.attention_train(p, x, cfg, pos)
    old = L.QCHUNK
    try:
        L.QCHUNK = 16                        # force 4 chunks
        chunked = L.attention_train(p, x, cfg, pos)
    finally:
        L.QCHUNK = old
    np.testing.assert_allclose(np.asarray(full), np.asarray(chunked),
                               rtol=1e-5, atol=1e-5)


def test_causality():
    """Future tokens cannot influence past logits."""
    r = dataclasses.replace(reduced(ARCHS["smollm-360m"]), dtype="float32")
    m = build_model(r, tp=16)
    params = m.init(jax.random.PRNGKey(7))
    B, S = 1, 12
    b1 = _tokens_batch(r, B, S, seed=1)
    tok2 = b1["tokens"].at[:, S // 2:].set(7)     # change the future
    h1 = m.apply(params, b1, remat=False)
    h2 = m.apply(params, {"tokens": tok2, "labels": b1["labels"]},
                 remat=False)
    np.testing.assert_allclose(np.asarray(h1[:, :S // 2]),
                               np.asarray(h2[:, :S // 2]),
                               rtol=1e-5, atol=1e-5)


def test_moe_sharded_dispatch_matches_global():
    """Hierarchical (per-data-shard) dispatch == global dispatch when
    capacity is generous (the §Perf granite-moe hillclimb is exact)."""
    d, ff, E, k = 16, 32, 4, 2
    key = jax.random.PRNGKey(11)
    m_g = MoEConfig(num_experts=E, top_k=k, d_ff_expert=ff,
                    capacity_factor=float(E))
    m_s = dataclasses.replace(m_g, dispatch="sharded")
    p = jax.tree.map(lambda a: a[0], MOE.init_moe(key, d, m_g, layers=1))
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 8, d), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(MOE.moe_ffn(p, x, m_g)),
        np.asarray(MOE.moe_ffn(p, x, m_s)), rtol=1e-4, atol=1e-5)


def test_mixed_precision_train_step_tracks_full_precision():
    from repro.train.step import init_state, make_train_step

    r = dataclasses.replace(reduced(ARCHS["smollm-360m"]), dtype="float32")
    m = build_model(r, tp=16)
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, r.vocab, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, r.vocab, (2, 16)),
                                   jnp.int32)}
    step = make_train_step(m, microbatches=1)
    s_fp = init_state(m, jax.random.PRNGKey(0))
    s_mp = init_state(m, jax.random.PRNGKey(0), mixed_precision=True)
    assert jax.tree.leaves(s_mp["params"])[0].dtype == jnp.bfloat16
    for _ in range(3):
        s_fp, m_fp = jax.jit(step)(s_fp, batch)
        s_mp, m_mp = jax.jit(step)(s_mp, batch)
    # master copy stays close to the full-precision trajectory
    assert abs(float(m_fp["loss"]) - float(m_mp["loss"])) < 0.05
    for a, b in zip(jax.tree.leaves(s_fp["params"]),
                    jax.tree.leaves(s_mp["opt"]["master"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=0.05, atol=5e-3)
