"""Chaos suite: deterministic fault injection against the PlanService.

Every test scripts :class:`~repro.runtime.fault.ServiceFaultInjector`
faults (crash / hang / device OOM / poison error / profile corruption)
into the service's real solve paths and asserts the acceptance
properties of the resilience issue: under ANY injected fault the caller
still gets a *feasible* schedule (or a structured rejection), the
degradation ladder stops at exactly the right stage with the right
``attempts`` log, quarantine isolates the poisoned request from its
batch-mates, and a fault-free service stays bit-identical to direct
``Planner.plan``.

Marked ``chaos`` (deselected from tier-1 via addopts); run with
``make test-chaos`` / ``pytest -m chaos``. Faults are scripted specs or
seeded RNG — no real nondeterminism, every run takes the same path.
"""
import time

import numpy as np
import pytest

from repro.api import Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    validate_schedule,
)
from repro.runtime.fault import FaultSpec, ServiceFaultInjector
from repro.serve import InvalidRequest, PlanFailure, PlanService
from repro.workflows import make_workflow

pytestmark = pytest.mark.chaos

# the suite runs once per worker count: `pytest -m chaos --chaos-workers 4`
# (make test-chaos sweeps 1 and 4); `--chaos-seed N` offsets the scenario
# seeds so the flake guard exercises distinct workloads per repetition


def _setup(kind="eager", samples=3, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


def _assert_same_plan(a, b):
    assert a.variants == b.variants
    assert (a.costs == b.costs).all()
    for ra, rb in zip(a.results, b.results):
        for ca, cb in zip(ra, rb):
            for name in ca:
                assert (ca[name].start == cb[name].start).all(), name


def _assert_feasible(res, inst, prof):
    """Whatever the ladder returned, it is a feasible schedule."""
    for name in res.variants:
        validate_schedule(inst, prof, res.result(variant=name).start)


# --- single-fault ladder walks ---------------------------------------------

def test_persistent_crash_exhausts_retries_then_degrades(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage="ilp", times=99)])
    with PlanService(planner.clone(), injector=inj, retries=1,
                     backoff=0.01, workers=chaos_workers) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="ilp"))
    assert res.degraded and res.fallback_stage == "heuristic"
    assert res.attempts == ("ilp:crash", "ilp:crash", "heuristic:ok")
    assert res.variants == svc.fallback_variants
    _assert_feasible(res, inst, prof)
    assert inj.fired == [("crash", "ilp")] * 2


def test_hang_trips_watchdog_within_budget(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="hang", stage="heuristic", times=5,
                          seconds=2.0)])
    with PlanService(planner.clone(), injector=inj,
                     workers=chaos_workers) as svc:
        t0 = time.monotonic()
        res = svc.plan(PlanRequest(instances=inst, profiles=prof),
                       budget=0.3)
        elapsed = time.monotonic() - t0
    # the watchdog abandoned the hung solve at ~budget, not at ~2s
    assert elapsed < 1.5, elapsed
    assert res.degraded and res.fallback_stage == "asap"
    assert res.attempts == ("heuristic:timeout", "asap:ok")
    _assert_feasible(res, inst, prof)


def test_double_oom_exhausts_blocked_retry_then_degrades(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="oom", stage="heuristic", times=2)])
    with PlanService(planner.clone(), injector=inj,
                     workers=chaos_workers) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
        assert svc.stats()["oom_retries"] == 1
    assert res.degraded and res.fallback_stage == "asap"
    assert res.attempts == ("heuristic:oom",
                            "heuristic:oom-retry-blocked-lp",
                            "heuristic:oom", "asap:ok")
    _assert_feasible(res, inst, prof)


def test_exact_chain_walks_every_rung(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage="exact", times=9),
                FaultSpec(kind="crash", stage="ilp", times=9)])
    with PlanService(planner.clone(), injector=inj, retries=0,
                     workers=chaos_workers) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="exact"))
    assert res.degraded and res.fallback_stage == "heuristic"
    assert res.attempts == ("exact:crash", "ilp:crash", "heuristic:ok")
    _assert_feasible(res, inst, prof)


def test_budget_blown_mid_chain_skips_to_terminal_asap(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="hang", stage="exact", times=1,
                          seconds=2.0)])
    with PlanService(planner.clone(), injector=inj,
                     workers=chaos_workers) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="exact"), budget=0.25)
    assert res.degraded and res.fallback_stage == "asap"
    assert res.attempts == ("exact:timeout", "ilp:skipped",
                            "heuristic:skipped", "asap:ok")
    _assert_feasible(res, inst, prof)


def test_crash_on_every_stage_is_a_structured_failure(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage=None, times=99)])
    with PlanService(planner.clone(), injector=inj, retries=0,
                     backoff=0.01, workers=chaos_workers) as svc:
        with pytest.raises(PlanFailure) as ei:
            svc.plan(PlanRequest(instances=inst, profiles=prof))
        assert svc.stats()["failed"] == 1
    d = ei.value.to_dict()
    assert d["code"] == "plan_failure"
    # to_dict is the JSON wire shape: tuples travel as lists
    assert d["attempts"] == ["heuristic:crash", "asap:crash"]


# --- quarantine isolation --------------------------------------------------

def test_corrupt_request_is_quarantined_batch_survives(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="corrupt", times=1)])
    with PlanService(planner.clone(), injector=inj,
                     workers=chaos_workers) as svc:
        svc.pause()
        t1 = svc.submit(PlanRequest(instances=inst, profiles=prof))
        t2 = svc.submit(PlanRequest(instances=inst, profiles=prof))
        t3 = svc.submit(PlanRequest(instances=inst, profiles=prof))
        svc.resume()
        with pytest.raises(InvalidRequest, match="batch assembly"):
            t1.result(timeout=120)       # first in queue ate the corruption
        r2, r3 = t2.result(timeout=120), t3.result(timeout=120)
        stats = svc.stats()
    _assert_same_plan(r2, direct)        # batch-mates: full fidelity
    _assert_same_plan(r3, direct)
    assert not r2.degraded and not r3.degraded
    assert stats["quarantined"] == 1
    assert stats["batches"] == 1 and stats["coalesced_requests"] == 2


def test_poison_error_bisects_batch_each_ticket_rechains_alone(
        chaos_workers):
    plat, inst, prof = _setup(samples=2, seed=5)
    wf2 = make_workflow("eager", 2, seed=9)
    plat2 = make_cluster(1, seed=5)
    inst2 = build_instance(wf2, heft_mapping(wf2, plat2), plat2)
    prof2 = generate_profile("S1", deadline_from_asap(inst2, 1.5), plat2,
                             J=16, seed=7)
    planner = Planner(plat, engine="numpy")
    d1 = planner.plan(PlanRequest(instances=inst, profiles=prof))
    d2 = planner.plan(PlanRequest(instances=inst2, profiles=prof2))
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="error", stage="heuristic", times=1)])
    with PlanService(planner.clone(), injector=inj,
                     workers=chaos_workers) as svc:
        svc.pause()
        t1 = svc.submit(PlanRequest(instances=inst, profiles=prof))
        t2 = svc.submit(PlanRequest(instances=inst2, profiles=prof2))
        svc.resume()
        r1, r2 = t1.result(timeout=120), t2.result(timeout=120)
        assert svc.stats()["splits"] == 1
    for r, d in ((r1, d1), (r2, d2)):
        assert r.attempts[0] == "quarantine:split"
        assert r.attempts[-1] == "heuristic:ok"
        assert not r.degraded            # solo re-runs reached full fidelity
        _assert_same_plan(r, d)


def test_persistent_poison_degrades_every_split_ticket_to_asap(
        chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="error", stage="heuristic", times=99)])
    with PlanService(planner.clone(), injector=inj,
                     workers=chaos_workers) as svc:
        svc.pause()
        tickets = [svc.submit(PlanRequest(instances=inst, profiles=prof))
                   for _ in range(2)]
        svc.resume()
        results = [t.result(timeout=120) for t in tickets]
    for res in results:
        assert res.degraded and res.fallback_stage == "asap"
        assert res.attempts == ("quarantine:split", "heuristic:error",
                                "asap:ok")
        _assert_feasible(res, inst, prof)


# --- seeded probabilistic sweep --------------------------------------------

def test_seeded_random_crash_sweep_always_yields_feasible_plans(
        chaos_workers, chaos_seed):
    plat, inst, prof = _setup(seed=3 + chaos_seed)
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(prob=0.35, seed=1234 + chaos_seed)
    with PlanService(planner.clone(), injector=inj, retries=3,
                     backoff=0.01, workers=chaos_workers) as svc:
        results = [svc.plan(PlanRequest(instances=inst, profiles=prof))
                   for _ in range(6)]
        stats = svc.stats()
    assert stats["completed"] == 6 and stats["failed"] == 0
    assert inj.fired, "seed produced no faults; pick a different seed"
    for res in results:
        assert res.fallback_stage in ("heuristic", "asap")
        assert res.degraded == (res.fallback_stage != "heuristic")
        _assert_feasible(res, inst, prof)
    # the sweep is scripted RNG: same seed, same fault sequence —
    # reproducible fault-for-fault at any worker count (requests are
    # submitted one at a time, so claims cannot reorder)
    inj2 = ServiceFaultInjector(prob=0.35, seed=1234 + chaos_seed)
    with PlanService(planner.clone(), injector=inj2, retries=3,
                     backoff=0.01, workers=chaos_workers) as svc:
        results2 = [svc.plan(PlanRequest(instances=inst, profiles=prof))
                    for _ in range(6)]
    assert inj2.fired == inj.fired
    for a, b in zip(results, results2):
        assert a.attempts == b.attempts
        _assert_same_plan(a, b)


# --- worker supervision ----------------------------------------------------

def test_worker_death_restarts_and_requeues_tickets(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="worker-death", times=1)])
    with PlanService(planner.clone(), injector=inj, workers=chaos_workers,
                     heartbeat_timeout=0.2) as svc:
        t = svc.submit(PlanRequest(instances=inst, profiles=prof))
        res = t.result(timeout=60)       # served by the REPLACEMENT worker
        stats = svc.stats()
    _assert_same_plan(res, direct)       # requeue lost no fidelity
    assert not res.degraded
    assert stats["worker_restarts"] >= 1
    assert stats["requeued"] >= 1
    assert ("worker-death", None) in inj.fired


def test_wedged_worker_is_deposed_within_heartbeat_timeout(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="wedge", times=1, seconds=30.0)])
    with PlanService(planner.clone(), injector=inj, workers=chaos_workers,
                     heartbeat_timeout=0.2) as svc:
        t0 = time.monotonic()
        t = svc.submit(PlanRequest(instances=inst, profiles=prof))
        res = t.result(timeout=60)
        elapsed = time.monotonic() - t0
        stats = svc.stats()
    # deposed at ~heartbeat_timeout + served fresh, not after the 30s stall
    assert elapsed < 10.0, elapsed
    _assert_same_plan(res, direct)
    assert not res.degraded
    assert stats["worker_restarts"] >= 1 and stats["requeued"] >= 1


def test_mid_burst_kill_replays_journal_without_losing_tickets(
        tmp_path, chaos_workers):
    """The crash-recovery acceptance drill: the service dies mid-burst
    (first batch claim), the restarted service replays every
    admitted-but-unfinished ticket from the journal, each resolves at
    full fidelity, and a third restart finds nothing left (no
    duplicates, no losses)."""
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    jdir = str(tmp_path / "journal")
    inj = ServiceFaultInjector(faults=[FaultSpec(kind="kill", times=1)])
    svc = PlanService(planner.clone(), injector=inj, workers=chaos_workers,
                      journal_dir=jdir)
    svc.pause()
    tickets = [svc.submit(PlanRequest(instances=inst, profiles=prof))
               for _ in range(5)]
    svc.resume()                         # first batch claim kills the service
    deadline = time.monotonic() + 30
    while not svc._killed and time.monotonic() < deadline:
        time.sleep(0.01)
    assert svc._killed
    unresolved = [t for t in tickets if not t.done()]
    assert len(unresolved) == 5          # killed before anything was served
    svc2 = PlanService(planner.clone(), workers=chaos_workers,
                       journal_dir=jdir)
    assert len(svc2.replayed) == len(unresolved)
    results = [t.result(timeout=120) for t in svc2.replayed]
    stats2 = svc2.stats()
    svc2.close()
    for res in results:
        _assert_same_plan(res, direct)   # replay serves full fidelity
        assert not res.degraded
    assert stats2["replayed"] == 5 and stats2["completed"] == 5
    svc3 = PlanService(planner.clone(), workers=chaos_workers,
                       journal_dir=jdir)
    assert svc3.replayed == []           # everything resolved exactly once
    svc3.close()


def test_full_fault_matrix_under_worker_pool_always_feasible(
        chaos_workers, chaos_seed):
    """Every fault kind at once, against one burst: solver crashes, a
    hang, a device OOM, a poison error, profile corruption, a worker
    death, and a wedge — whatever the interleaving under the worker
    pool, every ticket resolves feasibly or with a structured
    quarantine, and nothing ends in PlanFailure."""
    plat, inst, prof = _setup(seed=3 + chaos_seed)
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(faults=[
        FaultSpec(kind="crash", stage="heuristic", times=2),
        FaultSpec(kind="hang", stage="heuristic", times=1, seconds=2.0),
        FaultSpec(kind="oom", stage="heuristic", times=1),
        FaultSpec(kind="error", stage="heuristic", times=1),
        FaultSpec(kind="corrupt", times=1),
        FaultSpec(kind="worker-death", times=1),
        FaultSpec(kind="wedge", times=1, seconds=30.0),
    ])
    with PlanService(planner.clone(), injector=inj, workers=chaos_workers,
                     heartbeat_timeout=0.25, retries=1, backoff=0.01,
                     default_budget=2.0) as svc:
        tickets = [svc.submit(PlanRequest(instances=inst, profiles=prof))
                   for _ in range(10)]
        quarantined, served = 0, []
        for t in tickets:
            try:
                served.append(t.result(timeout=120))
            except InvalidRequest:
                quarantined += 1         # the corrupted ticket, structured
        stats = svc.stats()
    assert quarantined == 1 and len(served) == 9
    for res in served:
        _assert_feasible(res, inst, prof)
    assert stats["failed"] == 0


def test_fault_free_multi_worker_bit_identical_to_single_worker():
    """Worker count is invisible: the same burst under 4 workers and
    under 1 worker resolves every ticket bit-identically (and equal to
    direct Planner.plan)."""
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))

    def burst(workers):
        with PlanService(planner.clone(), workers=workers) as svc:
            svc.pause()
            tickets = [svc.submit(PlanRequest(instances=inst,
                                              profiles=prof))
                       for _ in range(6)]
            svc.resume()
            return [t.result(timeout=120) for t in tickets]

    multi, solo = burst(4), burst(1)
    for a, b in zip(multi, solo):
        _assert_same_plan(a, b)
        _assert_same_plan(a, direct)
        assert not a.degraded


# --- fault-free control ----------------------------------------------------

def test_fault_free_mixed_workload_bit_identical_to_direct(chaos_workers):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    reqs = [
        PlanRequest(instances=inst, profiles=prof),
        PlanRequest(instances=inst, profiles=prof, robust=True),
        PlanRequest(instances=inst, profiles=prof, solver="asap"),
        PlanRequest(instances=inst, profiles=prof,
                    variants=("slack", "pressWR-LS")),
    ]
    direct = [planner.plan(r) for r in reqs]
    with PlanService(planner.clone(), workers=chaos_workers) as svc:
        svc.pause()
        tickets = [svc.submit(r) for r in reqs]
        svc.resume()
        served = [t.result(timeout=120) for t in tickets]
        stats = svc.stats()
    for s, d in zip(served, direct):
        _assert_same_plan(s, d)
        assert not s.degraded
    assert stats["degraded"] == 0 and stats["completed"] == 4
