"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + no NaNs; decode agrees with training-mode forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import build_model, param_count
from repro.train.step import init_state, make_train_step

B, S = 2, 32


def _batch(r, key):
    if r.family == "vlm":
        return {"embeds": jax.random.normal(key, (B, S, r.d_model)),
                "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32),
                                              (3, B, S)),
                "labels": jnp.zeros((B, S), jnp.int32)}
    if r.family == "audio":
        return {"enc_embeds": jax.random.normal(key, (B, S, r.d_model)),
                "dec_tokens": jnp.ones((B, S), jnp.int32),
                "labels": jnp.zeros((B, S), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32),
            "labels": jnp.zeros((B, S), jnp.int32)}


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_and_train_step(arch):
    r = reduced(ARCHS[arch])
    m = build_model(r, tp=16)
    key = jax.random.PRNGKey(0)
    state = init_state(m, key)
    assert param_count(state["params"]) > 0
    batch = _batch(r, key)
    # raw forward
    loss0 = m.loss(state["params"], batch, remat=False)
    assert jnp.isfinite(loss0)
    # one full train step (grad + AdamW)
    step = make_train_step(m, microbatches=1)
    state2, metrics = jax.jit(step)(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["gnorm"])
    assert int(state2["opt"]["step"]) == 1
    # params actually moved
    moved = any(
        float(jnp.abs(a - b).max()) > 0
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(state2["params"])))
    assert moved


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_decode_shapes_and_finite(arch):
    r = reduced(ARCHS[arch])
    m = build_model(r, tp=16)
    key = jax.random.PRNGKey(1)
    params = m.init(key)
    if r.family == "audio":
        cache = m.init_cache(B, 16, enc_len=8)
        cache = m.prefill(params, cache,
                          jax.random.normal(key, (B, 8, r.d_model)))
    else:
        cache = m.init_cache(B, 16)
    toks = jnp.ones((B,), jnp.int32)
    for _ in range(4):
        logits, cache = m.decode_step(params, cache, toks)
        toks = logits.argmax(-1).astype(jnp.int32)
    assert logits.shape == (B, r.vocab)
    assert jnp.isfinite(logits).all()
    assert int(cache["len"]) == 4


def test_loss_decreases_when_training():
    """A tiny dense model memorizes a fixed batch in a few steps."""
    r = reduced(ARCHS["smollm-360m"])
    m = build_model(r, tp=16)
    key = jax.random.PRNGKey(2)
    state = init_state(m, key)
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, r.vocab, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, r.vocab, (4, 16)),
                                   jnp.int32)}
    step = jax.jit(make_train_step(m, microbatches=1, peak_lr=1e-2,
                                   warmup=2))
    losses = []
    for _ in range(15):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_equivalence():
    """mb=2 grad accumulation ~ mb=1 on the same global batch."""
    r = reduced(ARCHS["qwen1.5-0.5b"])
    m = build_model(r, tp=16)
    key = jax.random.PRNGKey(3)
    state = init_state(m, key)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, r.vocab, (4, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, r.vocab, (4, 16)),
                                   jnp.int32)}
    s1, m1 = jax.jit(make_train_step(m, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(m, microbatches=2))(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-2
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-3)
