"""Planner API: PlanRequest normalization, equivalence of every request
shape with the legacy entry points per engine (against the independent
sequential oracle), PlanResult accessors, profile windowing, commit-K
regression, engine resolution, and the async rolling-horizon session."""
import numpy as np
import pytest

from repro.api import (
    LocalSearchConfig,
    Planner,
    PlanningSession,
    PlanRequest,
    crop_profile,
    window_profile,
)
from repro.cluster import make_cluster
from repro.core import (
    PORTFOLIO_VARIANTS,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    prepare_graph,
    schedule,
    schedule_cost,
    schedule_portfolio,
    schedule_portfolio_multi,
    schedule_reference,
    validate_schedule,
)
from repro.core.local_search import local_search
from repro.core.portfolio import portfolio_cost_matrix, robust_pick
from repro.workflows import make_workflow

jax_engine = pytest.param("jax", marks=pytest.mark.device)


def _setup(kind="eager", samples=3, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


def _ensemble(plat, T, n, scenario="S3", seed0=100, J=16):
    return [generate_profile(scenario, T, plat, J=J, seed=seed0 + i)
            for i in range(n)]


# --- request shapes: one code path, oracle equivalence ---------------------

def test_plan_1x1x1_matches_sequential_reference():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    for v in ("asap", "slack", "pressWR", "slack-LS", "pressWR-LS"):
        res = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                       variants=v))
        assert res.shape == (1, 1, 1)
        ref = schedule_reference(inst, prof, plat, v)
        got = res.result(variant=v)
        assert (got.start == ref.start).all(), v
        assert got.cost == ref.cost == res.costs[0, 0, 0], v


def test_plan_1x1x17_matches_sequential_reference():
    plat, inst, prof = _setup(kind="atacseq", seed=1, factor=1.0,
                              scenario="S1")
    res = Planner(plat, engine="numpy").plan(
        PlanRequest(instances=inst, profiles=prof))
    assert res.shape == (1, 1, 17)
    for vi, name in enumerate(res.variants):
        ref = schedule_reference(inst, prof, plat, name)
        assert (res.results[0][0][name].start == ref.start).all(), name
        assert res.costs[0, 0, vi] == ref.cost, name


def test_plan_1xPx17_and_IxPx17_match_per_cell_reference():
    plat, inst, prof = _setup(samples=2, seed=5)
    profs = _ensemble(plat, prof.T, 3)
    wf2 = make_workflow("eager", 2, seed=9)
    inst2 = build_instance(wf2, heft_mapping(wf2, plat), plat)
    T2 = deadline_from_asap(inst2, 1.5)
    profs2 = _ensemble(plat, T2, 3, scenario="S1", seed0=200)

    planner = Planner(plat, engine="numpy")
    one = planner.plan(PlanRequest(instances=inst, profiles=profs))
    both = planner.plan(PlanRequest(instances=[inst, inst2],
                                    profiles=[profs, profs2]))
    assert one.shape == (1, 3, 17) and both.shape == (2, 3, 17)
    for i, (ins, ps) in enumerate(((inst, profs), (inst2, profs2))):
        for p, pr in enumerate(ps):
            for name in PORTFOLIO_VARIANTS:
                ref = schedule_reference(ins, pr, plat, name)
                got = both.results[i][p][name]
                assert (got.start == ref.start).all(), (i, p, name)
                assert got.cost == ref.cost, (i, p, name)
    # the 1xP slice of the grid equals the standalone 1xP plan
    assert (both.costs[0] == one.costs[0]).all()


@pytest.mark.device
def test_plan_grid_jax_greedy_matches_numpy_and_ls_is_polished():
    plat, inst, prof = _setup(samples=2, seed=1)
    profs = _ensemble(plat, prof.T, 3)
    wf2 = make_workflow("eager", 2, seed=9)
    inst2 = build_instance(wf2, heft_mapping(wf2, plat), plat)
    profs2 = _ensemble(plat, deadline_from_asap(inst2, 1.5), 3, seed0=200)

    req = PlanRequest(instances=[inst, inst2], profiles=[profs, profs2])
    rj = Planner(plat, engine="jax").plan(req)
    rn = Planner(plat, engine="numpy").plan(req)
    assert rj.engine == "jax" and rn.engine == "numpy"
    for i, (ins, ps) in enumerate(((inst, profs), (inst2, profs2))):
        for p, pr in enumerate(ps):
            for name in PORTFOLIO_VARIANTS:
                got = rj.results[i][p][name]
                validate_schedule(ins, pr, got.start)
                if name.endswith("-LS"):
                    # batched climber may differ; never worse than greedy,
                    # never improvable by one sequential reference round
                    assert got.cost <= rj.results[i][p][name[:-3]].cost
                    polished = local_search(ins, pr, plat, got.start,
                                            max_rounds=1)
                    assert (polished == got.start).all(), (i, p, name)
                else:
                    ref = rn.results[i][p][name]
                    assert (got.start == ref.start).all(), (i, p, name)


@pytest.mark.parametrize("engine", ["numpy", jax_engine])
def test_legacy_entry_points_bit_identical_to_planner(engine):
    """The deprecation shims and a direct Planner.plan agree exactly."""
    plat, inst, prof = _setup(samples=2, seed=4, factor=2.0, scenario="S1")
    profs = _ensemble(plat, prof.T, 3)
    planner = Planner(plat, engine=engine)

    port = schedule_portfolio(inst, prof, plat, engine=engine)
    res = planner.plan(PlanRequest(instances=inst, profiles=prof))
    for name in PORTFOLIO_VARIANTS:
        assert (port[name].start == res.results[0][0][name].start).all()
        assert port[name].cost == res.results[0][0][name].cost

    multi = schedule_portfolio_multi(inst, profs, plat, engine=engine)
    resm = planner.plan(PlanRequest(instances=inst, profiles=profs))
    for p in range(len(profs)):
        for name in PORTFOLIO_VARIANTS:
            assert (multi[p][name].start
                    == resm.results[0][p][name].start).all()
            assert multi[p][name].cost == resm.results[0][p][name].cost

    if engine == "numpy":
        one = schedule(inst, prof, plat, "pressWR-LS")
        assert (one.start
                == res.results[0][0]["pressWR-LS"].start).all()


def test_planner_graph_cache_reuse_and_seed():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    a = planner.plan(PlanRequest(instances=inst, profiles=prof))
    g = planner.prepared(inst, prof.T)
    b = planner.plan(PlanRequest(instances=inst, profiles=prof))
    assert planner.prepared(inst, prof.T) is g           # cache hit
    assert (a.costs == b.costs).all()
    # seeding an external graph is picked up by identity
    g2 = prepare_graph(inst, plat, prof.T)
    planner.seed_graph(g2)
    assert planner.prepared(inst, prof.T) is g2
    # regression: a zero-sized cache still plans (holds the current graph)
    tiny = Planner(plat, engine="numpy", graph_cache=0)
    assert (tiny.plan(PlanRequest(instances=inst, profiles=prof)).costs
            == a.costs).all()


def test_plan_result_accessors_match_portfolio_helpers():
    plat, inst, prof = _setup(samples=2, seed=5)
    profs = _ensemble(plat, prof.T, 3)
    res = Planner(plat, engine="numpy").plan(
        PlanRequest(instances=inst, profiles=profs, robust=True))
    legacy = schedule_portfolio_multi(inst, profs, plat)
    costs, names = portfolio_cost_matrix(legacy)
    got_costs, got_names = res.cost_matrix(0)
    assert got_names == names and (got_costs == costs).all()
    assert res.robust(0) == robust_pick(costs, names)
    # best() = nominal-profile cheapest heuristic
    heur = [n for n in names if n != "asap"]
    want = min(heur, key=lambda n: legacy[0][n].cost)
    assert res.best().cost == legacy[0][want].cost
    # robust request -> pick() executes the robust variant's nominal plan
    assert res.pick().variant == res.robust(0)[0]
    assert str(res.table(0)).count("\n") == len(names)


def test_plan_request_validation():
    plat, inst, prof = _setup()
    with pytest.raises(ValueError):
        PlanRequest(instances=inst, profiles=[]).resolve()
    with pytest.raises(ValueError):
        PlanRequest(instances=inst, profiles=prof,
                    variants=("nope",)).resolve()
    with pytest.raises(ValueError):
        PlanRequest(instances=[inst, inst],
                    profiles=[[prof], [prof, prof]]).resolve()
    with pytest.raises(ValueError):
        Planner(plat, engine="tpu")
    with pytest.raises(TypeError):
        Planner(plat).plan(PlanRequest(instances=inst, profiles=prof),
                           instances=inst)


def test_deadline_scale_crops_long_forecast():
    plat, inst, _ = _setup()
    T = deadline_from_asap(inst, 1.5)
    long = generate_profile("S3", 4 * T, plat, J=64, seed=11)
    res = Planner(plat, engine="numpy").plan(PlanRequest(
        instances=inst, profiles=long, deadline_scale=1.5))
    cropped = crop_profile(long, T)
    assert cropped.T == T
    assert (cropped.unit_budget(plat.idle_total)
            == long.unit_budget(plat.idle_total)[:T]).all()
    ref = schedule_portfolio(inst, cropped, plat)
    for name in PORTFOLIO_VARIANTS:
        assert (res.results[0][0][name].start == ref[name].start).all()
    with pytest.raises(ValueError):
        crop_profile(cropped, T + 1)


def test_window_profile_slices_unit_budget():
    plat, inst, _ = _setup()
    W = deadline_from_asap(inst, 1.5)
    long = generate_profile("S1", 3 * W + 5, plat, J=40, seed=13)
    ub = long.unit_budget(plat.idle_total)
    for t0 in (0, 1, W, 2 * W + 3):
        w = window_profile(long, t0, W)
        assert w.T == W
        assert (w.unit_budget(plat.idle_total) == ub[t0:t0 + W]).all()
    with pytest.raises(ValueError):
        window_profile(long, 3 * W, W + 6)


# --- engine / backend resolution -------------------------------------------

def test_resolve_engine_rules():
    from repro.kernels.backend import resolve_engine

    assert resolve_engine("numpy") == "numpy"
    assert resolve_engine("jax", fanout=1) == "jax"
    assert resolve_engine("auto", fanout=1) == "numpy"
    assert resolve_engine("auto", fanout=2) == "jax"
    assert resolve_engine(None, fanout=8) == "jax"
    with pytest.raises(ValueError):
        resolve_engine("cuda")


def test_resolve_interpret_routes_through_resolve_mode():
    from repro.kernels.backend import resolve_interpret, resolve_mode

    for flag in (None, True, False):
        assert resolve_interpret(flag) == (resolve_mode(flag) != "pallas")


# --- commit width (LocalSearchConfig.commit_k) -----------------------------

@pytest.mark.device
def test_nondefault_commit_k_still_matches_sequential_reference():
    """ROADMAP open item: the device climb's commit width is tunable; any
    K must land on a state the sequential reference cannot improve."""
    from repro.core.greedy import greedy_schedule
    from repro.core.local_search_jax import local_search_portfolio

    plat, inst, prof = _setup(samples=3, seed=4, factor=2.0, scenario="S1")
    combos = (("press", False, True), ("slack", True, False),
              ("press", True, True))
    stack = np.stack([greedy_schedule(inst, prof, plat, s, w, r)
                      for (s, w, r) in combos])
    base = [schedule_cost(inst, prof, st) for st in stack]
    for kk in (1, 4, 96):
        improved = local_search_portfolio(inst, prof, stack, mu=10,
                                          commit_k=kk)
        for i in range(len(combos)):
            validate_schedule(inst, prof, improved[i])
            assert schedule_cost(inst, prof, improved[i]) <= base[i]
            polished = local_search(inst, prof, plat, improved[i],
                                    max_rounds=1)
            assert (polished == improved[i]).all(), (kk, i)


@pytest.mark.device
def test_planner_threads_commit_k_to_device_climb():
    plat, inst, prof = _setup(samples=3, seed=4, factor=2.0, scenario="S1")
    res = Planner(plat, engine="jax",
                  ls=LocalSearchConfig(commit_k=4)).plan(
        PlanRequest(instances=inst, profiles=prof))
    for name in PORTFOLIO_VARIANTS:
        if not name.endswith("-LS"):
            continue
        got = res.results[0][0][name]
        validate_schedule(inst, prof, got.start)
        assert got.cost <= res.results[0][0][name[:-3]].cost
        polished = local_search(inst, prof, plat, got.start, max_rounds=1)
        assert (polished == got.start).all(), name
    with pytest.raises(ValueError):
        LocalSearchConfig(commit_k=0)


# --- async rolling-horizon session -----------------------------------------

def _session_fixture(n_windows=3, samples=3, seed=3):
    plat, inst, _ = _setup(samples=samples, seed=seed, factor=1.6)
    W = deadline_from_asap(inst, 1.6)
    long = generate_profile("S3", n_windows * W, plat, J=48, seed=7)

    def wprofs(k):
        base = window_profile(long, k * W, W)
        return [base] + [generate_profile("S3", W, plat, J=16,
                                          seed=50 + 10 * k + j)
                         for j in range(2)]

    return plat, inst, wprofs


def test_session_three_windows_reproduce_eager_plans():
    plat, inst, wprofs = _session_fixture()
    planner = Planner(plat, engine="numpy")
    with planner.session(inst, wprofs, n_windows=3) as sess:
        got = [sess.plan_for(k) for k in range(3)]
    eager = Planner(plat, engine="numpy")
    for k, res in enumerate(got):
        ref = eager.plan(PlanRequest(instances=inst, profiles=wprofs(k),
                                     robust=True))
        assert (res.costs == ref.costs).all(), k
        for p in range(res.shape[1]):
            for name in res.variants:
                assert (res.results[0][p][name].start
                        == ref.results[0][p][name].start).all(), (k, name)
        assert res.pick(0).variant == ref.robust(0)[0]


def test_session_prefetches_next_window():
    plat, inst, wprofs = _session_fixture()
    with PlanningSession(Planner(plat, engine="numpy"), inst, wprofs,
                         n_windows=3, lookahead=1) as sess:
        sess.plan_for(0)
        assert 1 in sess._plans          # window 1 in flight/done
        assert 2 not in sess._plans
        sess.plan_for(1)
        assert 2 in sess._plans
        with pytest.raises(IndexError):
            sess.plan_for(3)
    with pytest.raises(RuntimeError):
        sess.plan_for(0)                 # closed session fails loudly


def test_session_sequence_source_and_out_of_range():
    plat, inst, wprofs = _session_fixture()
    seq = [wprofs(k) for k in range(2)]
    with PlanningSession(Planner(plat, engine="numpy"), inst, seq) as sess:
        assert sess.n_windows == 2
        a = sess.plan_for(1)
    assert a.shape[1] == 3
    with pytest.raises(ValueError):
        PlanningSession(Planner(plat, engine="numpy"), inst, wprofs)


def test_carbon_gate_replan_session_matches_gate_plans():
    from repro.runtime.carbon_gate import CarbonGate, fleet_platform

    plat = fleet_platform(pods=1, chip_watts_idle=10, chip_watts_work=25,
                          chips_per_pod=4)
    chunk = [[7, 9, 6, 8]]
    horizon = int(3 * sum(chunk[0]))
    profs = [generate_profile("S1", horizon, plat, J=16, seed=2 + i,
                              work_capacity=int(plat.p_work[:1].sum()))
             for i in range(4)]
    gate = CarbonGate(profs[0], plat, variant="auto", profiles=profs[1:],
                      engine="numpy")
    windows = [[profs[k]] for k in range(3)]
    with gate.replan_session(chunk, windows) as sess:
        for k in range(3):
            res = sess.plan_for(k)
            single = CarbonGate(profs[k], plat, variant="auto",
                                engine="numpy")
            plan = single.make_plan(chunk)
            name, _ = res.robust(0)
            assert name == plan.variant
            assert (res.results[0][0][name].start == plan.start).all()
