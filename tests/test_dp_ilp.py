"""Exactness: pseudo-poly DP == poly DP == ILP == brute force (uniproc),
ILP lower-bounds heuristics (multiproc), UCAS/3-partition reduction."""
import itertools

import numpy as np
import pytest

from repro.cluster import make_cluster, make_uniform_platform
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    schedule,
    schedule_cost,
    validate_schedule,
)
from repro.core.carbon import PowerProfile
from repro.core.dag import trivial_mapping
from repro.core.dp_uniproc import dp_poly, dp_pseudo
from repro.core.ilp import solve_ilp
from repro.workflows import independent_tasks, layered_random, make_workflow
from repro.core.heft import heft_mapping


def brute_force_uniproc(inst, profile):
    """Enumerate all feasible start tuples (chain order). Tiny inputs only."""
    chain = [c for c in inst.proc_chains if c][0]
    T = profile.T
    durs = [int(inst.dur[v]) for v in chain]
    best = (None, np.inf)

    def rec(i, t, starts):
        nonlocal best
        if i == len(chain):
            s = np.zeros(inst.num_tasks, dtype=np.int64)
            for v, st in zip(chain, starts):
                s[v] = st
            c = schedule_cost(inst, profile, s)
            if c < best[1]:
                best = (s, c)
            return
        rem = sum(durs[i:])
        for st in range(t, T - rem + 1):
            rec(i + 1, st + durs[i], starts + [st])

    rec(0, 0, [])
    return best


@pytest.mark.parametrize("seed", range(4))
def test_dp_matches_bruteforce(seed):
    rng = np.random.default_rng(seed)
    plat = make_cluster(1, seed=seed)
    wf = layered_random(4, 3, seed=seed)
    inst = build_instance(wf, trivial_mapping(wf, plat, by="single"), plat)
    D = deadline_from_asap(inst, 1.0)
    T = D + 4
    J = 3
    bounds = np.round(np.linspace(0, T, J + 1)).astype(np.int64)
    budget = plat.idle_total + rng.integers(
        0, int(inst.task_work.max()) + 5, size=J)
    prof = PowerProfile(bounds=bounds, budget=budget)
    c_ps, s_ps = dp_pseudo(inst, prof)
    c_pl, s_pl = dp_poly(inst, prof)
    _, c_bf = brute_force_uniproc(inst, prof)
    assert c_ps == c_pl == c_bf
    validate_schedule(inst, prof, s_ps)
    validate_schedule(inst, prof, s_pl)
    assert schedule_cost(inst, prof, s_ps) == c_ps
    assert schedule_cost(inst, prof, s_pl) == c_pl


@pytest.mark.parametrize("seed", range(3))
def test_ilp_equals_dp_uniproc(seed):
    rng = np.random.default_rng(seed + 100)
    plat = make_cluster(1, seed=seed)
    wf = layered_random(5, 3, seed=seed + 7)
    inst = build_instance(wf, trivial_mapping(wf, plat, by="single"), plat)
    T = deadline_from_asap(inst, 1.4)
    J = 4
    bounds = np.round(np.linspace(0, T, J + 1)).astype(np.int64)
    budget = plat.idle_total + rng.integers(
        0, int(inst.task_work.max()) + 10, size=J)
    prof = PowerProfile(bounds=bounds, budget=budget)
    c_dp, _ = dp_pseudo(inst, prof)
    res = solve_ilp(inst, prof, time_limit=120)
    assert abs(res.cost - c_dp) < 1e-6


def test_ilp_lower_bounds_heuristics():
    plat = make_cluster(1, seed=0)
    wf = make_workflow("bacass", 2, seed=7)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.5)
    prof = generate_profile("S1", T, plat, J=8, seed=1)
    res = solve_ilp(inst, prof, time_limit=180)
    validate_schedule(inst, prof, res.start)
    assert abs(schedule_cost(inst, prof, res.start) - res.cost) < 1e-6
    for v in ("slack", "pressWR-LS", "slackR-LS", "asap"):
        assert schedule(inst, prof, plat, v).cost >= res.cost - 1e-6


def test_ucas_three_partition_reduction():
    """Theorem 4.3 construction: zero-cost schedule exists iff 3-partition."""
    # yes-instance: B=12, triplets exist
    xs = [4, 4, 4, 4, 4, 4]          # n=2, B=12 (relaxed B/4<x<B/2 -> x=4)
    n = 2
    B = 12
    plat = make_uniform_platform(len(xs))
    wf = independent_tasks(xs)
    mp = trivial_mapping(wf, plat)
    # remap: task i on processor i
    from repro.core.dag import FixedMapping
    mp = FixedMapping(
        proc=np.arange(len(xs), dtype=np.int64),
        order=tuple((i,) for i in range(len(xs))),
        comm_order={})
    inst = build_instance(wf, mp, plat, dur=np.asarray(xs))
    # intervals: n blocks of length B with budget 1, separated by len-1 zeros
    bounds = [0]
    budget = []
    for k in range(n):
        bounds.append(bounds[-1] + B)
        budget.append(1)
        if k < n - 1:
            bounds.append(bounds[-1] + 1)
            budget.append(0)
    prof = PowerProfile(bounds=np.asarray(bounds, dtype=np.int64),
                        budget=np.asarray(budget, dtype=np.int64))
    res = solve_ilp(inst, prof, time_limit=120)
    assert res.cost < 1e-6           # partition exists -> zero carbon

    # no-instance: total work exceeds green capacity -> positive cost
    xs_bad = [5, 5, 5, 5, 4, 4]      # sum = 28 > n*B = 24
    wf2 = independent_tasks(xs_bad)
    mp2 = FixedMapping(
        proc=np.arange(len(xs_bad), dtype=np.int64),
        order=tuple((i,) for i in range(len(xs_bad))),
        comm_order={})
    T2 = int(np.asarray(bounds)[-1])
    inst2 = build_instance(wf2, mp2, plat, dur=np.asarray(xs_bad))
    res2 = solve_ilp(inst2, prof, time_limit=120)
    assert res2.cost > 0
