"""CI-sized dry-run: the full lowering machinery (specs, meshes, roofline
extraction) on a reduced arch with 8 host devices in a subprocess — proves
the launch stack without the 512-device production sweep."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.models import build_model, input_specs
from repro.roofline.analysis import collective_bytes, roofline_terms
from repro.sharding.ctx import configure
from repro.sharding.specs import batch_specs, cache_specs, tree_param_specs
from repro.train.optimizer import adamw_init
from repro.train.step import make_train_step

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
configure(mesh)
cfg = dataclasses.replace(reduced(ARCHS["qwen2.5-3b"]), num_heads=4,
                          kv_heads=2)
model = build_model(cfg, tp=2)
shape = ShapeConfig("t", "train", 32, 8)

params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
p_specs = tree_param_specs(params, tp=2, dsize=4)
opt = jax.eval_shape(adamw_init, params)
state = {"params": params, "opt": opt}
s_specs = {"params": p_specs, "opt": {"m": p_specs, "v": p_specs,
                                      "step": P()}}
batch = input_specs(cfg, shape)
b_specs = batch_specs(("pod", "data"), cfg, shape)

ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                            is_leaf=lambda x: isinstance(x, P))
step = make_train_step(model, microbatches=2)
lowered = jax.jit(step, in_shardings=(ns(s_specs), ns(b_specs)),
                  out_shardings=(ns(s_specs),
                                 ns({"loss": P(), "gnorm": P(),
                                     "lr": P()}))).lower(state, batch)
compiled = lowered.compile()
ca = compiled.cost_analysis()
if isinstance(ca, (list, tuple)):
    ca = ca[0]
cb = collective_bytes(compiled.as_text())
assert ca["flops"] > 0
assert cb["total"] > 0, "multi-axis mesh must produce collectives"
terms = roofline_terms(ca["flops"] * 8, ca["bytes accessed"] * 8,
                       cb["total"], chips=8)
assert terms["dominant"] in ("compute", "memory", "collective")
print("DRYRUN_MACHINERY_OK", cb["counts"])
"""


def test_dryrun_machinery_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "DRYRUN_MACHINERY_OK" in out.stdout, out.stdout + out.stderr
