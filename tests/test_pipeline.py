"""Pipeline parallelism: GPipe schedule == sequential stage application.

Runs in a subprocess with 8 host devices (the main test process must keep
seeing 1 device)."""
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import functools
import jax, jax.numpy as jnp
import numpy as np
from repro.train.pipeline import make_pipelined_forward

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

def body(params, x):
    return jnp.tanh(x @ params)

key = jax.random.PRNGKey(0)
d = 16
stage_params = jax.random.normal(key, (2, d, d)) * 0.5   # 2 stages
M, mb = 8, 4
x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

pipe = make_pipelined_forward(body, mesh, "pod")
got = pipe(stage_params, x)

# reference: stage 0 then stage 1, per microbatch
want = body(stage_params[1], body(stage_params[0], x))
np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                           rtol=1e-5, atol=1e-5)
print("PIPELINE_OK")
"""


def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "PIPELINE_OK" in out.stdout, out.stdout + out.stderr
