"""Greedy variants, EST/LST, local search, ASAP — behavioural tests."""
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    ALL_VARIANTS,
    asap_schedule,
    build_instance,
    compute_est,
    compute_lst,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    makespan,
    schedule,
    schedule_cost,
    validate_schedule,
)
from repro.core.estlst import est_lst_jnp
from repro.core.greedy import greedy_schedule
from repro.core.local_search import local_search, move_gain, apply_move, timeline_cost
from repro.core.local_search_jax import local_search_batched
from repro.workflows import make_workflow


def _setup(kind="eager", samples=5, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


def test_est_lst_sanity():
    plat, inst, prof = _setup()
    est = compute_est(inst)
    lst = compute_lst(inst, prof.T)
    assert (est <= lst).all()
    # ASAP = EST, makespan = max completion
    asap = asap_schedule(inst)
    assert (asap == est).all()
    assert makespan(inst, asap) <= prof.T
    ej, lj = est_lst_jnp(inst, prof.T)
    assert (np.asarray(ej) == est).all()
    assert (np.asarray(lj) == lst).all()


@pytest.mark.parametrize("variant", [v.name for v in ALL_VARIANTS] + ["asap"])
def test_all_variants_valid(variant):
    plat, inst, prof = _setup()
    r = schedule(inst, prof, plat, variant)
    validate_schedule(inst, prof, r.start)


def test_greedy_deterministic():
    plat, inst, prof = _setup()
    a = greedy_schedule(inst, prof, plat, score="press", weighted=True,
                        refined=True)
    b = greedy_schedule(inst, prof, plat, score="press", weighted=True,
                        refined=True)
    assert (a == b).all()


def test_local_search_monotone_and_valid():
    plat, inst, prof = _setup(factor=2.0)
    g = greedy_schedule(inst, prof, plat, score="slack")
    c0 = schedule_cost(inst, prof, g)
    s = local_search(inst, prof, plat, g, mu=10)
    validate_schedule(inst, prof, s)
    assert schedule_cost(inst, prof, s) <= c0


def test_batched_ls_matches_reference_quality():
    plat, inst, prof = _setup(factor=2.0, scenario="S1")
    g = greedy_schedule(inst, prof, plat, score="press", refined=True)
    c0 = schedule_cost(inst, prof, g)
    ref = schedule_cost(inst, prof, local_search(inst, prof, plat, g))
    bat = schedule_cost(inst, prof, local_search_batched(inst, prof, g))
    assert bat <= c0
    # both hill climbers should land in the same ballpark
    assert bat <= max(1.15 * ref, ref + 50)


def test_move_gain_matches_recompute():
    rng = np.random.default_rng(0)
    T = 200
    rem = rng.integers(-50, 80, T).astype(np.int64)
    for _ in range(50):
        w = int(rng.integers(1, 40))
        dur = int(rng.integers(1, 30))
        s = int(rng.integers(0, T - dur - 25))
        new_s = s + int(rng.integers(-min(20, s), 20))
        new_s = max(0, min(new_s, T - dur))
        base = rem.copy()
        base[s:s + dur] -= w            # place the task
        g = move_gain(base, s, s + dur, new_s, w)
        after = base.copy()
        apply_move(after, s, s + dur, new_s, w)
        assert timeline_cost(base) - timeline_cost(after) == g


def test_greedy_beats_asap_usually():
    wins = total = 0
    for seed in range(4):
        plat, inst, prof = _setup(seed=seed, factor=2.0, scenario="S1")
        base = schedule(inst, prof, plat, "asap").cost
        best = min(schedule(inst, prof, plat, v.name).cost
                   for v in ALL_VARIANTS)
        total += 1
        if best <= base:
            wins += 1
    assert wins == total            # with 2x deadline slack we never lose
