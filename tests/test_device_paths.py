"""Device (jittable) scheduler paths and .dot I/O."""
import os

import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    schedule_cost,
    validate_schedule,
)
from repro.core.greedy import greedy_schedule
from repro.core.greedy_jax import greedy_schedule_jax
from repro.workflows import make_workflow
from repro.workflows.dot_io import load_dot, save_dot


@pytest.mark.device
@pytest.mark.parametrize("seed,kind,scen,sc,wt,rf", [
    (3, "eager", "S3", "press", True, True),
    (1, "atacseq", "S1", "slack", False, False),
    (7, "bacass", "S4", "press", False, True),
    (2, "methylseq", "S2", "slack", True, False),
])
def test_device_greedy_matches_reference_exactly(seed, kind, scen, sc, wt,
                                                 rf):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, 3, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.5)
    prof = generate_profile(scen, T, plat, J=12, seed=seed)
    a = greedy_schedule(inst, prof, plat, score=sc, weighted=wt, refined=rf)
    b = np.asarray(greedy_schedule_jax(inst, prof, plat, score=sc,
                                       weighted=wt, refined=rf),
                   dtype=np.int64)
    assert (a == b).all()
    validate_schedule(inst, prof, b)
    assert schedule_cost(inst, prof, a) == schedule_cost(inst, prof, b)


def test_dot_roundtrip(tmp_path):
    wf = make_workflow("bacass", 3, seed=5)
    p = os.path.join(tmp_path, "wf.dot")
    save_dot(wf, p)
    wf2 = load_dot(p, name=wf.name)
    assert wf2.n == wf.n and wf2.m == wf.m
    np.testing.assert_array_equal(np.sort(wf.edges, axis=0),
                                  np.sort(wf2.edges, axis=0))
    np.testing.assert_array_equal(wf.node_w, wf2.node_w)


def test_dot_pseudo_task_cleanup(tmp_path):
    p = os.path.join(tmp_path, "nf.dot")
    with open(p, "w") as f:
        f.write("""digraph G {
  a [weight=10];
  nf_internal_1;
  b [weight=20];
  a -> nf_internal_1;
  nf_internal_1 -> b;
  a -> b [weight=3];
}
""")
    wf = load_dot(p, pseudo_patterns=(r"nf_internal",), seed=0)
    assert wf.n == 2
    # reconnection keeps a -> b (deduplicated)
    assert wf.m == 1
    wf.validate()
