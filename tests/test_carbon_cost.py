"""Carbon-cost oracle agreement: subinterval sweep == per-unit == jnp."""
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    ALL_VARIANTS,
    asap_schedule,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    schedule,
    schedule_cost,
    schedule_cost_jnp,
)
from repro.core.carbon import cost_timeline, work_timeline
from repro.workflows import make_workflow


@pytest.mark.parametrize("scenario", ["S1", "S2", "S3", "S4"])
@pytest.mark.parametrize("seed", [0, 1])
def test_oracles_agree(scenario, seed):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow("atacseq", 4, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.3)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    start = asap_schedule(inst)
    c1 = schedule_cost(inst, prof, start)
    c2 = cost_timeline(inst, prof, start)
    c3 = float(schedule_cost_jnp(start, inst.dur, inst.task_work,
                                 prof.bounds, prof.effective(inst.idle_total),
                                 T))
    assert c1 == c2
    assert abs(c3 - c1) < 1e-3 * max(c1, 1)


def test_profile_guarantees():
    plat = make_cluster(2, seed=0)
    prof = generate_profile("S3", 500, plat, J=24, seed=1)
    assert prof.T == 500
    assert (prof.budget >= plat.idle_total).all()
    cap = plat.idle_total + 0.8 * plat.p_work.sum()
    assert (prof.budget <= cap + 1).all()


def test_work_timeline_matches_deltas():
    plat = make_cluster(1, seed=0)
    wf = make_workflow("bacass", 2, seed=2)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    start = asap_schedule(inst)
    T = int((start + inst.dur).max()) + 5
    tl = work_timeline(inst, T, start)
    # brute force
    ref = np.zeros(T, dtype=np.int64)
    for v in range(inst.num_tasks):
        ref[start[v]:start[v] + inst.dur[v]] += inst.task_work[v]
    assert (tl == ref).all()


def test_variant_costs_recorded_consistently():
    plat = make_cluster(1, seed=1)
    wf = make_workflow("methylseq", 4, seed=1)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.5)
    prof = generate_profile("S1", T, plat, J=16, seed=0)
    for v in ALL_VARIANTS:
        r = schedule(inst, prof, plat, v.name)
        assert r.cost == schedule_cost(inst, prof, r.start)
