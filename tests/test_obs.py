"""Observability subsystem suite: tracing, metrics, service integration.

Covers the PR's acceptance criteria:

* a forced fallback-chain solve through :class:`PlanService` produces a
  SINGLE connected trace — admission -> queue wait -> per-rung attempts
  -> resolution — with per-rung timings;
* the JSONL export is loadable with ``json.loads`` line by line;
* ``PlanService.stats()`` (the legacy wire shape) is exactly a read of
  the per-service metrics registry;
* the Prometheus text exposition parses and its histogram invariants
  hold;
* journal compaction is lossless under replay, and the replay cap
  defers (never drops) excess entries;
* the no-leaked-spans fixture guards every traced test.
"""
import json
import re
import threading

import numpy as np
import pytest

from repro import obs
from repro.api import Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    validate_schedule,
)
from repro.core.cancel import Cancelled, CancelToken
from repro.runtime.fault import FaultSpec, ServiceFaultInjector
from repro.serve import PlanService, TicketJournal, decode_ticket
from repro.serve.service import _STAT_EVENTS
from repro.workflows import make_workflow


def _setup(kind="eager", samples=3, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


@pytest.fixture
def traced():
    """A fresh process tracer; fails the test if any span leaks open."""
    prev = obs.set_tracer(obs.Tracer())
    tr = obs.tracer()
    try:
        yield tr
        leaked = tr.open_spans()
        assert not leaked, f"leaked open spans: {leaked}"
    finally:
        obs.set_tracer(prev)


# --- tracer primitives -----------------------------------------------------

def test_span_nesting_and_idempotent_end(traced):
    with traced.span("root") as root:
        with traced.span("child", k=1) as child:
            assert child.parent_id == root.span_id
            assert child.trace_id == root.trace_id
    child.end()                                # second end: no-op
    assert len(traced.finished()) == 2
    tree = traced.tree(root.trace_id)
    assert [n["name"] for n in tree] == ["root"]
    assert [n["name"] for n in tree[0]["children"]] == ["child"]


def test_span_records_exception_as_error_attr(traced):
    with pytest.raises(ValueError):
        with traced.span("boom"):
            raise ValueError("x")
    (sp,) = traced.finished()
    assert sp.attrs["error"] == "ValueError"


def test_attach_reanchors_worker_thread(traced):
    with traced.span("parent") as parent:
        seen = {}

        def worker():
            with traced.attach(parent):
                with traced.span("inner") as sp:
                    seen["parent_id"] = sp.parent_id

        t = threading.Thread(target=worker)
        t.start()
        t.join()
    assert seen["parent_id"] == parent.span_id


def test_disabled_tracing_returns_null_span():
    prev = obs.set_tracer(None)
    try:
        sp = obs.span("anything", k=1)
        assert sp is obs.NULL_SPAN and not sp
        with sp:
            sp.set(x=2).end()
        assert obs.current_span() is None
    finally:
        obs.set_tracer(prev)


def test_jsonl_export_loads_line_by_line(traced, tmp_path):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    planner.plan(PlanRequest(instances=inst, profiles=prof))
    path = tmp_path / "trace.jsonl"
    n = traced.dump_jsonl(str(path))
    lines = path.read_text().strip().split("\n")
    assert len(lines) == n > 0
    events = [json.loads(line) for line in lines]   # every line parses
    for ev in events:
        assert ev["ph"] == "X"
        assert ev["ts"] >= 0 and ev["dur"] >= 0
        assert "span_id" in ev["args"]
    assert any(ev["name"] == "plan" for ev in events)


# --- the acceptance trace: forced fallback chain through the service -------

def test_forced_fallback_chain_is_one_connected_trace(traced):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage="heuristic", times=10)])
    with PlanService(planner.clone(), injector=inj, retries=1,
                     backoff=0.01) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
    assert res.degraded and res.fallback_stage == "asap"
    assert res.attempts == ("heuristic:crash", "heuristic:crash",
                            "asap:ok")

    spans = traced.finished()
    roots = [s for s in spans if s.name == "request"]
    assert len(roots) == 1
    root = roots[0]
    ours = [s for s in spans if s.trace_id == root.trace_id]
    # single CONNECTED trace: every span of this request shares the
    # root's trace id and reaches the root through parent links
    by_id = {s.span_id: s for s in ours}
    for s in ours:
        node = s
        while node.parent_id:
            node = by_id[node.parent_id]
        assert node is root

    names = [s.name for s in ours]
    assert "admission" in names and "queue_wait" in names
    assert "resolution" in names
    rungs = sorted((s for s in ours if s.name.startswith("rung:")),
                   key=lambda s: s.t0)
    assert [(s.attrs["stage"], s.attrs["outcome"]) for s in rungs] == \
        [("heuristic", "crash"), ("heuristic", "crash"), ("asap", "ok")]
    for s in rungs:                      # per-rung timings
        assert s.t1 is not None and s.duration >= 0
        assert s.parent_id == root.span_id
    # the winning rung ran a solve that reached the planner layer
    ok_rung = rungs[-1]
    solves = [s for s in ours if s.name == "solve"
              and s.parent_id == ok_rung.span_id]
    assert len(solves) == 1
    assert any(s.name == "plan" and s.parent_id == solves[0].span_id
               for s in ours)
    assert root.attrs["outcome"] == "completed"


# --- metrics: stats() is a registry read -----------------------------------

def test_stats_equals_registry_read(traced):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage="heuristic", times=1)])
    with PlanService(planner.clone(), injector=inj, retries=2,
                     backoff=0.01) as svc:
        for _ in range(3):
            svc.plan(PlanRequest(instances=inst, profiles=prof))
        with pytest.raises(Exception):
            svc.plan(PlanRequest(instances=inst, profiles=[]))
        stats = svc.stats()
        reg = svc.registry
        ev = reg.get("plan_service_events_total")
        for key in _STAT_EVENTS:
            assert stats[key] == int(ev.value(event=key)), key
        assert stats["submitted"] == 3 and stats["completed"] == 3
        assert stats["retries"] == 1 and stats["rejected_invalid"] == 1
        stage_counter = reg.get("plan_service_stage_served_total")
        assert stats["stages"] == {
            k[0]: int(v) for k, v in stage_counter.values().items()}
        lat = reg.get("plan_service_plan_latency_seconds")
        assert stats["latency"]["n"] == len(lat.samples()) == 3
        assert stats["latency"]["p50_ms"] == pytest.approx(
            float(np.percentile(np.asarray(lat.samples()), 50) * 1e3))
        assert stats["inflight_solves"] == 0
        assert stats["max_queue_depth"] == int(
            reg.get("plan_service_max_queue_depth").value())


def test_two_services_never_cross_count():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    with PlanService(planner.clone()) as a, \
            PlanService(planner.clone()) as b:
        a.plan(PlanRequest(instances=inst, profiles=prof))
        assert a.stats()["submitted"] == 1
        assert b.stats()["submitted"] == 0
        assert a.registry is not b.registry


# --- Prometheus exposition -------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z0-9_]+="[^"]*"'
    r'(,[a-zA-Z0-9_]+="[^"]*")*\})? [^ ]+$')


def test_prometheus_exposition_parses(traced):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    with PlanService(planner.clone()) as svc:
        svc.plan(PlanRequest(instances=inst, profiles=prof))
        text = svc.metrics_text()
    typed = set()
    for line in text.strip().split("\n"):
        if line.startswith("# TYPE "):
            name, kind = line.split()[2:4]
            assert kind in ("counter", "gauge", "histogram")
            typed.add(name)
        elif not line.startswith("#"):
            assert _SAMPLE_RE.match(line), line
            metric = line.split("{")[0].split(" ")[0]
            base = re.sub(r"_(bucket|sum|count)$", "", metric)
            assert metric in typed or base in typed, line
    assert "plan_service_events_total" in typed
    assert "plan_service_plan_latency_seconds" in typed
    # histogram invariants: buckets cumulative, +Inf == _count
    hist = [line for line in text.split("\n")
            if line.startswith("plan_service_plan_latency_seconds")]
    buckets = [float(line.split()[-1]) for line in hist
               if "_bucket" in line]
    assert buckets == sorted(buckets)
    count = next(float(line.split()[-1]) for line in hist
                 if line.startswith("plan_service_plan_latency_seconds_count"))
    inf = next(float(line.split()[-1]) for line in hist
               if 'le="+Inf"' in line)
    assert inf == count == 1


def test_metric_type_and_label_safety():
    reg = obs.MetricsRegistry()
    c = reg.counter("x_total", labels=("a",))
    with pytest.raises(ValueError):
        c.inc(-1, a="v")
    with pytest.raises(ValueError):
        c.inc(a="v", b="w")
    with pytest.raises(TypeError):
        reg.gauge("x_total")
    with pytest.raises(ValueError):
        reg.counter("x_total", labels=("other",))
    g = reg.gauge("depth")
    g.set_max(5)
    g.set_max(3)
    assert g.value() == 5


def test_cancel_latency_histogram_observes():
    hist = obs.registry().get("cancel_observe_latency_seconds")
    before = hist.count()
    token = CancelToken()
    token.cancel("test")
    with pytest.raises(Cancelled):
        token.check()
    with pytest.raises(Cancelled):
        token.check()                   # latency recorded exactly once
    assert hist.count() == before + 1


# --- journal compaction + replay cap ---------------------------------------

def _fill_killed_journal(tmp_path, n, samples=2):
    plat, inst, prof = _setup(samples=samples)
    planner = Planner(plat, engine="numpy")
    jd = str(tmp_path / "journal")
    svc = PlanService(planner.clone(), journal_dir=jd)
    svc.pause()
    for k in range(n):
        svc.submit(PlanRequest(instances=inst, profiles=prof))
    svc.kill()
    return planner, inst, prof, jd


def test_journal_compaction_lossless_replay(tmp_path):
    planner, inst, prof, jd = _fill_killed_journal(tmp_path, 3)
    journal = TicketJournal(jd)
    assert len(journal) == 3
    journal.resolve(1)                         # punch a hole: seqs 0, 2
    before = {seq: decode_ticket(state) for seq, state in journal.pending()}
    mapping = journal.compact()
    assert mapping == {0: 0, 2: 1}
    after = {seq: decode_ticket(state) for seq, state in journal.pending()}
    assert sorted(after) == [0, 1]
    # lossless: entry content survives renumbering bit-for-bit
    for old, new in mapping.items():
        old_inst = before[old][0]
        new_inst = after[new][0]
        assert len(old_inst) == len(new_inst)
        for a, b in zip(old_inst, new_inst):
            assert (a.dur == b.dur).all() and (a.proc == b.proc).all()
    # a service on the compacted journal replays and serves both
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    with PlanService(planner.clone(), journal_dir=jd) as svc:
        assert len(svc.replayed) == 2
        for t in svc.replayed:
            res = t.result(timeout=60)
            assert (res.costs == direct.costs).all()
    assert len(TicketJournal(jd)) == 0         # clean close, all resolved


def test_journal_replay_cap_defers_excess(tmp_path):
    planner, inst, prof, jd = _fill_killed_journal(tmp_path, 4)
    with PlanService(planner.clone(), journal_dir=jd,
                     journal_replay_cap=2) as svc:
        assert len(svc.replayed) == 2
        assert [t.journal_seq for t in svc.replayed] == [0, 1]  # oldest
        assert svc.stats()["replay_deferred"] == 2
        for t in svc.replayed:
            t.result(timeout=60)
        # a new admission must not collide with the deferred entries
        t = svc.submit(PlanRequest(instances=inst, profiles=prof))
        assert t.journal_seq >= 4
        t.result(timeout=60)
    # deferred entries survived on disk; an uncapped restart drains them
    assert len(TicketJournal(jd)) == 2
    with PlanService(planner.clone(), journal_dir=jd) as svc2:
        assert len(svc2.replayed) == 2
        assert svc2.stats()["replay_deferred"] == 0
        for t in svc2.replayed:
            res = t.result(timeout=60)
            validate_schedule(inst, prof, res.result().start)
    assert len(TicketJournal(jd)) == 0


# --- planner/core layer metrics --------------------------------------------

def test_planner_metrics_count_plans_and_cache_hits():
    plat, inst, prof = _setup()
    reg = obs.registry()
    plans = reg.counter("planner_plans_total",
                        labels=("solver", "engine"))
    cache = reg.counter("planner_graph_cache_total", labels=("outcome",))
    p0 = plans.value(solver="heuristic", engine="numpy")
    h0, m0 = cache.value(outcome="hit"), cache.value(outcome="miss")
    planner = Planner(plat, engine="numpy")
    planner.plan(PlanRequest(instances=inst, profiles=prof))
    planner.plan(PlanRequest(instances=inst, profiles=prof))
    assert plans.value(solver="heuristic", engine="numpy") == p0 + 2
    assert cache.value(outcome="miss") == m0 + 1      # first prepare
    assert cache.value(outcome="hit") >= h0 + 1       # second reuses


def test_jax_hooks_snapshot_shape():
    from repro.obs import jax_hooks
    reg = obs.MetricsRegistry()
    jax_hooks.install(reg)
    jax_hooks.install(reg)                     # idempotent
    jax_hooks.update_device_gauges(reg)
    snap = jax_hooks.snapshot(reg)
    assert set(snap) >= {"compile_events", "compile_seconds",
                         "jit_cache_entries", "live_arrays"}
    assert isinstance(snap["jit_cache_entries"], dict)
