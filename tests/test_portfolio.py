"""Portfolio engine: equivalence with the per-variant loop, segment greedy,
endpoint-rule regression, batched local search, batched gain kernel."""
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    PORTFOLIO_VARIANTS,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    prepare_instance,
    schedule,
    schedule_cost,
    schedule_portfolio,
    validate_schedule,
)
from repro.core.greedy import (
    greedy_core_segments,
    greedy_schedule,
    greedy_schedule_segments,
    segment_state,
)
from repro.core.local_search_jax import local_search_portfolio
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask
from repro.workflows import make_workflow


def _setup(kind="eager", samples=3, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


@pytest.mark.parametrize("seed,kind,scenario,factor", [
    (3, "eager", "S3", 1.5),
    (1, "atacseq", "S1", 1.0),
    (7, "bacass", "S4", 2.0),
    (5, "methylseq", "S2", 1.5),
])
def test_portfolio_bit_identical_to_variant_loop(seed, kind, scenario,
                                                 factor):
    from repro.core import schedule_reference

    plat, inst, prof = _setup(kind=kind, seed=seed, factor=factor,
                              scenario=scenario)
    port = schedule_portfolio(inst, prof, plat)
    assert set(port) == set(PORTFOLIO_VARIANTS)
    for name in PORTFOLIO_VARIANTS:
        # schedule_reference is the independent sequential oracle
        # (schedule() itself is a Planner shim since the API redesign)
        ref = schedule_reference(inst, prof, plat, name)
        assert (port[name].start == ref.start).all(), name
        assert port[name].cost == ref.cost, name
        shim = schedule(inst, prof, plat, name)
        assert (shim.start == ref.start).all(), name


def test_portfolio_reuses_prepared_instance():
    plat, inst, prof = _setup()
    prep = prepare_instance(inst, prof, plat)
    a = schedule_portfolio(inst, prof, plat, prep=prep)
    b = schedule_portfolio(inst, prof, plat, prep=prep)
    for name in PORTFOLIO_VARIANTS:
        assert (a[name].start == b[name].start).all()
    # prep is never mutated: est0 still equals a fresh EST computation
    assert (prep.est0 == prepare_instance(inst, prof, plat).est0).all()


@pytest.mark.parametrize("sc,wt,rf", [
    ("press", True, True), ("slack", False, False), ("press", False, True),
    ("slack", True, False),
])
def test_segment_greedy_matches_per_unit(sc, wt, rf):
    for seed in (0, 4):
        plat, inst, prof = _setup(seed=seed, factor=1.0, scenario="S1")
        a = greedy_schedule(inst, prof, plat, sc, wt, rf)
        b = greedy_schedule_segments(inst, prof, plat, sc, wt, rf)
        assert (a == b).all()


def _per_unit_reference(inst, profile, est, lst, order):
    """greedy_schedule's loop body with injected EST/LST/order (mirrors
    repro.core.greedy so overrun states can be exercised directly)."""
    from repro.core.estlst import lower_lst_from, raise_est_from

    T = profile.T
    est, lst = est.copy(), lst.copy()
    mask = candidate_mask(inst, profile, refined=False)
    rem = profile.unit_budget(inst.idle_total).astype(np.int64).copy()
    start = np.zeros(inst.num_tasks, dtype=np.int64)
    scheduled = np.zeros(inst.num_tasks, dtype=bool)
    for v in order:
        a, b = int(est[v]), int(lst[v])
        cand = np.flatnonzero(mask[a:b + 1])
        s = a if len(cand) == 0 else int(cand[np.argmax(rem[cand + a])] + a)
        e = s + int(inst.dur[v])
        start[v] = s
        scheduled[v] = True
        rem[s:e] -= int(inst.task_work[v])
        mask[s] = True
        if e <= T:                       # the endpoint rule under test
            mask[e] = True
        raise_est_from(inst, est, int(v), s, scheduled)
        lower_lst_from(inst, lst, int(v), s, scheduled)
    return start, mask


def test_endpoint_rule_on_overrunning_task():
    """Regression (jax/segment endpoint semantics): a task whose end
    overruns the horizon must NOT create a candidate point at T — both
    interval representations must keep identical candidate sets and starts
    even when a (pathologically placed) task clips at the deadline."""
    plat, inst, prof = _setup(samples=2, seed=2, factor=1.5)
    T = prof.T
    from repro.core.estlst import compute_est, compute_lst
    est = compute_est(inst)
    lst = compute_lst(inst, T)
    order = task_order(inst, est, lst, "press", False, plat)
    # force a sink task, placed LAST, to overrun the horizon: pin its window
    # to T - 1 so e = s + dur > T (no successors -> no cascading placements)
    sinks = np.flatnonzero(np.diff(inst.succ_ptr) == 0)
    v0 = int(sinks[np.argmax(inst.dur[sinks])])
    assert inst.dur[v0] >= 2, "need a clipping sink task"
    order = np.concatenate([order[order != v0], [v0]])
    est = est.copy()
    lst = lst.copy()
    est[v0] = lst[v0] = T - 1            # e = T - 1 + dur > T
    ref_start, ref_mask = _per_unit_reference(inst, prof, est, lst, order)
    pts0, vals0 = segment_state(inst, prof, refined=False)
    seg_start = greedy_core_segments(inst, T, est, lst, order, pts0, vals0)
    assert (ref_start == seg_start).all()
    assert ref_start[v0] + int(inst.dur[v0]) > T   # it really clipped
    # T is a profile bound, not a task endpoint: the overrun must not have
    # added any new candidate point at or beyond T
    assert ref_mask[T]                   # from the profile bounds
    assert not (ref_start[v0] + inst.dur[v0] <= T)


@pytest.mark.device
def test_device_greedy_matches_numpy_at_tight_deadline():
    """Regression companion: the jax scan uses the numpy endpoint rule."""
    from repro.core.greedy_jax import greedy_schedule_jax

    for seed, kind in ((0, "eager"), (6, "bacass")):
        plat, inst, prof = _setup(kind=kind, seed=seed, factor=1.0,
                                  scenario="S2")
        a = greedy_schedule(inst, prof, plat, "press", True, False)
        b = np.asarray(greedy_schedule_jax(inst, prof, plat, "press", True,
                                           False))
        assert (a == b.astype(np.int64)).all()


@pytest.mark.device
def test_jax_engine_greedy_rows_match_numpy():
    plat, inst, prof = _setup(samples=2, seed=1)
    pn = schedule_portfolio(inst, prof, plat, engine="numpy")
    pj = schedule_portfolio(inst, prof, plat, engine="jax")
    for name in PORTFOLIO_VARIANTS:
        if name.endswith("-LS"):
            continue                      # batched climber differs by design
        assert (pn[name].start == pj[name].start).all(), name


@pytest.mark.device
def test_instance_batched_fanout_matches_reference():
    """Two same-shape instances (same workflow/platform, different profile
    budgets) ride one doubly-vmapped call; every (instance, combo) row must
    equal the numpy reference greedy."""
    from repro.core.portfolio import _COMBOS, portfolio_starts_batch

    plat = make_cluster(1, seed=3)
    wf = make_workflow("eager", 2, seed=3)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.5)
    profs = [generate_profile(s, T, plat, J=12, seed=3) for s in ("S1", "S4")]
    preps = [prepare_instance(inst, p, plat) for p in profs]
    combos = _COMBOS[:3]
    starts = portfolio_starts_batch(preps, combos=combos)
    assert len(starts) == 2
    for p, st in zip(preps, starts):
        assert st.shape == (len(combos), inst.num_tasks)
        for i, (sc, wt, rf) in enumerate(combos):
            ref = greedy_schedule(inst, p.profile, plat, sc, wt, rf)
            assert (st[i] == ref).all(), (sc, wt, rf)


@pytest.mark.device
def test_jax_engine_asap_only_does_not_fan_out():
    """Regression: an empty greedy combo set (asap-only request) must not
    crash the jax engine's fan-out stacking."""
    plat, inst, prof = _setup(samples=2, seed=0)
    res = schedule_portfolio(inst, prof, plat, variants=("asap",),
                             engine="jax")
    assert set(res) == {"asap"}
    ref = schedule(inst, prof, plat, "asap")
    assert (res["asap"].start == ref.start).all()


@pytest.mark.device
def test_batched_portfolio_local_search_monotone_and_valid():
    plat, inst, prof = _setup(samples=3, seed=4, factor=2.0, scenario="S1")
    combos = (("press", False, True), ("slack", True, False),
              ("press", True, True))
    stack = np.stack([greedy_schedule(inst, prof, plat, s, w, r)
                      for (s, w, r) in combos])
    base = [schedule_cost(inst, prof, st) for st in stack]
    improved = local_search_portfolio(inst, prof, stack, mu=10)
    for i in range(len(combos)):
        validate_schedule(inst, prof, improved[i])
        assert schedule_cost(inst, prof, improved[i]) <= base[i]


@pytest.mark.device
def test_gain_scan_batched_matches_rows():
    from repro.kernels.ops import ls_gains, ls_gains_batched

    rng = np.random.default_rng(0)
    B, N, T, mu = 3, 40, 160, 6
    rem = rng.integers(-30, 60, (B, T)).astype(np.float32)
    dur = rng.integers(1, 12, N).astype(np.float32)
    work = rng.integers(0, 25, N).astype(np.float32)
    start = np.stack([rng.integers(0, T - 15, N) for _ in range(B)]) \
        .astype(np.float32)
    lo = np.maximum(start - rng.integers(0, mu + 3, (B, N)), 0) \
        .astype(np.float32)
    hi = np.minimum(start + rng.integers(0, mu + 3, (B, N)), T - dur) \
        .astype(np.float32)
    got = np.asarray(ls_gains_batched(rem, start, dur, work, lo, hi, mu=mu))
    for b in range(B):
        want = np.asarray(ls_gains(rem[b], start[b], dur, work, lo[b],
                                   hi[b], mu=mu))
        np.testing.assert_allclose(got[b], want, rtol=0, atol=0)


def test_interpret_autodetect_resolves_cpu():
    import jax

    from repro.kernels.backend import resolve_interpret

    assert resolve_interpret(None) == (jax.default_backend() == "cpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
