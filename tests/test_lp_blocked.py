"""Blocked longest-path formulation: generator-driven differential suite.

The blocked form (``greedy_jax.BlockedLP``) must be bit-identical to the
dense ``longest_path_matrix`` — in the matrix values themselves (every
block width, every generator family) AND downstream (the jax engine's
greedy fan-out and device local search produce the same schedules whether
the lp rides resident on device or streams in chunks). The big-instance
regression (``pytest.mark.big``, ``make test-big``) proves the point of
the formulation: an instance past the dense ``LP_MAX_BYTES`` envelope
schedules on ``engine="jax"`` without the O(N^2) matrix ever existing,
matching the sequential ``schedule_reference`` oracle.
"""
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    BlockedLP,
    LP_MAX_BYTES,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    longest_path_matrix,
    lp_block_bytes,
    lp_matrix_bytes,
    prepare_graph,
    schedule_portfolio_grid,
    schedule_reference,
    trivial_mapping,
)
from repro.core.greedy_jax import NEG_PATH, pad_dims
from repro.workflows import make_workflow, wfgen_scale
from repro.workflows.generators import independent_tasks, layered_random

# one representative per workflows.generators family (the paper's suite):
# the four nf-core pipeline motifs, a WFGen scale-up, a layered random
# DAG, and the edge-free UCAS instances
FAMILIES = {
    "atacseq": lambda seed: make_workflow("atacseq", 3, seed=seed),
    "bacass": lambda seed: make_workflow("bacass", 4, seed=seed),
    "eager": lambda seed: make_workflow("eager", 3, seed=seed),
    "methylseq": lambda seed: make_workflow("methylseq", 4, seed=seed),
    "wfgen_scale": lambda seed: wfgen_scale("eager", 120, seed=seed),
    "layered_random": lambda seed: layered_random(48, 6, seed=seed),
    "independent_tasks": lambda seed: independent_tasks(
        np.random.default_rng(seed).integers(1, 9, size=60),
        name=f"independent-{seed}"),
}


def _instance(family, seed, mapping="heft"):
    plat = make_cluster(1, seed=seed)
    wf = FAMILIES[family](seed)
    mp = heft_mapping(wf, plat) if mapping == "heft" \
        else trivial_mapping(wf, plat)
    return build_instance(wf, mp, plat), plat


# --- matrix bit-identity ----------------------------------------------------

@pytest.mark.parametrize("seed", [0, 3, 11])
@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_blocked_matrix_bit_identical(family, seed):
    inst, _ = _instance(family, seed)
    N = inst.num_tasks
    lp = longest_path_matrix(inst)
    blp = BlockedLP(inst)
    for block in (1, 7, 64, N):
        assert (blp.materialize(block) == lp).all(), (family, seed, block)
    # the backward column sweeps (what the chunked scan actually consumes
    # for the lst updates) must canonicalize to the same entries
    idx = np.arange(0, N, max(N // 9, 1))
    assert (blp.cols(idx) == lp[:, idx].T).all()
    assert (blp.rows(idx) == lp[idx]).all()
    # canonical sentinel: every no-path entry is exactly NEG_PATH
    assert np.isin(lp[lp < 0], (NEG_PATH,)).all()


def test_blocked_property_random_dags():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(6, 40), layers=st.integers(2, 6),
           p=st.floats(0.05, 0.5), seed=st.integers(0, 10_000))
    def prop(n, layers, p, seed):
        wf = layered_random(n, layers, p_edge=p, seed=seed)
        plat = make_cluster(1, seed=seed % 5)
        inst = build_instance(wf, trivial_mapping(wf, plat), plat)
        lp = longest_path_matrix(inst)
        blp = BlockedLP(inst)
        N = inst.num_tasks
        for block in (1, 7, 64, N):
            assert (blp.materialize(block) == lp).all(), block

    prop()


# --- downstream schedules (greedy fan-out + device local search) ------------

def _n_orders():
    """Unique greedy configurations the full portfolio fans out — what
    the grid passes to ``BlockedLP.chunk_width``."""
    from repro.core.portfolio import _COMBOS
    return len(_COMBOS)


def _force_blocked_budget(inst, T, n_orders=None):
    """A budget that forces the blocked form but still admits >= 1 step."""
    n_orders = _n_orders() if n_orders is None else n_orders
    Np, _ = pad_dims(inst.num_tasks, T)
    budget = lp_block_bytes(2, n_orders, Np)
    if budget >= lp_matrix_bytes(inst.num_tasks):
        budget = lp_block_bytes(1, n_orders, Np)
    assert budget < lp_matrix_bytes(inst.num_tasks)
    return budget


@pytest.mark.device
@pytest.mark.parametrize("family,seed,factor,scenario", [
    ("atacseq", 3, 1.5, "S3"),
    ("wfgen_scale", 1, 2.0, "S1"),
    ("layered_random", 7, 1.5, "S4"),
    ("independent_tasks", 5, 2.0, "S2"),
])
def test_blocked_schedules_bit_identical(family, seed, factor, scenario):
    """Full 17-variant jax grid, dense lp vs streamed BlockedLP: greedy
    starts, -LS climbs and costs must match bit for bit."""
    inst, plat = _instance(family, seed)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    dense = schedule_portfolio_grid([inst], [[prof]], plat, engine="jax")
    graph = prepare_graph(inst, plat, T,
                          lp_budget_bytes=_force_blocked_budget(inst, T))
    assert graph.lp_is_blocked
    blocked = schedule_portfolio_grid([inst], [[prof]], plat, engine="jax",
                                      graphs=[graph])
    for name, ref in dense[0][0].items():
        got = blocked[0][0][name]
        assert (got.start == ref.start).all(), name
        assert got.cost == ref.cost, name
        if not name.endswith("-LS") and name != "asap":
            oracle = schedule_reference(inst, prof, plat, name)
            assert got.cost == oracle.cost, name


@pytest.mark.device
def test_blocked_multi_profile_grid():
    """Profile-ensemble fan-out through the blocked path: every cell
    bit-identical to the dense engine's."""
    inst, plat = _instance("eager", 3)
    T = deadline_from_asap(inst, 1.5)
    profs = [generate_profile("S3", T, plat, J=16, seed=s) for s in (3, 9)]
    dense = schedule_portfolio_grid([inst], [profs], plat, engine="jax")
    blocked = schedule_portfolio_grid(
        [inst], [profs], plat, engine="jax",
        lp_budget_bytes=_force_blocked_budget(inst, T))
    for p in range(len(profs)):
        for name, ref in dense[0][p].items():
            assert (blocked[0][p][name].start == ref.start).all(), name
            assert blocked[0][p][name].cost == ref.cost, name


@pytest.mark.device
def test_mixed_dense_blocked_bucket():
    """One grid bucket mixing a dense-lp and a blocked-lp instance: the
    dense rows still ride the batched launch, the blocked row streams,
    and both match the all-dense grid."""
    inst_a, plat = _instance("bacass", 2)
    inst_b, _ = _instance("bacass", 6)
    T = max(deadline_from_asap(inst_a, 1.5), deadline_from_asap(inst_b, 1.5))
    profs = [[generate_profile("S1", T, plat, J=16, seed=1)]] * 2
    dense = schedule_portfolio_grid([inst_a, inst_b], profs, plat,
                                    engine="jax")
    graphs = [None,
              prepare_graph(inst_b, plat, T,
                            lp_budget_bytes=_force_blocked_budget(inst_b, T))]
    mixed = schedule_portfolio_grid([inst_a, inst_b], profs, plat,
                                    engine="jax", graphs=graphs)
    for i in range(2):
        for name, ref in dense[i][0].items():
            assert (mixed[i][0][name].start == ref.start).all(), (i, name)


# --- failure-mode boundary --------------------------------------------------

def test_dense_guard_names_shipped_api():
    inst, _ = _instance("bacass", 0)
    with pytest.raises(MemoryError, match="BlockedLP"):
        longest_path_matrix(inst, max_bytes=8)
    with pytest.raises(MemoryError, match="lp_budget_bytes"):
        longest_path_matrix(inst, max_bytes=8)


def test_blocked_floor_raises_with_byte_estimate():
    inst, _ = _instance("bacass", 0)
    blp = BlockedLP(inst, budget_bytes=64)
    V = _n_orders()
    floor = lp_block_bytes(1, V, 128)
    with pytest.raises(MemoryError, match=rf"{floor} bytes"):
        blp.chunk_width(V, 128)
    # one-step chunks are the floor: exactly the floor budget admits B=1
    assert BlockedLP(inst, budget_bytes=floor).chunk_width(V, 128) == 1


@pytest.mark.device
def test_grid_over_blocked_floor_raises():
    inst, plat = _instance("bacass", 0)
    T = deadline_from_asap(inst, 1.5)
    prof = generate_profile("S1", T, plat, J=16, seed=0)
    with pytest.raises(MemoryError, match="lp budget"):
        schedule_portfolio_grid([inst], [[prof]], plat, engine="jax",
                                lp_budget_bytes=64)


def test_resolve_lp_form_envelope():
    from repro.kernels.backend import resolve_lp_form

    assert resolve_lp_form(5000) == "dense"          # under LP_MAX_BYTES
    assert resolve_lp_form(6000) == "blocked"        # over it
    assert resolve_lp_form(100, lp_matrix_bytes(100)) == "dense"
    assert resolve_lp_form(100, lp_matrix_bytes(100) - 1) == "blocked"


def test_chunk_width_divides_padded_n():
    inst, _ = _instance("bacass", 0)
    for budget_steps, Np in ((3, 384), (9, 384), (64, 1024), (10_000, 640)):
        blp = BlockedLP(inst, budget_bytes=lp_block_bytes(budget_steps, 1,
                                                          Np))
        B = blp.chunk_width(1, Np)
        assert Np % B == 0 and B <= max(budget_steps, Np)


# --- big-instance regression (make test-big) --------------------------------

@pytest.mark.big
@pytest.mark.device
def test_big_instance_schedules_without_dense_matrix(monkeypatch):
    """An instance past LP_MAX_BYTES schedules on engine="jax" under a
    small lp_budget_bytes, bit-identical in cost (and starts) to the
    sequential schedule_reference oracle — with the dense-matrix
    constructor tripwired to prove it is never touched."""
    import repro.core.greedy_jax as gj

    plat = make_cluster(1, seed=0)
    wf = wfgen_scale("bacass", 3200, seed=0)
    rng = np.random.default_rng(0)
    inst = build_instance(wf, trivial_mapping(wf, plat), plat,
                          dur=rng.integers(1, 4, size=wf.n))
    assert lp_matrix_bytes(inst.num_tasks) > LP_MAX_BYTES
    T = deadline_from_asap(inst, 1.2)
    prof = generate_profile("S3", T, plat, J=24, seed=0)
    graph = prepare_graph(inst, plat, T, lp_budget_bytes=8 * 2**20)
    assert graph.lp_is_blocked

    def _no_dense(*a, **k):
        raise AssertionError("dense longest-path matrix materialized")

    monkeypatch.setattr(gj, "longest_path_matrix", _no_dense)
    res = schedule_portfolio_grid([inst], [[prof]], plat,
                                  variants=("press",), engine="jax",
                                  graphs=[graph])
    got = res[0][0]["press"]
    ref = schedule_reference(inst, prof, plat, "press")
    assert got.cost == ref.cost
    assert (got.start == ref.start).all()
