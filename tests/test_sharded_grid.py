"""Multi-device sharded portfolio grid: sharded == single-device BITWISE.

Forces 8 virtual host devices (must happen before the jax backend
initializes — same module-import pattern as tests/test_pipeline.py) and
proves the `shard_map` grid launch (`devices=` on `Planner` /
`PlanRequest` / `schedule_portfolio_grid`) changes nothing but the
device placement: the greedy scan is integer arithmetic over independent
vmap rows, so every start time and cost must match the single-device
launch exactly, through every entry layer.

Run via `make test-sharded` (wired into `make verify`), which sets the
forced-host-device-count flag so the multi-device path cannot rot on
CPU-only CI.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import pytest

from repro.api import Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (build_instance, deadline_from_asap,
                        generate_profile, heft_mapping)
from repro.core.portfolio import schedule_portfolio_grid
from repro.workflows import make_workflow

pytestmark = pytest.mark.device

VARIANTS = ("asap", "pressWR-LS", "pressW")


@pytest.fixture(scope="module")
def platform():
    return make_cluster(1, seed=0)


@pytest.fixture(scope="module")
def grid_case(platform):
    """5 instances x 2 profiles (odd instance count exercises the
    pad-rows-to-device-multiple path at ndev=8)."""
    kinds = ["eager", "atacseq", "eager", "bacass", "methylseq"]
    insts, rows = [], []
    for i, kind in enumerate(kinds):
        wf = make_workflow(kind, 2, seed=i)
        inst = build_instance(wf, heft_mapping(wf, platform), platform)
        T = deadline_from_asap(inst, 2.0)
        insts.append(inst)
        rows.append([generate_profile("S3", T, platform, J=8, seed=i),
                     generate_profile("S1", T, platform, J=8, seed=i + 50)])
    return insts, rows


def _flatten(cells):
    out = {}
    for i, row in enumerate(cells):
        for p, cell in enumerate(row):
            for name, r in cell.items():
                out[(i, p, name)] = (np.asarray(r.start), int(r.cost))
    return out


def test_eight_virtual_devices_visible():
    import jax

    assert len(jax.devices()) == 8


def test_grid_mesh_and_spec():
    import jax
    from jax.sharding import PartitionSpec as P

    from repro.sharding.ctx import grid_mesh
    from repro.sharding.specs import grid_batch_spec

    mesh = grid_mesh()
    assert mesh.axis_names == ("data",)
    assert mesh.shape["data"] == len(jax.devices()) == 8
    assert grid_mesh(3).shape["data"] == 3
    assert grid_batch_spec() == P("data")
    with pytest.raises(ValueError, match="devices"):
        grid_mesh(99)
    with pytest.raises(ValueError, match="devices"):
        grid_mesh(0)


@pytest.mark.parametrize("ndev", [2, 8])
def test_sharded_grid_bitwise_identical(grid_case, platform, ndev):
    insts, rows = grid_case
    base = _flatten(schedule_portfolio_grid(
        insts, rows, platform, variants=VARIANTS, engine="jax"))
    shard = _flatten(schedule_portfolio_grid(
        insts, rows, platform, variants=VARIANTS, engine="jax",
        devices=ndev))
    assert base.keys() == shard.keys()
    for key in base:
        assert np.array_equal(base[key][0], shard[key][0]), key
        assert base[key][1] == shard[key][1], key


def test_planner_devices_knob_bitwise(grid_case, platform):
    insts, rows = grid_case
    res1 = Planner(platform, engine="jax").plan(
        instances=insts, profiles=rows, variants=VARIANTS)
    res8 = Planner(platform, engine="jax", devices=8).plan(
        instances=insts, profiles=rows, variants=VARIANTS)
    assert np.array_equal(res1.costs, res8.costs)
    a, b = _flatten(res1.results), _flatten(res8.results)
    for key in a:
        assert np.array_equal(a[key][0], b[key][0]), key


def test_request_devices_overrides_planner(grid_case, platform):
    insts, rows = grid_case
    planner = Planner(platform, engine="jax", devices=2)
    assert planner.clone().devices == 2      # clone carries the knob
    res = planner.plan(PlanRequest(instances=insts, profiles=rows,
                                   variants=VARIANTS, devices=8))
    base = Planner(platform, engine="jax").plan(
        instances=insts, profiles=rows, variants=VARIANTS)
    assert np.array_equal(res.costs, base.costs)


def test_single_instance_pads_to_device_multiple(grid_case, platform):
    """I=1 at ndev=8: rows pad 1 -> 8 by repeating, result sliced back."""
    insts, rows = grid_case
    base = _flatten(schedule_portfolio_grid(
        insts[:1], rows[:1], platform, variants=VARIANTS, engine="jax"))
    shard = _flatten(schedule_portfolio_grid(
        insts[:1], rows[:1], platform, variants=VARIANTS, engine="jax",
        devices=8))
    assert base.keys() == shard.keys()
    for key in base:
        assert np.array_equal(base[key][0], shard[key][0]), key


def test_devices_request_validation(grid_case, platform):
    insts, rows = grid_case
    with pytest.raises(ValueError, match="devices"):
        PlanRequest(instances=insts, profiles=rows, variants=VARIANTS,
                    devices=0).resolve()
    with pytest.raises(ValueError, match="devices"):
        PlanRequest(instances=insts, profiles=rows, variants=VARIANTS,
                    devices=2.5).resolve()
