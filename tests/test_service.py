"""PlanService tier-1 suite: admission, coalescing, structured errors,
deadline budgets, the degradation ladder's fast paths, resolved-grid
validation, mip_gap surfacing, and the PlanningSession robustness fixes.

The heavier fault-matrix scenarios (seeded sweeps, watchdog hangs,
quarantine bisects) live in tests/test_chaos.py behind the ``chaos``
marker (`make test-chaos`); this file keeps the acceptance-critical
behaviours in the default tier-1 gate.
"""
import time

import numpy as np
import pytest

from repro.api import Planner, PlanRequest, PlanningSession
from repro.api.request import validate_resolved
from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    validate_schedule,
)
from repro.runtime.fault import FaultSpec, ServiceFaultInjector
from repro.serve import (
    InvalidRequest,
    Overloaded,
    PlanFailure,
    PlanService,
    ServiceClosed,
    ServiceError,
    TicketCancelled,
    TicketJournal,
    decode_ticket,
    encode_ticket,
)
from repro.workflows import make_workflow


def _setup(kind="eager", samples=3, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


def _assert_same_plan(a, b):
    """Bit-identity of two PlanResults: costs, and every cell's starts."""
    assert a.variants == b.variants
    assert (a.costs == b.costs).all()
    for ra, rb in zip(a.results, b.results):
        for ca, cb in zip(ra, rb):
            for name in ca:
                assert (ca[name].start == cb[name].start).all(), name


# --- fault-free service == direct Planner.plan -----------------------------

def test_service_fault_free_bit_identical_to_planner():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    with PlanService(planner.clone()) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
    _assert_same_plan(res, direct)
    assert not res.degraded
    assert res.fallback_stage == "heuristic"
    assert res.attempts == ("heuristic:ok",)


def test_service_coalesces_concurrent_requests_bit_identically():
    plat, inst, prof = _setup(samples=2, seed=5)
    wf2 = make_workflow("eager", 2, seed=9)
    inst2 = build_instance(wf2, heft_mapping(wf2, plat), plat)
    prof2 = generate_profile("S1", deadline_from_asap(inst2, 1.5), plat,
                             J=16, seed=7)
    planner = Planner(plat, engine="numpy")
    d1 = planner.plan(PlanRequest(instances=inst, profiles=prof))
    d2 = planner.plan(PlanRequest(instances=inst2, profiles=prof2))
    with PlanService(planner.clone()) as svc:
        svc.pause()                      # hold the worker: deterministic
        t1 = svc.submit(PlanRequest(instances=inst, profiles=prof))
        t2 = svc.submit(PlanRequest(instances=inst2, profiles=prof2))
        t3 = svc.submit(PlanRequest(instances=inst, profiles=prof))
        svc.resume()
        r1, r2, r3 = (t.result(timeout=120) for t in (t1, t2, t3))
        stats = svc.stats()
    _assert_same_plan(r1, d1)
    _assert_same_plan(r2, d2)
    _assert_same_plan(r3, d1)
    # all three tickets share one coalesce key -> ONE combined launch
    assert stats["batches"] == 1
    assert stats["coalesced_requests"] == 3
    assert stats["coalesce_ratio"] == 3.0
    assert stats["completed"] == 3 and stats["degraded"] == 0
    assert stats["latency"]["n"] == 3


def test_service_mixed_solver_queue_groups_by_key():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    da = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                  solver="asap"))
    dh = planner.plan(PlanRequest(instances=inst, profiles=prof))
    with PlanService(planner.clone()) as svc:
        svc.pause()
        ta = svc.submit(PlanRequest(instances=inst, profiles=prof,
                                    solver="asap"))
        th = svc.submit(PlanRequest(instances=inst, profiles=prof))
        svc.resume()
        ra, rh = ta.result(timeout=120), th.result(timeout=120)
        assert svc.stats()["batches"] == 2      # different solver keys
    _assert_same_plan(ra, da)
    _assert_same_plan(rh, dh)
    assert ra.solver == "asap" and not ra.degraded


# --- structured rejections -------------------------------------------------

def test_service_overloaded_is_structured():
    plat, inst, prof = _setup()
    with PlanService(Planner(plat, engine="numpy"), max_queue=2) as svc:
        svc.pause()
        svc.submit(PlanRequest(instances=inst, profiles=prof))
        svc.submit(PlanRequest(instances=inst, profiles=prof))
        with pytest.raises(Overloaded) as ei:
            svc.submit(PlanRequest(instances=inst, profiles=prof))
        d = ei.value.to_dict()
        assert d["code"] == "overloaded"
        assert d["queue_depth"] == 2 and d["max_queue"] == 2
        assert svc.stats()["rejected_overloaded"] == 1
        svc.resume()


def test_service_invalid_request_rejected_at_admission():
    plat, inst, prof = _setup()
    with PlanService(Planner(plat, engine="numpy")) as svc:
        with pytest.raises(InvalidRequest) as ei:
            svc.submit(PlanRequest(instances=inst, profiles=[]))
        assert ei.value.to_dict()["code"] == "invalid_request"
        # an infeasible horizon is caught structurally, not downstream
        tiny = generate_profile("S1", 2, plat, J=1, seed=0)
        with pytest.raises(InvalidRequest):
            svc.submit(PlanRequest(instances=inst, profiles=tiny))
        assert svc.stats()["rejected_invalid"] == 2
        # the service still serves healthy requests afterwards
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
        assert not res.degraded


def test_service_closed_rejects_new_and_pending():
    plat, inst, prof = _setup()
    svc = PlanService(Planner(plat, engine="numpy"))
    svc.pause()
    t = svc.submit(PlanRequest(instances=inst, profiles=prof))
    svc.close()
    with pytest.raises(ServiceClosed):
        t.result(timeout=10)
    with pytest.raises(ServiceClosed):
        svc.submit(PlanRequest(instances=inst, profiles=prof))


# --- deadline budgets + fast ladder paths ----------------------------------

def test_service_exhausted_budget_still_returns_feasible_asap():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    with PlanService(planner.clone()) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof),
                       budget=0.0)
    assert res.degraded and res.fallback_stage == "asap"
    assert res.attempts == ("heuristic:skipped", "asap:ok")
    validate_schedule(inst, prof, res.result(variant="asap").start)


def test_service_solver_crash_degrades_to_feasible_schedule():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage="heuristic", times=10)])
    with PlanService(planner.clone(), injector=inj, retries=1,
                     backoff=0.01) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
    assert res.degraded and res.fallback_stage == "asap"
    assert res.attempts == ("heuristic:crash", "heuristic:crash", "asap:ok")
    validate_schedule(inst, prof, res.result(variant="asap").start)


def test_service_transient_crash_retries_to_full_fidelity():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="crash", stage="heuristic", times=1)])
    with PlanService(planner.clone(), injector=inj, retries=2,
                     backoff=0.01) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
        assert svc.stats()["retries"] == 1
    _assert_same_plan(res, direct)          # retry healed: NOT degraded
    assert not res.degraded
    assert res.attempts == ("heuristic:crash", "heuristic:ok")


def test_service_device_oom_retries_on_blocked_lp_planner():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="oom", stage="heuristic", times=1)])
    with PlanService(planner.clone(), injector=inj) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
        assert svc.stats()["oom_retries"] == 1
    _assert_same_plan(res, direct)
    assert not res.degraded
    assert res.attempts == ("heuristic:oom",
                            "heuristic:oom-retry-blocked-lp",
                            "heuristic:ok")


# --- priority admission + aging --------------------------------------------

def _completion_order(named_tickets, timeout=60.0):
    order, pending = [], dict(named_tickets)
    deadline = time.monotonic() + timeout
    while pending and time.monotonic() < deadline:
        for name, t in list(pending.items()):
            if t.done():
                order.append(name)
                del pending[name]
        time.sleep(0.005)
    assert not pending, f"tickets never resolved: {sorted(pending)}"
    return order


def test_priority_admission_serves_earliest_deadline_first():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    with PlanService(planner.clone(), max_batch=1) as svc:
        svc.pause()
        # submitted FIRST but budget-less: virtual deadline = now + aging
        slow = svc.submit(PlanRequest(instances=inst, profiles=prof))
        urgent = svc.submit(PlanRequest(instances=inst, profiles=prof,
                                        solver="asap"), budget=10.0)
        svc.resume()
        order = _completion_order({"slow": slow, "urgent": urgent})
    assert order == ["urgent", "slow"]


def test_aging_prevents_starvation_of_budgetless_tickets():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    with PlanService(planner.clone(), max_batch=1, aging=0.05) as svc:
        svc.pause()
        old = svc.submit(PlanRequest(instances=inst, profiles=prof))
        time.sleep(0.1)
        # arrives more than `aging` after `old`: the aged budget-less
        # ticket now outranks even a tight real deadline
        urgent = svc.submit(PlanRequest(instances=inst, profiles=prof,
                                        solver="asap"), budget=10.0)
        svc.resume()
        order = _completion_order({"old": old, "urgent": urgent})
    assert order == ["old", "urgent"]


# --- cooperative cancellation ----------------------------------------------

def test_cancel_queued_ticket_never_runs():
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    with PlanService(planner.clone()) as svc:
        svc.pause()
        t = svc.submit(PlanRequest(instances=inst, profiles=prof))
        assert t.cancel("changed my mind")
        assert not t.cancel()                # second cancel lost: resolved
        svc.resume()
        with pytest.raises(TicketCancelled) as ei:
            t.result(timeout=10)
        assert ei.value.to_dict()["reason"] == "changed my mind"
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
        stats = svc.stats()
    assert not res.degraded                  # service healthy afterwards
    assert stats["cancelled"] == 1
    assert stats["completed"] == 1           # the cancelled ticket never ran


def test_cancel_stops_inflight_solve_within_rung_budget():
    """Tentpole acceptance: cancellation is cooperative all the way down —
    after Ticket.cancel() the solve pool goes idle within one rung budget
    (observed via the solver-side token polls), not after the 30s hang."""
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    inj = ServiceFaultInjector(
        faults=[FaultSpec(kind="hang", stage="heuristic", times=1,
                          seconds=30.0)])
    with PlanService(planner.clone(), injector=inj) as svc:
        t = svc.submit(PlanRequest(instances=inst, profiles=prof))
        deadline = time.monotonic() + 10
        while svc.stats()["inflight_solves"] == 0 \
                and time.monotonic() < deadline:
            time.sleep(0.005)
        assert svc.stats()["inflight_solves"] == 1
        t0 = time.monotonic()
        assert t.cancel()
        while svc.stats()["inflight_solves"] > 0 \
                and time.monotonic() < deadline:
            time.sleep(0.005)
        latency = time.monotonic() - t0
        stats = svc.stats()
        with pytest.raises(TicketCancelled):
            t.result(timeout=5)
    assert stats["inflight_solves"] == 0
    assert latency < 2.0, latency            # one rung, not the 30s hang
    assert stats["cancel_checks"] > 0        # the solver really polled
    assert stats["cancelled"] == 1 and stats["cancelled_solves"] == 1
    assert stats["completed"] == 0 and stats["failed"] == 0


# --- wire shapes round-trip -------------------------------------------------

def test_service_error_wire_round_trip():
    import json

    errs = [
        ServiceError("plain", hint="x"),
        Overloaded("queue full", queue_depth=3, max_queue=2),
        InvalidRequest("bad profile", reason="budget length"),
        PlanFailure("every stage failed",
                    attempts=("heuristic:crash", "asap:crash"),
                    last_error=None),
        ServiceClosed("closed"),
        TicketCancelled("ticket cancelled: bye", reason="bye"),
    ]
    for e in errs:
        d = e.to_dict()
        assert d == json.loads(json.dumps(d)), type(e).__name__
        back = ServiceError.from_dict(d)
        assert type(back) is type(e)
        assert str(back) == str(e)
        assert back.to_dict() == d           # lossless round-trip


def test_plan_result_summary_dict_round_trips_losslessly():
    import dataclasses
    import json

    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    res = planner.plan(PlanRequest(instances=inst, profiles=[prof, prof]))
    gap = np.full(res.costs.shape[:2], np.nan)
    gap[0, 0] = 0.25                         # mixed known/NaN gap cells
    res = dataclasses.replace(
        res, degraded=True, fallback_stage="ilp",
        attempts=("ilp:timeout", "heuristic:ok"),
        lower_bound=res.best_costs(), mip_gap=gap)
    d = res.summary_dict()
    assert d == json.loads(json.dumps(d))    # JSON-safe, NaN travels as None
    back = type(res).summary_from_dict(d)
    assert back.summary_dict() == d          # lossless round-trip
    assert (back.costs == res.costs).all()
    assert back.attempts == res.attempts and back.degraded
    assert np.isnan(back.mip_gap[0, 1]) and back.mip_gap[0, 0] == 0.25


# --- write-ahead ticket journal ---------------------------------------------

def test_ticket_journal_round_trips_and_resolves(tmp_path):
    plat, inst, prof = _setup()
    j = TicketJournal(str(tmp_path / "journal"))
    assert j.next_seq() == 0 and j.pending() == []
    state = encode_ticket([inst], [[prof]], ("asap", "pressWR-LS"),
                          "heuristic", True, {"x": 1}, 2.5)
    j.record(j.next_seq(), state)
    j.record(j.next_seq(), state)
    pend = j.pending()
    assert [s for s, _ in pend] == [0, 1] and j.next_seq() == 2
    insts, grid, names, solver, robust, options, budget = \
        decode_ticket(pend[0][1])
    assert names == ("asap", "pressWR-LS") and solver == "heuristic"
    assert robust is True and options == {"x": 1} and budget == 2.5
    back = insts[0]
    assert back.name == inst.name and back.proc_chains == inst.proc_chains
    for f in ("dur", "proc", "task_work", "pred_ptr", "pred_idx",
              "succ_ptr", "succ_idx", "chain_proc_ids", "topo", "level"):
        assert (np.asarray(getattr(back, f))
                == np.asarray(getattr(inst, f))).all(), f
    p = grid[0][0]
    assert (p.bounds == prof.bounds).all() and \
        (p.budget == prof.budget).all() and p.scenario == prof.scenario
    j.resolve(0)
    j.resolve(0)                             # idempotent
    assert [s for s, _ in j.pending()] == [1]


def test_kill_then_restart_replays_admitted_tickets(tmp_path):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof))
    jdir = str(tmp_path / "journal")
    svc = PlanService(planner.clone(), journal_dir=jdir)
    svc.pause()
    t1 = svc.submit(PlanRequest(instances=inst, profiles=prof))
    t2 = svc.submit(PlanRequest(instances=inst, profiles=prof))
    svc.kill()                               # abrupt death: futures hang,
    assert not t1.done() and not t2.done()   # journal keeps both entries
    svc2 = PlanService(planner.clone(), journal_dir=jdir)
    assert len(svc2.replayed) == 2
    results = [t.result(timeout=120) for t in svc2.replayed]
    assert svc2.stats()["replayed"] == 2
    svc2.close()
    for r in results:
        _assert_same_plan(r, direct)         # replay serves full fidelity
        assert not r.degraded
    # every replayed ticket resolved -> the journal is empty again
    svc3 = PlanService(planner.clone(), journal_dir=jdir)
    assert svc3.replayed == []
    svc3.close()


def test_clean_close_leaves_empty_journal(tmp_path):
    plat, inst, prof = _setup()
    planner = Planner(plat, engine="numpy")
    jdir = str(tmp_path / "journal")
    with PlanService(planner.clone(), journal_dir=jdir) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof))
        assert not res.degraded
    assert TicketJournal(jdir).pending() == []


# --- compilation cache wiring ------------------------------------------------

def test_service_enables_compilation_cache_with_opt_out():
    plat, _, _ = _setup()
    with PlanService(Planner(plat, engine="numpy")) as svc:
        assert svc.compile_cache_dir          # enabled by default
    with PlanService(Planner(plat, engine="numpy"),
                     compilation_cache=False) as svc:
        assert svc.compile_cache_dir is None  # explicit opt-out


_WARM_RESTART_SCRIPT = """
from repro.api import Planner, PlanRequest
from repro.cluster import make_cluster
from repro.core import (build_instance, deadline_from_asap,
                        generate_profile, heft_mapping)
from repro.serve import PlanService
from repro.workflows import make_workflow

plat = make_cluster(1, seed=3)
wf = make_workflow("eager", 2, seed=3)
inst = build_instance(wf, heft_mapping(wf, plat), plat)
prof = generate_profile("S3", deadline_from_asap(inst, 1.5), plat, J=8,
                        seed=3)
svc = PlanService(Planner(plat, engine="jax"))
assert svc.compile_cache_dir, "compilation cache not enabled"
res = svc.plan(PlanRequest(instances=inst, profiles=[prof, prof]))
assert not res.degraded
svc.close()
print("CACHE_DIR=" + svc.compile_cache_dir)
"""


@pytest.mark.device
def test_service_restart_reuses_persistent_compilation_cache(tmp_path):
    """Warm-restart compiles drop to zero: the first service process
    populates the persistent jax compilation cache the startup hook
    enables; an identical second process adds no new entries (every
    compile is a cache hit)."""
    import os
    import subprocess
    import sys

    env = dict(os.environ, HOME=str(tmp_path),
               PYTHONPATH=os.pathsep.join(sys.path))
    cache_dir = None
    counts = []
    for _ in range(2):
        out = subprocess.run(
            [sys.executable, "-c", _WARM_RESTART_SCRIPT], env=env,
            capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr
        line = [ln for ln in out.stdout.splitlines()
                if ln.startswith("CACHE_DIR=")][0]
        cache_dir = line[len("CACHE_DIR="):]
        counts.append(len(os.listdir(cache_dir)))
    assert cache_dir.startswith(str(tmp_path))
    assert counts[0] > 0, "cold run persisted no compiled executables"
    assert counts[1] == counts[0], \
        f"warm restart recompiled: {counts[0]} -> {counts[1]} entries"


# --- resolved-grid validation (the quarantine check) -----------------------

def test_validate_resolved_catches_structural_corruption():
    from repro.runtime.fault import corrupt_profile

    plat, inst, prof = _setup()
    validate_resolved([inst], [[prof]])                  # healthy passes
    with pytest.raises(ValueError, match="budget length"):
        validate_resolved([inst], [[corrupt_profile(prof)]])
    with pytest.raises(ValueError, match="critical path"):
        validate_resolved([inst], [[generate_profile("S1", 2, plat, J=1,
                                                     seed=0)]])
    import dataclasses

    idx = inst.succ_idx.copy()
    idx[0] = inst.num_tasks + 5                          # dangling edge
    bad = dataclasses.replace(inst, succ_idx=idx)
    with pytest.raises(ValueError, match="adjacency"):
        validate_resolved([bad], [[prof]])


# --- mip_gap / lower_bound surfacing (ilp time-limit exits) ----------------

def test_ilp_time_limit_exit_surfaces_gap_not_failure(monkeypatch):
    """A time-limited ILP that returns an incumbent is a degraded success:
    the PlanResult carries the schedule + lower_bound + mip_gap, and the
    service flags it degraded without walking further down the chain."""
    import repro.core.ilp as ilp_mod
    from repro.core.ilp import ILPResult

    plat, inst, prof = _setup(samples=2, seed=5)
    asap = Planner(plat, engine="numpy").plan(
        PlanRequest(instances=inst, profiles=prof, solver="asap"))
    incumbent = asap.result(variant="asap").start
    cost = int(asap.costs[0, 0, 0])

    def fake_solve(inst_, prof_, time_limit=300.0, mip_gap=0.0,
                   cancel=None):
        return ILPResult(cost=float(cost), start=incumbent.copy(),
                         status=1, message="time limit reached",
                         lower_bound=cost * 0.5, mip_gap=0.5)

    monkeypatch.setattr(ilp_mod, "solve_ilp", fake_solve)
    planner = Planner(plat, engine="numpy")
    res = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="ilp"))
    assert res.mip_gap is not None and res.mip_gap[0, 0] == 0.5
    assert res.lower_bound[0, 0] == int(np.ceil(cost * 0.5 - 1e-6))
    with PlanService(planner.clone()) as svc:
        served = svc.plan(PlanRequest(instances=inst, profiles=prof,
                                      solver="ilp"))
    assert served.degraded                       # open gap => degraded
    assert served.fallback_stage == "ilp"        # but NOT a fallback
    assert served.attempts == ("ilp:ok",)
    assert served.mip_gap[0, 0] == 0.5
    validate_schedule(inst, prof, served.result(variant="ilp").start)


@pytest.mark.ilp
def test_exact_through_service_matches_direct_and_certifies():
    pytest.importorskip("scipy.optimize", reason="needs scipy HiGHS")
    from repro.core.carbon import PowerProfile
    from repro.core.dag import trivial_mapping
    from repro.workflows import layered_random

    rng = np.random.default_rng(0)
    plat = make_cluster(1, seed=0)
    wf = layered_random(6, 3, seed=0)
    inst = build_instance(wf, trivial_mapping(wf, plat, by="round_robin"),
                          plat, dur=rng.integers(1, 6, size=wf.n))
    T = deadline_from_asap(inst, 1.5)
    bounds = np.unique(np.round(np.linspace(0, T, 5)).astype(np.int64))
    budget = plat.idle_total + rng.integers(
        0, max(int(inst.task_work.max()) // 2, 2), size=len(bounds) - 1)
    prof = PowerProfile(bounds=bounds, budget=budget)

    planner = Planner(plat, engine="numpy")
    direct = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                      solver="exact"))
    with PlanService(planner.clone()) as svc:
        res = svc.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="exact"))
    _assert_same_plan(res, direct)
    assert not res.degraded                      # proven optimum
    assert res.lower_bound[0, 0] == res.costs[0, 0, 0]


# --- PlanningSession robustness fixes --------------------------------------

def _session_fixture(n_windows=3):
    plat, inst, _ = _setup(factor=1.6)
    from repro.api.request import window_profile

    W = deadline_from_asap(inst, 1.6)
    long = generate_profile("S3", n_windows * W, plat, J=48, seed=7)
    return plat, inst, lambda k: window_profile(long, k * W, W)


def test_session_evicts_failed_future_and_resubmits_once():
    plat, inst, wprofs = _session_fixture()
    planner = Planner(plat, engine="numpy")
    real_plan = planner.plan
    boom = {"left": 1}

    def flaky_plan(request, cancel=None):
        if boom["left"]:
            boom["left"] -= 1
            raise RuntimeError("transient device hiccup")
        return real_plan(request)

    planner.plan = flaky_plan
    with PlanningSession(planner, inst, wprofs, n_windows=3,
                         lookahead=0) as sess:
        res = sess.plan_for(0)           # first background plan fails,
        assert res.shape[0] == 1         # eviction + resubmit heals it
        ref = real_plan(sess.request_for(0))
        assert (res.costs == ref.costs).all()


def test_session_second_failure_propagates_and_sticks():
    plat, inst, wprofs = _session_fixture()
    planner = Planner(plat, engine="numpy")

    def always_fail(request, cancel=None):
        raise RuntimeError("persistent failure")

    planner.plan = always_fail
    with PlanningSession(planner, inst, wprofs, n_windows=3,
                         lookahead=0) as sess:
        with pytest.raises(RuntimeError, match="persistent"):
            sess.plan_for(0)             # retried once, then propagates
        with pytest.raises(RuntimeError, match="persistent"):
            sess.plan_for(0)             # sticky: no unbounded resubmits


def test_session_close_cancels_prefetched_windows():
    plat, inst, wprofs = _session_fixture(n_windows=8)
    planner = Planner(plat, engine="numpy")
    real_plan = planner.plan

    def slow_plan(request, cancel=None):
        time.sleep(0.25)
        return real_plan(request, cancel=cancel)

    planner.plan = slow_plan
    sess = PlanningSession(planner, inst, wprofs, n_windows=8, lookahead=6)
    sess.plan_for(0)                     # queues 6 lookahead windows
    t0 = time.monotonic()
    sess.close()                         # cancel_futures: don't drain them
    closed_in = time.monotonic() - t0
    # closing waits for at most the one in-flight plan, not 6 queued ones
    assert closed_in < 1.5, closed_in
    with pytest.raises(RuntimeError):
        sess.plan_for(1)


def test_session_close_cancels_in_flight_solve_via_token():
    """close() stops the ONE in-flight background solve through its
    CancelToken, not just the queued prefetches — an endless solve that
    polls its token unwinds within a chunk instead of pinning close()."""
    plat, inst, wprofs = _session_fixture()
    planner = Planner(plat, engine="numpy")

    def endless_plan(request, cancel=None):
        while True:                      # a solver chunk loop in miniature
            if cancel is not None:
                cancel.check()
            time.sleep(0.01)

    planner.plan = endless_plan
    sess = PlanningSession(planner, inst, wprofs, n_windows=3, lookahead=0)
    sess._submit(0)                      # in flight, would never finish
    time.sleep(0.1)
    t0 = time.monotonic()
    sess.close()                         # shutdown(wait=True) + token cancel
    assert time.monotonic() - t0 < 1.0
    with pytest.raises(RuntimeError):
        sess.plan_for(0)
