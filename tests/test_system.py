"""End-to-end behaviour tests for the paper's system.

Full pipeline: nf-core-like workflow -> HEFT mapping -> communication-
enhanced instance -> power profiles -> all 16 CaWoSched variants + ASAP ->
(small instances) ILP optimality gap, mirroring the paper's §6 protocol.
"""
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    ALL_VARIANTS,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    schedule,
    schedule_cost,
    validate_schedule,
)
from repro.core.ilp import solve_ilp
from repro.workflows import WORKFLOW_KINDS, make_workflow, wfgen_scale


def test_full_pipeline_all_kinds():
    plat = make_cluster(1, seed=0)
    for kind in WORKFLOW_KINDS:
        wf = make_workflow(kind, 4, seed=1)
        inst = build_instance(wf, heft_mapping(wf, plat), plat)
        assert inst.num_tasks >= wf.n
        T = deadline_from_asap(inst, 1.5)
        prof = generate_profile("S3", T, plat, J=16, seed=2)
        base = schedule(inst, prof, plat, "asap")
        best = min(schedule(inst, prof, plat, v.name).cost
                   for v in ALL_VARIANTS)
        assert best <= base.cost


def test_paper_protocol_small():
    """ASAP is beaten on most instances; every variant is deadline-valid;
    heuristics sit between ILP (lower bound) and ASAP on small instances."""
    plat = make_cluster(1, seed=3)
    wf = make_workflow("bacass", 2, seed=11)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 2.0)
    prof = generate_profile("S1", T, plat, J=8, seed=4)
    res = {v.name: schedule(inst, prof, plat, v.name) for v in ALL_VARIANTS}
    base = schedule(inst, prof, plat, "asap")
    for r in res.values():
        validate_schedule(inst, prof, r.start)
    ilp = solve_ilp(inst, prof, time_limit=180)
    best = min(r.cost for r in res.values())
    assert ilp.cost - 1e-6 <= best <= base.cost


def test_scaling_instances():
    """wfgen-scaled workflows build + schedule at 1k tasks quickly."""
    plat = make_cluster(2, seed=0)          # 12 compute processors
    wf = wfgen_scale("atacseq", 1000, seed=5)
    assert 700 <= wf.n <= 1400
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.5)
    prof = generate_profile("S3", T, plat, J=48, seed=5)
    r = schedule(inst, prof, plat, "pressWR-LS")
    validate_schedule(inst, prof, r.start)
    base = schedule(inst, prof, plat, "asap")
    assert r.cost <= base.cost
    assert r.seconds < 60
