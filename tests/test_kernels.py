"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + real instances."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    asap_schedule,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    schedule_cost,
)
from repro.core.carbon import work_timeline
from repro.kernels.carbon_cost import deficit_timeline
from repro.kernels.gain_scan import gain_scan
from repro.kernels.ops import carbon_cost, ls_gains
from repro.kernels.ref import deficit_timeline_ref, gain_scan_ref
from repro.workflows import make_workflow


def _rand(n, t, seed):
    rng = np.random.default_rng(seed)
    starts = rng.integers(0, max(t - 20, 1), n).astype(np.float32)
    durs = rng.integers(1, 20, n).astype(np.float32)
    works = rng.integers(0, 120, n).astype(np.float32)
    g = rng.integers(0, 2500, t).astype(np.float32)
    return starts, durs, works, g


@pytest.mark.parametrize("n", [1, 7, 63, 300, 1000])
@pytest.mark.parametrize("t", [16, 700, 2048])
def test_deficit_timeline_sweep(n, t):
    starts, durs, works, g = _rand(n, t, seed=n * 1000 + t)
    got = np.asarray(deficit_timeline(jnp.asarray(starts),
                                      jnp.asarray(starts + durs),
                                      jnp.asarray(works), jnp.asarray(g)))
    want = np.asarray(deficit_timeline_ref(jnp.asarray(starts),
                                           jnp.asarray(starts + durs),
                                           jnp.asarray(works),
                                           jnp.asarray(g)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@pytest.mark.parametrize("n,t,mu", [(1, 64, 1), (17, 300, 5), (120, 900, 10),
                                    (256, 512, 20), (300, 2048, 42)])
def test_gain_scan_sweep(n, t, mu):
    rng = np.random.default_rng(n + t + mu)
    starts, durs, works, g = _rand(n, t, seed=n + t)
    starts = np.minimum(starts, t - durs - 1)
    power = np.asarray(deficit_timeline_ref(
        jnp.asarray(starts), jnp.asarray(starts + durs), jnp.asarray(works),
        jnp.asarray(np.zeros(t, np.float32))))
    rem = (g - power).astype(np.float32)
    lo = np.maximum(starts - rng.integers(0, 30, n), 0).astype(np.float32)
    hi = np.minimum(starts + rng.integers(0, 30, n),
                    t - durs).astype(np.float32)
    got = np.asarray(gain_scan(jnp.asarray(rem), jnp.asarray(starts),
                               jnp.asarray(durs), jnp.asarray(works),
                               jnp.asarray(lo), jnp.asarray(hi), mu=mu))
    want = np.asarray(gain_scan_ref(jnp.asarray(rem), jnp.asarray(starts),
                                    jnp.asarray(durs), jnp.asarray(works),
                                    jnp.asarray(lo), jnp.asarray(hi), mu=mu))
    legal = want > -1e29
    assert (legal == (got > -1e29)).all()
    np.testing.assert_allclose(got[legal], want[legal], atol=1e-3)


def test_kernel_cost_matches_core_oracle():
    plat = make_cluster(1, seed=2)
    wf = make_workflow("eager", 5, seed=4)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 1.4)
    prof = generate_profile("S3", T, plat, J=12, seed=3)
    start = asap_schedule(inst)
    want = schedule_cost(inst, prof, start)
    got = float(carbon_cost(start, inst.dur, inst.task_work,
                            prof.unit_budget(inst.idle_total)))
    assert abs(got - want) < 1e-3 * max(want, 1)


def test_gain_kernel_on_real_instance():
    plat = make_cluster(1, seed=5)
    wf = make_workflow("methylseq", 4, seed=6)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, 2.0)
    prof = generate_profile("S1", T, plat, J=12, seed=3)
    start = asap_schedule(inst)
    rem = prof.unit_budget(inst.idle_total) - work_timeline(inst, T, start)
    N = inst.num_tasks
    lo = np.zeros(N)
    hi = np.full(N, T) - inst.dur
    gains = np.asarray(ls_gains(rem, start, inst.dur, inst.task_work,
                                lo, hi, mu=6))
    base = schedule_cost(inst, prof, start)
    # applying any positive-gain single move must reduce the exact cost by
    # exactly that gain
    idx = np.argwhere(gains > 0)
    for (v, d) in idx[:20]:
        s2 = start.copy()
        s2[v] += d - 6
        c2 = schedule_cost(inst, prof, s2)
        assert abs((base - c2) - gains[v, d]) < 1e-3


@pytest.mark.device
class TestGainKernelBitIdentity:
    """The tiled Pallas gain kernel vs the jnp prefix-sum twin.

    All gain summands are integers below 2^24, so f32 accumulation is
    exact in any order — the two executors must agree BITWISE, not just
    within tolerance. On CPU the kernel path runs under the Pallas
    interpreter (``interpret=True``), which executes the same kernel
    body the TPU/GPU compiled path lowers.
    """

    @staticmethod
    def _case(n, t, mu, seed):
        rng = np.random.default_rng(seed)
        rem = rng.integers(-9, 9, t).astype(np.float32)
        dur = rng.integers(1, 9, n).astype(np.float32)
        start = rng.integers(0, max(t - 10, 1), n).astype(np.float32)
        work = rng.integers(0, 7, n).astype(np.float32)
        lo = np.maximum(start - rng.integers(0, 2 * mu + 5, n), 0)
        hi = start + rng.integers(0, 2 * mu + 5, n)
        return tuple(jnp.asarray(a) for a in (rem, start, dur, work,
                                              lo.astype(np.float32),
                                              hi.astype(np.float32)))

    @pytest.mark.parametrize("mu", [1, 5, 10, 21, 42])
    @pytest.mark.parametrize("n,t", [(1, 64), (63, 300), (257, 777)])
    def test_bit_identity_across_mu(self, n, t, mu):
        args = self._case(n, t, mu, seed=n * t + mu)
        twin = np.asarray(gain_scan(*args, mu=mu, interpret=None))
        kern = np.asarray(gain_scan(*args, mu=mu, interpret=True))
        assert (twin == kern).all()

    def test_bit_identity_masked_edges(self):
        """Window clipping at both horizon edges, rows with no legal
        move (lo > hi), and zero-work rows — all exactly NEG-masked the
        same way on both paths."""
        mu = 10
        t = 96
        rem = jnp.asarray(np.tile([-3.0, 2.0, -1.0, 4.0], t // 4),
                          jnp.float32)
        start = jnp.asarray([0.0, 1.0, 90.0, 40.0, 40.0, 88.0], jnp.float32)
        dur = jnp.asarray([4.0, 2.0, 6.0, 5.0, 5.0, 8.0], jnp.float32)
        work = jnp.asarray([3.0, 2.0, 1.0, 2.0, 0.0, 5.0], jnp.float32)
        lo = jnp.asarray([0.0, 0.0, 80.0, 41.0, 30.0, 0.0], jnp.float32)
        hi = jnp.asarray([12.0, 9.0, 90.0, 39.0, 50.0, 88.0], jnp.float32)
        twin = np.asarray(gain_scan(rem, start, dur, work, lo, hi, mu=mu,
                                    interpret=None))
        kern = np.asarray(gain_scan(rem, start, dur, work, lo, hi, mu=mu,
                                    interpret=True))
        assert (twin == kern).all()
        assert (twin[3] == -1e30).all()      # no legal move: lo > hi
        assert (twin[4] == -1e30).all()      # zero-work row all-illegal
        assert (twin[:, mu] == -1e30).all()  # delta=0 always illegal

    @pytest.mark.parametrize("mu", [3, 17])
    def test_batched_bit_identity(self, mu):
        from repro.kernels.gain_scan import gain_scan_batched

        rng = np.random.default_rng(mu)
        B, n, t = 3, 40, 256
        rem = rng.integers(-9, 9, (B, t)).astype(np.float32)
        dur = rng.integers(1, 9, n).astype(np.float32)
        work = rng.integers(0, 7, n).astype(np.float32)
        start = rng.integers(0, t - 10, (B, n)).astype(np.float32)
        lo = np.maximum(start - 20, 0).astype(np.float32)
        hi = (start + 20).astype(np.float32)
        args = tuple(jnp.asarray(a) for a in (rem, start, dur, work, lo, hi))
        twin = np.asarray(gain_scan_batched(args[0], args[1], args[2],
                                            args[3], args[4], args[5],
                                            mu=mu, interpret=None))
        kern = np.asarray(gain_scan_batched(args[0], args[1], args[2],
                                            args[3], args[4], args[5],
                                            mu=mu, interpret=True))
        assert twin.shape == (B, n, 2 * mu + 1)
        assert (twin == kern).all()

    def test_windows_auto_dispatch(self):
        """gains_windows_auto is the climb's oracle: explicit interpret
        settings pick the kernel/twin, both bitwise-equal."""
        from repro.kernels.gain_scan import (gains_from_windows,
                                             gains_windows_auto,
                                             gather_windows)

        mu = 8
        rng = np.random.default_rng(0)
        rem = jnp.asarray(rng.integers(-5, 5, 128).astype(np.float32))
        start = jnp.asarray(rng.integers(0, 100, 30).astype(np.float32))
        dur = jnp.asarray(rng.integers(1, 8, 30).astype(np.float32))
        work = jnp.asarray(rng.integers(0, 6, 30).astype(np.float32))
        win_s, win_e = gather_windows(rem, start, dur, mu=mu)
        lo_rel = jnp.full(30, -5.0, jnp.float32)
        hi_rel = jnp.full(30, 5.0, jnp.float32)
        twin = np.asarray(gains_from_windows(win_s, win_e, work, dur,
                                             lo_rel, hi_rel, mu=mu))
        auto = np.asarray(gains_windows_auto(win_s, win_e, work, dur,
                                             lo_rel, hi_rel, mu=mu))
        kern = np.asarray(gains_windows_auto(win_s, win_e, work, dur,
                                             lo_rel, hi_rel, mu=mu,
                                             interpret=True))
        assert (twin == auto).all()          # CPU auto = the jnp twin
        assert (twin == kern).all()          # interpreter = same bits


@pytest.mark.parametrize("B,S,H,hd,causal,dtype", [
    (2, 128, 2, 64, True, jnp.float32),
    (1, 256, 4, 128, True, jnp.float32),
    (2, 200, 2, 64, False, jnp.float32),     # non-multiple S (padding path)
    (1, 384, 1, 128, True, jnp.bfloat16),
    (1, 130, 3, 64, True, jnp.float32),
])
def test_flash_attention_sweep(B, S, H, hd, causal, dtype):
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    got = np.asarray(flash_attention(q, k, v, causal=causal), np.float32)
    want = np.asarray(flash_attention_ref(q, k, v, causal=causal),
                      np.float32)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)
