"""Hypothesis property tests on the scheduling system's invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.cluster import make_cluster
from repro.core import (
    build_instance,
    deadline_from_asap,
    generate_profile,
    schedule,
    schedule_cost,
    validate_schedule,
)
from repro.core.carbon import PowerProfile, cost_timeline
from repro.core.heft import heft_mapping
from repro.core.local_search import local_search
from repro.workflows import layered_random


def _instance(n, seed):
    plat = make_cluster(1, seed=seed)
    wf = layered_random(max(n, 4), 4, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    return plat, inst


@settings(max_examples=20, deadline=None)
@given(n=st.integers(5, 25), seed=st.integers(0, 1000),
       scen=st.sampled_from(["S1", "S2", "S3", "S4"]),
       factor=st.sampled_from([1.0, 1.5, 2.0]),
       variant=st.sampled_from(
           ["slack", "slackW", "pressR", "pressWR-LS", "slack-LS"]))
def test_schedules_always_valid(n, seed, scen, factor, variant):
    plat, inst = _instance(n, seed)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scen, T, plat, J=8, seed=seed)
    r = schedule(inst, prof, plat, variant)
    validate_schedule(inst, prof, r.start)          # precedence + deadline
    assert r.cost == schedule_cost(inst, prof, r.start)
    assert r.cost == cost_timeline(inst, prof, r.start)  # oracle agreement


@settings(max_examples=15, deadline=None)
@given(n=st.integers(5, 20), seed=st.integers(0, 500),
       mu=st.integers(1, 12))
def test_local_search_never_increases_cost(n, seed, mu):
    plat, inst = _instance(n, seed)
    T = deadline_from_asap(inst, 1.7)
    prof = generate_profile("S3", T, plat, J=8, seed=seed)
    base = schedule(inst, prof, plat, "pressR").start
    c0 = schedule_cost(inst, prof, base)
    improved = local_search(inst, prof, plat, base, mu=mu)
    validate_schedule(inst, prof, improved)
    assert schedule_cost(inst, prof, improved) <= c0


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 500), split=st.integers(1, 50))
def test_cost_invariant_under_interval_refinement(seed, split):
    """Splitting a profile interval (same budgets) cannot change the cost."""
    plat, inst = _instance(12, seed)
    T = deadline_from_asap(inst, 1.3)
    prof = generate_profile("S2", T, plat, J=6, seed=seed)
    start = schedule(inst, prof, plat, "asap").start
    c0 = schedule_cost(inst, prof, start)
    # refine: split each interval at an interior point
    bounds = [int(prof.bounds[0])]
    budget = []
    for j in range(prof.J):
        b, e = int(prof.bounds[j]), int(prof.bounds[j + 1])
        mid = b + (split % max(e - b, 1))
        if b < mid < e:
            bounds += [mid, e]
            budget += [int(prof.budget[j])] * 2
        else:
            bounds += [e]
            budget += [int(prof.budget[j])]
    prof2 = PowerProfile(bounds=np.asarray(bounds, dtype=np.int64),
                         budget=np.asarray(budget, dtype=np.int64))
    assert schedule_cost(inst, prof2, start) == c0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 300))
def test_uniform_shift_into_identical_budget_is_neutral(seed):
    """With a constant profile, shifting the whole schedule right by k
    (within the horizon) keeps the carbon cost unchanged."""
    plat, inst = _instance(10, seed)
    D = deadline_from_asap(inst, 1.0)
    T = D + 40
    prof = PowerProfile(
        bounds=np.asarray([0, T], dtype=np.int64),
        budget=np.asarray([plat.idle_total + 100], dtype=np.int64))
    start = schedule(inst, prof, plat, "asap").start
    c0 = schedule_cost(inst, prof, start)
    for k in (1, 7, 40):
        if (start + inst.dur + k).max() <= T:
            assert schedule_cost(inst, prof, start + k) == c0
