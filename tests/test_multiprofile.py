"""Multi-profile replanning engine: graph/overlay split, multi==loop per
engine, longest-path relaxation identity, jnp gain twin, LS termination
parity, CarbonGate ensemble planning."""
import numpy as np
import pytest

from repro.cluster import make_cluster
from repro.core import (
    PORTFOLIO_VARIANTS,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    overlay_profile,
    portfolio_cost_matrix,
    prepare_graph,
    prepare_instance,
    schedule_portfolio,
    schedule_portfolio_multi,
)
from repro.workflows import make_workflow


def _setup(kind="eager", samples=3, seed=3, factor=1.5, scenario="S3"):
    plat = make_cluster(1, seed=seed)
    wf = make_workflow(kind, samples, seed=seed)
    inst = build_instance(wf, heft_mapping(wf, plat), plat)
    T = deadline_from_asap(inst, factor)
    prof = generate_profile(scenario, T, plat, J=16, seed=seed)
    return plat, inst, prof


def _ensemble(plat, T, n, scenario="S3", seed0=100, J=16):
    return [generate_profile(scenario, T, plat, J=J, seed=seed0 + i)
            for i in range(n)]


def test_graph_plus_overlay_bit_identical_to_prepare_instance():
    """Property: prepare_graph(inst) + overlay(profile_i) reproduces every
    field of prepare_instance(inst, profile_i) exactly, for N random
    profiles over one graph."""
    plat, inst, prof = _setup()
    graph = prepare_graph(inst, plat, prof.T)
    rng = np.random.default_rng(0)
    for i in range(6):
        scen = ("S1", "S2", "S3", "S4")[int(rng.integers(4))]
        p = generate_profile(scen, prof.T, plat, J=int(rng.integers(4, 40)),
                             seed=int(rng.integers(1 << 16)))
        split = overlay_profile(graph, p)
        ref = prepare_instance(inst, p, plat)
        assert (graph.est0 == ref.est0).all()
        assert (graph.lst0 == ref.lst0).all()
        assert graph.feasible == ref.feasible
        for sc in ("slack", "press"):
            for wt in (False, True):
                assert (graph.order_for(sc, wt)
                        == ref.graph.order_for(sc, wt)).all()
        for r in (False, True):
            assert (split.masks[r] == ref.masks[r]).all()
            assert (split.segs[r][0] == ref.segs[r][0]).all()
            assert (split.segs[r][1] == ref.segs[r][1]).all()
        assert (split.unit_budget == ref.ls["unit_budget"]).all()
        assert split.ls["visit"] == ref.ls["visit"]


def test_overlay_rejects_horizon_mismatch():
    plat, inst, prof = _setup()
    graph = prepare_graph(inst, plat, prof.T)
    bad = generate_profile("S1", prof.T + 7, plat, J=8, seed=0)
    with pytest.raises(ValueError):
        overlay_profile(graph, bad)


@pytest.mark.parametrize(
    "engine", ["numpy", pytest.param("jax", marks=pytest.mark.device)])
def test_multi_matches_per_profile_loop(engine):
    plat, inst, prof = _setup(samples=2, seed=1)
    profs = _ensemble(plat, prof.T, 4)
    multi = schedule_portfolio_multi(inst, profs, plat, engine=engine)
    assert len(multi) == len(profs)
    for p, res in zip(profs, multi):
        ref = schedule_portfolio(inst, p, plat, engine=engine)
        for name in PORTFOLIO_VARIANTS:
            assert (res[name].start == ref[name].start).all(), name
            assert res[name].cost == ref[name].cost, name


def test_multi_empty_profiles():
    plat, inst, prof = _setup(samples=2, seed=0)
    assert schedule_portfolio_multi(inst, [], plat) == []


def test_cost_matrix_and_robust_pick():
    plat, inst, prof = _setup(samples=2, seed=5)
    profs = _ensemble(plat, prof.T, 3)
    res = schedule_portfolio_multi(inst, profs, plat)
    costs, names = portfolio_cost_matrix(res)
    assert costs.shape == (3, len(PORTFOLIO_VARIANTS))
    for pi, r in enumerate(res):
        for vi, n in enumerate(names):
            assert costs[pi, vi] == r[n].cost
    worst = costs.max(axis=0)
    pick = int(worst.argmin())
    assert worst[pick] <= worst.min(initial=np.iinfo(np.int64).max)


def test_longest_path_matrix_matches_worklist_relaxation():
    """The device greedy's closed-form EST update (max over placed
    ancestors of start + lp) equals the reference worklist fixpoint after
    every placement prefix."""
    from repro.core.estlst import compute_est
    from repro.core.greedy_jax import NEG_PATH, longest_path_matrix

    plat, inst, prof = _setup(kind="bacass", samples=2, seed=7)
    lp = longest_path_matrix(inst)
    N = inst.num_tasks
    # direct edges: lp dominates every edge bound
    for v in range(N):
        for u in inst.preds(v):
            assert lp[u, v] >= inst.dur[u]
    rng = np.random.default_rng(1)
    est = compute_est(inst).copy()
    start_fixed = np.zeros(N, dtype=np.int64)
    fixed = np.zeros(N, dtype=bool)
    est_inc = est.astype(np.int64).copy()
    for v in inst.topo:                   # place in topo order, random slack
        s = int(est_inc[v] + rng.integers(0, 5))
        start_fixed[v] = s
        fixed[v] = True
        # incremental closed-form update
        row = lp[v].astype(np.int64)
        upd = np.where(row > NEG_PATH // 2, s + row, est_inc)
        est_inc = np.maximum(est_inc, upd)
        # reference: full fixpoint with placed tasks pinned
        ref = compute_est(inst, start_fixed, fixed)
        unplaced = ~fixed
        assert (est_inc[unplaced] == ref[unplaced]).all()


@pytest.mark.device
def test_gains_jnp_twin_matches_pallas_interpreter():
    from repro.kernels.ops import ls_gains, ls_gains_batched

    rng = np.random.default_rng(2)
    N, T, mu = 70, 200, 9
    rem = rng.integers(-40, 50, T).astype(np.float32)
    dur = rng.integers(1, 14, N).astype(np.float32)
    work = rng.integers(0, 30, N).astype(np.float32)
    start = rng.integers(0, T - 16, N).astype(np.float32)
    lo = np.maximum(start - rng.integers(0, mu + 4, N), 0).astype(np.float32)
    hi = np.minimum(start + rng.integers(0, mu + 4, N),
                    T - dur).astype(np.float32)
    jnp_path = np.asarray(ls_gains(rem, start, dur, work, lo, hi, mu=mu,
                                   interpret=None))
    pallas = np.asarray(ls_gains(rem, start, dur, work, lo, hi, mu=mu,
                                 interpret=True))
    np.testing.assert_array_equal(jnp_path, pallas)
    # batched twin
    rem2 = np.stack([rem, np.roll(rem, 11)])
    start2 = np.stack([start, start])
    lo2, hi2 = np.stack([lo, lo]), np.stack([hi, hi])
    a = np.asarray(ls_gains_batched(rem2, start2, dur, work, lo2, hi2,
                                    mu=mu, interpret=None))
    b = np.asarray(ls_gains_batched(rem2, start2, dur, work, lo2, hi2,
                                    mu=mu, interpret=True))
    np.testing.assert_array_equal(a, b)


@pytest.mark.device
def test_portfolio_ls_no_earlier_termination_than_sequential():
    """Every -LS row of the batched climber ends at a state the sequential
    reference cannot improve: one extra reference round is a no-op."""
    from repro.core.local_search import local_search

    plat, inst, prof = _setup(samples=3, seed=4, factor=2.0, scenario="S1")
    res = schedule_portfolio(inst, prof, plat, engine="jax")
    for name in PORTFOLIO_VARIANTS:
        if not name.endswith("-LS"):
            continue
        polished = local_search(inst, prof, plat, res[name].start,
                                max_rounds=1)
        assert (polished == res[name].start).all(), name


@pytest.mark.device
def test_portfolio_ls_monotone_per_row():
    plat, inst, prof = _setup(samples=3, seed=4, factor=2.0, scenario="S1")
    from repro.core import schedule_cost, validate_schedule
    res = schedule_portfolio(inst, prof, plat, engine="jax")
    for name in PORTFOLIO_VARIANTS:
        if not name.endswith("-LS"):
            continue
        base = res[name[:-3]]
        validate_schedule(inst, prof, res[name].start)
        assert res[name].cost <= base.cost, name


def test_carbon_gate_ensemble_plans_robust_variant():
    from repro.runtime.carbon_gate import CarbonGate, fleet_platform

    plat = fleet_platform(pods=2, chip_watts_idle=10, chip_watts_work=25,
                          chips_per_pod=4)
    chunk = [[7, 9, 6, 8, 7, 9], [8, 8, 9, 7, 6, 6]]
    horizon = int(2.5 * max(sum(c) for c in chunk))
    profs = [generate_profile("S3", horizon, plat, J=24, seed=5 + i,
                              work_capacity=int(plat.p_work[:2].sum()))
             for i in range(4)]
    gate = CarbonGate(profs[0], plat, variant="auto", profiles=profs[1:],
                      engine="numpy")
    plan = gate.make_plan(chunk, barriers=[2])
    assert plan.variant in plan.variant_names and plan.variant != "asap"
    assert plan.cost_matrix.shape[0] == 4
    vi = plan.variant_names.index(plan.variant)
    heur = [i for i, n in enumerate(plan.variant_names) if n != "asap"]
    worst = plan.cost_matrix[:, heur].max(axis=0)
    assert plan.robust_cost == plan.cost_matrix[:, vi].max() == worst.min()
    assert plan.cost <= plan.asap_cost
    # the plan's start/cost are the nominal profile's, for the chosen variant
    from repro.core import schedule
    ref = schedule(plan.instance, profs[0], plat, plan.variant)
    assert plan.cost == ref.cost


def test_carbon_gate_pinned_asap_baseline():
    """Regression: a gate pinned to the asap baseline must still plan
    (robust_pick falls back to asap when it is the only variant)."""
    from repro.runtime.carbon_gate import CarbonGate, fleet_platform

    plat = fleet_platform(pods=1, chip_watts_idle=10, chip_watts_work=25,
                          chips_per_pod=4)
    chunk = [[7, 9, 6, 8]]
    horizon = int(3 * sum(chunk[0]))
    prof = generate_profile("S1", horizon, plat, J=16, seed=2,
                            work_capacity=int(plat.p_work[:1].sum()))
    plan = CarbonGate(prof, plat, variant="asap").make_plan(chunk)
    assert plan.variant == "asap"
    assert plan.cost == plan.asap_cost


def test_carbon_gate_single_profile_back_compat():
    from repro.runtime.carbon_gate import CarbonGate, fleet_platform

    plat = fleet_platform(pods=1, chip_watts_idle=10, chip_watts_work=25,
                          chips_per_pod=4)
    chunk = [[7, 9, 6, 8]]
    horizon = int(3 * sum(chunk[0]))
    prof = generate_profile("S1", horizon, plat, J=16, seed=2,
                            work_capacity=int(plat.p_work[:1].sum()))
    gate = CarbonGate(prof, plat, variant="pressWR-LS")
    plan = gate.make_plan(chunk)
    from repro.core import schedule
    ref = schedule(plan.instance, prof, plat, "pressWR-LS")
    assert (plan.start == ref.start).all()
    assert plan.cost == ref.cost and plan.variant == "pressWR-LS"
