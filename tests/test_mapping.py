"""The mapping subsystem: HEFT seeding, joint mapping x scheduling search,
request validation on the mapping axis, and the serving-tier integration
(`make test-mapping`; part of `make verify`)."""
import numpy as np
import pytest

from repro.api import MAPPING_MODES, Planner, PlanRequest, PlanResult
from repro.api.request import validate_resolved
from repro.cluster import make_cluster
from repro.core import (build_instance, deadline_from_asap, generate_profile,
                        heft_mapping, schedule_cost, trivial_mapping)
from repro.core.cancel import Cancelled, CancelToken
from repro.mapping import (MappingOptions, critical_path, heft_generic,
                           mapping_from_assignment, neighborhood,
                           rank_priority, upward_ranks)
from repro.workflows import Workflow, make_workflow


@pytest.fixture(scope="module")
def platform():
    return make_cluster(1, seed=0)       # 6 compute procs, one per type


def _diamond():
    """A hand-checkable 4-task diamond: 0 -> {1, 2} -> 3."""
    return Workflow(
        name="diamond",
        node_w=np.array([8, 16, 4, 8], dtype=np.int64),
        edges=np.array([[0, 1], [0, 2], [1, 3], [2, 3]], dtype=np.int64),
        edge_w=np.array([2, 3, 4, 5], dtype=np.int64))


def _scarce_profile(platform, T, seed=2, cap=40):
    return generate_profile("S3", T, platform, J=12, seed=seed,
                            work_capacity=cap)


# ---------------------------------------------------------------------------
# core/heft.py direct unit tests (satellite: the stranded seed algorithm)
# ---------------------------------------------------------------------------

class TestHeft:
    def test_upward_ranks_hand_computed(self, platform):
        wf = _diamond()
        exec_t = np.maximum(
            np.ceil(wf.node_w[:, None] / platform.speed[None, :]), 1)
        mean = exec_t.mean(axis=1)
        rank = upward_ranks(wf, mean)
        # sink first: rank[3] = mean[3]; then its predecessors
        assert rank[3] == pytest.approx(mean[3])
        assert rank[1] == pytest.approx(mean[1] + 4 + rank[3])
        assert rank[2] == pytest.approx(mean[2] + 5 + rank[3])
        assert rank[0] == pytest.approx(
            mean[0] + max(2 + rank[1], 3 + rank[2]))
        # ranks strictly decrease along every edge (priority is topological)
        for u, v in wf.edges:
            assert rank[u] > rank[v]

    def test_heft_mapping_valid_and_deterministic(self, platform):
        wf = make_workflow("bacass", 2, seed=5)
        m1 = heft_mapping(wf, platform)
        m2 = heft_mapping(wf, platform)
        assert np.array_equal(m1.proc, m2.proc)
        assert m1.order == m2.order and m1.comm_order == m2.comm_order
        # every task mapped on a real compute processor, orders partition
        assert (m1.proc >= 0).all() and (m1.proc < platform.num_compute).all()
        assert sorted(t for o in m1.order for t in o) == list(range(wf.n))
        build_instance(wf, m1, platform)     # asserts G_c acyclic

    def test_eft_insertion_fills_hole(self):
        """The insertion policy schedules a late-ranked short task into an
        earlier idle hole of the busy processor instead of appending."""
        from repro.cluster import Platform

        plat = Platform(speed=np.array([1, 4], dtype=np.int64),
                        p_idle=np.zeros(4, dtype=np.int64),
                        p_work=np.ones(4, dtype=np.int64),
                        type_of=np.zeros(2, dtype=np.int64))
        # ranks (mean exec): 0 -> 16, 1 -> 10, 2 -> 5, so HEFT schedules
        # 0, 1, 2.  Task 0 lands on p0 at [0,1); the 0->1 comm (cw=5)
        # delays task 1 on p1 to [6,10), leaving a [0,6) hole there.
        # Independent task 2 (exec 2 on p1) must be *inserted* into that
        # hole (eft 2) rather than take p0's append slot (eft 9).
        wf = Workflow(name="hole",
                      node_w=np.array([1, 16, 8], dtype=np.int64),
                      edges=np.array([[0, 1]], dtype=np.int64),
                      edge_w=np.array([5], dtype=np.int64))
        m = heft_mapping(wf, plat)
        assert tuple(m.proc) == (0, 1, 1)
        # order on p1 reflects insertion: task 2 at [0,2) before 1 at [6,10)
        assert m.order[0] == (0,)
        assert m.order[1] == (2, 1)
        inst = build_instance(wf, m, plat)
        assert inst.num_tasks == 4           # cross-proc edge adds a comm task

    def test_heft_generic_defaults_match_heft(self, platform):
        wf = make_workflow("eager", 2, seed=3)
        a = heft_mapping(wf, platform)
        b = heft_generic(wf, platform)
        assert np.array_equal(a.proc, b.proc)
        assert a.order == b.order and a.comm_order == b.comm_order

    def test_heft_generic_allowed_restricts(self, platform):
        wf = make_workflow("atacseq", 2, seed=3)
        slow = platform.speed <= np.median(platform.speed)
        m = heft_generic(wf, platform, allowed=slow)
        assert set(np.unique(m.proc)) <= set(np.flatnonzero(slow))


# ---------------------------------------------------------------------------
# moves: canonical assignment completion + neighborhood
# ---------------------------------------------------------------------------

class TestMoves:
    def test_assignment_completion_always_acyclic(self, platform):
        wf = make_workflow("methylseq", 2, seed=7)
        priority = rank_priority(wf, platform)
        rng = np.random.default_rng(0)
        for _ in range(20):
            proc = rng.integers(platform.num_compute, size=wf.n)
            m = mapping_from_assignment(wf, platform, proc, priority)
            build_instance(wf, m, platform)  # asserts acyclicity of G_c

    def test_critical_path_is_a_path(self, platform):
        wf = make_workflow("eager", 2, seed=1)
        proc = heft_mapping(wf, platform).proc
        path = critical_path(wf, platform, proc)
        assert len(path) >= 1
        edge_set = {(int(u), int(v)) for u, v in wf.edges}
        for a, b in zip(path[:-1], path[1:]):
            assert (a, b) in edge_set

    def test_neighborhood_deterministic_and_perturbing(self, platform):
        wf = make_workflow("bacass", 2, seed=2)
        base = heft_mapping(wf, platform).proc
        out1 = neighborhood(wf, platform, [base],
                            np.random.default_rng(9), 9)
        out2 = neighborhood(wf, platform, [base],
                            np.random.default_rng(9), 9)
        assert len(out1) == 9
        for (k1, v1), (k2, v2) in zip(out1, out2):
            assert k1 == k2 and np.array_equal(v1, v2)
        kinds = {k for k, _ in out1}
        assert kinds == {"reassign", "swap", "migrate"}
        assert all(not np.array_equal(v, base) for _, v in out1)


# ---------------------------------------------------------------------------
# request validation on the mapping axis
# ---------------------------------------------------------------------------

class TestValidation:
    def test_mapping_modes_constant(self):
        assert MAPPING_MODES == ("fixed", "heft", "search")

    def test_unknown_mapping_rejected(self, platform):
        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 300)
        with pytest.raises(ValueError, match="unknown mapping"):
            PlanRequest(instances=wf, profiles=prof,
                        mapping="bogus").resolve()

    @pytest.mark.parametrize("bad", [
        {"nope": 1},                      # unknown key
        {"seeds": 0},                     # below bound
        {"rounds": -1},
        {"objective": "fastest"},         # unknown objective
        {"seeds": "many"},                # wrong type
        "not-a-dict",
    ])
    def test_malformed_mapping_options_rejected(self, platform, bad):
        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 300)
        with pytest.raises(ValueError, match="mapping_options"):
            PlanRequest(instances=wf, profiles=prof, mapping="search",
                        mapping_options=bad).resolve()

    def test_mapping_options_require_mapping_mode(self, platform, medium_instance):
        prof = _scarce_profile(platform, 400)
        with pytest.raises(ValueError, match="mapping_options"):
            PlanRequest(instances=medium_instance, profiles=prof,
                        mapping_options={"seeds": 3}).resolve()

    def test_instances_rejected_in_mapping_mode(self, platform,
                                                medium_instance):
        prof = _scarce_profile(platform, 400)
        with pytest.raises(TypeError, match="Workflow"):
            PlanRequest(instances=medium_instance, profiles=prof,
                        mapping="heft").resolve()

    def test_deadline_scale_accepted_in_mapping_mode(self, platform):
        """Regression: deadline_scale used to raise ValueError outright
        in mapping modes; it now resolves cleanly (the HEFT-referenced
        horizon crop happens later, in resolve_mappings) and the grid
        passes through resolve() uncropped."""
        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 300)
        for mode in ("heft", "search"):
            insts, grid, _ = PlanRequest(
                instances=wf, profiles=prof, mapping=mode,
                deadline_scale=1.5).resolve()   # InvalidRequest no more
            assert insts == [wf]
            assert grid[0][0].T == prof.T       # crop deferred to mapping

    def test_deadline_scale_crops_via_reference_heft(self, platform):
        """In mapping modes the deadline is scale x ASAP(HEFT): every
        produced schedule meets the HEFT-referenced deadline, which is a
        real crop of the supplied forecast."""
        from repro.core.estlst import makespan

        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 600)
        ref = build_instance(wf, heft_mapping(wf, platform), platform,
                             name="ref")
        scale = 2.0
        want_T = deadline_from_asap(ref, scale)
        assert want_T < prof.T                  # the crop is real
        planner = Planner(platform, engine="numpy")
        for mode in ("heft", "search"):
            res = planner.plan(PlanRequest(
                instances=wf, profiles=prof, mapping=mode,
                deadline_scale=scale,
                mapping_options=None if mode == "heft" else
                {"seeds": 3, "rounds": 1, "neighbors": 4}))
            assert res.mapping_info[0].mode == mode
            inst = build_instance(wf, res.mappings[0], platform)
            for r in res.results[0][0].values():
                assert makespan(inst, r.start) <= want_T

    def test_structured_invalid_request_at_admission(self, platform):
        from repro.serve import InvalidRequest, PlanService

        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 300)
        svc = PlanService(Planner(platform, engine="numpy"))
        try:
            for kw in ({"mapping": "bogus"},
                       {"mapping": "search",
                        "mapping_options": {"elite": 0}}):
                with pytest.raises(InvalidRequest) as ei:
                    svc.submit(PlanRequest(instances=wf, profiles=prof,
                                           **kw))
                assert ei.value.details["reason"]   # structured error
        finally:
            svc.close()

    def test_validate_resolved_workflow_branch(self, platform):
        prof = _scarce_profile(platform, 300)
        cyclic = Workflow(name="cycle",
                          node_w=np.array([5, 5], dtype=np.int64),
                          edges=np.array([[0, 1], [1, 0]], dtype=np.int64),
                          edge_w=np.array([1, 1], dtype=np.int64))
        with pytest.raises(ValueError, match="cycle"):
            validate_resolved([cyclic], [[prof]])
        deep = Workflow(name="chain",
                        node_w=np.ones(9, dtype=np.int64),
                        edges=np.array([[i, i + 1] for i in range(8)],
                                       dtype=np.int64),
                        edge_w=np.zeros(8, dtype=np.int64))
        short = generate_profile("S4", 4, platform, J=2, seed=0)
        with pytest.raises(ValueError, match="depth"):
            validate_resolved([deep], [[short]])
        ok = make_workflow("bacass", 2, seed=0)
        validate_resolved([ok], [[prof]])    # no raise


# ---------------------------------------------------------------------------
# joint-search quality + reproducibility
# ---------------------------------------------------------------------------

class TestSearchQuality:
    @pytest.fixture(scope="class")
    def setup(self, platform):
        wf = make_workflow("bacass", 2, seed=1)
        # horizon roomy for HEFT (3x its ASAP) yet tight for the naive
        # round-robin comparison mapping (1.1x its much larger ASAP) —
        # feasible for every seed, but the naive mapping has no slack to
        # chase green windows, so the quality chain is strict
        inst_h = build_instance(wf, heft_mapping(wf, platform), platform)
        fixed = build_instance(wf, trivial_mapping(wf, platform), platform)
        T = max(deadline_from_asap(inst_h, 3.0),
                int(deadline_from_asap(fixed, 1.0) * 1.1))
        prof = _scarce_profile(platform, T)
        planner = Planner(platform, engine="numpy")
        return wf, prof, planner

    def test_search_beats_heft_beats_fixed_seed(self, platform, setup):
        wf, prof, planner = setup
        fixed = build_instance(wf, trivial_mapping(wf, platform), platform)
        res_f = planner.plan(PlanRequest(instances=fixed, profiles=prof))
        res_h = planner.plan(PlanRequest(instances=wf, profiles=prof,
                                         mapping="heft"))
        res_s = planner.plan(PlanRequest(
            instances=wf, profiles=prof, mapping="search",
            mapping_options={"seeds": 6, "rounds": 3, "neighbors": 9,
                             "seed": 0}))
        assert res_s.best().cost <= res_h.best().cost <= res_f.best().cost
        info = res_s.mapping_info[0]
        assert info.mode == "search" and info.candidates >= 6
        assert info.trace == tuple(sorted(info.trace, reverse=True))
        assert res_s.best().cost == info.trace[-1] == min(
            info.candidate_costs)
        # the winning mapping's instance really costs what the result says
        inst_w = build_instance(wf, res_s.mappings[0], platform)
        best = res_s.best()
        assert schedule_cost(inst_w, prof, best.start) == best.cost

    def test_search_bit_reproducible(self, platform, setup):
        wf, prof, planner = setup
        req = PlanRequest(instances=wf, profiles=prof, mapping="search",
                          mapping_options={"seeds": 5, "rounds": 2,
                                           "neighbors": 6, "seed": 42})
        a = planner.plan(req)
        b = Planner(platform, engine="numpy").plan(req)
        assert np.array_equal(a.mappings[0].proc, b.mappings[0].proc)
        assert a.mapping_info[0].trace == b.mapping_info[0].trace
        assert a.mapping_info[0].label == b.mapping_info[0].label
        assert np.array_equal(a.costs, b.costs)

    def test_fixed_mode_unchanged_vs_direct_solver(self, platform, setup):
        """mapping='fixed' results are bit-identical to the solver layer
        invoked directly — the pre-mapping plan path is untouched."""
        from repro.core.solvers import get_solver

        wf, prof, planner = setup
        inst = build_instance(wf, heft_mapping(wf, platform), platform)
        res = planner.plan(PlanRequest(instances=inst, profiles=prof))
        assert res.mapping_mode == "fixed"
        assert res.mappings is None and res.mapping_info is None
        out = get_solver("heuristic").solve_grid(
            [inst], [[prof]], platform, res.variants, k=planner.k,
            mu=planner.ls.mu, engine="numpy",
            graphs=[planner.prepared(inst, prof.T)],
            commit_k=planner.ls.commit_k)
        assert np.array_equal(res.costs, out.cost_tensor(res.variants))

    @pytest.mark.ilp
    def test_gap_vs_exact_under_searched_mapping(self, platform):
        pytest.importorskip("scipy.optimize", reason="needs scipy HiGHS")
        wf = make_workflow("bacass", 1, seed=0)
        inst_h = build_instance(wf, heft_mapping(wf, platform), platform)
        T = deadline_from_asap(inst_h, 2.0)
        prof = _scarce_profile(platform, T)
        planner = Planner(platform, engine="numpy")
        res = planner.plan(PlanRequest(
            instances=wf, profiles=prof, mapping="search",
            mapping_options={"seeds": 4, "rounds": 1, "neighbors": 4}))
        inst_w = build_instance(wf, res.mappings[0], platform)
        exact = planner.plan(PlanRequest(
            instances=inst_w, profiles=prof, solver="exact",
            solver_options={"time_limit": 60.0}))
        gap = res.gap(exact)
        assert gap.shape == (1, 1) and gap[0, 0] >= 1.0 - 1e-9

    def test_heft_mode_info_and_wire_round_trip(self, platform, setup):
        import json

        wf, prof, planner = setup
        res = planner.plan(PlanRequest(instances=wf, profiles=prof,
                                       mapping="heft"))
        assert res.mapping_mode == "heft"
        assert np.array_equal(res.mappings[0].proc,
                              heft_mapping(wf, platform).proc)
        d = res.summary_dict()
        back = PlanResult.summary_from_dict(json.loads(json.dumps(d)))
        assert back.summary_dict() == d
        assert back.mapping_mode == "heft"
        assert back.mapping_info[0].mode == "heft"


# ---------------------------------------------------------------------------
# serving tier: cancellation, degradation, coalescing
# ---------------------------------------------------------------------------

class TestServing:
    def test_cancel_token_stops_search(self, platform):
        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 400)
        token = CancelToken()
        token.cancel("test")
        with pytest.raises(Cancelled):
            Planner(platform, engine="numpy").plan(
                PlanRequest(instances=wf, profiles=prof, mapping="search"),
                cancel=token)

    def test_service_deadline_budget_degrades_search_to_heft(self, platform):
        """A deadline budget too small for the search walks the fallback
        chain; the terminal rung downgrades mapping='search' to 'heft'
        and still returns a feasible (degraded) plan."""
        from repro.serve import PlanService

        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 400)
        svc = PlanService(Planner(platform, engine="numpy"))
        try:
            res = svc.plan(PlanRequest(
                instances=wf, profiles=prof, mapping="search",
                mapping_options={"seeds": 8, "rounds": 6,
                                 "neighbors": 16}), budget=1e-6)
            assert res.degraded and res.fallback_stage == "asap"
            assert any(a.endswith((":timeout", ":skipped"))
                       for a in res.attempts)
            assert res.mapping_mode == "heft"      # downgraded rung
            assert "mapping:heft" in res.attempts  # decision is surfaced
            assert res.mappings is not None
        finally:
            svc.close()

    def test_service_search_matches_direct_plan(self, platform):
        from repro.serve import PlanService

        wf = make_workflow("bacass", 2, seed=3)
        prof = _scarce_profile(platform, 300)
        req = PlanRequest(instances=wf, profiles=prof, mapping="search",
                          mapping_options={"seeds": 4, "rounds": 1,
                                           "neighbors": 4, "seed": 7})
        direct = Planner(platform, engine="numpy").plan(req)
        svc = PlanService(Planner(platform, engine="numpy"))
        try:
            served = svc.plan(req)
        finally:
            svc.close()
        assert not served.degraded
        assert "mapping:search" in served.attempts
        assert np.array_equal(served.costs, direct.costs)
        assert np.array_equal(served.mappings[0].proc,
                              direct.mappings[0].proc)

    def test_mapping_modes_do_not_coalesce(self, platform):
        from repro.serve.service import Ticket

        wf = make_workflow("eager", 2, seed=0)
        prof = _scarce_profile(platform, 300)
        keys = []
        for kw in ({"mapping": "heft"},
                   {"mapping": "search"},
                   {"mapping": "search",
                    "mapping_options": {"seeds": 3}}):
            req = PlanRequest(instances=wf, profiles=prof, **kw)
            instances, grid, names = req.resolve()
            keys.append(Ticket(req, instances, grid, names, "numpy",
                               None)._coalesce_key())
        assert len(set(keys)) == 3

    def test_journal_replay_preserves_mapping(self, platform):
        from repro.serve.journal import decode_ticket, encode_ticket

        wf = make_workflow("methylseq", 2, seed=4)
        prof = _scarce_profile(platform, 300)
        state = encode_ticket(
            [wf], [[prof]], ("exact",), "exact", True, {"time_limit": 9.0},
            12.5, mapping="search", mapping_options={"seeds": 4})
        dec = decode_ticket(state)
        instances, grid, names, solver, robust, options, budget = dec
        assert isinstance(instances[0], Workflow)
        assert np.array_equal(instances[0].node_w, wf.node_w)
        assert np.array_equal(instances[0].edges, wf.edges)
        assert dec.mapping == "search"
        assert dec.mapping_options == {"seeds": 4}
        assert (solver, robust, budget) == ("exact", True, 12.5)


# ---------------------------------------------------------------------------
# budget-aware degradation: MappingOptions.shrunk_to + the serving tier
# ---------------------------------------------------------------------------

class TestShrunkTo:
    def test_identity_when_budget_fits(self):
        opts = MappingOptions(seeds=4, rounds=2, neighbors=5)
        assert opts.max_candidates() == 14
        assert opts.shrunk_to(14) is opts
        assert opts.shrunk_to(999) is opts

    def test_none_below_minimal_search(self):
        assert MappingOptions().shrunk_to(1) is None
        assert MappingOptions().shrunk_to(0) is None
        assert MappingOptions().shrunk_to(-3) is None

    def test_shrinks_rounds_then_neighbors_then_seeds(self):
        opts = MappingOptions(seeds=4, rounds=4, neighbors=10)   # 44 max
        mid = opts.shrunk_to(24)                 # rounds give first
        assert (mid.seeds, mid.neighbors, mid.rounds) == (4, 10, 2)
        tight = opts.shrunk_to(7)                # then neighbors
        assert (tight.seeds, tight.neighbors, tight.rounds) == (4, 3, 1)
        floor = opts.shrunk_to(2)                # finally seeds
        assert (floor.seeds, floor.rounds) == (2, 0)
        assert floor.elite <= floor.seeds        # elite stays valid

    def test_budget_respected_across_sweep(self):
        opts = MappingOptions(seeds=6, rounds=4, neighbors=12, elite=3,
                              seed=9, objective="robust")
        for budget in range(2, opts.max_candidates() + 1):
            s = opts.shrunk_to(budget)
            assert s.max_candidates() <= budget
            # reproducibility knobs survive the shrink
            assert s.seed == opts.seed and s.objective == opts.objective


class TestBudgetAwareFallback:
    """The serving tier's `_degrade_mapping`: fallback rungs shrink the
    search to what the remaining deadline budget affords (per-candidate
    EMA) instead of always dropping to HEFT."""

    @pytest.fixture()
    def svc(self, platform):
        from repro.serve import PlanService

        svc = PlanService(Planner(platform, engine="numpy"))
        yield svc
        svc.close()

    def test_shrinks_search_when_budget_affords(self, svc):
        svc._mapping_cand_ema = 1.0              # 1 s per candidate
        mode, opts = svc._degrade_mapping(
            "heuristic", "search",
            {"seeds": 6, "rounds": 4, "neighbors": 12},
            remaining=16.0, n_workflows=1)       # affords 16*0.5/1 = 8
        assert mode == "search"
        assert MappingOptions.from_dict(opts).max_candidates() <= 8
        assert svc.stats()["mapping_search_shrinks"] == 1
        assert svc.stats()["mapping_heft_downgrades"] == 0

    def test_drops_to_heft_when_nothing_fits(self, svc):
        svc._mapping_cand_ema = 1.0
        mode, opts = svc._degrade_mapping(
            "heuristic", "search", None,
            remaining=2.0, n_workflows=1)        # affords 1 < 2 candidates
        assert (mode, opts) == ("heft", None)
        assert svc.stats()["mapping_heft_downgrades"] == 1

    def test_batch_size_splits_the_budget(self, svc):
        svc._mapping_cand_ema = 1.0
        mode, _ = svc._degrade_mapping("heuristic", "search", None,
                                       remaining=16.0, n_workflows=1)
        assert mode == "search"
        # same budget across 8 coalesced workflows affords only 1 each
        mode, opts = svc._degrade_mapping("heuristic", "search", None,
                                          remaining=16.0, n_workflows=8)
        assert (mode, opts) == ("heft", None)

    def test_capped_without_deadline(self, svc):
        # error-triggered rung (no deadline pressure): small fixed cap
        mode, opts = svc._degrade_mapping(
            "heuristic", "search",
            {"seeds": 20, "rounds": 5, "neighbors": 20},
            remaining=None, n_workflows=1)
        assert mode == "search"
        assert MappingOptions.from_dict(opts).max_candidates() \
            <= svc._MAPPING_FALLBACK_CAP

    def test_terminal_asap_rung_always_heft(self, svc):
        mode, opts = svc._degrade_mapping("asap", "search", {"seeds": 3},
                                          remaining=1e9, n_workflows=1)
        assert (mode, opts) == ("heft", None)
        # non-search mappings pass straight through to heft too
        assert svc._degrade_mapping("heuristic", "heft", None, 50.0, 1) \
            == ("heft", None)

    def test_delivered_search_feeds_the_ema(self, svc, platform):
        wf = make_workflow("bacass", 2, seed=3)
        prof = _scarce_profile(platform, 300)
        assert svc._mapping_cand_ema is None
        res = svc.plan(PlanRequest(instances=wf, profiles=prof,
                                   mapping="search",
                                   mapping_options={"seeds": 3,
                                                    "rounds": 1,
                                                    "neighbors": 3}))
        assert res.mapping_info[0].mode == "search"
        assert svc._mapping_cand_ema is not None
        assert svc._mapping_cand_ema > 0.0


# ---------------------------------------------------------------------------
# batched grid launch: candidates ride the cached compile
# ---------------------------------------------------------------------------

@pytest.mark.device
def test_candidate_batch_adds_no_jit_cache_misses(platform):
    """Steady state, growing the candidate count adds ZERO new compiled
    signatures: every candidate mapping lands in the same padded shape
    bucket of the triple-vmapped launch."""
    wf = make_workflow("bacass", 2, seed=1)
    inst_h = build_instance(wf, heft_mapping(wf, platform), platform)
    T = min(deadline_from_asap(inst_h, 3.0), 250)   # stay in one T bucket
    prof = _scarce_profile(platform, T)
    planner = Planner(platform, engine="jax")
    # warm: compile the bucket once with a small candidate batch
    planner.plan(PlanRequest(
        instances=wf, profiles=[prof, prof], mapping="search",
        mapping_options={"seeds": 3, "rounds": 1, "neighbors": 3}))
    # steady: more than twice the candidates through the same bucket
    res = planner.plan(PlanRequest(
        instances=wf, profiles=[prof, prof], mapping="search",
        mapping_options={"seeds": 6, "rounds": 2, "neighbors": 8,
                         "seed": 1}))
    info = res.mapping_info[0]
    assert info.candidates > 8
    assert sum(info.cache_misses) == 0, (
        f"candidate fan-out retraced: {info.cache_misses}")


@pytest.mark.device
def test_padded_candidate_batch_counts_real_candidates_only(platform):
    """The jax evaluator pads each candidate batch to the 8-wide shape
    bucket by repeating the last candidate BY IDENTITY.  The portfolio
    layer must alias the pad rows' host-side work (dedupe counter moves)
    and the search provenance must count only real candidates — the pad
    never leaks into `candidates` / `candidate_costs`."""
    from repro import obs

    wf = make_workflow("eager", 2, seed=2)
    inst_h = build_instance(wf, heft_mapping(wf, platform), platform)
    T = deadline_from_asap(inst_h, 3.0)
    prof = _scarce_profile(platform, T)
    before = obs.registry().value("portfolio_rows_deduped_total")
    res = Planner(platform, engine="jax").plan(PlanRequest(
        instances=wf, profiles=prof, mapping="search",
        mapping_options={"seeds": 3, "rounds": 0}))
    info = res.mapping_info[0]
    assert info.mode == "search"
    assert 1 <= info.candidates <= 3         # seeds only — pad rows excluded
    assert len(info.candidate_costs) == info.candidates
    assert len(info.candidate_labels) == info.candidates
    after = obs.registry().value("portfolio_rows_deduped_total")
    # the 8-bucket's >= 5 pad rows were recognized as identity repeats
    assert after - before >= 8 - info.candidates
