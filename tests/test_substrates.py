"""Checkpoint/restart, fault injection, stragglers, data determinism,
sharding specs, roofline parsing, carbon gate."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.checkpoint.ckpt import latest_checkpoint
from repro.configs import ARCHS, SHAPES, reduced
from repro.configs.base import ShapeConfig
from repro.core import generate_profile
from repro.data import SyntheticTokens, make_batch_iter
from repro.models import build_model
from repro.roofline.analysis import collective_bytes, roofline_terms
from repro.runtime import FailureInjector, StragglerMonitor, run_with_restarts
from repro.runtime.carbon_gate import CarbonGate, fleet_platform
from repro.runtime.elastic import rebuild_mesh, remesh_plan
from repro.runtime.fault import SimulatedFailure
from repro.sharding.specs import param_spec, tree_param_specs
from repro.train.step import init_state, make_train_step


def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
                        "b": {"c": np.ones(4, dtype=np.int32)}},
             "opt": {"step": np.asarray(7)}}
    p = save_checkpoint(state, 7, str(tmp_path))
    got, step = load_checkpoint(p, like=state)
    assert step == 7
    np.testing.assert_array_equal(got["params"]["a"], state["params"]["a"])
    np.testing.assert_array_equal(got["params"]["b"]["c"],
                                  state["params"]["b"]["c"])


def test_checkpoint_rotation(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    st = {"x": np.zeros(3)}
    for s in range(5):
        mgr.maybe_save(st, s)
    cands = sorted(d for d in os.listdir(tmp_path) if d.startswith("ckpt_"))
    assert len(cands) == 2
    assert latest_checkpoint(str(tmp_path)).endswith("ckpt_00000004")


def test_fault_tolerant_training_resumes(tmp_path):
    """Injected failures + restart: training completes all steps and the
    final state equals an uninterrupted run (deterministic data)."""
    r = reduced(ARCHS["smollm-360m"])
    m = build_model(r, tp=16)
    shape = ShapeConfig("tiny", "train", 16, 4)
    src = SyntheticTokens(r, shape, seed=5)
    step_fn = jax.jit(make_train_step(m, microbatches=1))
    total = 8

    def make_train(injector):
        def train(state, start, stop):
            for s in range(start, stop):
                if injector is not None:
                    injector.maybe_fail(s)
                state, _ = step_fn(state, src.batch(s))
                mgr.maybe_save(state, s)
            return state
        return train

    # uninterrupted reference
    ref_state = init_state(m, jax.random.PRNGKey(0))
    for s in range(total):
        ref_state, _ = step_fn(ref_state, src.batch(s))

    mgr = CheckpointManager(str(tmp_path), keep=2, every=1)
    inj = FailureInjector(prob_per_step=0.35, seed=3)
    state, done, restarts = run_with_restarts(
        make_train(inj), mgr, lambda: init_state(m, jax.random.PRNGKey(0)),
        total, max_restarts=50)
    assert done == total
    assert restarts > 0, "test should exercise at least one restart"
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_data_determinism():
    r = reduced(ARCHS["qwen1.5-0.5b"])
    shape = ShapeConfig("tiny", "train", 8, 2)
    a = SyntheticTokens(r, shape, seed=1).batch(42)
    b = SyntheticTokens(r, shape, seed=1).batch(42)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(r, shape, seed=2).batch(42)
    assert (a["tokens"] != c["tokens"]).any()


def test_batch_iter_prefetch():
    r = reduced(ARCHS["qwen1.5-0.5b"])
    shape = ShapeConfig("tiny", "train", 8, 2)
    it = make_batch_iter(SyntheticTokens(r, shape, seed=1), start_step=3)
    s0, b0 = next(it)
    s1, b1 = next(it)
    it.close()
    assert (s0, s1) == (3, 4)
    assert b0["tokens"].shape == (2, 8)


def test_straggler_monitor():
    mon = StragglerMonitor(n_pods=2, evict_after=3)
    for _ in range(20):
        assert mon.observe(0, 1.0).action == "ok"
        mon.observe(1, 1.0)
    acts = [mon.observe(1, 3.0).action for _ in range(4)]
    assert "rebalance" in acts
    assert acts[-1] == "evict"


def test_elastic_remesh_plan():
    plan = remesh_plan(old_pods=2, lost_pods=1)
    assert plan.mesh_shape == (16, 16)
    assert plan.microbatch_scale == 2
    # rebuild on this host's devices is impossible (1 device) -> assert guard
    with pytest.raises(AssertionError):
        rebuild_mesh(plan, devices=jax.devices())


def test_carbon_gate_plans_greener_than_asap():
    plat = fleet_platform(pods=2, chip_watts_idle=100, chip_watts_work=250,
                          chips_per_pod=4)
    # horizon: chunks of ~30s each, 20 per pod; deadline 3x
    chunks = [[30] * 12, [30] * 12]
    total = 3 * 12 * 30
    prof = generate_profile("S1", total, plat, J=24, seed=0)
    gate = CarbonGate(prof, plat, variant="pressWR-LS")
    plan = gate.make_plan(chunks, barriers=[5])
    assert plan.cost <= plan.asap_cost
    # chunk starts respect chain order
    for pod in range(2):
        chain = plan.instance.proc_chains[pod]
        st = plan.start[list(chain)]
        dur = plan.instance.dur[list(chain)]
        assert ((st[1:] - (st[:-1] + dur[:-1])) >= 0).all()
    assert gate.wait_time(0, 0, now=0.0) >= 0.0


def test_roofline_parser_and_terms():
    hlo = """
  %all-reduce.1 = f32[256,128]{1,0} all-reduce(f32[256,128]{1,0} %x), replica_groups={}
  %all-gather.2 = bf16[64,1024]{1,0} all-gather(%fusion.7), dimensions={0}
  %rs = f32[32]{0} reduce-scatter(f32[512]{0} %y), dimensions={0}
  %cp = collective-permute(bf16[8,8]{1,0} %z), source_target_pairs={{0,1}}
  %ar-start = f32[16]{0} all-reduce-start(f32[16]{0} %w)
  %ar-done = f32[16]{0} all-reduce-done(%ar-start)
"""
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 256 * 128 * 4 + 16 * 4
    assert cb["all-gather"] == 64 * 1024 * 2      # result fallback
    assert cb["reduce-scatter"] == 512 * 4
    assert cb["collective-permute"] == 8 * 8 * 2
    assert cb["counts"]["all-reduce"] == 2
    terms = roofline_terms(1e15, 1e13, 1e9, chips=256)
    assert terms["compute_s"] == pytest.approx(1e15 / (256 * 197e12))
    assert terms["dominant"] in ("compute", "memory", "collective")


def test_param_specs_rules():
    tp, ds = 16, 16
    # attention heads shard when divisible
    assert param_spec("attn/wq", (32, 3584, 32, 128), tp, ds)[2] == "model"
    # fsdp picks a large remaining axis
    s = param_spec("attn/wq", (32, 3584, 32, 128), tp, ds)
    assert "data" in s
    # non-divisible heads replicate
    s2 = param_spec("blocks/mlstm/wq", (10, 768, 4, 192), tp, ds)
    assert s2[2] is None
    # moe experts shard on E
    s3 = param_spec("moe/w1", (24, 32, 1024, 512), tp, ds)
    assert s3[1] == "model"
    # norms replicate fully
    assert all(a is None for a in param_spec("ln1", (32, 960), tp, ds))


def test_tree_specs_cover_all_archs():
    for name, cfg in ARCHS.items():
        r = reduced(cfg)
        m = build_model(r, tp=16)
        params = jax.eval_shape(m.init, jax.random.PRNGKey(0))
        specs = tree_param_specs(params, 16, 16)
        flat_p = jax.tree.leaves(params)
        flat_s = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "index"))
        assert len(flat_p) == len(flat_s)
