# Tier-1: the correctness gate — must stay NO WORSE than the seed
# baseline (tests/test_dryrun_machinery.py and tests/test_pipeline.py fail
# since the seed commit: the installed jax lacks `jax.lax.axis_size` /
# changed `cost_analysis()`; everything else must pass).
# Tier-2: cheap perf smoke for PRs touching the hot paths — refreshes
# benchmarks/out/BENCH_portfolio.json on a tiny matrix in <60s.

PY := PYTHONPATH=src python

.PHONY: test bench bench-smoke

test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run --only portfolio

bench-smoke:
	$(PY) -m benchmarks.run --only portfolio --smoke
