# Tier-1: the correctness gate — must stay NO WORSE than the seed
# baseline. (The two seed-era failures — tests/test_pipeline.py and
# tests/test_dryrun_machinery.py tripping over `jax.lax.axis_size` /
# list-valued `cost_analysis()` API drift — were fixed in PR 8; the
# whole suite is expected green.)
# Tier-2: cheap perf smoke for PRs touching the hot paths — refreshes
# benchmarks/out/BENCH_portfolio.json on a tiny matrix in <60s.

PY := PYTHONPATH=src python

.PHONY: test test-device test-host test-exact test-big test-chaos \
	test-chaos-flake test-obs test-mapping test-sharded bench \
	bench-smoke planner-smoke verify

test:
	$(PY) -m pytest -x -q

# jax-engine / device fan-out tests only (the `device` pytest marker)
test-device:
	$(PY) -m pytest -x -q -m "device and not big"

# everything but the device tests (quick CPU-only signal)
test-host:
	$(PY) -m pytest -x -q -m "not device and not big"

# the exact-solver stack (HiGHS ILP; self-skips where scipy.milp is absent)
test-exact:
	$(PY) -m pytest -x -q -m ilp

# big-instance regressions: over-the-dense-envelope instances streamed
# through the blocked longest-path form (deselected from tier-1)
test-big:
	$(PY) -m pytest -x -q -m big

# chaos drills: scripted fault injection against the PlanService
# degradation ladder + worker supervision (deselected from tier-1;
# deterministic per seed). Runs the whole suite under BOTH a single
# drain worker and a 4-worker pool — supervision, requeue, and
# bit-identity must hold at every worker count.
test-chaos:
	$(PY) -m pytest -x -q -m chaos --chaos-workers 1
	$(PY) -m pytest -x -q -m chaos --chaos-workers 4

# flake guard: the 4-worker chaos suite repeated across 3 seed offsets —
# catches interleaving-dependent failures the single deterministic run
# can miss
test-chaos-flake:
	for seed in 0 1 2; do \
	  $(PY) -m pytest -x -q -m chaos --chaos-workers 4 \
	    --chaos-seed $$seed || exit 1; \
	done

# observability subsystem: tracing/metrics primitives, the service's
# registry-backed stats(), Prometheus exposition, journal compaction
test-obs:
	$(PY) -m pytest -x -q tests/test_obs.py

# mapping subsystem: HEFT seeds, neighborhood moves, joint search
# quality chain, mapping-mode request validation, service integration
test-mapping:
	$(PY) -m pytest -x -q tests/test_mapping.py

# multi-device sharded grid under 8 forced virtual host devices: the
# shard_map launch must stay bitwise-identical to single-device (the
# flag must land before jax initializes, hence the explicit env here —
# the test module also sets it at import for plain `pytest` runs)
test-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	  $(PY) -m pytest -x -q tests/test_sharded_grid.py

bench:
	$(PY) -m benchmarks.run --only portfolio

bench-smoke:
	$(PY) -m benchmarks.run --only portfolio --smoke

planner-smoke:
	$(PY) -c "from repro.api import LocalSearchConfig, Planner, \
	PlanRequest, PlanResult, PlanningSession; print('planner api: ok')"

# the PR gate: tier-1 tests + chaos drills + observability suite +
# mapping suite + sharded-grid suite + Planner import smoke + tier-2
# bench refresh
verify: test test-chaos test-obs test-mapping test-sharded planner-smoke \
	bench-smoke
