"""Typed request surface of the Planner API.

One :class:`PlanRequest` describes every scheduling scenario the repo
serves: a single variant of a single instance, the full 17-variant
portfolio, a forecast ensemble, or a whole instance suite against a
profile grid. The request normalizes all accepted input spellings to the
dense (instances x profiles x variants) grid that
:func:`repro.core.portfolio.schedule_portfolio_grid` evaluates in one
pass.

Profile windowing helpers live here too: :func:`crop_profile` restricts a
long forecast to a deadline window (``PlanRequest.deadline_scale``), and
:func:`window_profile` slices the ``[t0, t0+T)`` window out of a long
forecast — the rolling-horizon overlay the async
:class:`~repro.api.session.PlanningSession` replans against.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.carbon import PowerProfile
from repro.core.cawosched import VARIANTS_BY_NAME, deadline_from_asap
from repro.core.dag import Instance
from repro.workflows.generators import Workflow, topological_order

# mapping axis: "fixed" schedules pre-built Instances under their baked-in
# mapping (the paper's setting); "heft"/"search" accept raw Workflows and
# resolve the task->processor mapping inside the plan (repro.mapping)
MAPPING_MODES = ("fixed", "heft", "search")


@dataclasses.dataclass(frozen=True)
class LocalSearchConfig:
    """Local-search knobs threaded from the Planner into every engine.

    ``mu`` is the paper's +-mu shift radius; ``max_rounds`` bounds the
    gain/commit rounds per hill climb; ``commit_k`` is the device climb's
    commit width — how many proposals a row commits per device round (the
    rest wait a round). Any ``commit_k`` yields the same termination
    guarantee (the sequential-reference polish runs regardless), but a
    profile-tuned width can cut round counts on dense-gain instances;
    ``commit_k="auto"`` picks the width per instance from its gain
    density (:func:`repro.core.local_search_jax.auto_commit_k`, scaled
    with the candidate-segment count).
    """

    mu: int = 10
    max_rounds: int = 200
    commit_k: int | str = 32

    def __post_init__(self):
        if self.mu < 1 or self.max_rounds < 1:
            raise ValueError("mu, max_rounds must be >= 1")
        if self.commit_k != "auto" and (
                not isinstance(self.commit_k, int) or self.commit_k < 1):
            raise ValueError("commit_k must be an int >= 1 or 'auto'")


def crop_profile(profile: PowerProfile, T: int) -> PowerProfile:
    """Restrict a profile to the deadline window ``[0, T)``.

    The forecast must cover the window (``profile.T >= T``); interval
    structure and budgets inside the window are preserved exactly.
    """
    T = int(T)
    if profile.T == T:
        return profile
    if profile.T < T:
        raise ValueError(
            f"profile horizon {profile.T} is shorter than deadline {T}")
    keep = profile.bounds < T
    bounds = np.append(profile.bounds[keep], T)
    return PowerProfile(bounds=bounds.astype(np.int64),
                        budget=profile.budget[:len(bounds) - 1].copy(),
                        scenario=profile.scenario)


def window_profile(profile: PowerProfile, t0: int, T: int) -> PowerProfile:
    """Slice the ``[t0, t0+T)`` window of a long forecast.

    Returns a T-horizon profile whose unit budget equals the forecast's on
    the window (``out.unit_budget(x) == profile.unit_budget(x)[t0:t0+T]``
    for every idle draw x) — the rolling-horizon overlay a
    :class:`~repro.api.session.PlanningSession` replans each execution
    window against.
    """
    t0, T = int(t0), int(T)
    if t0 < 0 or T < 1:
        raise ValueError("need t0 >= 0 and T >= 1")
    if t0 + T > profile.T:
        raise ValueError(
            f"window [{t0}, {t0 + T}) exceeds forecast horizon {profile.T}")
    b = profile.bounds
    j0 = int(np.searchsorted(b, t0, side="right")) - 1
    j1 = int(np.searchsorted(b, t0 + T, side="left"))
    bounds = np.clip(b[j0:j1 + 1] - t0, 0, T).astype(np.int64)
    return PowerProfile(bounds=bounds, budget=profile.budget[j0:j1].copy(),
                        scenario=profile.scenario)


def validate_resolved(instances, grid) -> None:
    """Structural sanity of a resolved (instances x profiles) grid.

    The serving tier's quarantine check (:class:`~repro.serve.service
    .PlanService`): a corrupt instance or profile must be rejected with a
    precise, per-cell error *before* it reaches the shared
    ``PreparedGraph`` cache or the coalesced batch it rode in on.
    Checks, per instance: CSR adjacency indices in range, positive
    durations; per (instance, profile) cell: monotone bounds starting at
    0, ``len(budget) == len(bounds) - 1``, and a horizon long enough for
    the instance's critical path (otherwise no feasible schedule exists
    and every solver would fail downstream with a far worse message).
    Raises :class:`ValueError` naming the failing cell.
    """
    from repro.core.estlst import compute_est

    for i, (inst, ps) in enumerate(zip(instances, grid)):
        if isinstance(inst, Workflow):
            _validate_workflow(i, inst, ps)
            continue
        n = inst.num_tasks
        for name, idx in (("succ", inst.succ_idx), ("pred", inst.pred_idx)):
            if len(idx) and (idx.min() < 0 or idx.max() >= n):
                raise ValueError(
                    f"instance {i} ({inst.name!r}): {name} adjacency "
                    f"index outside [0, {n})")
        if (inst.dur < 1).any():
            raise ValueError(
                f"instance {i} ({inst.name!r}): non-positive duration")
        need = int((compute_est(inst) + inst.dur).max()) if n else 0
        for p, prof in enumerate(ps):
            b = np.asarray(prof.bounds)
            g = np.asarray(prof.budget)
            if b.ndim != 1 or len(b) < 2 or int(b[0]) != 0 \
                    or (np.diff(b) <= 0).any():
                raise ValueError(
                    f"cell ({i}, {p}): malformed profile bounds "
                    f"(need 0 = b[0] < ... < b[J] = T)")
            if g.ndim != 1 or len(g) != len(b) - 1:
                raise ValueError(
                    f"cell ({i}, {p}): profile budget length {len(g)} != "
                    f"{len(b) - 1} intervals")
            if prof.T < need:
                raise ValueError(
                    f"cell ({i}, {p}): horizon {prof.T} is shorter than "
                    f"the instance's critical path {need} (infeasible)")


def _validate_workflow(i: int, wf: Workflow, ps) -> None:
    """The workflow branch of :func:`validate_resolved` (mapping modes).

    Structural checks mirror the instance branch, but the horizon check
    uses a mapping-independent lower bound — the longest chain in tasks
    (every task runs >= 1 time unit on any processor), since the actual
    critical path depends on the mapping the plan will choose.
    """
    n = wf.n
    if n < 1:
        raise ValueError(f"workflow {i} ({wf.name!r}): empty workflow")
    edges = np.asarray(wf.edges)
    if edges.ndim != 2 or (len(edges) and edges.shape[1] != 2):
        raise ValueError(
            f"workflow {i} ({wf.name!r}): edges must be [m, 2] pairs")
    if len(edges) and (edges.min() < 0 or edges.max() >= n):
        raise ValueError(
            f"workflow {i} ({wf.name!r}): edge endpoint outside [0, {n})")
    if (np.asarray(wf.node_w) < 1).any():
        raise ValueError(
            f"workflow {i} ({wf.name!r}): non-positive task weight")
    if len(edges) and (np.asarray(wf.edge_w) < 0).any():
        raise ValueError(
            f"workflow {i} ({wf.name!r}): negative communication weight")
    order = topological_order(n, edges)
    if len(order) != n:
        raise ValueError(f"workflow {i} ({wf.name!r}): graph has a cycle")
    depth = np.zeros(n, dtype=np.int64)
    for v in order:
        for u in edges[edges[:, 1] == v, 0] if len(edges) else ():
            depth[v] = max(depth[v], depth[int(u)] + 1)
    need = int(depth.max()) + 1 if n else 0
    for p, prof in enumerate(ps):
        b = np.asarray(prof.bounds)
        g = np.asarray(prof.budget)
        if b.ndim != 1 or len(b) < 2 or int(b[0]) != 0 \
                or (np.diff(b) <= 0).any():
            raise ValueError(
                f"cell ({i}, {p}): malformed profile bounds "
                f"(need 0 = b[0] < ... < b[J] = T)")
        if g.ndim != 1 or len(g) != len(b) - 1:
            raise ValueError(
                f"cell ({i}, {p}): profile budget length {len(g)} != "
                f"{len(b) - 1} intervals")
        if prof.T < need:
            raise ValueError(
                f"cell ({i}, {p}): horizon {prof.T} is shorter than the "
                f"workflow's depth {need} (infeasible under any mapping)")


def _as_instances(instances) -> list[Instance]:
    if isinstance(instances, Instance):
        return [instances]
    out = list(instances)
    if not all(isinstance(i, Instance) for i in out):
        raise TypeError("instances must be Instance objects")
    return out


def _as_workflows(instances) -> list[Workflow]:
    if isinstance(instances, Workflow):
        return [instances]
    err = TypeError(
        "mapping modes 'heft'/'search' take raw Workflow objects "
        "(the mapping is the decision variable); pass Instances only "
        "with mapping='fixed'")
    if isinstance(instances, Instance):
        raise err
    try:
        out = list(instances)
    except TypeError:
        raise err from None
    if not all(isinstance(w, Workflow) for w in out):
        raise err
    return out


def _as_grid(profiles, I: int) -> list[list[PowerProfile]]:
    """Normalize to one profile list per instance (shared list broadcast)."""
    if isinstance(profiles, PowerProfile):
        return [[profiles] for _ in range(I)]
    rows = list(profiles)
    if not rows:
        raise ValueError("at least one profile is required")
    if isinstance(rows[0], PowerProfile):
        if not all(isinstance(p, PowerProfile) for p in rows):
            raise TypeError("mixed profile spellings in one request")
        return [list(rows) for _ in range(I)]
    grid = [list(ps) for ps in rows]
    if len(grid) != I:
        raise ValueError(
            f"per-instance profiles: got {len(grid)} lists for {I} "
            f"instances")
    return grid


@dataclasses.dataclass
class PlanRequest:
    """One request over the (instances x profiles x variants) grid.

    Accepted spellings (all normalize to the dense grid):

    * ``instances`` — one :class:`Instance` or a sequence of them.
    * ``profiles`` — one :class:`PowerProfile`, a sequence shared by every
      instance, or a per-instance sequence of sequences (every instance
      the same count P; an instance's profiles share its horizon).
    * ``variants`` — ``None`` (asap + all 16 paper variants), one name, or
      a sequence of names.
    * ``deadline_scale`` — optional: crop every profile to the owning
      instance's deadline ``deadline_scale x ASAP-makespan``
      (:func:`crop_profile`); lets one long grid forecast serve instances
      with different deadlines. In mapping modes the ASAP makespan
      depends on the mapping being decided, so the horizon is derived
      from a reference HEFT mapping per workflow and every candidate is
      evaluated under that cropped row (:func:`repro.mapping.search.
      resolve_mappings`).
    * ``devices`` — shard the jax engine's combined grid launch over this
      many devices (``shard_map`` over the instance-row axis; see
      ``sharding.ctx.grid_mesh``). ``None`` = single-device launch;
      results are bitwise-identical at any device count.
    * ``robust`` — plan for the min-max pick across the profile axis
      (:meth:`PlanResult.pick` then returns the robust variant's nominal
      schedule instead of the nominal-best one).
    * ``solver`` — which registered backend serves the grid
      (:mod:`repro.core.solvers`): ``"heuristic"`` (default, the
      portfolio engine; the only solver with a variant axis), ``"exact"``
      (§4.1 DP on uniprocessor chains, time-indexed ILP otherwise),
      ``"ilp"``, ``"dp"``, or ``"asap"``. Non-heuristic solvers serve one
      variant column named after the solver.
    * ``solver_options`` — solver-specific knobs: ``time_limit`` /
      ``mip_gap`` (ilp, exact), ``check`` (dp: cross-validate against the
      pseudo-polynomial oracle).
    * ``mapping`` — the mapping axis (:mod:`repro.mapping`):
      ``"fixed"`` (default, the paper's setting — ``instances`` are
      pre-built :class:`Instance` objects scheduled under their baked-in
      mapping), ``"heft"`` (``instances`` are raw
      :class:`~repro.workflows.generators.Workflow` objects, mapped with
      exact HEFT before scheduling), or ``"search"`` (joint mapping x
      scheduling: candidate mappings evaluated in batch through the grid,
      elite kept by best/robust carbon cost).
    * ``mapping_options`` — :class:`repro.mapping.MappingOptions` knobs
      as a dict (``seeds``, ``rounds``, ``neighbors``, ``elite``,
      ``patience``, ``seed``, ``objective``); only valid with
      ``mapping="search"``/``"heft"``.
    """

    instances: object
    profiles: object
    variants: object = None
    deadline_scale: float | None = None
    robust: bool = False
    solver: str = "heuristic"
    solver_options: dict | None = None
    mapping: str = "fixed"
    mapping_options: dict | None = None
    devices: int | None = None

    def resolve(self) -> tuple[list[Instance], list[list[PowerProfile]],
                               tuple[str, ...]]:
        """The normalized (instances, profile grid, variant names) triple.

        Mapping modes (``mapping="heft"``/``"search"``) return raw
        :class:`Workflow` objects in the instances slot — the Planner
        resolves them to Instances via :mod:`repro.mapping` before the
        schedule solve.
        """
        if self.mapping not in MAPPING_MODES:
            raise ValueError(
                f"unknown mapping {self.mapping!r}; one of {MAPPING_MODES}")
        if self.mapping == "fixed":
            if self.mapping_options:
                raise ValueError(
                    "mapping_options requires mapping='heft' or 'search'")
            instances = _as_instances(self.instances)
        else:
            from repro.mapping.options import MappingOptions

            MappingOptions.from_dict(self.mapping_options)  # raises early
            instances = _as_workflows(self.instances)
        if not instances:
            raise ValueError("at least one instance is required")
        if self.devices is not None and (
                not isinstance(self.devices, int)
                or isinstance(self.devices, bool) or self.devices < 1):
            raise ValueError(
                f"devices must be a positive int or None, "
                f"got {self.devices!r}")
        grid = _as_grid(self.profiles, len(instances))
        P = len(grid[0])
        if any(len(ps) != P for ps in grid):
            raise ValueError("every instance needs the same number of "
                             "profiles (dense grid)")
        if self.deadline_scale is not None:
            if self.deadline_scale <= 0:
                raise ValueError(
                    f"deadline_scale must be positive, "
                    f"got {self.deadline_scale!r}")
            if self.mapping == "fixed":
                grid = [[crop_profile(p, deadline_from_asap(
                            inst, self.deadline_scale)) for p in ps]
                        for inst, ps in zip(instances, grid)]
            # mapping modes: the ASAP makespan depends on the mapping
            # being decided — the Planner derives the horizon from a
            # reference HEFT mapping and crops per workflow inside
            # resolve_mappings (the grid passes through uncropped here)
        for inst, ps in zip(instances, grid):
            if any(p.T != ps[0].T for p in ps):
                raise ValueError(
                    "an instance's profiles must share one horizon")
        from repro.kernels.backend import resolve_solver

        solver = resolve_solver(self.solver)    # raises on unknown solvers
        if self.variants is None:
            names = solver.default_variants()
        elif isinstance(self.variants, str):
            names = (self.variants,)
        else:
            names = tuple(self.variants)
        if not names:
            raise ValueError("at least one variant is required")
        if solver.name == "heuristic":
            for n in names:
                if n != "asap" and n not in VARIANTS_BY_NAME:
                    raise ValueError(f"unknown variant {n!r}")
        elif names != solver.default_variants():
            raise ValueError(
                f"solver {solver.name!r} serves exactly the variant "
                f"column {solver.default_variants()}; drop variants= "
                f"(got {names!r})")
        return instances, grid, names
