"""Async rolling-horizon replanning: plan window k+1 while k executes.

The ROADMAP's async-replanning item. Execution proceeds in fixed
*windows*; each window is planned against that window's forecast (an
ensemble slice of a long forecast — see :func:`repro.api.request
.window_profile` — or any per-window profile source). All windows share
the instances' horizon, so every window reuses the same cached
:class:`~repro.core.portfolio.PreparedGraph` (overlay-only replanning)
and, under the jax engine, the jit cache is warm from window 0 on — the
steady-state plan latency is one device launch.

:meth:`PlanningSession.plan_for` returns window k's :class:`PlanResult`
and *prefetches* windows k+1..k+lookahead on a background worker, so by
the time window k finishes executing, window k+1's plan is (typically)
already done. Plans are deterministic: the session's results are
bit-identical to planning each window eagerly on the caller's thread
(tested).
"""
from __future__ import annotations

import concurrent.futures as _fut

from repro import obs
from repro.api.request import PlanRequest
from repro.core.cancel import Cancelled, CancelToken

_WINDOW_FETCH = obs.registry().counter(
    "session_window_fetch_total",
    "plan_for() outcomes: prefetched = plan already done, waited = the "
    "caller blocked on the background worker", labels=("outcome",))


class PlanningSession:
    """Rolling-horizon planning over a :class:`~repro.api.planner.Planner`.

    Args:
      planner: the shared facade (its graph cache and jit executables are
        what make per-window replanning cheap).
      instances: one instance or a sequence (the fleet being replanned).
      window_profiles: the per-window forecast source — a callable
        ``k -> profiles`` (one profile or an ensemble, any spelling
        :class:`PlanRequest` accepts) or a pre-built sequence indexed by
        window (its length bounds the session).
      n_windows: optional window count (required for callables that never
        exhaust; a sequence source defaults to its length).
      variants / robust: forwarded into each window's request.
      lookahead: how many future windows to keep in flight (default 1 =
        plan k+1 while k executes).

    All planning runs on ONE background worker, so concurrent plan calls
    never race on the planner's caches; the caller only blocks in
    :meth:`plan_for` when a window's plan is not ready yet.
    """

    def __init__(self, planner, instances, window_profiles,
                 n_windows: int | None = None, variants=None,
                 robust: bool = True, lookahead: int = 1):
        if callable(window_profiles):
            if n_windows is None:
                raise ValueError("n_windows is required with a callable "
                                 "window_profiles source")
            self._source = window_profiles
        else:
            seq = list(window_profiles)
            if n_windows is None:
                n_windows = len(seq)
            elif n_windows > len(seq):
                raise ValueError("n_windows exceeds the profile sequence")
            self._source = seq.__getitem__
        self.planner = planner
        self.instances = instances
        self.n_windows = int(n_windows)
        self.variants = variants
        self.robust = robust
        self.lookahead = max(int(lookahead), 0)
        self._pool = _fut.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="planning-session")
        self._plans: dict[int, _fut.Future] = {}
        self._tokens: dict[int, CancelToken] = {}
        self._retried: set[int] = set()
        self._closed = False

    def request_for(self, window: int) -> PlanRequest:
        """The :class:`PlanRequest` window ``window`` plans against."""
        return PlanRequest(instances=self.instances,
                           profiles=self._source(window),
                           variants=self.variants, robust=self.robust)

    def _submit(self, window: int) -> None:
        if (0 <= window < self.n_windows and window not in self._plans
                and not self._closed):
            # each window's plan carries its own CancelToken so close()
            # can stop the ONE in-flight solve, not just the queue
            token = CancelToken()
            self._tokens[window] = token

            def _plan(window=window, token=token,
                      parent=obs.current_span()):
                # re-anchor the worker thread to the caller's span (the
                # context variable does not cross pool submission)
                with obs.attach(parent):
                    with obs.span("session_window", window=window):
                        return self.planner.plan(self.request_for(window),
                                                 cancel=token)

            self._plans[window] = self._pool.submit(_plan)

    def plan_for(self, window: int):
        """Window ``window``'s :class:`PlanResult`; blocks only when its
        background plan has not finished. Prefetches the next
        ``lookahead`` windows before blocking, so planning overlaps the
        caller's execution of the current window.

        A failed background plan is NOT cached forever: its future is
        evicted and the window resubmitted once (a transient failure —
        a device hiccup, an injected fault — heals on retry); only a
        second failure propagates, and later calls re-raise it instead
        of looping."""
        if self._closed:
            raise RuntimeError("planning session is closed")
        if not 0 <= window < self.n_windows:
            raise IndexError(f"window {window} outside "
                             f"[0, {self.n_windows})")
        self._submit(window)
        for nxt in range(window + 1, window + 1 + self.lookahead):
            self._submit(nxt)
        _WINDOW_FETCH.inc(outcome="prefetched"
                          if self._plans[window].done() else "waited")
        try:
            return self._plans[window].result()
        except (_fut.CancelledError, Cancelled):
            raise RuntimeError("planning session is closed") from None
        except Exception:
            if window in self._retried or self._closed:
                raise
            self._retried.add(window)
            del self._plans[window]
            self._tokens.pop(window, None)
            self._submit(window)
            return self._plans[window].result()

    def windows(self):
        """Iterate ``(window, PlanResult)`` over the whole session."""
        for k in range(self.n_windows):
            yield k, self.plan_for(k)

    def close(self) -> None:
        """Close the session without draining the lookahead: queued
        prefetch plans are cancelled (``cancel_futures``) AND the one
        in-flight plan (if any) is cancelled through its
        :class:`~repro.core.cancel.CancelToken`, so closing mid-run
        returns within one solver chunk instead of waiting for the
        in-flight window to plan to completion first."""
        self._closed = True
        for token in self._tokens.values():
            token.cancel("session closed")
        self._pool.shutdown(wait=True, cancel_futures=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False
