"""Dense result surface of the Planner API.

One :class:`PlanResult` replaces the three incompatible legacy shapes
(``ScheduleResult``, ``{variant: ScheduleResult}``, and a list of such
dicts): a dense integer cost tensor indexed ``[instance, profile,
variant]`` plus the per-cell schedules and timings, with accessors for
the common reads (nominal best, robust min-max pick, a printable table).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.cawosched import ScheduleResult
from repro.core.portfolio import heuristic_indices, robust_pick


def _mapping_info_from_wire(m: dict | None):
    if not m or m.get("info") is None:
        return None
    from repro.mapping.search import MappingSearchInfo

    return tuple(MappingSearchInfo.from_dict(x) for x in m["info"])


@dataclasses.dataclass
class PlanResult:
    """The (instances x profiles x variants) planning grid, densely.

    ``costs[i, p, v]`` is the carbon cost of scheduling instance i against
    profile p under variant ``variants[v]``; ``results[i][p]`` maps each
    variant name to its full :class:`ScheduleResult` (start times, cost,
    seconds). ``engine`` records the backend that actually ran (after
    ``"auto"`` resolution); ``seconds`` is the wall clock of the whole
    plan call.

    ``solver`` is the registered backend that produced the grid
    (:mod:`repro.core.solvers`); exact solvers fill ``lower_bound`` with
    a valid per-cell bound on the optimal cost (``lower_bound == cost``
    certifies a proven optimum), which :meth:`gap` and :meth:`compare`
    consume to report heuristic-vs-optimal quality. ``mip_gap`` is the
    MILP backend's relative per-cell gap (0.0 proven, >0 on time-limit
    exits, NaN unknown) — present only on ilp/exact results.

    Results served by :class:`~repro.serve.service.PlanService` also
    carry the degradation record: ``degraded`` flags that the service
    could not deliver the request's own solver at full fidelity within
    its deadline budget, ``fallback_stage`` names the chain stage that
    produced the plan (``"exact" -> "ilp" -> "heuristic" -> "asap"``),
    and ``attempts`` logs every stage outcome the watchdog walked
    (``"exact:crash"``, ``"ilp:timeout"``, ``"heuristic:ok"`` ...).
    Plans straight from :meth:`Planner.plan` leave all three at their
    defaults.
    """

    variants: tuple[str, ...]
    results: list                       # I x P of {variant: ScheduleResult}
    costs: np.ndarray                   # int64 [I, P, V]
    engine: str
    seconds: float
    robust_requested: bool = False
    solver: str = "heuristic"
    lower_bound: np.ndarray | None = None   # int64 [I, P] (exact solvers)
    mip_gap: np.ndarray | None = None       # float [I, P] (ilp/exact)
    degraded: bool = False                  # service fallback record
    fallback_stage: str | None = None
    attempts: tuple[str, ...] = ()
    # mapping axis (repro.mapping): how the task->processor mapping was
    # chosen. "fixed" = baked into the request's Instances (the paper's
    # setting); "heft"/"search" resolved it inside the plan — `mappings`
    # then carries the winning FixedMapping per instance and
    # `mapping_info` the search provenance (rounds, candidates evaluated,
    # improvement trace). Schedules in `results` are under the winning
    # mapping's instance.
    mapping_mode: str = "fixed"
    mappings: tuple | None = None           # FixedMapping per instance
    mapping_info: tuple | None = None       # MappingSearchInfo per instance

    @property
    def shape(self) -> tuple[int, int, int]:
        """(instances, profiles, variants)."""
        return tuple(self.costs.shape)

    # --- RPC-ready wire shape -------------------------------------------

    def summary_dict(self) -> dict:
        """The JSON-safe wire summary of this result (no schedules).

        Everything an RPC front needs to route on — the cost tensor, the
        degradation record (``degraded``/``fallback_stage``/``attempts``),
        and the bound certificates — as plain lists/ints/floats/None:
        ``json.dumps`` round-trips it byte-for-byte, and
        :meth:`summary_from_dict` restores an equivalent summary-level
        result (``restored.summary_dict() == d``). NaN gap cells travel
        as ``None`` (JSON has no NaN).
        """
        def grid(a, none_nan=False):
            if a is None:
                return None
            a = np.asarray(a)
            if none_nan:
                return [[None if not np.isfinite(x) else float(x)
                         for x in row] for row in a]
            return [[int(x) for x in row] for row in a]

        return {
            "variants": list(self.variants),
            "costs": [grid(self.costs[i]) for i in range(len(self.costs))],
            "engine": self.engine,
            "seconds": float(self.seconds),
            "robust_requested": bool(self.robust_requested),
            "solver": self.solver,
            "lower_bound": grid(self.lower_bound),
            "mip_gap": grid(self.mip_gap, none_nan=True),
            "degraded": bool(self.degraded),
            "fallback_stage": self.fallback_stage,
            "attempts": list(self.attempts),
            # FixedMappings themselves don't travel (array-heavy); the
            # mode + per-instance search provenance do
            "mapping": {
                "mode": self.mapping_mode,
                "info": None if self.mapping_info is None else
                        [inf.to_dict() for inf in self.mapping_info],
            },
        }

    @classmethod
    def summary_from_dict(cls, d: dict) -> "PlanResult":
        """Rebuild a summary-level result from :meth:`summary_dict`.

        Schedules do not travel on the wire, so ``results`` comes back
        empty; every other field (including the cost tensor and the
        degradation record) round-trips losslessly —
        ``cls.summary_from_dict(d).summary_dict() == d``.
        """
        def arr(g, dtype=np.int64, nan_none=False):
            if g is None:
                return None
            if nan_none:
                return np.array([[np.nan if x is None else float(x)
                                  for x in row] for row in g], dtype=dtype)
            return np.asarray(g, dtype=dtype)

        return cls(
            variants=tuple(d["variants"]),
            results=[],
            costs=np.asarray(d["costs"], dtype=np.int64),
            engine=d["engine"],
            seconds=float(d["seconds"]),
            robust_requested=bool(d["robust_requested"]),
            solver=d["solver"],
            lower_bound=arr(d.get("lower_bound")),
            mip_gap=arr(d.get("mip_gap"), dtype=np.float64, nan_none=True),
            degraded=bool(d["degraded"]),
            fallback_stage=d.get("fallback_stage"),
            attempts=tuple(d.get("attempts", ())),
            mapping_mode=(d.get("mapping") or {}).get("mode", "fixed"),
            mapping_info=_mapping_info_from_wire(d.get("mapping")),
        )

    def result(self, instance: int = 0, profile: int = 0,
               variant: str | None = None) -> ScheduleResult:
        """One cell's :class:`ScheduleResult` (default: the cell's best)."""
        if variant is None:
            return self.best(instance, profile)
        return self.results[instance][profile][variant]

    def starts(self, instance: int = 0, profile: int = 0) -> dict:
        """``{variant: start times}`` of one (instance, profile) cell."""
        return {n: r.start for n, r in self.results[instance][profile]
                .items()}

    def cost_matrix(self, instance: int = 0
                    ) -> tuple[np.ndarray, tuple[str, ...]]:
        """One instance's [P, V] ensemble x variant cost matrix + names
        (the shape :func:`repro.core.portfolio.robust_pick` consumes)."""
        return self.costs[instance], self.variants

    def best(self, instance: int = 0, profile: int = 0) -> ScheduleResult:
        """The cheapest heuristic variant of one (instance, profile) cell
        (``asap`` competes only when it is the sole variant)."""
        heur = heuristic_indices(self.variants)
        row = self.costs[instance, profile, heur]
        name = self.variants[heur[int(np.argmin(row))]]
        return self.results[instance][profile][name]

    def robust(self, instance: int = 0) -> tuple[str, int]:
        """The min-max variant across the instance's profile axis:
        ``(variant, worst_cost)`` minimizing the worst ensemble cost."""
        return robust_pick(self.costs[instance], self.variants)

    def pick(self, instance: int = 0) -> ScheduleResult:
        """The schedule to execute, under the request's planning mode:
        the robust variant's nominal-profile schedule when the request
        asked for ``robust=True``, else the nominal-profile best."""
        if self.robust_requested:
            name, _ = self.robust(instance)
            return self.results[instance][0][name]
        return self.best(instance, 0)

    def best_costs(self) -> np.ndarray:
        """Per-cell best competing cost, int64 [I, P] (the min across the
        columns :func:`repro.core.portfolio.heuristic_indices` admits)."""
        heur = heuristic_indices(self.variants)
        return self.costs[:, :, heur].min(axis=2)

    def gap(self, exact: "PlanResult | None" = None) -> np.ndarray:
        """Optimality-gap ratios, float [I, P]: per-cell best cost over
        the optimal-cost lower bound (1.0 = provably optimal).

        The bound comes from ``exact`` — a second :class:`PlanResult` of
        the same (instances x profiles) grid planned with an exact solver
        (``plan(request, solver="exact")``) — or, when ``exact`` is
        omitted, from this result's own ``lower_bound`` (set when this
        result itself came from an exact solver). Cells with a zero bound
        follow the paper's convention: 1.0 when the best cost is also
        zero, ``inf`` otherwise.
        """
        if exact is not None:
            if exact.costs.shape[:2] != self.costs.shape[:2]:
                raise ValueError(
                    f"grid shapes differ: {self.costs.shape[:2]} vs "
                    f"{exact.costs.shape[:2]}")
            lb = exact.lower_bound if exact.lower_bound is not None \
                else exact.best_costs()
        else:
            lb = self.lower_bound
        if lb is None:
            raise ValueError(
                "no lower bound available: pass an exact PlanResult "
                "(e.g. plan(..., solver='exact')) to gap()")
        best = self.best_costs().astype(np.float64)
        lb = np.asarray(lb, dtype=np.float64)
        out = np.where(best <= 0, 1.0, np.inf)
        pos = lb > 0
        out[pos] = best[pos] / lb[pos]
        return out

    def compare(self, other: "PlanResult", instance: int = 0,
                profile: int = 0) -> str:
        """Printable quality table of one cell: every variant of this
        result against ``other``'s best cost in the same cell (typically
        an exact plan — the paper's heuristics-vs-baseline-vs-exact
        evaluation in one string). Ratios follow :meth:`gap`'s zero-cost
        conventions; a trailing line reports whether ``other``'s bound
        certifies optimality for the cell.
        """
        ref = int(other.best_costs()[instance, profile])
        lines = [f"{'variant':<12} {'cost':>10} {other.solver:>10} "
                 f"{'ratio':>8}"]
        for v, name in enumerate(self.variants):
            c = int(self.costs[instance, profile, v])
            r = c / ref if ref > 0 else (1.0 if c <= 0 else float("inf"))
            lines.append(f"{name:<12} {c:>10} {ref:>10} {r:>8.3f}")
        if other.lower_bound is not None:
            lb = int(other.lower_bound[instance, profile])
            lines.append(f"[{other.solver}] lower bound {lb} "
                         f"({'proven optimal' if lb >= ref else 'gap open'})")
        return "\n".join(lines)

    def table(self, instance: int = 0) -> str:
        """Printable per-variant summary of one instance: nominal cost,
        worst ensemble cost, and mean planning seconds per profile."""
        lines = [f"{'variant':<12} {'nominal':>10} {'worst':>10} "
                 f"{'ms':>8}"]
        P = self.costs.shape[1]
        for v, name in enumerate(self.variants):
            col = self.costs[instance, :, v]
            secs = sum(self.results[instance][p][name].seconds
                       for p in range(P)) / max(P, 1)
            lines.append(f"{name:<12} {int(col[0]):>10} "
                         f"{int(col.max()):>10} {secs * 1e3:>8.1f}")
        return "\n".join(lines)
