"""Unified Planner API: one ``PlanRequest -> PlanResult`` surface.

    from repro.api import Planner, PlanRequest

    planner = Planner(platform)                      # engine="auto"
    res = planner.plan(PlanRequest(instances=inst, profiles=ensemble))
    best = res.best()                                # nominal cheapest
    variant, worst = res.robust()                    # min-max across members

covers every scheduling scenario — one variant, the 17-variant portfolio,
forecast ensembles, whole instance suites — through one code path, and
:class:`PlanningSession` adds async rolling-horizon replanning (plan
window k+1 while window k executes).

The ``solver=`` request axis picks the backend serving the grid
(:mod:`repro.core.solvers`): the heuristic portfolio (default), the exact
DP/ILP dispatch, or the asap baseline — so the paper's full
heuristics-vs-baseline-vs-exact evaluation is three ``plan()`` calls:

    heur = planner.plan(PlanRequest(instances=inst, profiles=prof))
    base = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                    solver="asap"))
    opt = planner.plan(PlanRequest(instances=inst, profiles=prof,
                                   solver="exact"))
    heur.gap(opt)                                    # [I, P] ratios
    print(heur.compare(opt))                         # quality table
"""
from repro.api.planner import Planner  # noqa: F401
from repro.api.request import (  # noqa: F401
    LocalSearchConfig,
    MAPPING_MODES,
    PlanRequest,
    crop_profile,
    window_profile,
)
from repro.api.result import PlanResult  # noqa: F401
from repro.api.session import PlanningSession  # noqa: F401
