"""The Planner facade: one ``plan(PlanRequest) -> PlanResult`` surface.

``Planner(platform)`` owns everything amortizable across plan calls — a
bounded cache of :class:`~repro.core.portfolio.PreparedGraph` precomputes
(keyed by instance identity and horizon), the resolved engine, and the
local-search configuration — and serves every request shape through ONE
code path (:func:`repro.core.portfolio.schedule_portfolio_grid`):

* ``1 x 1 x 1``  — one variant of one instance (legacy ``schedule``);
* ``1 x 1 x 17`` — the full portfolio (legacy ``schedule_portfolio``);
* ``1 x P x 17`` — a forecast ensemble (legacy
  ``schedule_portfolio_multi``);
* ``I x P x 17`` — a whole instance suite x ensemble grid, previously
  unreachable: under the jax engine all (instance, profile, variant) rows
  of a padded shape bucket launch as ONE triple-vmapped device call.

``engine="auto"`` resolution is centralized in
:func:`repro.kernels.backend.resolve_engine` — the same rule the kernels'
``interpret=None`` tri-state routes through, so the facade and the
kernels can never disagree on the active backend.

The wider ``solver=`` axis (:mod:`repro.core.solvers`, resolved by
:func:`repro.kernels.backend.resolve_solver`) picks WHICH backend serves
the grid: the heuristic portfolio above (default), the exact DP/ILP
dispatch (``solver="exact"``), the raw ``"ilp"``/``"dp"`` oracles, or the
``"asap"`` baseline — so one Planner runs the paper's full
heuristics-vs-baseline-vs-exact evaluation in three ``plan()`` calls and
:meth:`PlanResult.gap`/:meth:`PlanResult.compare` report the quality.
"""
from __future__ import annotations

import collections
import threading
import time

import numpy as np

from repro import obs
from repro.api.request import LocalSearchConfig, PlanRequest
from repro.api.result import PlanResult
from repro.core.portfolio import PreparedGraph, prepare_graph
from repro.kernels.backend import resolve_engine, resolve_solver


class Planner:
    """Compile instances once, then serve any (I x P x V) plan request.

    Args:
      platform: the fixed-mapping platform every request schedules on.
      engine: ``"numpy"``, ``"jax"``, or ``"auto"`` (resolved per request
        by :func:`repro.kernels.backend.resolve_engine`: the device
        fan-out as soon as the request has more than one
        (instance, profile) cell).
      k: refined-subdivision granularity (paper's k).
      ls: :class:`LocalSearchConfig` — mu, round budget, and the device
        climb's commit width, threaded through every engine.
      validate: assert precedence + deadline feasibility of every
        produced schedule.
      graph_cache: how many ``PreparedGraph`` precomputes to keep (FIFO).
        A cached graph pins its instance, so equal ``id()`` keys cannot
        collide while an entry lives.
      lp_budget_bytes: jax engine's per-instance longest-path memory
        envelope (None = :data:`repro.core.greedy_jax.LP_MAX_BYTES`).
        Instances whose dense O(N^2) matrix fits ride the device-resident
        fast path; bigger ones stream the blocked form
        (:class:`repro.core.greedy_jax.BlockedLP`) bit-identically, so
        ``engine="jax"`` serves instances far past the dense envelope.
      devices: shard the jax engine's combined grid launch over this many
        devices (``shard_map`` over the instance-row axis of each shape
        bucket; ``sharding.ctx.grid_mesh`` builds the 1-D mesh). ``None``
        = single-device launch. A request's ``PlanRequest.devices``
        overrides this default per call; results are bitwise-identical
        at any device count.
    """

    def __init__(self, platform, engine: str = "auto", k: int = 3,
                 ls: LocalSearchConfig | None = None, validate: bool = True,
                 graph_cache: int = 32,
                 lp_budget_bytes: int | None = None,
                 devices: int | None = None):
        resolve_engine(engine)              # fail fast on unknown engines
        self.platform = platform
        self.engine = engine
        self.k = int(k)
        self.ls = ls if ls is not None else LocalSearchConfig()
        self.validate = validate
        self.lp_budget_bytes = lp_budget_bytes
        self.devices = devices
        self._graph_cache = int(graph_cache)
        self._graphs: collections.OrderedDict[tuple, PreparedGraph] = \
            collections.OrderedDict()
        # the graph cache is shared mutable state: the serving tier
        # (repro.serve.service) hits one Planner from a worker plus
        # watchdog-abandoned solve threads, so cache mutation is locked
        # (planning itself is outside the lock — only the bookkeeping is)
        self._cache_lock = threading.Lock()

    def clone(self, *, engine: str | None = None,
              lp_budget_bytes: int | None = None) -> "Planner":
        """A planner with this one's configuration but its own caches.

        The serving tier uses clones to pin the engine per coalesced
        batch (so coalescing can never flip a request's ``auto``
        resolution) and to retry device OOMs under a reduced blocked-LP
        budget without disturbing the shared planner.
        """
        return Planner(self.platform,
                       engine=self.engine if engine is None else engine,
                       k=self.k, ls=self.ls, validate=self.validate,
                       graph_cache=self._graph_cache,
                       lp_budget_bytes=self.lp_budget_bytes
                       if lp_budget_bytes is None else lp_budget_bytes,
                       devices=self.devices)

    # --- PreparedGraph cache ---------------------------------------------

    def prepared(self, inst, T: int) -> PreparedGraph:
        """The cached profile-independent precompute of ``(inst, T)``."""
        key = (id(inst), int(T), self.k)
        with self._cache_lock:
            g = self._graphs.get(key)
            if g is not None and g.inst is inst:
                self._graphs.move_to_end(key)
                obs.registry().counter(
                    "planner_graph_cache_total",
                    "PreparedGraph cache lookups", labels=("outcome",)
                ).inc(outcome="hit")
                return g
        with obs.span("prepare_graph", N=int(getattr(inst, "N", 0)),
                      T=int(T), cache_hit=False):
            g = prepare_graph(inst, self.platform, int(T), k=self.k,
                              lp_budget_bytes=self.lp_budget_bytes)
        obs.registry().counter(
            "planner_graph_cache_total",
            "PreparedGraph cache lookups", labels=("outcome",)
        ).inc(outcome="miss")
        self.seed_graph(g)
        return g

    def seed_graph(self, graph: PreparedGraph) -> None:
        """Adopt an externally prepared graph (legacy ``prep=``/``graph=``
        reuse); it must match this planner's platform and k."""
        with self._cache_lock:
            cap = max(self._graph_cache, 1)  # always hold the current graph
            while self._graphs and len(self._graphs) >= cap:
                self._graphs.popitem(last=False)
            self._graphs[(id(graph.inst), graph.T, graph.k)] = graph

    # --- planning --------------------------------------------------------

    def plan(self, request: PlanRequest | None = None, /,
             cancel=None, **kw) -> PlanResult:
        """Evaluate one request grid; see :class:`PlanRequest`.

        ``plan(instances=..., profiles=..., ...)`` builds the request
        inline; passing a prebuilt :class:`PlanRequest` is equivalent.
        ``cancel`` (an optional :class:`repro.core.cancel.CancelToken`)
        is threaded into the solver, which polls it at its chunk
        boundaries and raises :class:`repro.core.cancel.Cancelled` when
        the token fires — the serving tier's watchdog and
        ``Ticket.cancel()`` route through this.
        """
        if request is None:
            request = PlanRequest(**kw)
        elif kw:
            raise TypeError("pass a PlanRequest or keywords, not both")
        t0 = time.perf_counter()
        instances, grid, names = request.resolve()
        solver = resolve_solver(request.solver)
        devices = request.devices if request.devices is not None \
            else self.devices
        outcomes = None
        if request.mapping != "fixed":
            # mapping modes resolve raw Workflows to mapped Instances
            # first (repro.mapping); the winning instances then ride the
            # unchanged fixed-mapping path below, with winner graphs
            # pre-seeded into the cache. deadline_scale is applied HERE
            # (not in resolve()): the ASAP horizon needs a mapping, so
            # resolve_mappings derives it from a reference HEFT mapping
            # per workflow and returns the cropped grid
            from repro.mapping.search import resolve_mappings

            outcomes, grid = resolve_mappings(
                self, instances, grid, names, solver,
                mode=request.mapping, options=request.mapping_options,
                robust=bool(request.robust),
                solver_options=request.solver_options, cancel=cancel,
                deadline_scale=request.deadline_scale, devices=devices)
            instances = [o.instance for o in outcomes]
            for o in outcomes:
                if o.graph is not None:
                    self.seed_graph(o.graph)
        I = len(instances)
        P = len(grid[0]) if I else 0
        # engine= is the heuristic solver's sub-knob; exact solvers run
        # on host scipy/numpy regardless, and only graph-consuming
        # solvers pay for (and cache) the PreparedGraph precompute
        engine = resolve_engine(self.engine, fanout=I * P) \
            if solver.name == "heuristic" else "numpy"
        with obs.span("plan", solver=solver.name, engine=engine,
                      instances=I, profiles=P, variants=len(names)):
            graphs = [self.prepared(inst, ps[0].T)
                      for inst, ps in zip(instances, grid)] \
                if solver.uses_graphs else None
            out = solver.solve_grid(
                instances, grid, self.platform, names, k=self.k,
                mu=self.ls.mu, validate=self.validate, engine=engine,
                graphs=graphs, commit_k=self.ls.commit_k,
                ls_max_rounds=self.ls.max_rounds,
                options=request.solver_options, cancel=cancel,
                devices=devices)
        obs.registry().counter(
            "planner_plans_total", "Planner.plan calls served",
            labels=("solver", "engine")).inc(solver=solver.name,
                                             engine=engine)
        obs.registry().histogram(
            "planner_plan_seconds", "wall time of Planner.plan",
            labels=("solver", "engine"), reservoir=256,
        ).observe(time.perf_counter() - t0, solver=solver.name,
                  engine=engine)
        return PlanResult(variants=names, results=out.cells,
                          costs=out.cost_tensor(names), engine=engine,
                          seconds=time.perf_counter() - t0,
                          robust_requested=bool(request.robust),
                          solver=solver.name, lower_bound=out.lower,
                          mip_gap=out.mip_gap,
                          mapping_mode=request.mapping,
                          mappings=None if outcomes is None else
                          tuple(o.mapping for o in outcomes),
                          mapping_info=None if outcomes is None else
                          tuple(o.info for o in outcomes))

    def session(self, instances, window_profiles, **kw):
        """An async rolling-horizon :class:`~repro.api.session
        .PlanningSession` over this planner; see its docstring."""
        from repro.api.session import PlanningSession

        return PlanningSession(self, instances, window_profiles, **kw)
