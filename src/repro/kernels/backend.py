"""Backend auto-detection for the Pallas kernels.

``interpret=None`` (the default everywhere) resolves to "interpret exactly
when the JAX default backend is CPU": the container runs the kernels through
the Pallas interpreter, while on a TPU runtime the same call sites compile
to Mosaic with no caller changes.
"""
from __future__ import annotations


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag against the active backend.

    A thin projection of :func:`resolve_mode` for kernels that only have a
    compiled and an interpreted path (no jnp twin): every ``interpret=None``
    decision in the tree routes through the same mode resolution, so no two
    call sites can disagree on the active backend.
    """
    return resolve_mode(interpret) != "pallas"


def resolve_mode(interpret: bool | None) -> str:
    """Kernel execution mode for the tri-state ``interpret`` flag.

    ``None`` (auto) picks the fastest exact path for the backend: the
    Mosaic-compiled Pallas kernel on TPU, the pure-jnp XLA formulation on
    CPU (bit-identical outputs, orders of magnitude faster than the Pallas
    interpreter). Explicit ``True`` forces the Pallas interpreter (the
    kernel-logic test path); explicit ``False`` forces the compiled kernel.
    """
    if interpret is None:
        import jax
        return "jnp" if jax.default_backend() == "cpu" else "pallas"
    return "interpret" if interpret else "pallas"


def resolve_solver(solver: str | None):
    """Resolve a ``PlanRequest.solver`` spelling to a registered
    :class:`repro.core.solvers.Solver`.

    The solver-axis generalization of :func:`resolve_engine`: the solver
    picks WHICH backend serves the grid (heuristic portfolio, exact
    ILP/DP dispatch, asap baseline), while ``engine=`` remains the
    heuristic solver's sub-knob (numpy vs jax fan-out). ``None``/"auto"
    resolve to the heuristic solver — the historical behaviour of every
    request that predates the axis.
    """
    from repro.core.solvers import get_solver

    return get_solver("heuristic" if solver in (None, "auto") else solver)


def resolve_lp_form(num_tasks: int, budget_bytes: int | None = None) -> str:
    """Longest-path representation for the jax engine: ``"dense"`` or
    ``"blocked"``.

    THE dense-vs-blocked decision rule, shared by
    :meth:`repro.core.portfolio.PreparedGraph.lp` and
    :func:`repro.core.greedy_jax.lp_for`: the O(N^2) int32 matrix when it
    fits ``budget_bytes`` (default
    :data:`repro.core.greedy_jax.LP_MAX_BYTES`) — the fast path, resident
    on device — and the O(N * B) streamed
    :class:`repro.core.greedy_jax.BlockedLP` form past it. Centralized
    here next to :func:`resolve_engine`/:func:`resolve_mode` so no two
    call sites can disagree on where the envelope sits.
    """
    from repro.core.greedy_jax import LP_MAX_BYTES, lp_matrix_bytes

    limit = LP_MAX_BYTES if budget_bytes is None else int(budget_bytes)
    return "dense" if lp_matrix_bytes(num_tasks) <= limit else "blocked"


def resolve_engine(engine: str | None, fanout: int = 1) -> str:
    """Resolve a scheduling-engine request to ``"numpy"`` or ``"jax"``.

    The single source of the ``engine="auto"`` rule shared by
    :class:`repro.api.Planner` and :class:`repro.runtime.carbon_gate
    .CarbonGate`: ``auto`` picks the device fan-out as soon as the request
    actually fans out (``fanout`` = number of (instance, profile) cells
    > 1 — replanning loops amortize the jit cache and the vmapped launch
    pays off immediately), and the numpy engine for one-off single-cell
    calls (where compile latency would dominate). The heuristic-solver
    sub-knob of the wider :func:`resolve_solver` axis.
    """
    if engine in (None, "auto"):
        return "jax" if fanout > 1 else "numpy"
    if engine not in ("numpy", "jax"):
        raise ValueError(f"unknown engine {engine!r}")
    return engine


def enable_compilation_cache(path: str | None = None) -> str | None:
    """Persist compiled executables across processes (best effort).

    The in-process jit cache already reuses executables across calls (the
    fan-out pads its inputs to shape buckets precisely so distinct
    instances hit it); this extends the reuse across process restarts —
    benchmark re-runs and replanning daemons skip the cold compile.
    Returns the cache dir, or None when the jax version refuses.
    """
    import os

    if path is None:
        path = os.path.join(os.path.expanduser("~"), ".cache",
                            "repro-jax-cache")
    try:
        import jax
        os.makedirs(path, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", path)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        return path
    except Exception:
        return None
