"""Backend auto-detection for the Pallas kernels.

``interpret=None`` (the default everywhere) resolves to "interpret exactly
when the JAX default backend is CPU": the container runs the kernels through
the Pallas interpreter, while on a TPU runtime the same call sites compile
to Mosaic with no caller changes.
"""
from __future__ import annotations


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag against the active backend."""
    if interpret is None:
        import jax
        return jax.default_backend() == "cpu"
    return bool(interpret)
