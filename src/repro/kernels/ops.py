"""Public jit'd entry points for the Pallas kernels.

``interpret`` defaults to True in this CPU container (TPU is the lowering
TARGET); on a real TPU runtime pass ``interpret=False``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.carbon_cost import deficit_timeline
from repro.kernels.gain_scan import gain_scan


def carbon_cost(starts, durs, works, g_eff, *, interpret: bool = True):
    """Total carbon cost of a schedule (scalar f32)."""
    starts = jnp.asarray(starts, jnp.float32)
    ends = starts + jnp.asarray(durs, jnp.float32)
    return deficit_timeline(
        starts, ends, jnp.asarray(works, jnp.float32),
        jnp.asarray(g_eff, jnp.float32), interpret=interpret).sum()


def ls_gains(rem, start, dur, work, lo, hi, *, mu: int = 10,
             interpret: bool = True):
    """Local-search gain matrix f32[N, 2*mu+1] (illegal moves = -1e30)."""
    return gain_scan(
        jnp.asarray(rem, jnp.float32), jnp.asarray(start, jnp.float32),
        jnp.asarray(dur, jnp.float32), jnp.asarray(work, jnp.float32),
        jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32),
        mu=mu, interpret=interpret)
