"""Public jit'd entry points for the Pallas kernels.

``interpret=None`` auto-detects the backend (interpret on CPU, compile on
TPU — see :mod:`repro.kernels.backend`); pass an explicit bool to override.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.carbon_cost import deficit_timeline
from repro.kernels.gain_scan import gain_scan, gain_scan_batched


def carbon_cost(starts, durs, works, g_eff, *, interpret: bool | None = None):
    """Total carbon cost of a schedule (scalar f32)."""
    starts = jnp.asarray(starts, jnp.float32)
    ends = starts + jnp.asarray(durs, jnp.float32)
    return deficit_timeline(
        starts, ends, jnp.asarray(works, jnp.float32),
        jnp.asarray(g_eff, jnp.float32), interpret=interpret).sum()


def ls_gains(rem, start, dur, work, lo, hi, *, mu: int = 10,
             interpret: bool | None = None):
    """Local-search gain matrix f32[N, 2*mu+1] (illegal moves = -1e30)."""
    return gain_scan(
        jnp.asarray(rem, jnp.float32), jnp.asarray(start, jnp.float32),
        jnp.asarray(dur, jnp.float32), jnp.asarray(work, jnp.float32),
        jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32),
        mu=mu, interpret=interpret)


def ls_gains_batched(rem, start, dur, work, lo, hi, *, mu: int = 10,
                     interpret: bool | None = None):
    """Batched gain matrices f32[B, N, 2*mu+1] in ONE kernel launch.

    ``rem``/``start``/``lo``/``hi`` carry a leading batch axis [B, ...]
    (one row per portfolio variant); ``dur``/``work`` are shared [N].
    """
    return gain_scan_batched(
        jnp.asarray(rem, jnp.float32), jnp.asarray(start, jnp.float32),
        jnp.asarray(dur, jnp.float32), jnp.asarray(work, jnp.float32),
        jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32),
        mu=mu, interpret=interpret)
