"""Pallas TPU kernel: per-unit carbon-deficit timeline from task arrays.

Computes, for every time unit t, ``max(sum_i w_i * active_i(t) - g_eff(t), 0)``
— the paper's carbon cost integrand (§3) — by tiling time into VMEM-resident
tiles and streaming task chunks through VMEM. The (task x time) activity
outer-comparison maps onto the VPU's (sublane x lane) grid; the task-chunk
grid axis accumulates into a VMEM scratch, the final chunk applies the
budget subtraction + relu.

Grid: (time_tiles, task_chunks)   — task_chunks is the reduction axis.
Blocks:
  starts/ends/works: (1, TASK_CHUNK)   f32, revisited per time tile;
  g_eff:             (1, TIME_TILE)    f32, per time tile;
  out:               (1, TIME_TILE)    f32, revisited across task chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

TIME_TILE = 512
TASK_CHUNK = 512


def _kernel(starts_ref, ends_ref, works_ref, g_ref, t0_ref, out_ref, acc_ref):
    tile = pl.program_id(0)
    chunk = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(chunk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # time coordinates of this tile: t0 + tile*TIME_TILE + [0..TIME_TILE)
    t = (t0_ref[0] + tile * TIME_TILE
         + jax.lax.broadcasted_iota(jnp.float32, (1, TIME_TILE), 1))
    s = starts_ref[...]            # (1, TASK_CHUNK)
    e = ends_ref[...]
    w = works_ref[...]
    # (TASK_CHUNK, TIME_TILE) activity matrix on the VPU
    active = ((s.T <= t) & (t < e.T)).astype(jnp.float32)
    acc_ref[...] += jnp.sum(w.T * active, axis=0, keepdims=True)

    @pl.when(chunk == n_chunks - 1)
    def _finish():
        out_ref[...] = jnp.maximum(acc_ref[...] - g_ref[...], 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def deficit_timeline(starts, ends, works, g_eff, *,
                     interpret: bool | None = None):
    """Per-unit deficit (cost) timeline.

    Args:
      starts, ends, works: f32[N] task windows and work powers. Pad tasks
        with zero-length windows (start == end) — they contribute nothing.
      g_eff: f32[T] effective green budget per unit; T padded to TIME_TILE
        (pad with +inf so padding units cost 0).
      interpret: None = auto (interpret iff the backend is CPU).
    Returns:
      f32[T] with ``max(power(t) - g_eff(t), 0)``.
    """
    interpret = resolve_interpret(interpret)
    (n,) = starts.shape
    (T,) = g_eff.shape
    n_pad = -n % TASK_CHUNK
    t_pad = -T % TIME_TILE
    starts = jnp.pad(starts, (0, n_pad)).reshape(1, -1)
    ends = jnp.pad(ends, (0, n_pad)).reshape(1, -1)
    works = jnp.pad(works, (0, n_pad)).reshape(1, -1)
    g = jnp.pad(g_eff, (0, t_pad), constant_values=jnp.inf).reshape(1, -1)
    n_tiles = g.shape[1] // TIME_TILE
    n_chunks = starts.shape[1] // TASK_CHUNK
    t0 = jnp.zeros((1,), dtype=jnp.float32)

    out = pl.pallas_call(
        _kernel,
        grid=(n_tiles, n_chunks),
        in_specs=[
            pl.BlockSpec((1, TASK_CHUNK), lambda i, j: (0, j)),
            pl.BlockSpec((1, TASK_CHUNK), lambda i, j: (0, j)),
            pl.BlockSpec((1, TASK_CHUNK), lambda i, j: (0, j)),
            pl.BlockSpec((1, TIME_TILE), lambda i, j: (0, i)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, TIME_TILE), lambda i, j: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, g.shape[1]), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, TIME_TILE), jnp.float32)],
        interpret=interpret,
    )(starts, ends, works, g, t0)
    return out.reshape(-1)[:T]
