"""Pure-jnp oracles for the Pallas kernels (same shapes & semantics)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def deficit_timeline_ref(starts, ends, works, g_eff):
    """O(N*T) dense oracle for kernels.carbon_cost.deficit_timeline."""
    T = g_eff.shape[0]
    t = jnp.arange(T, dtype=jnp.float32)[None, :]
    active = ((starts[:, None] <= t) & (t < ends[:, None])).astype(jnp.float32)
    power = (works[:, None] * active).sum(axis=0)
    return jnp.maximum(power - g_eff, 0.0)


def gain_scan_ref(rem, start, dur, work, lo, hi, *, mu: int = 10):
    """Oracle for kernels.gain_scan.gain_scan, vectorized over (task, shift).

    Uses the direct definition: total deficit of the timeline after the move
    minus before, evaluated only on the +-mu neighbourhood (identical to the
    kernel's symmetric-difference form).
    """
    T = rem.shape[0]
    t = jnp.arange(T, dtype=jnp.float32)

    def one(s, d, w, l, h):
        old = ((s <= t) & (t < s + d)).astype(jnp.float32)
        base = rem + w * old          # timeline without the task

        def for_delta(delta):
            ns = s + delta
            new = ((ns <= t) & (t < ns + d)).astype(jnp.float32)
            before = jnp.maximum(-(base - w * old), 0.0).sum()
            after = jnp.maximum(-(base - w * new), 0.0).sum()
            legal = (l <= ns) & (ns <= h) & (delta != 0) & (w > 0)
            return jnp.where(legal, before - after, -1e30)

        deltas = jnp.arange(-mu, mu + 1, dtype=jnp.float32)
        return jax.vmap(for_delta)(deltas)

    return jax.vmap(one)(start, dur, work, lo, hi)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Dense-softmax oracle for kernels.flash_attention."""
    B, S, H, hd = q.shape
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * hd ** -0.5
    if causal:
        m = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(m[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)
