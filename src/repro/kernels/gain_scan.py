"""Local-search gain sweep (paper §5.3, batched): a tiled Pallas kernel +
an exact jnp twin that serves CPU.

For every task i and every shift delta in [-mu, mu], computes the exact
carbon-cost gain of moving task i by delta, given the current remaining-
budget timeline. Only the symmetric difference of the old/new execution
windows contributes, and both difference regions lie within ``mu`` units of
the task's start (s) or end (e). The wrapper therefore gathers two
lane-aligned windows of the timeline per task,

    win_s[i, j] = rem[s_i - PAD + j],   win_e[i, j] = rem[e_i - PAD + j],

and evaluates all 2*mu+1 shifts for every task at once. Two executors over
the same windows (``repro.kernels.backend.resolve_mode`` picks one):

* :func:`_gain_kernel` — the tiled Pallas kernel, blocked over the
  candidate(-segment) axis: the grid walks ``TASK_TILE``-row tiles of the
  flattened candidate axis (a "parallel" grid dimension — tiles are
  independent), every tile holds its two (TASK_TILE, W) windows in VMEM,
  and the 2*mu+1 shift columns are written as ONE lane-aligned
  (TASK_TILE, W) store built by select-accumulation over a lane iota.
  Every op is a 2-D VPU op (masked reductions over the 128-lane window
  axis) — no concatenate/pad inside the kernel — so the same body lowers
  through Mosaic on TPU and runs under the interpreter on CPU.
* :func:`gains_from_windows` — the jnp twin: every delta's masked window
  sum is a contiguous range, so all 2*mu+1 gains fall out of four prefix
  sums (O(N*mu) instead of O(N*W*mu)). All summands are integers below
  2^24, so f32 accumulation is exact in any order and the two paths are
  bit-identical (tested). This is the CPU fast path and stays the gain
  oracle of the device-resident climb on CPU; on TPU the climb routes
  through the compiled kernel (:func:`gains_windows_auto`).

The jnp twin wins at small N (four prefix sums beat 2*mu+1 masked
reductions until the kernel's tiling amortizes); the measured crossover
vs the kernel is recorded in ``BENCH_portfolio.json`` under
``sharded["gain_kernel"]`` (``make bench-smoke``).

Gain identities (rem includes the task at its old position; the newly
occupied region never overlaps the old window, so rem == rem-without-task
there):
  released(t) = min(max(-rem[t], 0), w)          on vacated units
  incurred(t) = min(max(w - max(rem[t], 0), 0), w)  on newly occupied units
  gain(delta) = sum released - sum incurred ;  illegal shifts -> -BIG.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_mode

TASK_TILE = 256
W = 128          # lane-aligned window length; supports mu <= 42
NEG = -1e30


def _gain_kernel(mu: int, win_s_ref, win_e_ref, w_ref, dur_ref, lo_ref,
                 hi_ref, out_ref):
    """One candidate tile of the gain sweep; all ops 2-D, Mosaic-lowerable.

    Refs (one grid step = one TASK_TILE tile of the candidate axis):
      win_s/win_e: f32 (TASK_TILE, W) timeline windows around start/end.
      w/dur/lo/hi: f32 (TASK_TILE, 1) work, duration, RELATIVE legal
        shift bounds (lo > hi marks a row with no legal move).
      out: f32 (TASK_TILE, W) — lane d holds the gain of shift d - mu for
        d < 2*mu+1, NEG beyond (the caller slices the real columns).

    The shift loop is a static unroll (mu is a compile-time constant):
    per delta, the vacated/occupied sums are two masked reductions over
    the W lanes, and the resulting column is merged into the lane-aligned
    accumulator with a select against the lane iota — the whole tile is
    written back as one aligned store, so the kernel compiles on TPU
    instead of living interpreter-only.
    """
    pad = mu
    win_s = win_s_ref[...]                      # (TASK_TILE, W)
    win_e = win_e_ref[...]
    w = w_ref[...]                              # (TASK_TILE, 1)
    dur = dur_ref[...]
    lo = lo_ref[...]
    hi = hi_ref[...]
    j = jax.lax.broadcasted_iota(jnp.float32, (1, W), 1)
    lane = jax.lax.broadcasted_iota(jnp.int32, out_ref.shape, 1)

    released_s = jnp.minimum(jnp.maximum(-win_s, 0.0), w)
    released_e = jnp.minimum(jnp.maximum(-win_e, 0.0), w)
    incurred_s = jnp.minimum(jnp.maximum(w - jnp.maximum(win_s, 0.0), 0.0), w)
    incurred_e = jnp.minimum(jnp.maximum(w - jnp.maximum(win_e, 0.0), 0.0), w)

    acc = jnp.full(out_ref.shape, NEG, jnp.float32)
    for d in range(2 * mu + 1):
        delta = d - mu
        ln = jnp.minimum(jnp.float32(abs(delta)), dur)   # (TASK_TILE, 1)
        if delta > 0:
            # vacated: times [s, s+ln)         -> win_s j in [pad, pad+ln)
            vac = (j >= pad) & (j < pad + ln)
            rel = jnp.sum(jnp.where(vac, released_s, 0.0), axis=1,
                          keepdims=True)
            # occupied: times [e+delta-ln, e+delta) -> win_e j
            occ = (j >= pad + delta - ln) & (j < pad + delta)
            inc = jnp.sum(jnp.where(occ, incurred_e, 0.0), axis=1,
                          keepdims=True)
        elif delta < 0:
            # vacated: times [e-ln, e)         -> win_e j in [pad-ln, pad)
            vac = (j >= pad - ln) & (j < pad)
            rel = jnp.sum(jnp.where(vac, released_e, 0.0), axis=1,
                          keepdims=True)
            # occupied: times [s+delta, s+delta+ln) -> win_s j
            occ = (j >= pad + delta) & (j < pad + delta + ln)
            inc = jnp.sum(jnp.where(occ, incurred_s, 0.0), axis=1,
                          keepdims=True)
        else:
            rel = jnp.zeros_like(w)
            inc = jnp.zeros_like(w)
        gain = rel - inc
        legal = (lo <= delta) & (delta <= hi) & (delta != 0) & (w > 0)
        col = jnp.where(legal, gain, NEG)                # (TASK_TILE, 1)
        acc = jnp.where(lane == d, col, acc)
    out_ref[...] = acc


def gather_windows(rem, start, dur, *, mu: int):
    """(win_s, win_e) f32[N, W] timeline windows around start and end."""
    t_total = rem.shape[0]
    rem_pad = jnp.pad(rem, (W, W))
    idx = jnp.arange(W)[None, :] - mu
    s_i = start.astype(jnp.int32)
    e_i = (start + dur).astype(jnp.int32)
    win_s = rem_pad[jnp.clip(s_i[:, None] + idx + W, 0, t_total + 2 * W - 1)]
    win_e = rem_pad[jnp.clip(e_i[:, None] + idx + W, 0, t_total + 2 * W - 1)]
    return win_s, win_e


def gains_from_windows(win_s, win_e, work, dur, lo_rel, hi_rel, *, mu: int):
    """The kernel's gain matrix from pre-gathered windows, in pure jnp.

    Every delta's vacated/occupied region is a contiguous index range in
    its window, so the masked sums collapse to differences of four prefix
    sums. Bit-identical to :func:`_gain_kernel` (integer summands, exact
    in f32).

    Args:
      win_s, win_e: f32[N, W] from :func:`gather_windows`.
      work, dur:    f32[N].
      lo_rel, hi_rel: f32[N] legal shift bounds RELATIVE to the current
        start (lo_rel > hi_rel marks a row with no legal move).
    Returns:
      f32[N, 2*mu+1]; illegal moves = -1e30.
    """
    pad = mu
    w = work[:, None]
    released_s = jnp.minimum(jnp.maximum(-win_s, 0.0), w)
    released_e = jnp.minimum(jnp.maximum(-win_e, 0.0), w)
    incurred_s = jnp.minimum(jnp.maximum(w - jnp.maximum(win_s, 0.0), 0.0), w)
    incurred_e = jnp.minimum(jnp.maximum(w - jnp.maximum(win_e, 0.0), 0.0), w)

    def csum(x):                                  # [N, W] -> [N, W+1]
        z = jnp.zeros((x.shape[0], 1), x.dtype)
        return jnp.concatenate([z, jnp.cumsum(x, axis=1)], axis=1)

    r_s, r_e = csum(released_s), csum(released_e)
    i_s, i_e = csum(incurred_s), csum(incurred_e)

    delta = jnp.arange(-mu, mu + 1, dtype=jnp.int32)[None, :]   # [1, D]
    ln = jnp.minimum(jnp.abs(delta), dur[:, None].astype(jnp.int32))

    def take(c, i):
        # indices of the inapplicable delta branch may leave [0, W]; they
        # are masked out below, so clip them into range first
        return jnp.take_along_axis(c, jnp.clip(i, 0, W), axis=1)

    # delta > 0: vacated [pad, pad+ln) of win_s, occupied
    # [pad+delta-ln, pad+delta) of win_e
    g_pos = (take(r_s, pad + ln) - r_s[:, pad:pad + 1]) \
        - (take(i_e, pad + delta) - take(i_e, pad + delta - ln))
    # delta < 0: vacated [pad-ln, pad) of win_e, occupied
    # [pad+delta, pad+delta+ln) of win_s
    g_neg = (r_e[:, pad:pad + 1] - take(r_e, pad - ln)) \
        - (take(i_s, pad + delta + ln) - take(i_s, pad + delta))
    gain = jnp.where(delta > 0, g_pos, jnp.where(delta < 0, g_neg, 0.0))

    deltaf = delta.astype(win_s.dtype)
    legal = ((lo_rel[:, None] <= deltaf) & (deltaf <= hi_rel[:, None])
             & (delta != 0) & (work[:, None] > 0))
    return jnp.where(legal, gain, NEG)


def _kernel_call(win_s, win_e, work, dur, lo_rel, hi_rel, *, mu: int,
                 mode: str):
    """Launch :func:`_gain_kernel` over TASK_TILE tiles of the candidate
    axis (``mode`` = "pallas" compiled / "interpret")."""
    n = win_s.shape[0]
    n_pad = -n % TASK_TILE

    def pad2(x, v=0.0):
        return jnp.pad(x, ((0, n_pad), (0, 0)), constant_values=v)

    win_s = pad2(win_s)
    win_e = pad2(win_e)
    w2 = pad2(work[:, None])
    dur2 = pad2(dur[:, None])
    lo2 = pad2(lo_rel[:, None], v=1.0)   # lo > hi on padding => illegal
    hi2 = pad2(hi_rel[:, None], v=-1.0)

    n_tiles = (n + n_pad) // TASK_TILE
    kwargs = {}
    if mode == "pallas":
        # candidate tiles are independent: let Mosaic parallelize the grid
        from jax.experimental.pallas import tpu as pltpu
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel",))
    out = pl.pallas_call(
        functools.partial(_gain_kernel, mu),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((TASK_TILE, W), lambda i: (i, 0)),
            pl.BlockSpec((TASK_TILE, W), lambda i: (i, 0)),
            pl.BlockSpec((TASK_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((TASK_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((TASK_TILE, 1), lambda i: (i, 0)),
            pl.BlockSpec((TASK_TILE, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((TASK_TILE, W), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + n_pad, W), jnp.float32),
        interpret=(mode == "interpret"),
        **kwargs,
    )(win_s, win_e, w2, dur2, lo2, hi2)
    return out[:n, :2 * mu + 1]


def gains_windows_auto(win_s, win_e, work, dur, lo_rel, hi_rel, *,
                       mu: int, interpret: bool | None = None):
    """Mode-dispatched gain matrix over pre-gathered windows.

    The shared oracle of :func:`gain_scan` and the device-resident climb
    (:mod:`repro.core.local_search_jax`): CPU resolves to the jnp
    prefix-sum twin, TPU/GPU to the compiled tiled kernel,
    ``interpret=True`` forces the Pallas interpreter — all three
    bit-identical (integer summands, exact in f32; tested).
    Bounds are RELATIVE to the current start, as in
    :func:`gains_from_windows`.
    """
    assert mu <= (W // 2) - 22, f"mu={mu} too large for W={W}"
    mode = resolve_mode(interpret)
    if mode == "jnp":
        return gains_from_windows(win_s, win_e, work, dur, lo_rel, hi_rel,
                                  mu=mu)
    return _kernel_call(win_s, win_e, work, dur, lo_rel, hi_rel, mu=mu,
                        mode=mode)


@functools.partial(jax.jit, static_argnames=("mu", "interpret"))
def gain_scan(rem, start, dur, work, lo, hi, *, mu: int = 10,
              interpret: bool | None = None):
    """All-pairs (task, shift) gains.

    Args:
      rem:  f32[T] remaining-budget timeline (g_eff - active work power).
      start, dur, work: f32[N].
      lo, hi: f32[N] legal *absolute* start-time bounds per task.
      mu: max shift.
      interpret: None = auto (jnp twin on CPU, compiled kernel on TPU);
        True = Pallas interpreter; False = compiled kernel.
    Returns:
      f32[N, 2*mu+1]; entry (i, d) = gain of moving task i by (d - mu);
      illegal moves = -1e30.
    """
    win_s, win_e = gather_windows(rem, start, dur, mu=mu)
    return gains_windows_auto(win_s, win_e, work, dur, lo - start,
                              hi - start, mu=mu, interpret=interpret)


def _gain_scan_windows(win_s, win_e, start, dur, work, lo, hi, *, mu,
                       interpret):
    """Legacy absolute-bounds spelling of :func:`gains_windows_auto`."""
    return gains_windows_auto(win_s, win_e, work, dur, lo - start,
                              hi - start, mu=mu, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("mu", "interpret"))
def gain_scan_batched(rem, start, dur, work, lo, hi, *, mu: int = 10,
                      interpret: bool | None = None):
    """Gains for a whole portfolio of schedules in ONE kernel launch.

    The kernel body is per-task-independent once the timeline windows are
    gathered, so a batch of B schedules (portfolio variants, ensemble
    profiles, or both flattened) becomes a (B*N)-task problem: windows are
    gathered per (batch row, task) from that row's timeline, and a single
    launch covers all rows.

    Args:
      rem:  f32[B, T] per-row remaining-budget timelines.
      start, lo, hi: f32[B, N] per-row schedules / legal bounds.
      dur, work: f32[N], shared across rows (same instance).
      mu: max shift.
      interpret: None = auto (see :func:`gain_scan`).
    Returns:
      f32[B, N, 2*mu+1].
    """
    B, n = start.shape
    win = jnp.arange(W)[None, None, :] - mu                   # (1, 1, W)
    rem_pad = jnp.pad(rem, ((0, 0), (W, W)))
    t_total = rem.shape[1]
    s_i = start.astype(jnp.int32)
    e_i = (start + dur[None, :]).astype(jnp.int32)
    idx_s = jnp.clip(s_i[:, :, None] + win + W, 0, t_total + 2 * W - 1)
    idx_e = jnp.clip(e_i[:, :, None] + win + W, 0, t_total + 2 * W - 1)
    win_s = jnp.take_along_axis(rem_pad[:, None, :], idx_s, axis=2)
    win_e = jnp.take_along_axis(rem_pad[:, None, :], idx_e, axis=2)

    flat = _gain_scan_windows(
        win_s.reshape(B * n, W), win_e.reshape(B * n, W),
        start.reshape(B * n), jnp.tile(dur, B), jnp.tile(work, B),
        lo.reshape(B * n), hi.reshape(B * n), mu=mu, interpret=interpret)
    return flat.reshape(B, n, 2 * mu + 1)
