"""Pallas TPU kernel: fused causal flash attention (forward).

The §Perf loop identified the unfused attention score buffers as the next
lever on the memory-dominant train cells (EXPERIMENTS §Perf, Pair 3): the
q-chunked jnp path still materializes (QCHUNK x S) scores in HBM on the CPU
pipeline. This kernel keeps the whole softmax in VMEM with the standard
online-softmax recurrence:

  grid = (batch*heads, q_blocks, k_blocks)      k_blocks is the reduction
  blocks: q (BQ, hd), k/v (BK, hd), out (BQ, hd)
  scratch: acc f32 (BQ, hd), m/l f32 (BQ, 1)

Causal masking is positional (q_idx >= k_idx); fully-masked k-blocks are
skipped. Forward-only: training integration would wrap it in jax.custom_vjp
with the recomputation backward (future work, noted in EXPERIMENTS).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

BQ = 128
BK = 128
NEG = -1e30


def _kernel(scale: float, seq: int, causal: bool,
            q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * BQ + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 0)
    k_pos = ki * BK + jax.lax.broadcasted_iota(jnp.int32, (BQ, BK), 1)

    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        mask = k_pos < seq                      # padded keys
        if causal:
            mask = mask & (k_pos <= q_pos)
        s = jnp.where(mask, s, NEG)

        m_prev = m_ref[...]                     # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr + pv
        m_ref[...] = m_new

    if causal:
        pl.when(ki * BK <= qi * BQ + BQ - 1)(_block)
    else:
        _block()

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    interpret: bool | None = None):
    """Fused attention. q/k/v: [B, S, H, hd] (kv heads already expanded).

    Returns [B, S, H, hd]. S is padded to the block size internally; padded
    keys are masked, padded queries are sliced off. ``interpret=None`` = auto
    (interpret iff the backend is CPU).
    """
    interpret = resolve_interpret(interpret)
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd)
    scale = hd ** -0.5
    s_pad = -S % max(BQ, BK)

    def prep(x):
        x = jnp.moveaxis(x, 2, 1).reshape(B * H, S, hd)
        return jnp.pad(x, ((0, 0), (0, s_pad), (0, 0)))

    qf, kf, vf = prep(q), prep(k), prep(v)
    sp = S + s_pad
    grid = (B * H, sp // BQ, sp // BK)
    out = pl.pallas_call(
        functools.partial(_kernel, scale, S, causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BQ, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BK, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BK, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BQ, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BQ, hd), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
            pltpu.VMEM((BQ, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    out = out[:, :S].reshape(B, H, S, hd)
    return jnp.moveaxis(out, 1, 2)
