"""Serving driver: continuous batching over the decode step.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.models import build_model, param_count
from repro.serve import ContinuousBatcher, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=512)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    model = build_model(cfg, tp=16)
    params = model.init(jax.random.PRNGKey(0))
    print(f"{cfg.name}: {param_count(params) / 1e6:.2f}M params")

    batcher = ContinuousBatcher(model, params, batch_size=args.slots,
                                max_len=args.max_len, eos=0)
    rng = np.random.default_rng(0)
    for rid in range(args.requests):
        batcher.submit(Request(
            rid=rid,
            prompt=rng.integers(1, cfg.vocab, rng.integers(2, 8)).tolist(),
            max_tokens=args.max_new))
    t0 = time.time()
    steps = 0
    while batcher.queue or any(r is not None and not r.done
                               for r in batcher.slots):
        batcher.step()
        steps += 1
    dt = time.time() - t0
    print(f"{args.requests} requests, {steps} decode steps, {dt:.1f}s "
          f"({steps * args.slots / max(dt, 1e-9):.1f} tok/s)")


if __name__ == "__main__":
    main()
