import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Two modes per cell:
  * compile — full-depth model (scan over layers), production mesh,
    ``.lower().compile()`` must succeed; records memory_analysis() and the
    collective schedule (post-SPMD HLO).
  * cost    — roofline terms. XLA cost_analysis counts scan bodies once, so
    we lower small UNROLLED depth variants (L in {1,2}; jamba {8,16} = 1-2
    groups; whisper {(1,1),(2,1),(1,2)}) and fit the exact linear-in-depth
    cost model total(L) = a + b*L, then evaluate at the true depth
    (everything — fwd/bwd, optimizer, collectives — is linear in L).

Usage: python -m repro.launch.dryrun --arch qwen2.5-3b --shape train_4k \
         --mesh single --mode both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.launch.mesh import batch_axes, data_size, make_production_mesh
from repro.models import build_model, input_specs, model_flops
from repro.models import unroll as unroll_mod
from repro.models import xlstm as xlstm_mod
from repro.roofline.analysis import HW, collective_bytes, roofline_terms
from repro.sharding.ctx import configure
from repro.sharding.specs import batch_specs, cache_specs, tree_param_specs
from repro.train.optimizer import adamw_init
from repro.train.step import make_train_step

MICROBATCHES = {
    "arctic-480b": 16, "granite-34b": 8, "jamba-v0.1-52b": 8,
    "qwen2-vl-7b": 8, "qwen2.5-3b": 4, "whisper-large-v3": 4,
}

COST_CHUNK = 512        # bigger chunks for unrolled cost lowerings


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _depth_points(cfg):
    if cfg.family == "hybrid":
        return [cfg.attn_every, 2 * cfg.attn_every]
    if cfg.family == "audio":
        return [(1, 1), (2, 1), (1, 2)]
    if cfg.family == "ssm":
        return None                      # python-unrolled: exact as-is
    return [1, 2]


def _with_depth(cfg, pt, seq=4096):
    if cfg.family == "audio":
        e, d = pt
        return dataclasses.replace(cfg, encoder_layers=e, num_layers=d)
    kw = {"num_layers": pt}
    if cfg.family == "hybrid" and cfg.mamba is not None:
        # keep the unrolled chunk count at ~8 regardless of sequence length
        kw["mamba"] = dataclasses.replace(
            cfg.mamba, chunk=max(seq // 8, COST_CHUNK))
    if cfg.slstm_layers:
        kw["slstm_layers"] = tuple(i for i in cfg.slstm_layers if i < pt)
    return dataclasses.replace(cfg, **kw)


def _state_struct_and_specs(model, mesh, fsdp=True, mp=False):
    tp = mesh.shape["model"]
    dsize = data_size(mesh)
    params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = tree_param_specs(params, tp, dsize, fsdp=fsdp)
    opt = jax.eval_shape(lambda p: adamw_init(p, mixed_precision=mp), params)
    o_specs = {"m": p_specs, "v": p_specs, "step": P()}
    if mp:
        o_specs["master"] = p_specs
        params = jax.tree.map(
            lambda st: jax.ShapeDtypeStruct(st.shape, jnp.bfloat16), params)
    state = {"params": params, "opt": opt}
    specs = {"params": p_specs, "opt": o_specs}
    return state, specs


def _batch_struct_and_specs(cfg, shape, mesh):
    batch = input_specs(cfg, shape)
    specs = batch_specs(batch_axes(mesh), cfg, shape)
    return batch, specs


def _extract(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    return flops, byts, coll


def _memory(compiled):
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        out = {}
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                out[k] = int(v)
        return out
    except Exception as e:          # CPU backend may not support it
        return {"error": str(e)}


# ---------------------------------------------------------------------------
# lowering builders
# ---------------------------------------------------------------------------

def lower_train(cfg, shape, mesh, mb, *, with_opt=True, fsdp=True,
                mp=False):
    model = build_model(cfg, tp=mesh.shape["model"])
    configure(mesh)
    state, s_specs = _state_struct_and_specs(model, mesh, fsdp=fsdp, mp=mp)
    batch, b_specs = _batch_struct_and_specs(cfg, shape, mesh)
    if with_opt:
        step = make_train_step(model, microbatches=mb)
        in_sh = (_ns(mesh, s_specs), _ns(mesh, b_specs))
        out_sh = (_ns(mesh, s_specs),
                  _ns(mesh, {"loss": P(), "gnorm": P(), "lr": P()}))
        fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
        return fn.lower(state, batch)

    def fwdbwd(params, b):
        return jax.value_and_grad(model.loss)(params, b)

    in_sh = (_ns(mesh, s_specs["params"]), _ns(mesh, b_specs))
    out_sh = (NamedSharding(mesh, P()), _ns(mesh, s_specs["params"]))
    fn = jax.jit(fwdbwd, in_shardings=in_sh, out_shardings=out_sh)
    return fn.lower(state["params"], batch)


def lower_prefill(cfg, shape, mesh, fsdp=True):
    model = build_model(cfg, tp=mesh.shape["model"])
    configure(mesh)
    state, s_specs = _state_struct_and_specs(model, mesh, fsdp=fsdp)
    batch, b_specs = _batch_struct_and_specs(cfg, shape, mesh)

    if cfg.family == "audio":
        def prefill(params, b):
            enc = model.encode(params, b["enc_embeds"], remat=False)
            xk, xv = model._cross_kv(params, enc)
            return enc[:, -1], xk, xv
        out_sh = None
    else:
        def prefill(params, b):
            h = model.apply(params, b, remat=False)
            from repro.models import layers as L
            logits = L.unembed(h[:, -1:], params["embed"])
            return logits[:, 0]
        out_sh = None

    in_sh = (_ns(mesh, s_specs["params"]), _ns(mesh, b_specs))
    fn = jax.jit(prefill, in_shardings=in_sh)
    return fn.lower(state["params"], batch)


def lower_decode(cfg, shape, mesh, fsdp=True):
    model = build_model(cfg, tp=mesh.shape["model"])
    configure(mesh)
    state, s_specs = _state_struct_and_specs(model, mesh, fsdp=fsdp)
    din = input_specs(cfg, shape, model=model)
    tp = mesh.shape["model"]
    kv_shardable = (model.hkv % tp == 0) if hasattr(model, "hkv") else False
    c_specs = cache_specs(batch_axes(mesh), cfg, shape.batch,
                          kv_shardable, data_size(mesh))
    ba = batch_axes(mesh) if shape.batch >= data_size(mesh) else None
    tok_spec = P(ba) if ba else P()

    def serve_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    v_ax = "model" if cfg.vocab % tp == 0 else None
    in_sh = (_ns(mesh, s_specs["params"]), _ns(mesh, c_specs),
             NamedSharding(mesh, tok_spec))
    out_sh = (NamedSharding(mesh, P(ba, v_ax) if ba else P(None, v_ax)),
              _ns(mesh, c_specs))
    fn = jax.jit(serve_step, in_shardings=in_sh, out_shardings=out_sh)
    return fn.lower(state["params"], din["cache"], din["tokens"])


def _lower_for(cfg, shape, mesh, mb, kind, **kw):
    if shape.kind == "train":
        return lower_train(cfg, shape, mesh, mb, **kw)
    if shape.kind == "prefill":
        return lower_prefill(cfg, shape, mesh,
                             **{k: v for k, v in kw.items() if k == "fsdp"})
    return lower_decode(cfg, shape, mesh,
                        **{k: v for k, v in kw.items() if k == "fsdp"})


# ---------------------------------------------------------------------------
# cost calibration
# ---------------------------------------------------------------------------

def _slstm_correction(cfg, shape) -> float:
    """Analytic FLOPs for the sequential sLSTM recurrence (scan-hidden)."""
    if cfg.family != "ssm" or not cfg.slstm_layers:
        return 0.0
    H, hd = cfg.num_heads, cfg.head_dim
    n = len(cfg.slstm_layers)
    steps = shape.seq if shape.kind != "decode" else 1
    per_tok = 4 * H * hd * hd * 2              # recurrent matmuls
    mult = 3.0 if shape.kind == "train" else 1.0
    return n * shape.batch * steps * per_tok * mult


def cost_cell(cfg, shape, mesh, mb, fsdp=True, mp=False):
    """Calibrated whole-step cost: flops, bytes, collective bytes/chip."""
    unroll_mod.set_unroll(True)
    old_chunk = xlstm_mod.CHUNK
    xlstm_mod.CHUNK = COST_CHUNK
    try:
        pts = _depth_points(cfg)
        is_train = shape.kind == "train"
        # per-microbatch shape for train cost lowering
        if is_train and mb > 1:
            shape_mb = dataclasses.replace(shape, batch=shape.batch // mb)
        else:
            shape_mb = shape

        if pts is None:     # xlstm: exact (python-unrolled everywhere)
            if is_train:
                lw_f = lower_train(cfg, shape_mb, mesh, 1, with_opt=False,
                                   fsdp=fsdp, mp=mp)
                lw_s = lower_train(cfg, shape_mb, mesh, 1, with_opt=True,
                                   fsdp=fsdp, mp=mp)
                f1, b1, c1 = _extract(lw_f.compile())
                f2, b2, c2 = _extract(lw_s.compile())
                flops = mb * f1 + (f2 - f1)
                byts = mb * b1 + (b2 - b1)
                coll = mb * c1["total"] + (c2["total"] - c1["total"])
            else:
                f, b, c = _extract(
                    _lower_for(cfg, shape_mb, mesh, 1, shape.kind).compile())
                flops, byts, coll = f, b, c["total"]
            flops += _slstm_correction(cfg, shape)
            return flops, byts, coll

        def measure(depth, with_opt):
            c2 = _with_depth(cfg, depth, seq=shape_mb.seq)
            if is_train:
                lw = lower_train(c2, shape_mb, mesh, 1, with_opt=with_opt,
                                 fsdp=fsdp, mp=mp)
            else:
                lw = _lower_for(c2, shape_mb, mesh, 1, shape.kind, fsdp=fsdp)
            f, b, c = _extract(lw.compile())
            return np.asarray([f, b, c["total"]], dtype=np.float64)

        if cfg.family == "audio":
            # total(e, d) = a + be*e + bd*d, exact from three points
            Le, Ld = cfg.encoder_layers, cfg.num_layers

            def solve3(m11, m21, m12):
                be = m21 - m11
                bd = m12 - m11
                a = m11 - be - bd
                return a + be * Le + bd * Ld

            m11, m21, m12 = (measure(p, False)
                             for p in ((1, 1), (2, 1), (1, 2)))
            fb = solve3(m11, m21, m12)
            if is_train:
                s11, s21, s12 = (measure(p, True)
                                 for p in ((1, 1), (2, 1), (1, 2)))
                opt = solve3(s11 - m11, s21 - m21, s12 - m12)
                fb = mb * fb + opt
            return tuple(float(x) for x in fb)

        # total(L) = a + b*L, exact from two points
        g1, g2 = pts
        if cfg.family == "hybrid":
            l1, l2 = 1, 2                       # depth unit = groups
            Ltrue = cfg.num_layers // cfg.attn_every
        else:
            l1, l2 = g1, g2
            Ltrue = cfg.num_layers

        def solve2(vA, vB):
            b = (vB - vA) / (l2 - l1)
            a = vA - b * l1
            return a + b * Ltrue

        mA, mB = measure(g1, False), measure(g2, False)
        fb = solve2(mA, mB)
        if is_train:
            sA, sB = measure(g1, True), measure(g2, True)
            fb = mb * fb + solve2(sA - mA, sB - mB)
        return tuple(float(x) for x in fb)
    finally:
        unroll_mod.set_unroll(False)
        xlstm_mod.CHUNK = old_chunk


# ---------------------------------------------------------------------------
# cell driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, mode: str,
             out_dir: str, fsdp: bool = True, mp: bool = False,
             moe_dispatch: str = "global", tag: str = "") -> dict:
    cfg = ARCHS[arch]
    if moe_dispatch != "global" and cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch=moe_dispatch))
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "mode": mode, "fsdp": fsdp, "mp": mp,
                 "moe_dispatch": moe_dispatch, "tag": tag}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec["skipped"] = reason
        _save(rec, out_dir)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    mb = MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1
    rec["chips"] = chips
    rec["microbatches"] = mb

    if mode in ("compile", "both"):
        t0 = time.time()
        kw = {"fsdp": fsdp, "mp": mp} if shape.kind == "train" else              {"fsdp": fsdp}
        lowered = _lower_for(cfg, shape, mesh, mb, shape.kind, **kw)
        rec["lower_s"] = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 1)
        rec["memory"] = _memory(compiled)
        f, b, c = _extract(compiled)
        rec["hlo_once"] = {"flops": f, "bytes": b, "collectives": c}

    if mode in ("cost", "both") and mesh_kind == "single":
        t0 = time.time()
        flops_dev, bytes_dev, coll = cost_cell(cfg, shape, mesh, mb,
                                               fsdp=fsdp, mp=mp)
        rec["cost_s"] = round(time.time() - t0, 1)
        model = build_model(cfg, tp=mesh.shape["model"])
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        mf = model_flops(cfg, params, shape)
        # cost_analysis reports the per-device (post-SPMD) program
        flops = flops_dev * chips
        byts = bytes_dev * chips
        rec["cost"] = {
            "hlo_flops": flops, "hlo_bytes": byts,
            "hlo_flops_per_chip": flops_dev,
            "collective_bytes_per_chip": coll,
            "model_flops": mf,
            "useful_ratio": mf / flops if flops else 0.0,
        }
        rec["roofline"] = roofline_terms(flops, byts, coll, chips)

    _save(rec, out_dir)
    return rec


def _save(rec: dict, out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}"
    if not rec.get("fsdp", True):
        name += "_nofsdp"
    if rec.get("tag"):
        name += "_" + rec["tag"]
    with open(os.path.join(out_dir, name + ".json"), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=sorted(ARCHS))
    ap.add_argument("--shape", required=True, choices=sorted(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--mode", default="both",
                    choices=["compile", "cost", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--mp", action="store_true",
                    help="bf16 live params + f32 master (halves gathers)")
    ap.add_argument("--moe-dispatch", default="global",
                    choices=["global", "sharded", "shardmap"])
    ap.add_argument("--tag", default="", help="output filename suffix")
    args = ap.parse_args()
    rec = run_cell(args.arch, args.shape, args.mesh, args.mode, args.out,
                   fsdp=not args.no_fsdp, mp=args.mp,
                   moe_dispatch=args.moe_dispatch, tag=args.tag)
    print(json.dumps(rec, indent=1, default=str))


if __name__ == "__main__":
    main()
