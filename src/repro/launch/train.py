"""Production train driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 --batch 8 --seq 256 [--reduced] [--mesh none|single|multi] \
        [--carbon-gate] [--mp] [--ckpt-dir DIR]

On real hardware the mesh flags select the production meshes of
launch/mesh.py; on this CPU container use ``--mesh none`` (default) with
``--reduced`` configs. The driver wires: config -> model -> sharded train
step -> deterministic data -> checkpoint manager -> (optional) CarbonGate.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, reduced
from repro.configs.base import ShapeConfig
from repro.core import generate_profile
from repro.data import SyntheticTokens
from repro.launch.mesh import batch_axes, data_size, make_production_mesh
from repro.models import build_model, param_count
from repro.runtime.carbon_gate import CarbonGate, fleet_platform
from repro.sharding.ctx import configure
from repro.train.step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="none",
                    choices=["none", "single", "multi"])
    ap.add_argument("--mp", action="store_true")
    ap.add_argument("--carbon-gate", action="store_true")
    ap.add_argument("--gate-chunk", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.reduced:
        cfg = dataclasses.replace(reduced(cfg), dtype="float32")
    tp = 16
    if args.mesh != "none":
        mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
        configure(mesh)
        tp = mesh.shape["model"]
    model = build_model(cfg, tp=tp)
    shape = ShapeConfig("cli", "train", args.seq, args.batch)
    data = SyntheticTokens(cfg, shape, seed=0)
    step_fn = jax.jit(make_train_step(model, microbatches=args.microbatches,
                                      warmup=min(50, args.steps // 5 + 1)))
    mgr = CheckpointManager(args.ckpt_dir, keep=3, every=args.ckpt_every,
                            async_save=True)

    state, start = mgr.restore_latest()
    if state is None:
        state = init_state(model, jax.random.PRNGKey(0),
                           mixed_precision=args.mp)
        start = -1
    print(f"{cfg.name}: {param_count(state['params']) / 1e6:.1f}M params, "
          f"resuming at step {start + 1}")

    gate = None
    if args.carbon_gate:
        plat = fleet_platform(1, 100, 250, chips_per_pod=256)
        horizon = 3 * args.steps
        prof = generate_profile("S1", horizon, plat, J=24, seed=7,
                                work_capacity=int(plat.p_work[0]))
        gate = CarbonGate(prof, plat)
        n_chunks = -(-args.steps // args.gate_chunk)
        plan = gate.make_plan([[args.gate_chunk] * n_chunks])
        print(f"carbon plan cost {plan.cost} vs ASAP {plan.asap_cost}")

    clock = 0.0
    t0 = time.time()
    for s in range(start + 1, args.steps):
        if gate is not None and s % args.gate_chunk == 0:
            wait = gate.wait_time(0, s // args.gate_chunk, clock)
            clock += wait
        state, metrics = step_fn(state, data.batch(s))
        clock += 1.0
        if s % args.log_every == 0:
            print(f"step {s:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['gnorm']):.3f} "
                  f"wall {time.time() - t0:.1f}s")
        mgr.maybe_save(state, s)
    mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
