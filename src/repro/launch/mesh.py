"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips across DCN.

A function (not a module constant) so importing never touches jax device
state; only launch/dryrun.py sets the 512-host-device XLA flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def data_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
