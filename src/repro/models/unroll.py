"""Loop-unrolling switch for cost-calibration lowerings.

XLA's ``cost_analysis`` counts a ``while`` (scan/fori) body ONCE, so the
roofline pass lowers small-depth *unrolled* model variants and fits the
linear per-layer cost model (see launch/dryrun.py). Production lowerings
keep scans (compact HLO, fast compile); only the calibration sets
``UNROLL = True``.
"""

UNROLL = False


def set_unroll(v: bool) -> None:
    global UNROLL
    UNROLL = v


def scan_or_unroll(scan_fn, body, init, xs, length: int):
    """lax.scan when UNROLL is off; python loop over leading axis otherwise."""
    if not UNROLL:
        return scan_fn(body, init, xs)
    import jax
    carry = init
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and ys[0] is not None:
        import jax.numpy as jnp
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys
