"""Shared model layers: RMSNorm, RoPE / M-RoPE, GQA attention, SwiGLU MLP.

Pure functions over explicit parameter pytrees (stacked along a leading
layer axis for ``lax.scan`` over layers). Attention is query-chunked so the
per-layer score buffer stays ~O(QCHUNK * S) — the TPU-friendly form (exact,
no approximation); decode reads a KV cache in one step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import unroll
from repro.sharding.ctx import shard

QCHUNK = 512


def dtype_of(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def normal(key, shape, scale, dtype=jnp.float32):
    return (scale * jax.random.normal(key, shape, dtype=jnp.float32)).astype(dtype)


def rmsnorm(x, w, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------

def _rope_cos_sin(pos, head_dim, theta):
    """pos [...]: returns cos/sin of shape [..., head_dim//2], f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) / half))
    ang = pos[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, pos, theta):
    """x [B,S,H,hd], pos [B,S] -> rotated x (rotate-half convention)."""
    hd = x.shape[-1]
    cos, sin = _rope_cos_sin(pos, hd, theta)      # [B,S,hd/2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, pos3, theta, sections):
    """Qwen2-VL M-RoPE: pos3 [3,B,S] (t/h/w); sections sum to head_dim//2."""
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, half)
    cs = [_rope_cos_sin(pos3[i], hd, theta) for i in range(3)]
    # per-frequency-band section selection
    parts_cos, parts_sin = [], []
    off = 0
    for i, sec in enumerate(sections):
        parts_cos.append(cs[i][0][..., off:off + sec])
        parts_sin.append(cs[i][1][..., off:off + sec])
        off += sec
    cos = jnp.concatenate(parts_cos, axis=-1)[:, :, None, :]
    sin = jnp.concatenate(parts_sin, axis=-1)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attn(key, cfg, layers, hq_pad, hkv_pad):
    """Stacked attention params; head counts padded per the TP head plan."""
    d, hd = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": normal(ks[0], (layers, d, hq_pad, hd), sc),
        "wk": normal(ks[1], (layers, d, hkv_pad, hd), sc),
        "wv": normal(ks[2], (layers, d, hkv_pad, hd), sc),
        "wo": normal(ks[3], (layers, hq_pad, hd, d),
                     (hq_pad * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((layers, hq_pad, hd))
        p["bk"] = jnp.zeros((layers, hkv_pad, hd))
        p["bv"] = jnp.zeros((layers, hkv_pad, hd))
    return p


def _qkv(p, x, cfg):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _gqa_scores_out(q, k, v, causal, q_offset=0, kv_len_mask=None):
    """Exact attention for one query chunk.

    q [B,Sq,Hq,hd]; k/v [B,Sk,Hkv,hd] with Hkv | Hq — kv heads are expanded
    (broadcast) to Hq so the head axis shards cleanly over TP; XLA fuses the
    repeat into the contraction.
    """
    B, Sq, Hq, hd = q.shape
    Hkv = k.shape[2]
    if Hkv != Hq:
        k = jnp.repeat(k, Hq // Hkv, axis=2)
        v = jnp.repeat(v, Hq // Hkv, axis=2)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k).astype(jnp.float32)
    s = s * (hd ** -0.5)
    Sk = k.shape[1]
    if causal:
        qpos = q_offset + jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        s = jnp.where(kpos <= qpos, s, -1e30)
    if kv_len_mask is not None:                    # decode: mask cache tail
        s = jnp.where(kv_len_mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqs,bshd->bqhd", w, v)


def attention_train(p, x, cfg, pos, causal=True, kv_override=None):
    """Query-chunked exact attention. pos: [B,S] or [3,B,S] or None."""
    q, k, v = _qkv(p, x, cfg)
    if kv_override is not None:                    # cross-attention
        k, v = kv_override
    if cfg.rope == "mrope" and pos is not None:
        q = apply_mrope(q, pos, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope == "std" and pos is not None:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    q = shard(q, "batch", None, "tp", None)
    B, S = x.shape[0], x.shape[1]
    chunk = min(QCHUNK, S)
    n_chunks = S // chunk if S % chunk == 0 else -(-S // chunk)

    if n_chunks <= 1:
        o = _gqa_scores_out(q, k, v, causal)
    elif unroll.UNROLL:
        o = jnp.concatenate(
            [_gqa_scores_out(q[:, i * chunk:(i + 1) * chunk], k, v, causal,
                             q_offset=i * chunk)
             for i in range(n_chunks)], axis=1)
    else:
        def body(i, acc):
            qs = lax.dynamic_slice_in_dim(q, i * chunk, chunk, axis=1)
            oc = _gqa_scores_out(qs, k, v, causal, q_offset=i * chunk)
            return lax.dynamic_update_slice_in_dim(acc, oc, i * chunk, axis=1)
        o = lax.fori_loop(0, n_chunks, body, jnp.zeros_like(q))
    o = shard(o, "batch", None, "tp", None)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def attention_decode(p, x, cfg, pos, cache_k, cache_v, cache_len):
    """One-token decode. x [B,1,d]; cache_k/v [B,Smax,Hkv,hd]; pos [B]."""
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope == "mrope":
        pos3 = jnp.broadcast_to(pos[None, :, None], (3,) + pos.shape + (1,))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope == "std":
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
    B = x.shape[0]
    # write new kv at position `pos` (same for all batch rows in this step)
    cache_k = lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype),
                                              cache_len, axis=1)
    cache_v = lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype),
                                              cache_len, axis=1)
    # pin the cache layout: without this, head-axis sharding propagates from
    # the TP'd query path into the cache and XLA all-gathers the WHOLE cache
    # (observed: 2 x 17 GB f32 gathers per decode step on smollm decode_32k)
    cache_k = shard(cache_k, "batch", None, "kv_tp", None)
    cache_v = shard(cache_v, "batch", None, "kv_tp", None)
    Smax = cache_k.shape[1]
    valid = jnp.arange(Smax)[None, :] <= cache_len      # [1, Smax]
    valid = jnp.broadcast_to(valid, (B, Smax))
    o = _gqa_scores_out(q, cache_k.astype(q.dtype), cache_v.astype(q.dtype),
                        causal=False, kv_len_mask=valid)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, d, ff, layers):
    ks = jax.random.split(key, 3)
    return {
        "w1": normal(ks[0], (layers, d, ff), d ** -0.5),
        "w3": normal(ks[1], (layers, d, ff), d ** -0.5),
        "w2": normal(ks[2], (layers, ff, d), ff ** -0.5),
    }


def mlp(p, x):
    dt = x.dtype
    h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["w1"].astype(dt)))
    h = h * jnp.einsum("bsd,df->bsf", x, p["w3"].astype(dt))
    h = shard(h, "batch", None, "tp")
    return jnp.einsum("bsf,fd->bsd", h, p["w2"].astype(dt))


def unembed(x, embed, lm_head=None):
    dt = x.dtype
    if lm_head is None:
        return jnp.einsum("bsd,vd->bsv", x, embed.astype(dt))
    return jnp.einsum("bsd,dv->bsv", x, lm_head.astype(dt))


def softmax_xent(logits, labels, vocab):
    """Cross-entropy with vocab-sharded logits (f32 reductions)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (lse - ll).mean()
