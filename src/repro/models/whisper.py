"""Whisper-style encoder-decoder backbone (conv frontend stubbed).

Encoder: bidirectional attention over precomputed frame embeddings
(`input_specs` supplies them — the paper-pool spec marks the modality
frontend as a stub). Decoder: causal self-attention + cross-attention to the
encoder output, learned absolute position embeddings (Whisper uses no RoPE).

Serving: cross-attention K/V are computed once at prefill and cached;
decode steps update only the self-attention cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.unroll import scan_or_unroll
from repro.sharding.ctx import head_plan, shard

MAX_POS = 40960     # covers the 32k shapes; sharded over TP rows


class EncDecModel:
    def __init__(self, cfg, tp: int = 16):
        self.cfg = cfg
        self.hq, self.hkv, self.shard_heads = head_plan(
            cfg.num_heads, cfg.kv_heads, tp)

    def init(self, key):
        cfg = self.cfg
        ks = iter(jax.random.split(key, 16))
        d = cfg.d_model
        Le, Ld = cfg.encoder_layers, cfg.num_layers
        p = {
            "embed": L.normal(next(ks), (cfg.vocab, d), 0.02),
            "enc_pos": L.normal(next(ks), (MAX_POS, d), 0.02),
            "dec_pos": L.normal(next(ks), (MAX_POS, d), 0.02),
            "final_norm": jnp.ones(d),
            "enc_final_norm": jnp.ones(d),
            "enc": {
                "ln1": jnp.ones((Le, d)), "ln2": jnp.ones((Le, d)),
                "attn": L.init_attn(next(ks), cfg, Le, self.hq, self.hkv),
                "mlp": L.init_mlp(next(ks), d, cfg.d_ff, Le),
            },
            "dec": {
                "ln1": jnp.ones((Ld, d)), "ln2": jnp.ones((Ld, d)),
                "ln3": jnp.ones((Ld, d)),
                "attn": L.init_attn(next(ks), cfg, Ld, self.hq, self.hkv),
                "xattn": L.init_attn(next(ks), cfg, Ld, self.hq, self.hkv),
                "mlp": L.init_mlp(next(ks), d, cfg.d_ff, Ld),
            },
        }
        return p

    def encode(self, params, enc_embeds, remat: bool = True):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        S = enc_embeds.shape[1]
        x = enc_embeds.astype(dt) + params["enc_pos"][:S].astype(dt)
        x = shard(x, "batch", None, None)

        def body(x, pl):
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            x = x + L.attention_train(pl["attn"], h, cfg, pos=None,
                                      causal=False)
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            x = x + L.mlp(pl["mlp"], h)
            return shard(x, "batch", None, None), None

        fn = jax.checkpoint(body) if remat else body
        x, _ = scan_or_unroll(lax.scan, fn, x, params["enc"],
                              cfg.encoder_layers)
        return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)

    def _cross_kv(self, params, enc_out):
        """Per-layer cross-attention K/V from encoder output: [Ld,B,Se,H,hd]."""
        cfg = self.cfg
        dt = enc_out.dtype

        def body(_, pl):
            k = jnp.einsum("bsd,dhk->bshk", enc_out, pl["wk"].astype(dt))
            v = jnp.einsum("bsd,dhk->bshk", enc_out, pl["wv"].astype(dt))
            if cfg.qkv_bias:
                k = k + pl["bk"].astype(dt)
                v = v + pl["bv"].astype(dt)
            return None, (k, v)

        _, (ks, vs) = scan_or_unroll(lax.scan, body, None,
                                     params["dec"]["xattn"],
                                     cfg.num_layers)
        return ks, vs

    def _dec_block(self, pl, x, xk, xv, cfg):
        h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
        x = x + L.attention_train(pl["attn"], h, cfg, pos=None, causal=True)
        h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
        # cross-attention: q from decoder; k/v precomputed from encoder
        q = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(x.dtype))
        if cfg.qkv_bias:
            q = q + pl["xattn"]["bq"].astype(x.dtype)
        o = L._gqa_scores_out(q, xk, xv, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", o,
                           pl["xattn"]["wo"].astype(x.dtype))
        h = L.rmsnorm(x, pl["ln3"], cfg.norm_eps)
        x = x + L.mlp(pl["mlp"], h)
        return shard(x, "batch", None, None)

    def loss(self, params, batch, remat: bool = True):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        enc_out = self.encode(params, batch["enc_embeds"], remat)
        xk, xv = self._cross_kv(params, enc_out)
        tok = batch["dec_tokens"]
        S = tok.shape[1]
        x = params["embed"][tok].astype(dt) + params["dec_pos"][:S].astype(dt)
        x = shard(x, "batch", None, None)

        def body(x, args):
            pl, k, v = args
            return self._dec_block(pl, x, k, v, cfg), None

        fn = jax.checkpoint(body) if remat else body
        x, _ = scan_or_unroll(lax.scan, fn, x, (params["dec"], xk, xv),
                              cfg.num_layers)
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(x, params["embed"])
        return L.softmax_xent(logits, batch["labels"], cfg.vocab)

    # -- serving -------------------------------------------------------------

    def init_cache(self, batch_size: int, max_len: int, enc_len: int):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        Ld = cfg.num_layers
        kv = (Ld, batch_size, max_len, self.hkv, cfg.head_dim)
        xkv = (Ld, batch_size, enc_len, self.hkv, cfg.head_dim)
        return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                "xk": jnp.zeros(xkv, dt), "xv": jnp.zeros(xkv, dt),
                "len": jnp.zeros((), jnp.int32)}

    def prefill(self, params, cache, enc_embeds):
        """Encode audio + fill cross-attention caches."""
        enc_out = self.encode(params, enc_embeds, remat=False)
        xk, xv = self._cross_kv(params, enc_out)
        cache = dict(cache)
        cache["xk"], cache["xv"] = xk, xv
        return cache

    def decode_step(self, params, cache, tokens):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        B = tokens.shape[0]
        pos = jnp.broadcast_to(cache["len"], (B,))
        x = (params["embed"][tokens][:, None].astype(dt)
             + params["dec_pos"][cache["len"]].astype(dt))

        def body(x, args):
            pl, ck, cv, xk, xv = args
            h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
            a, ck, cv = L.attention_decode(pl["attn"], h, cfg, pos, ck, cv,
                                           cache["len"])
            x = x + a
            h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, pl["xattn"]["wq"].astype(dt))
            if cfg.qkv_bias:
                q = q + pl["xattn"]["bq"].astype(dt)
            o = L._gqa_scores_out(q, xk, xv, causal=False)
            x = x + jnp.einsum("bshk,hkd->bsd", o,
                               pl["xattn"]["wo"].astype(dt))
            h = L.rmsnorm(x, pl["ln3"], cfg.norm_eps)
            x = x + L.mlp(pl["mlp"], h)
            return x, (ck, cv)

        x, (ks, vs) = scan_or_unroll(
            lax.scan, body, x, (params["dec"], cache["k"], cache["v"],
                                cache["xk"], cache["xv"]), cfg.num_layers)
        cache = dict(cache)
        cache["k"], cache["v"] = ks, vs
        cache["len"] = cache["len"] + 1
        x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return L.unembed(x, params["embed"])[:, 0].astype(jnp.float32), cache
