from repro.models.model_zoo import (  # noqa: F401
    build_model,
    input_specs,
    model_flops,
    param_count,
)
