"""Model registry: build models, count params/FLOPs, make dry-run input specs."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.transformer import DecoderModel
from repro.models.whisper import EncDecModel


def build_model(cfg: ModelConfig, tp: int = 16):
    if cfg.family == "audio":
        return EncDecModel(cfg, tp=tp)
    return DecoderModel(cfg, tp=tp)


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def active_param_count(cfg: ModelConfig, params) -> int:
    """Active params per token (MoE: top_k of num_experts experts)."""
    total = param_count(params)
    if cfg.moe is None:
        return total

    def expert_size(tree):
        return sum(int(x.size) for k, x in _walk(tree) if k in
                   ("w1", "w2", "w3") and x.ndim >= 4)

    # expert tensors have shape [..., E, d, ff]: active fraction = k/E
    e, k = cfg.moe.num_experts, cfg.moe.top_k
    exp = 0
    for key, x in _walk(params):
        if x.ndim >= 4 and x.shape[-3] == e and key in ("w1", "w2", "w3"):
            exp += int(x.size)
    return total - exp + int(exp * k / e)


def _walk(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _walk(v, k)
    else:
        yield prefix, tree


def model_flops(cfg: ModelConfig, params, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for the roofline ratio: 6*N*D (train) / 2*N*D (fwd-only),
    with N = active params (MoE) and D = processed tokens."""
    n_active = active_param_count(cfg, params)
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.batch


def input_specs(cfg: ModelConfig, shape: ShapeConfig, model=None):
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    Returns (batch_pytree, kind). For decode shapes the pytree includes the
    KV cache / recurrent state (the serve_step signature).
    """
    B, S = shape.batch, shape.seq
    i32 = jnp.int32
    f32 = jnp.float32
    bf16 = jnp.bfloat16

    def st(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind in ("train", "prefill"):
        if cfg.family == "vlm":
            batch = {"embeds": st((B, S, cfg.d_model), bf16),
                     "positions": st((3, B, S), i32),
                     "labels": st((B, S), i32)}
        elif cfg.family == "audio":
            batch = {"enc_embeds": st((B, S, cfg.d_model), bf16),
                     "dec_tokens": st((B, S), i32),
                     "labels": st((B, S), i32)}
        else:
            batch = {"tokens": st((B, S), i32), "labels": st((B, S), i32)}
        return batch

    # decode: token batch + cache structs
    assert model is not None
    if cfg.family == "audio":
        cache = jax.eval_shape(
            lambda: model.init_cache(B, S, enc_len=S))
    else:
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
    return {"tokens": st((B,), i32), "cache": cache}
