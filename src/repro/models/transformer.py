"""Decoder-only stacks: dense / vlm / moe / hybrid (jamba) / ssm (xlstm).

Homogeneous stacks scan over stacked layer params (O(1) compile time in
depth, remat per layer); jamba scans over groups of (1 attention + 7 mamba)
layers with the fixed intra-group FFN pattern unrolled; xlstm unrolls its 12
blocks (2 sLSTM + 10 mLSTM).

All forward paths share: embeddings (or stub frontend embeddings for vlm),
RMSNorm, tied unembedding, f32 logits/loss.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MOE
from repro.models import xlstm as X
from repro.models.unroll import scan_or_unroll
from repro.sharding.ctx import head_plan, shard


def _layer_counts(cfg):
    """Pattern bookkeeping for hybrid stacks."""
    if cfg.family != "hybrid":
        return None
    g = cfg.attn_every
    assert cfg.num_layers % g == 0
    return cfg.num_layers // g


class DecoderModel:
    """Functional model wrapper: init / loss / prefill / decode."""

    def __init__(self, cfg, tp: int = 16):
        self.cfg = cfg
        self.hq, self.hkv, self.shard_heads = head_plan(
            cfg.num_heads, cfg.kv_heads, tp)

    # -- params ------------------------------------------------------------

    def init(self, key):
        cfg = self.cfg
        ks = iter(jax.random.split(key, 24))
        p = {"embed": L.normal(next(ks), (cfg.vocab, cfg.d_model), 0.02),
             "final_norm": jnp.ones(cfg.d_model)}
        if cfg.family == "ssm":
            p["blocks"] = self._init_xlstm(next(ks))
            return p
        if cfg.family == "hybrid":
            p["groups"] = self._init_hybrid(next(ks))
            return p
        Ln = cfg.num_layers
        p["ln1"] = jnp.ones((Ln, cfg.d_model))
        p["ln2"] = jnp.ones((Ln, cfg.d_model))
        p["attn"] = L.init_attn(next(ks), cfg, Ln, self.hq, self.hkv)
        if cfg.d_ff:
            p["mlp"] = L.init_mlp(next(ks), cfg.d_model, cfg.d_ff, Ln)
        if cfg.moe is not None:
            p["moe"] = MOE.init_moe(next(ks), cfg.d_model, cfg.moe, Ln)
        return p

    def _init_xlstm(self, key):
        cfg = self.cfg
        k1, k2 = jax.random.split(key)
        n_s = len(cfg.slstm_layers)
        n_m = cfg.num_layers - n_s
        return {"mlstm": X.init_mlstm(k1, cfg, n_m),
                "slstm": X.init_slstm(k2, cfg, n_s)}

    def _init_hybrid(self, key):
        cfg = self.cfg
        G = _layer_counts(self.cfg)
        per = cfg.attn_every               # layers per group
        n_moe = per // 2
        n_mlp = per - n_moe
        ks = jax.random.split(key, 6)
        return {
            "ln1": jnp.ones((G, per, cfg.d_model)),
            "ln2": jnp.ones((G, per, cfg.d_model)),
            "attn": L.init_attn(ks[0], cfg, G, self.hq, self.hkv),
            "mamba": M.init_mamba(ks[1], cfg.d_model, cfg.mamba,
                                  G * (per - 1)),
            "mlp": L.init_mlp(ks[2], cfg.d_model, cfg.d_ff, G * n_mlp),
            "moe": MOE.init_moe(ks[3], cfg.d_model, cfg.moe, G * n_moe),
        }

    # -- shared blocks -------------------------------------------------------

    def _ffn(self, pl, x, use_moe: bool):
        cfg = self.cfg
        if use_moe:
            y = MOE.moe_ffn(pl["moe"], x, cfg.moe)
            if cfg.moe.dense_residual and cfg.d_ff:
                y = y + L.mlp(pl["mlp"], x)
            return y
        return L.mlp(pl["mlp"], x)

    def _dense_block(self, pl, x, pos):
        cfg = self.cfg
        h = L.rmsnorm(x, pl["ln1"], cfg.norm_eps)
        x = x + L.attention_train(
            {k: pl[k] for k in ("wq", "wk", "wv", "wo", "bq", "bk", "bv")
             if k in pl}, h, cfg, pos)
        h = L.rmsnorm(x, pl["ln2"], cfg.norm_eps)
        use_moe = cfg.moe is not None
        x = x + self._ffn(pl, h, use_moe)
        return shard(x, "batch", None, None)

    # -- forward (train / prefill) ------------------------------------------

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        if "embeds" in batch:                       # vlm stub frontend
            x = batch["embeds"].astype(dt)
        else:
            x = params["embed"][batch["tokens"]].astype(dt)
        if cfg.rope == "mrope":
            pos = batch["positions"]                 # [3,B,S]
        else:
            Bb, S = x.shape[0], x.shape[1]
            pos = jnp.broadcast_to(jnp.arange(S)[None], (Bb, S))
        return shard(x, "batch", None, None), pos

    def apply(self, params, batch, remat: bool = True):
        """Full-sequence forward -> final hidden states [B,S,d]."""
        cfg = self.cfg
        x, pos = self._embed_inputs(params, batch)
        if cfg.family in ("dense", "vlm", "moe"):
            x = self._stack_scan(params, x, pos, remat)
        elif cfg.family == "hybrid":
            x = self._hybrid_scan(params, x, pos, remat)
        elif cfg.family == "ssm":
            x = self._xlstm_stack(params, x)
        else:
            raise ValueError(cfg.family)
        return L.rmsnorm(x, params["final_norm"], cfg.norm_eps)

    def _stack_scan(self, params, x, pos, remat):
        cfg = self.cfg
        keys = [k for k in ("ln1", "ln2", "attn", "mlp", "moe")
                if k in params]

        def body(x, pl_flat):
            pl = dict(pl_flat["attn"])
            pl["ln1"], pl["ln2"] = pl_flat["ln1"], pl_flat["ln2"]
            if "mlp" in pl_flat:
                pl["mlp"] = pl_flat["mlp"]
            if "moe" in pl_flat:
                pl["moe"] = pl_flat["moe"]
            return self._dense_block(pl, x, pos), None

        stacked = {k: params[k] for k in keys}
        fn = jax.checkpoint(body) if remat else body
        x, _ = scan_or_unroll(lax.scan, fn, x, stacked, cfg.num_layers)
        return x

    def _hybrid_scan(self, params, x, pos, remat):
        cfg = self.cfg
        per = cfg.attn_every
        G = _layer_counts(cfg)
        g = params["groups"]

        mamba_g = jax.tree.map(
            lambda a: a.reshape((G, per - 1) + a.shape[1:]), g["mamba"])
        n_moe = per // 2
        moe_g = jax.tree.map(
            lambda a: a.reshape((G, n_moe) + a.shape[1:]), g["moe"])
        mlp_g = jax.tree.map(
            lambda a: a.reshape((G, per - n_moe) + a.shape[1:]), g["mlp"])

        def group_body(x, gp):
            i_mlp = 0
            i_moe = 0
            for j in range(per):
                h = L.rmsnorm(x, gp["ln1"][j], cfg.norm_eps)
                if j == 0:
                    x = x + L.attention_train(gp["attn"], h, cfg, pos)
                else:
                    x = x + M.mamba_train(
                        jax.tree.map(lambda a: a[j - 1], gp["mamba"]),
                        h, cfg.mamba)
                h = L.rmsnorm(x, gp["ln2"][j], cfg.norm_eps)
                if j % 2 == 1:                      # global odd layer -> MoE
                    pl = {"moe": jax.tree.map(lambda a: a[i_moe], gp["moe"])}
                    x = x + self._ffn(pl, h, True)
                    i_moe += 1
                else:
                    pl = jax.tree.map(lambda a: a[i_mlp], gp["mlp"])
                    x = x + L.mlp(pl, h)
                    i_mlp += 1
                x = shard(x, "batch", None, None)
            return x, None

        stacked = {"ln1": g["ln1"], "ln2": g["ln2"], "attn": g["attn"],
                   "mamba": mamba_g, "moe": moe_g, "mlp": mlp_g}
        fn = jax.checkpoint(group_body) if remat else group_body
        x, _ = scan_or_unroll(lax.scan, fn, x, stacked, G)
        return x

    def _xlstm_stack(self, params, x):
        cfg = self.cfg
        b = params["blocks"]
        i_m = i_s = 0
        for l in range(cfg.num_layers):
            if l in cfg.slstm_layers:
                pl = jax.tree.map(lambda a: a[i_s], b["slstm"])
                x = X.slstm_train(pl, x, cfg)
                i_s += 1
            else:
                pl = jax.tree.map(lambda a: a[i_m], b["mlstm"])
                x = X.mlstm_train(pl, x, cfg)
                i_m += 1
        return x

    def loss(self, params, batch, remat: bool = True):
        h = self.apply(params, batch, remat=remat)
        logits = L.unembed(h, params["embed"])
        return L.softmax_xent(logits, batch["labels"], self.cfg.vocab)

    # -- serving -------------------------------------------------------------

    def init_cache(self, batch_size: int, max_len: int):
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        if cfg.family in ("dense", "vlm", "moe"):
            Ln = cfg.num_layers
            kv = (Ln, batch_size, max_len, self.hkv, cfg.head_dim)
            return {"k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                    "len": jnp.zeros((), jnp.int32)}
        if cfg.family == "hybrid":
            G = _layer_counts(cfg)
            kv = (G, batch_size, max_len, self.hkv, cfg.head_dim)
            di = cfg.mamba.expand * cfg.d_model
            n_mamba = G * (cfg.attn_every - 1)
            return {
                "k": jnp.zeros(kv, dt), "v": jnp.zeros(kv, dt),
                "conv": jnp.zeros((n_mamba, batch_size,
                                   cfg.mamba.d_conv - 1, di), dt),
                "ssm": jnp.zeros((n_mamba, batch_size, di,
                                  cfg.mamba.d_state), jnp.float32),
                "len": jnp.zeros((), jnp.int32),
            }
        if cfg.family == "ssm":
            n_s = len(cfg.slstm_layers)
            n_m = cfg.num_layers - n_s
            H, hd = cfg.num_heads, cfg.head_dim
            return {
                "C": jnp.zeros((n_m, batch_size, H, hd, hd), jnp.float32),
                "n": jnp.zeros((n_m, batch_size, H, hd), jnp.float32),
                "c_s": jnp.zeros((n_s, batch_size, H, hd), jnp.float32),
                "h_s": jnp.zeros((n_s, batch_size, H, hd), jnp.float32),
                "len": jnp.zeros((), jnp.int32),
            }
        raise ValueError(cfg.family)

    def decode_step(self, params, cache, tokens):
        """One decode step for all batch rows. tokens [B] -> logits [B,V]."""
        cfg = self.cfg
        dt = L.dtype_of(cfg)
        x = params["embed"][tokens][:, None].astype(dt)     # [B,1,d]
        B = x.shape[0]
        pos = jnp.broadcast_to(cache["len"], (B,))
        if cfg.family in ("dense", "vlm", "moe"):
            x, cache = self._decode_stack(params, cache, x, pos)
        elif cfg.family == "hybrid":
            x, cache = self._decode_hybrid(params, cache, x, pos)
        elif cfg.family == "ssm":
            x, cache = self._decode_xlstm(params, cache, x)
        h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = L.unembed(h, params["embed"])[:, 0]
        cache = dict(cache)
        cache["len"] = cache["len"] + 1
        return logits.astype(jnp.float32), cache

    def _decode_stack(self, params, cache, x, pos):
        cfg = self.cfg
        keys = [k for k in ("ln1", "ln2", "attn", "mlp", "moe")
                if k in params]
        stacked = {k: params[k] for k in keys}

        def body(x, args):
            pl_flat, ck, cv = args
            h = L.rmsnorm(x, pl_flat["ln1"], cfg.norm_eps)
            a, ck, cv = L.attention_decode(pl_flat["attn"], h, cfg, pos,
                                           ck, cv, cache["len"])
            x = x + a
            h = L.rmsnorm(x, pl_flat["ln2"], cfg.norm_eps)
            pl2 = {k: pl_flat[k] for k in ("mlp", "moe") if k in pl_flat}
            x = x + self._ffn(pl2, h, cfg.moe is not None)
            return x, (ck, cv)

        x, (ks, vs) = scan_or_unroll(
            lax.scan, body, x, (stacked, cache["k"], cache["v"]),
            cfg.num_layers)
        cache = dict(cache)
        cache["k"], cache["v"] = ks, vs
        return x, cache

    def _decode_hybrid(self, params, cache, x, pos):
        cfg = self.cfg
        per = cfg.attn_every
        G = _layer_counts(cfg)
        g = params["groups"]
        ks_new, vs_new = [], []
        conv_new, ssm_new = [], []
        i_mamba = 0
        i_mlp = i_moe = 0
        for gi in range(G):
            for j in range(per):
                h = L.rmsnorm(x, g["ln1"][gi, j], cfg.norm_eps)
                if j == 0:
                    pl = jax.tree.map(lambda a: a[gi], g["attn"])
                    a, ck, cv = L.attention_decode(
                        pl, h, cfg, pos, cache["k"][gi], cache["v"][gi],
                        cache["len"])
                    ks_new.append(ck)
                    vs_new.append(cv)
                    x = x + a
                else:
                    pl = jax.tree.map(lambda a: a[i_mamba], g["mamba"])
                    st = {"conv": cache["conv"][i_mamba],
                          "ssm": cache["ssm"][i_mamba]}
                    a, st = M.mamba_decode(pl, h, cfg.mamba, st)
                    conv_new.append(st["conv"])
                    ssm_new.append(st["ssm"])
                    x = x + a
                    i_mamba += 1
                h = L.rmsnorm(x, g["ln2"][gi, j], cfg.norm_eps)
                if j % 2 == 1:
                    pl = {"moe": jax.tree.map(lambda a: a[i_moe], g["moe"])}
                    x = x + self._ffn(pl, h, True)
                    i_moe += 1
                else:
                    pl = jax.tree.map(lambda a: a[i_mlp], g["mlp"])
                    x = x + L.mlp(pl, h)
                    i_mlp += 1
        cache = dict(cache)
        cache["k"] = jnp.stack(ks_new)
        cache["v"] = jnp.stack(vs_new)
        cache["conv"] = jnp.stack(conv_new)
        cache["ssm"] = jnp.stack(ssm_new)
        return x, cache

    def _decode_xlstm(self, params, cache, x):
        cfg = self.cfg
        b = params["blocks"]
        C_new, n_new, cs_new, hs_new = [], [], [], []
        i_m = i_s = 0
        for l in range(cfg.num_layers):
            if l in cfg.slstm_layers:
                pl = jax.tree.map(lambda a: a[i_s], b["slstm"])
                x, st = X.slstm_decode(pl, x, cfg,
                                       {"c": cache["c_s"][i_s],
                                        "h": cache["h_s"][i_s]})
                cs_new.append(st["c"])
                hs_new.append(st["h"])
                i_s += 1
            else:
                pl = jax.tree.map(lambda a: a[i_m], b["mlstm"])
                x, st = X.mlstm_decode(pl, x, cfg,
                                       {"C": cache["C"][i_m],
                                        "n": cache["n"][i_m]})
                C_new.append(st["C"])
                n_new.append(st["n"])
                i_m += 1
        cache = dict(cache)
        cache["C"] = jnp.stack(C_new)
        cache["n"] = jnp.stack(n_new)
        cache["c_s"] = jnp.stack(cs_new)
        cache["h_s"] = jnp.stack(hs_new)
        return x, cache
