"""xLSTM blocks [arXiv:2405.04517]: chunkwise-parallel mLSTM + sequential sLSTM.

mLSTM keeps a matrix memory C [hd, hd] per head with scalar input/forget
gates; its linear recurrence admits the GLA-style chunkwise form (intra-chunk
attention-like term + inter-chunk state carry) — the TPU-native layout.
sLSTM's recurrence is not parallelizable (paper), so it runs as a
``lax.scan`` over time with block-diagonal (per-head) recurrent weights.

Stabilization: gates use sigmoid (f) and exp-capped (i, via sigmoid) forms
instead of the paper's exp-with-max-stabilizer — documented simplification;
shapes/FLOPs match.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import normal, rmsnorm
from repro.models.unroll import scan_or_unroll
from repro.sharding.ctx import shard

CHUNK = 64


def init_mlstm(key, cfg, layers):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 7)
    return {
        "ln": jnp.ones((layers, d)),
        "wq": normal(ks[0], (layers, d, H, hd), d ** -0.5),
        "wk": normal(ks[1], (layers, d, H, hd), d ** -0.5),
        "wv": normal(ks[2], (layers, d, H, hd), d ** -0.5),
        "wi": normal(ks[3], (layers, d, H), d ** -0.5),
        "wf": normal(ks[4], (layers, d, H), d ** -0.5),
        "bf": jnp.full((layers, H), 3.0),       # forget bias -> long memory
        "wgate": normal(ks[5], (layers, d, H * hd), d ** -0.5),
        "wo": normal(ks[6], (layers, H, hd, d), (H * hd) ** -0.5),
    }


def _mlstm_gates(p, x):
    i = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wi"]))
    f = jax.nn.sigmoid(jnp.einsum("bsd,dh->bsh", x.astype(jnp.float32), p["wf"])
                       + p["bf"])
    return i, f


def mlstm_train(p, x, cfg):
    """Chunkwise-parallel mLSTM. x [B,S,d] -> [B,S,d]."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"].astype(x.dtype)) * hd ** -0.5
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"].astype(x.dtype))
    i, f = _mlstm_gates(p, xn)                              # [B,S,H] f32

    ch = min(CHUNK, S)
    nc = S // ch
    assert S % ch == 0

    def resh(t):
        return t.reshape((B, nc, ch) + t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = resh(q), resh(k), resh(v)                  # [nc,B,ch,H,hd]
    ic, fc = resh(i), resh(f)                               # [nc,B,ch,H]

    def body(carry, args):
        C, n = carry                                        # [B,H,hd,hd],[B,H,hd]
        qq, kk, vv, ii, ff = args
        lf = jnp.log(ff + 1e-8)                             # [B,ch,H]
        acum = jnp.cumsum(lf, axis=1)                       # inclusive
        # inter-chunk: state contribution decayed to each position
        dec = jnp.exp(acum)                                 # [B,ch,H]
        y_int = jnp.einsum("bchd,bhde->bche", qq.astype(jnp.float32), C)
        y_int = y_int * dec[..., None]
        n_int = jnp.einsum("bchd,bhd->bch", qq.astype(jnp.float32), n)
        n_int = n_int * dec
        # intra-chunk: decay(t,s) = exp(acum_t - acum_s) * i_s for s <= t
        w_ts = jnp.exp(acum[:, :, None, :] - acum[:, None, :, :])  # [B,t,s,H]
        mask = (jnp.arange(ch)[:, None] >= jnp.arange(ch)[None, :])
        w_ts = jnp.where(mask[None, :, :, None], w_ts, 0.0)
        w_ts = w_ts * ii[:, None, :, :]
        sc = jnp.einsum("bthd,bshd->btsh",
                        qq.astype(jnp.float32), kk.astype(jnp.float32))
        sc = sc * w_ts
        y_intra = jnp.einsum("btsh,bshd->bthd", sc, vv.astype(jnp.float32))
        n_intra = sc.sum(axis=2)                            # [B,t,H]
        # combine + normalize
        y = y_int + y_intra
        nn = jnp.abs(n_int + n_intra)
        y = y / jnp.maximum(nn, 1.0)[..., None]
        # state update to end of chunk
        decN = jnp.exp(acum[:, -1:, :] - acum)              # [B,ch,H]
        wN = decN * ii                                      # [B,ch,H]
        C_new = (jnp.exp(acum[:, -1])[:, :, None, None] * C
                 + jnp.einsum("bsh,bshd,bshe->bhde", wN,
                              kk.astype(jnp.float32), vv.astype(jnp.float32)))
        n_new = (jnp.exp(acum[:, -1])[:, :, None] * n
                 + jnp.einsum("bsh,bshd->bhd", wN, kk.astype(jnp.float32)))
        return (C_new, n_new), y.astype(x.dtype)

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    _, ys = scan_or_unroll(lax.scan, body, (C0, n0),
                           (qc, kc, vc, ic, fc), nc)
    y = ys.swapaxes(0, 1).reshape(B, S, H, hd)
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", xn, p["wgate"].astype(x.dtype)))
    y = y.reshape(B, S, H * hd) * gate
    y = shard(y, "batch", None, "tp")
    out = jnp.einsum("bshk,hkd->bsd", y.reshape(B, S, H, hd),
                     p["wo"].astype(x.dtype))
    return x + out


def mlstm_init_state(cfg, batch):
    H, hd = cfg.num_heads, cfg.head_dim
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32)}


def mlstm_decode(p, x, cfg, state):
    """One-token mLSTM step. x [B,1,d]."""
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(x.dtype))[:, 0]
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"].astype(x.dtype))[:, 0] * hd ** -0.5
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"].astype(x.dtype))[:, 0]
    i, f = _mlstm_gates(p, xn)
    i, f = i[:, 0], f[:, 0]                                 # [B,H]
    C = (f[..., None, None] * state["C"]
         + i[..., None, None] * jnp.einsum(
             "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32)))
    n = f[..., None] * state["n"] + i[..., None] * k.astype(jnp.float32)
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
    nn = jnp.abs(jnp.einsum("bhd,bhd->bh", q.astype(jnp.float32), n))
    y = y / jnp.maximum(nn, 1.0)[..., None]
    gate = jax.nn.silu(jnp.einsum("bsd,de->bse", xn, p["wgate"].astype(x.dtype)))
    y = (y.reshape(B, 1, H * hd).astype(x.dtype)) * gate
    out = jnp.einsum("bshk,hkd->bsd", y.reshape(B, 1, H, hd),
                     p["wo"].astype(x.dtype))
    return x + out, {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM: strictly sequential scan with block-diagonal recurrence
# ---------------------------------------------------------------------------

def init_slstm(key, cfg, layers):
    d, H, hd = cfg.d_model, cfg.num_heads, cfg.head_dim
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.ones((layers, d)),
        "wx": normal(ks[0], (layers, d, 4, H, hd), d ** -0.5),   # z,i,f,o
        "wr": normal(ks[1], (layers, 4, H, hd, hd), hd ** -0.5),
        "b": jnp.zeros((layers, 4, H, hd)),
        "wo": normal(ks[2], (layers, H, hd, d), (H * hd) ** -0.5),
    }


def slstm_init_state(cfg, batch):
    H, hd = cfg.num_heads, cfg.head_dim
    return {"c": jnp.zeros((batch, H, hd), jnp.float32),
            "h": jnp.zeros((batch, H, hd), jnp.float32)}


def _slstm_step(p, xg, state):
    """xg [B,4,H,hd] (pre-computed x projections); returns (state, out)."""
    c, h = state["c"], state["h"]
    rec = jnp.einsum("bhk,ghkl->bghl", h, p["wr"])          # [B,4,H,hd]
    g = xg.astype(jnp.float32) + rec + p["b"]
    z = jnp.tanh(g[:, 0])
    i = jax.nn.sigmoid(g[:, 1])
    f = jax.nn.sigmoid(g[:, 2])
    o = jax.nn.sigmoid(g[:, 3])
    c = f * c + i * z
    h = o * jnp.tanh(c)
    return {"c": c, "h": h}, h


def slstm_train(p, x, cfg):
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.head_dim
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    xg = jnp.einsum("bsd,dghk->bsghk", xn, p["wx"].astype(x.dtype))

    def body(state, xg_t):
        return _slstm_step(p, xg_t, state)

    state0 = slstm_init_state(cfg, B)
    _, hs = lax.scan(body, state0, xg.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)                   # [B,S,H,hd]
    out = jnp.einsum("bshk,hkd->bsd", y, p["wo"].astype(x.dtype))
    return x + out


def slstm_decode(p, x, cfg, state):
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    xg = jnp.einsum("bsd,dghk->bsghk", xn, p["wx"].astype(x.dtype))[:, 0]
    state, h = _slstm_step(p, xg, state)
    out = jnp.einsum("bhk,hkd->bd", h.astype(x.dtype),
                     p["wo"].astype(x.dtype))[:, None]
    return x + out, state
