"""Mamba-1 selective SSM block (Jamba's mixer), chunked for TPU.

Training runs a ``lax.scan`` over sequence chunks carrying the SSM state;
within a chunk the linear recurrence h_t = dA_t * h_{t-1} + dBx_t is solved
with an associative scan (log-depth, parallel — the TPU-native adaptation of
the CUDA selective-scan kernel). Decode is the O(1) recurrent step.

d_inner shards over TP (all ops are elementwise or contract d_model).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import normal
from repro.models.unroll import scan_or_unroll
from repro.sharding.ctx import shard


def init_mamba(key, d, mcfg, layers):
    di = mcfg.expand * d
    dtr = max(d // 16, 1)
    ks = jax.random.split(key, 7)
    return {
        "in_proj": normal(ks[0], (layers, d, 2 * di), d ** -0.5),
        "conv_w": normal(ks[1], (layers, mcfg.d_conv, di), 0.2),
        "conv_b": jnp.zeros((layers, di)),
        "x_proj": normal(ks[2], (layers, di, dtr + 2 * mcfg.d_state), di ** -0.5),
        "dt_proj": normal(ks[3], (layers, dtr, di), dtr ** -0.5),
        "dt_bias": jnp.zeros((layers, di)),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, mcfg.d_state + 1, dtype=jnp.float32),
            (layers, di, mcfg.d_state))),
        "D": jnp.ones((layers, di)),
        "out_proj": normal(ks[4], (layers, di, d), di ** -0.5),
    }


def _ssm_inputs(p, x, mcfg):
    """Shared pre-SSM computation. x [B,S,d] -> (u, z, dt, B_, C_, A)."""
    dt_ = x.dtype
    di = p["conv_w"].shape[-1]
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(dt_))
    u, z = jnp.split(xz, 2, axis=-1)                        # [B,S,di]
    u = shard(u, "batch", None, "tp")
    return u, z, di


def _conv_silu(p, u, mcfg, conv_state=None):
    """Causal depthwise conv (kernel d_conv) + SiLU; returns (u, new_state)."""
    K = mcfg.d_conv
    if conv_state is None:
        pad = jnp.zeros(u.shape[:1] + (K - 1,) + u.shape[2:], u.dtype)
        full = jnp.concatenate([pad, u], axis=1)
    else:
        full = jnp.concatenate([conv_state.astype(u.dtype), u], axis=1)
    out = sum(full[:, i:i + u.shape[1]] * p["conv_w"][i].astype(u.dtype)
              for i in range(K))
    out = out + p["conv_b"].astype(u.dtype)
    new_state = full[:, -(K - 1):] if K > 1 else full[:, :0]
    return jax.nn.silu(out), new_state


def _ssm_params(p, u, mcfg):
    """dt [B,S,di] f32, Bc/Cc [B,S,ds] f32, A [di,ds] f32."""
    dtr = p["dt_proj"].shape[-2]
    ds = mcfg.d_state
    dbc = jnp.einsum("bsi,ir->bsr", u, p["x_proj"].astype(u.dtype))
    dt_raw, Bc, Cc = jnp.split(dbc.astype(jnp.float32), [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("bsr,ri->bsi", dt_raw, p["dt_proj"])
                         + p["dt_bias"])
    A = -jnp.exp(p["A_log"])                                # [di,ds]
    return dt, Bc, Cc, A


def mamba_train(p, x, mcfg):
    """Full-sequence forward. x [B,S,d] -> [B,S,d]."""
    u, z, di = _ssm_inputs(p, x, mcfg)
    u, _ = _conv_silu(p, u, mcfg)
    dt, Bc, Cc, A = _ssm_params(p, u, mcfg)
    B_, S, _ = u.shape
    ds = mcfg.d_state
    ch = min(mcfg.chunk, S)
    nc = S // ch
    assert S % ch == 0, (S, ch)

    dA = jnp.exp(dt[..., None] * A)                          # [B,S,di,ds]
    dBx = (dt * u.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

    def chunk_body(h, args):
        dA_c, dBx_c, Cc_c = args                             # [B,ch,di,ds]...
        # prefix recurrence inside the chunk (associative, log-depth)
        def comb(a, b):
            return (a[0] * b[0], b[0] * a[1] + b[1])
        pA, pB = lax.associative_scan(comb, (dA_c, dBx_c), axis=1)
        hs = pA * h[:, None] + pB                            # [B,ch,di,ds]
        y = jnp.einsum("bcis,bcs->bci", hs, Cc_c)
        return hs[:, -1], y

    dA_r = dA.reshape(B_, nc, ch, di, ds).swapaxes(0, 1)
    dBx_r = dBx.reshape(B_, nc, ch, di, ds).swapaxes(0, 1)
    Cc_r = Cc.reshape(B_, nc, ch, ds).swapaxes(0, 1)
    h0 = jnp.zeros((B_, di, ds), jnp.float32)
    _, ys = scan_or_unroll(lax.scan, chunk_body, h0,
                           (dA_r, dBx_r, Cc_r), nc)
    y = ys.swapaxes(0, 1).reshape(B_, S, di)
    y = y + p["D"] * u.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    y = shard(y, "batch", None, "tp")
    return jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))


def mamba_init_state(p, mcfg, batch, dtype=jnp.float32):
    di = p["conv_w"].shape[-1]
    return {
        "conv": jnp.zeros((batch, mcfg.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, mcfg.d_state), jnp.float32),
    }


def mamba_decode(p, x, mcfg, state):
    """One-token step. x [B,1,d] -> ([B,1,d], new state)."""
    u, z, di = _ssm_inputs(p, x, mcfg)
    u, conv_state = _conv_silu(p, u, mcfg, conv_state=state["conv"])
    dt, Bc, Cc, A = _ssm_params(p, u, mcfg)
    dA = jnp.exp(dt[:, 0, :, None] * A)                      # [B,di,ds]
    dBx = (dt[:, 0] * u[:, 0].astype(jnp.float32))[..., None] * Bc[:, 0, None, :]
    h = dA * state["ssm"] + dBx
    y = jnp.einsum("bis,bs->bi", h, Cc[:, 0])[:, None, :]
    y = y + p["D"] * u.astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"].astype(x.dtype))
    return out, {"conv": conv_state.astype(state["conv"].dtype), "ssm": h}
