"""Mixture-of-Experts FFN: sort-based capacity dispatch (GShard-style, but
scatter/gather instead of one-hot einsums so no [B,S,E,C] tensor is ever
materialized — the TPU-memory-native form).

Experts shard over TP ('expert' -> model axis); the capacity axis shards
over data. Token->expert routing becomes gather/scatter across both axes,
which the SPMD partitioner lowers to all-to-all-like collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import normal
from repro.sharding.ctx import shard


def init_moe(key, d, moe_cfg, layers):
    e, ff = moe_cfg.num_experts, moe_cfg.d_ff_expert
    ks = jax.random.split(key, 4)
    return {
        "gate": normal(ks[0], (layers, d, e), d ** -0.5),
        "w1": normal(ks[1], (layers, e, d, ff), d ** -0.5),
        "w3": normal(ks[2], (layers, e, d, ff), d ** -0.5),
        "w2": normal(ks[3], (layers, e, ff, d), ff ** -0.5),
    }


def moe_ffn(p, x, moe_cfg):
    d = getattr(moe_cfg, "dispatch", "global")
    if d == "sharded":
        return moe_ffn_sharded(p, x, moe_cfg)
    if d == "shardmap":
        return moe_ffn_shardmap(p, x, moe_cfg)
    return moe_ffn_global(p, x, moe_cfg)


def moe_ffn_global(p, x, moe_cfg):
    """x [B,S,d] -> [B,S,d]. Top-k routing with capacity dropping."""
    B, S, d = x.shape
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    nt = B * S
    cap = max(int(moe_cfg.capacity_factor * nt * k / E), 1)
    # round capacity to a data-shardable multiple
    cap = -(-cap // 8) * 8

    xt = x.reshape(nt, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["gate"])
    topv, topi = jax.lax.top_k(logits, k)                   # [nt, k]
    gates = jax.nn.softmax(topv, axis=-1)                   # normalize top-k

    e_flat = topi.reshape(-1)                               # [nt*k]
    t_flat = jnp.repeat(jnp.arange(nt), k)
    g_flat = gates.reshape(-1)

    # sort pairs by expert; rank within expert = position - segment offset
    order = jnp.argsort(e_flat)
    se, st, sg = e_flat[order], t_flat[order], g_flat[order]
    counts = jnp.bincount(se, length=E)
    seg_off = jnp.cumsum(counts) - counts
    rank = jnp.arange(nt * k) - seg_off[se]
    keep = rank < cap
    slot = jnp.where(keep, rank, 0)
    sg = jnp.where(keep, sg, 0.0)

    # dispatch: [E, cap, d] buffer (expert axis -> TP, capacity -> data)
    buf = jnp.zeros((E, cap, d), dtype=x.dtype)
    gathered = jnp.where(keep[:, None], xt[st], 0)
    buf = buf.at[se, slot].add(gathered)
    buf = shard(buf, "expert", "cap", None)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(x.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(x.dtype))
    y_buf = shard(y_buf, "expert", "cap", None)

    # combine: weighted scatter back to tokens
    y_pairs = y_buf[se, slot] * sg[:, None].astype(x.dtype)
    out = jnp.zeros_like(xt).at[st].add(y_pairs)
    return out.reshape(B, S, d)


def moe_ffn_sharded(p, x, moe_cfg):
    """Hierarchical dispatch: sort/rank/scatter stay LOCAL to each data
    shard; only the [shards, E, cap_local, d] buffer crosses the mesh
    (data->expert all-to-all), the GShard pattern. Removes the global
    argsort/scatter that forces per-layer token all-gathers in
    :func:`moe_ffn_global` (the §Perf granite-moe hillclimb).
    """
    from repro.sharding.ctx import axis_size

    B, S, d = x.shape
    E, k = moe_cfg.num_experts, moe_cfg.top_k
    nt = B * S
    ds = axis_size("batch")
    while nt % ds:
        ds //= 2
    ntl = nt // ds
    cap_l = max(int(moe_cfg.capacity_factor * ntl * k / E), 1)
    cap_l = -(-cap_l // 8) * 8
    pairs = ntl * k

    xs = shard(x.reshape(ds, ntl, d), "batch", None, None)
    logits = jnp.einsum("ptd,de->pte", xs.astype(jnp.float32), p["gate"])
    topv, topi = jax.lax.top_k(logits, k)                   # [ds,ntl,k]
    gates = jax.nn.softmax(topv, axis=-1)

    e_flat = topi.reshape(ds, pairs)
    t_flat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(ntl), k)[None], (ds, pairs))
    g_flat = gates.reshape(ds, pairs)

    order = jnp.argsort(e_flat, axis=1)
    se = jnp.take_along_axis(e_flat, order, axis=1)
    st = jnp.take_along_axis(t_flat, order, axis=1)
    sg = jnp.take_along_axis(g_flat, order, axis=1)
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(se)
    seg_off = jnp.cumsum(counts, axis=1) - counts           # [ds,E]
    rank = jnp.arange(pairs)[None] - jnp.take_along_axis(seg_off, se, axis=1)
    keep = rank < cap_l
    slot = jnp.where(keep, rank, 0)
    sg = jnp.where(keep, sg, 0.0)

    pidx = jnp.broadcast_to(jnp.arange(ds)[:, None], (ds, pairs))
    gathered = jnp.where(keep[..., None],
                         jnp.take_along_axis(xs, st[..., None], axis=1), 0)
    # build the buffer DATA-LOCAL (E replicated over the model axis): the
    # scatter stays on-chip; the explicit respec to (data, expert) below is
    # then a free slice. Without this, XLA lowers the expert-crossing
    # gather/scatter as ~10 GB masked all-reduces per layer.
    buf = jnp.zeros((ds, E, cap_l, d), dtype=x.dtype)
    buf = buf.at[pidx, se, slot].add(gathered)
    buf = shard(buf, "batch", None, None, None)
    buf = shard(buf, "batch", "expert", None, None)

    h = jax.nn.silu(jnp.einsum("pecd,edf->pecf", buf, p["w1"].astype(x.dtype)))
    h = h * jnp.einsum("pecd,edf->pecf", buf, p["w3"].astype(x.dtype))
    y_buf = jnp.einsum("pecf,efd->pecd", h, p["w2"].astype(x.dtype))
    y_buf = shard(y_buf, "batch", "expert", None, None)
    # bring each data shard's slice home (all-gather over experts), then the
    # un-dispatch gather/scatter is local again
    y_buf = shard(y_buf, "batch", None, None, None)

    y_pairs = y_buf[pidx, se, slot] * sg[..., None].astype(x.dtype)
    out = jnp.zeros_like(xs).at[pidx, st].add(y_pairs)
    out = shard(out, "batch", None, None)
    return out.reshape(B, S, d)


def moe_ffn_shardmap(p, x, moe_cfg):
    """shard_map dispatch: routing, sort and scatter are *provably local*.

    Each device holds its data shard's tokens (replicated over the model
    axis) and builds the full [E, cap_l, d] buffer redundantly; it computes
    only its model-rank's E/tp experts and all-gathers the expert outputs
    over 'model' (transpose: reduce-scatter in backward). Per layer the only
    mesh traffic is that gather — no data-dependent cross-shard gathers, so
    XLA cannot fall back to halo permutes / masked all-reduces (the failure
    modes of the pjit formulations, see EXPERIMENTS §Perf).
    """
    from repro.sharding.ctx import _CTX

    if _CTX is None:                      # single-device tests: pure local
        return _moe_shardmap_local(p, x, moe_cfg, tp=1, my_experts=None)

    mesh = _CTX["mesh"]
    batch_axes = _CTX["rules"]["batch"]
    tp = mesh.shape["model"]
    B, S, d = x.shape
    nt = B * S
    import math
    ds = math.prod(mesh.shape[a] for a in batch_axes)
    assert nt % ds == 0
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def inner(xs, gate, w1, w3, w2):
        # xs [ntl_local, d]; w* lead with E/tp local experts
        return _moe_shardmap_body(xs, gate, w1, w3, w2, moe_cfg, tp)

    fn = shard_map(
        inner, mesh=mesh,
        in_specs=(P(batch_axes, None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=P(batch_axes, None),
        check_rep=False)
    out = fn(x.reshape(nt, d), p["gate"].astype(jnp.float32),
             p["w1"].astype(x.dtype), p["w3"].astype(x.dtype),
             p["w2"].astype(x.dtype))
    return out.reshape(B, S, d)


def _moe_shardmap_body(xs, gate, w1, w3, w2, moe_cfg, tp):
    """Per-device body. xs [ntl, d] local tokens; w* [E/tp, d, ff] local."""
    from jax import lax

    E, k = moe_cfg.num_experts, moe_cfg.top_k
    ntl, d = xs.shape
    cap_l = max(int(moe_cfg.capacity_factor * ntl * k / E), 1)
    cap_l = -(-cap_l // 8) * 8
    pairs = ntl * k

    logits = jnp.einsum("td,de->te", xs.astype(jnp.float32), gate)
    topv, topi = lax.top_k(logits, k)
    gates = jax.nn.softmax(topv, axis=-1)

    e_flat = topi.reshape(pairs)
    t_flat = jnp.repeat(jnp.arange(ntl), k)
    g_flat = gates.reshape(pairs)
    order = jnp.argsort(e_flat)
    se, st, sg = e_flat[order], t_flat[order], g_flat[order]
    counts = jnp.bincount(se, length=E)
    seg_off = jnp.cumsum(counts) - counts
    rank = jnp.arange(pairs) - seg_off[se]
    keep = rank < cap_l
    slot = jnp.where(keep, rank, 0)
    sg = jnp.where(keep, sg, 0.0)

    buf = jnp.zeros((E, cap_l, d), dtype=xs.dtype)
    buf = buf.at[se, slot].add(jnp.where(keep[:, None], xs[st], 0))

    if tp > 1:
        mp = lax.axis_index("model")
        e_loc = E // tp
        buf_loc = lax.dynamic_slice_in_dim(buf, mp * e_loc, e_loc, axis=0)
    else:
        buf_loc = buf
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf_loc, w1))
    h = h * jnp.einsum("ecd,edf->ecf", buf_loc, w3)
    y_loc = jnp.einsum("ecf,efd->ecd", h, w2)
    if tp > 1:
        y_all = lax.all_gather(y_loc, "model", axis=0, tiled=True)
    else:
        y_all = y_loc

    y_pairs = y_all[se, slot] * sg[:, None].astype(xs.dtype)
    return jnp.zeros_like(xs).at[st].add(y_pairs)


def _moe_shardmap_local(p, x, moe_cfg, tp, my_experts):
    B, S, d = x.shape
    out = _moe_shardmap_body(
        x.reshape(B * S, d), p["gate"].astype(jnp.float32),
        p["w1"].astype(x.dtype), p["w3"].astype(x.dtype),
        p["w2"].astype(x.dtype), moe_cfg, tp=1)
    return out.reshape(B, S, d)
