"""jax runtime observability: retrace counters, compile/execute split,
device gauges.

Everything here degrades gracefully: jax is imported lazily, every
runtime probe is wrapped so API drift (the reason two seed tests broke)
turns a metric into an absence, never an exception on the solve path.

Three surfaces:

- :func:`install` — registers a ``jax.monitoring`` event-duration
  listener feeding ``jax_compile_events_total`` /
  ``jax_compile_seconds_total`` counters (and a histogram), giving the
  compile side of the compile-vs-execute split; execute time is what the
  planner/solver spans already measure, so
  ``execute ≈ span_time - compile_delta`` per window.
- :func:`jit_cache_entries` — sizes of the repro engine's jit caches
  (the fan-out ``grid``/``fanout`` launchers, the blocked twins, and the
  local-search climb), without forcing compilation of anything not
  already built. The per-bucket cache-miss *deltas* are recorded at the
  launch site in ``core/portfolio.py`` (``jax_jit_cache_misses_total``);
  this probe is the absolute snapshot.
- :func:`update_device_gauges` — best-effort ``memory_stats()`` and
  live-array gauges per device.
"""
from __future__ import annotations

import threading
from typing import Any, Dict, Optional

from .metrics import MetricsRegistry

__all__ = ["install", "installed", "jit_cache_entries",
           "update_device_gauges", "snapshot"]

_install_lock = threading.Lock()
_installed_registry: Optional[MetricsRegistry] = None


def installed() -> bool:
    return _installed_registry is not None


def install(registry: MetricsRegistry) -> bool:
    """Register jax.monitoring listeners feeding ``registry``.

    Idempotent; only the first registry wins (jax offers no listener
    deregistration). Returns True when the hooks are (already) live.
    """
    global _installed_registry
    with _install_lock:
        if _installed_registry is not None:
            return True
        try:
            import jax
            events = registry.counter(
                "jax_compile_events_total",
                "jax.monitoring duration events seen, by event key",
                labels=("event",))
            seconds = registry.counter(
                "jax_compile_seconds_total",
                "cumulative seconds attributed to jax compilation events",
                labels=("event",))
            hist = registry.histogram(
                "jax_compile_seconds",
                "distribution of per-event jax compilation durations",
                labels=("event",))

            def _on_duration(event: str, duration: float, **kw: Any) -> None:
                try:
                    key = event.strip("/").split("/")[-1] or event
                    events.inc(event=key)
                    seconds.inc(duration, event=key)
                    hist.observe(duration, event=key)
                except Exception:
                    pass

            jax.monitoring.register_event_duration_secs_listener(
                _on_duration)
        except Exception:
            return False
        _installed_registry = registry
        return True


def jit_cache_entries() -> Dict[str, int]:
    """Compiled-signature counts for the engine's jit launchers.

    Keys: ``greedy.<name>`` / ``blocked.<name>`` per jitted function in
    the (already-built) implementation bundles, plus ``climb.variants``
    for the local-search climb lru (distinct padded signatures). Probes
    that would *trigger* compilation are skipped.
    """
    out: Dict[str, int] = {}
    try:
        from repro.core import greedy_jax
        if greedy_jax._impl.cache_info().currsize:
            for name, fn in greedy_jax._impl().items():
                try:
                    out[f"greedy.{name}"] = int(fn._cache_size())
                except Exception:
                    pass
        if greedy_jax._blocked_impl.cache_info().currsize:
            for name, fn in greedy_jax._blocked_impl().items():
                try:
                    out[f"blocked.{name}"] = int(fn._cache_size())
                except Exception:
                    pass
    except Exception:
        pass
    try:
        from repro.core import local_search_jax
        out["climb.variants"] = int(
            local_search_jax._climb_impl.cache_info().currsize)
    except Exception:
        pass
    return out


def update_device_gauges(registry: MetricsRegistry) -> Dict[str, float]:
    """Refresh best-effort device gauges; returns what was recorded."""
    recorded: Dict[str, float] = {}
    try:
        import jax
    except Exception:
        return recorded
    mem = registry.gauge("jax_device_memory_bytes",
                         "device.memory_stats() values",
                         labels=("device", "stat"))
    try:
        for dev in jax.devices():
            stats = dev.memory_stats() or {}
            for key in ("bytes_in_use", "peak_bytes_in_use",
                        "bytes_limit", "largest_alloc_size"):
                if key in stats:
                    mem.set(float(stats[key]), device=str(dev.id), stat=key)
                    recorded[f"{dev.id}.{key}"] = float(stats[key])
    except Exception:
        pass
    try:
        live = len(jax.live_arrays())
        registry.gauge("jax_live_arrays",
                       "arrays currently alive on any device").set(live)
        recorded["live_arrays"] = float(live)
    except Exception:
        pass
    cache = registry.gauge("jax_jit_cache_entries",
                           "compiled signatures per engine jit launcher",
                           labels=("fn",))
    for name, size in jit_cache_entries().items():
        cache.set(float(size), fn=name)
        recorded[f"jit.{name}"] = float(size)
    return recorded


def snapshot(registry: MetricsRegistry) -> Dict[str, Any]:
    """One-call summary used by the bench's ``obs`` section."""
    update_device_gauges(registry)
    compile_events = 0.0
    compile_seconds = 0.0
    m = registry.get("jax_compile_events_total")
    if m is not None:
        compile_events = m.total()
    m = registry.get("jax_compile_seconds_total")
    if m is not None:
        compile_seconds = m.total()
    return {
        "hooks_installed": installed(),
        "compile_events": compile_events,
        "compile_seconds": round(compile_seconds, 6),
        "jit_cache_entries": jit_cache_entries(),
        "live_arrays": int(registry.value("jax_live_arrays")),
    }
