"""Observability facade: tracing, metrics, jax runtime hooks.

Usage (hot paths import this module once and call the module-level
helpers; the disabled path costs one attribute check):

    from repro import obs

    with obs.span("plan", solver="heuristic"):
        ...

    obs.registry().counter("plans_total").inc()

Tracing is off by default: ``obs.span(...)`` returns the inert
:data:`NULL_SPAN` singleton until a :class:`Tracer` is installed with
:func:`set_tracer` (or :func:`configure`). Metrics are always on —
registry updates are a dict update under a per-metric lock — while
*core-layer* metrics live in the process-global registry returned by
:func:`registry`; the ``PlanService`` owns a per-instance registry so
two services never cross-count (render both with
:func:`render_prometheus`).
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Tuple

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      render_prometheus)
from .trace import NULL_SPAN, NullSpan, Span, Tracer, span_tree
from . import jax_hooks

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "render_prometheus", "NULL_SPAN", "NullSpan", "Span", "Tracer",
    "span_tree", "jax_hooks",
    "tracer", "set_tracer", "registry", "set_registry", "configure",
    "span", "start_span", "attach", "current_span",
]

_tracer: Optional[Tracer] = None
_registry: MetricsRegistry = MetricsRegistry()


# -- tracer management ----------------------------------------------------

def tracer() -> Optional[Tracer]:
    """The active tracer, or None when tracing is disabled."""
    return _tracer


def set_tracer(t: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or, with None, disable) the process-global tracer."""
    global _tracer
    prev, _tracer = _tracer, t
    return prev


def registry() -> MetricsRegistry:
    """The process-global metrics registry (core/solver layer metrics)."""
    return _registry


def set_registry(r: MetricsRegistry) -> MetricsRegistry:
    global _registry
    prev, _registry = _registry, r
    return prev


def configure(tracing: bool = True, jax_hooks_on: bool = False,
              max_finished: int = 65536
              ) -> Tuple[Optional[Tracer], MetricsRegistry]:
    """One-call setup: fresh tracer (optional) + jax monitoring hooks."""
    t = Tracer(max_finished=max_finished) if tracing else None
    set_tracer(t)
    if jax_hooks_on:
        jax_hooks.install(_registry)
    return t, _registry


# -- hot-path span helpers ------------------------------------------------
# The disabled path must cost nothing measurable: one global read, one
# identity check, return a shared singleton. No allocation, no locks.

def span(name: str, parent: Optional[Span] = None, **attrs: Any):
    """Start a span for use as a context manager (NULL_SPAN when off)."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, parent=parent, **attrs)


def start_span(name: str, parent: Optional[Span] = None, **attrs: Any):
    """Start a span to be end()-ed explicitly (NULL_SPAN when off)."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.start(name, parent=parent, **attrs)


def attach(span: Optional[Span]):
    """Re-anchor implicit parenting to ``span`` on this thread."""
    t = _tracer
    if t is None or span is None or not span:
        return contextlib.nullcontext()
    return t.attach(span)


def current_span() -> Optional[Span]:
    t = _tracer
    return t.current() if t is not None else None
