"""Structured tracing: spans with parent links, exported as JSONL.

A :class:`Span` is a named, timed interval with attributes and an
optional parent; a :class:`Tracer` collects finished spans in a bounded
buffer and can render them as Chrome ``trace_event``-compatible JSONL
(one JSON object per line, loadable with ``json.loads`` line by line,
or pasted into ``chrome://tracing`` / Perfetto after wrapping in
``[...]``).

Parenting is implicit within a thread via a ``contextvars`` context
variable (``with tracer.span("child"):`` nests under the enclosing
span) and explicit across threads: pass ``parent=`` or re-anchor a
worker thread with ``with tracer.attach(span):``.

Hot-path contract: when tracing is disabled the module-level facade in
``repro.obs`` returns the singleton :data:`NULL_SPAN`, whose every
method is a constant no-op — no locks, no allocation beyond the call
itself. The enabled path takes one small lock per span start/end (never
per attribute set), which is fine: an enabled tracer is an explicit
opt-in.
"""
from __future__ import annotations

import contextlib
import contextvars
import itertools
import json
import threading
import time
from collections import deque
from typing import Any, Deque, Dict, Iterator, List, Optional

__all__ = ["Span", "NullSpan", "NULL_SPAN", "Tracer", "span_tree"]

_ids = itertools.count(1)
_current_span: contextvars.ContextVar[Optional["Span"]] = \
    contextvars.ContextVar("repro_obs_current_span", default=None)


class Span:
    """One timed interval. Use as a context manager or end() explicitly."""

    __slots__ = ("name", "span_id", "parent_id", "trace_id", "attrs",
                 "t0", "t1", "tid", "_tracer", "_token")

    def __init__(self, name: str, tracer: "Tracer",
                 parent: Optional["Span"] = None,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.span_id = next(_ids)
        if parent is not None and parent.span_id:
            self.parent_id = parent.span_id
            self.trace_id = parent.trace_id
        else:
            self.parent_id = 0
            self.trace_id = self.span_id
        self.attrs: Dict[str, Any] = dict(attrs) if attrs else {}
        self.t0 = time.perf_counter()
        self.t1: Optional[float] = None
        self.tid = threading.get_ident()
        self._tracer = tracer
        self._token = None

    # -- recording ---------------------------------------------------
    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, **attrs: Any) -> None:
        """Finish the span (idempotent; later calls are no-ops)."""
        if self.t1 is not None:
            return
        if attrs:
            self.attrs.update(attrs)
        self.t1 = time.perf_counter()
        self._tracer._finish(self)

    @property
    def duration(self) -> float:
        return (self.t1 if self.t1 is not None
                else time.perf_counter()) - self.t0

    # -- context manager: makes self the implicit parent -------------
    def __enter__(self) -> "Span":
        self._token = _current_span.set(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if self._token is not None:
            _current_span.reset(self._token)
            self._token = None
        if exc_type is not None and "error" not in self.attrs:
            self.attrs["error"] = exc_type.__name__
        self.end()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "open" if self.t1 is None else f"{self.duration * 1e3:.2f}ms"
        return f"Span({self.name!r}, id={self.span_id}, {state})"


class NullSpan:
    """Inert span: every operation is a constant-time no-op."""

    __slots__ = ()
    name = ""
    span_id = 0
    parent_id = 0
    trace_id = 0
    t0 = 0.0
    t1 = 0.0
    tid = 0
    attrs: Dict[str, Any] = {}
    duration = 0.0

    def set(self, **attrs: Any) -> "NullSpan":
        return self

    def end(self, **attrs: Any) -> None:
        pass

    def __enter__(self) -> "NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        pass

    def __bool__(self) -> bool:
        return False

    def __repr__(self) -> str:  # pragma: no cover
        return "NULL_SPAN"


NULL_SPAN = NullSpan()


class Tracer:
    """Collects spans; bounded buffer of finished spans, JSONL export."""

    def __init__(self, max_finished: int = 65536):
        self._finished: Deque[Span] = deque(maxlen=max_finished)
        self._open: Dict[int, Span] = {}
        self._lock = threading.Lock()
        self._epoch = time.perf_counter()
        self.enabled = True

    # -- span creation -----------------------------------------------
    def start(self, name: str, parent: Optional[Span] = None,
              **attrs: Any) -> Span:
        """Start a span without entering it (end() it explicitly)."""
        if parent is None:
            parent = _current_span.get()
        elif not parent:          # NULL_SPAN passed through from a caller
            parent = None
        sp = Span(name, self, parent=parent, attrs=attrs)
        with self._lock:
            self._open[sp.span_id] = sp
        return sp

    def span(self, name: str, parent: Optional[Span] = None,
             **attrs: Any) -> Span:
        """Start a span to be used as a context manager."""
        return self.start(name, parent=parent, **attrs)

    @contextlib.contextmanager
    def attach(self, span: Optional[Span]) -> Iterator[None]:
        """Make ``span`` the implicit parent on *this* thread.

        Context variables do not propagate across thread-pool submission,
        so worker threads re-anchor explicitly:
        ``with tracer.attach(rung_span): ...``.
        """
        if span is None or not span:
            yield
            return
        token = _current_span.set(span)
        try:
            yield
        finally:
            _current_span.reset(token)

    def current(self) -> Optional[Span]:
        return _current_span.get()

    def _finish(self, span: Span) -> None:
        with self._lock:
            self._open.pop(span.span_id, None)
            self._finished.append(span)

    # -- inspection ---------------------------------------------------
    def finished(self) -> List[Span]:
        with self._lock:
            return list(self._finished)

    def open_spans(self) -> List[Span]:
        with self._lock:
            return list(self._open.values())

    def clear(self) -> None:
        with self._lock:
            self._finished.clear()
            self._open.clear()

    def tree(self, trace_id: Optional[int] = None) -> List[Dict[str, Any]]:
        """Nested ``{name, attrs, duration_ms, children}`` dicts.

        With ``trace_id=None`` returns a forest of every root span seen.
        """
        spans = self.finished()
        if trace_id is not None:
            spans = [s for s in spans if s.trace_id == trace_id]
        return span_tree(spans)

    # -- export -------------------------------------------------------
    def to_events(self) -> List[Dict[str, Any]]:
        """Finished spans as Chrome ``trace_event`` complete events."""
        out = []
        for s in self.finished():
            args = dict(s.attrs)
            args["span_id"] = s.span_id
            args["trace_id"] = s.trace_id
            if s.parent_id:
                args["parent_id"] = s.parent_id
            out.append({
                "name": s.name,
                "cat": "repro",
                "ph": "X",
                "ts": (s.t0 - self._epoch) * 1e6,
                "dur": ((s.t1 or s.t0) - s.t0) * 1e6,
                "pid": 0,
                "tid": s.tid,
                "args": args,
            })
        return out

    def to_jsonl(self) -> str:
        return "\n".join(json.dumps(ev, default=str)
                         for ev in self.to_events())

    def dump_jsonl(self, path: str) -> int:
        """Write one trace_event JSON object per line; returns #events."""
        events = self.to_events()
        with open(path, "w") as fh:
            for ev in events:
                fh.write(json.dumps(ev, default=str))
                fh.write("\n")
        return len(events)


def span_tree(spans: List[Span]) -> List[Dict[str, Any]]:
    """Arrange finished spans into parent->children nests (roots first)."""
    nodes = {s.span_id: {"name": s.name, "attrs": dict(s.attrs),
                         "duration_ms": round(s.duration * 1e3, 3),
                         "children": []}
             for s in spans}
    roots: List[Dict[str, Any]] = []
    for s in sorted(spans, key=lambda s: s.t0):
        node = nodes[s.span_id]
        parent = nodes.get(s.parent_id)
        if parent is not None:
            parent["children"].append(node)
        else:
            roots.append(node)
    return roots
