"""Typed metrics: Counter / Gauge / Histogram with label sets.

A :class:`MetricsRegistry` owns named metrics; each metric owns children
keyed by label-value tuples. ``registry.render_prometheus()`` emits the
Prometheus text exposition format so an RPC front can serve the string
as ``/metrics`` verbatim.

Histograms keep cumulative buckets (Prometheus convention) plus an
optional bounded reservoir of raw samples so exact small-n percentiles
(e.g. the service's ``p50_ms``/``p99_ms`` wire fields) survive the
migration from ad-hoc deques.
"""
from __future__ import annotations

import math
import threading
from bisect import bisect_left
from collections import deque
from typing import Any, Deque, Dict, Iterable, List, Optional, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "render_prometheus", "DEFAULT_BUCKETS"]

# Latency-flavoured default buckets (seconds): 100us .. 60s.
DEFAULT_BUCKETS = (1e-4, 5e-4, 1e-3, 5e-3, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)

LabelKey = Tuple[str, ...]


def _label_key(metric: "_Metric", labels: Dict[str, Any]) -> LabelKey:
    if set(labels) != set(metric.label_names):
        raise ValueError(
            f"{metric.name}: expected labels {metric.label_names}, "
            f"got {tuple(sorted(labels))}")
    return tuple(str(labels[k]) for k in metric.label_names)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: Iterable[str] = ()):
        self.name = name
        self.help = help
        self.label_names: Tuple[str, ...] = tuple(label_names)
        self._lock = threading.Lock()

    def _fmt_labels(self, key: LabelKey,
                    extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        pairs = list(zip(self.label_names, key)) + list(extra)
        if not pairs:
            return ""
        body = ",".join(f'{k}="{_escape(v)}"' for k, v in pairs)
        return "{" + body + "}"


def _escape(v: str) -> str:
    return str(v).replace("\\", r"\\").replace('"', r"\"").replace(
        "\n", r"\n")


class Counter(_Metric):
    """Monotonic counter (per label set)."""

    kind = "counter"

    def __init__(self, name: str, help: str = "",
                 label_names: Iterable[str] = ()):
        super().__init__(name, help, label_names)
        self._values: Dict[LabelKey, float] = {}

    def inc(self, amount: float = 1, **labels: Any) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = _label_key(self, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + amount

    def value(self, **labels: Any) -> float:
        key = _label_key(self, labels)
        with self._lock:
            return self._values.get(key, 0)

    def values(self) -> Dict[LabelKey, float]:
        with self._lock:
            return dict(self._values)

    def total(self) -> float:
        with self._lock:
            return sum(self._values.values()) if self._values else 0

    def render(self) -> List[str]:
        with self._lock:
            items = sorted(self._values.items())
        if not items and not self.label_names:
            items = [((), 0)]
        return [f"{self.name}{self._fmt_labels(k)} {_num(v)}"
                for k, v in items]


class Gauge(_Metric):
    """Point-in-time value; supports inc/dec/set and high-watermarks."""

    kind = "gauge"

    def __init__(self, name: str, help: str = "",
                 label_names: Iterable[str] = ()):
        super().__init__(name, help, label_names)
        self._values: Dict[LabelKey, float] = {}

    def set(self, value: float, **labels: Any) -> None:
        key = _label_key(self, labels)
        with self._lock:
            self._values[key] = value

    def set_max(self, value: float, **labels: Any) -> None:
        """Keep the running maximum (high-watermark gauges)."""
        key = _label_key(self, labels)
        with self._lock:
            if value > self._values.get(key, float("-inf")):
                self._values[key] = value

    def inc(self, amount: float = 1, **labels: Any) -> None:
        key = _label_key(self, labels)
        with self._lock:
            self._values[key] = self._values.get(key, 0) + amount

    def dec(self, amount: float = 1, **labels: Any) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: Any) -> float:
        key = _label_key(self, labels)
        with self._lock:
            return self._values.get(key, 0)

    def values(self) -> Dict[LabelKey, float]:
        with self._lock:
            return dict(self._values)

    def render(self) -> List[str]:
        with self._lock:
            items = sorted(self._values.items())
        if not items and not self.label_names:
            items = [((), 0)]
        return [f"{self.name}{self._fmt_labels(k)} {_num(v)}"
                for k, v in items]


class _HistChild:
    __slots__ = ("counts", "sum", "count", "reservoir")

    def __init__(self, n_buckets: int, reservoir: int):
        self.counts = [0] * n_buckets   # per-bucket (non-cumulative)
        self.sum = 0.0
        self.count = 0
        self.reservoir: Optional[Deque[float]] = (
            deque(maxlen=reservoir) if reservoir else None)


class Histogram(_Metric):
    """Bucketed distribution + optional raw-sample reservoir."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 label_names: Iterable[str] = (),
                 buckets: Iterable[float] = DEFAULT_BUCKETS,
                 reservoir: int = 0):
        super().__init__(name, help, label_names)
        self.buckets: Tuple[float, ...] = tuple(sorted(buckets))
        self.reservoir_size = reservoir
        self._children: Dict[LabelKey, _HistChild] = {}

    def _child(self, key: LabelKey) -> _HistChild:
        child = self._children.get(key)
        if child is None:
            child = self._children.setdefault(
                key, _HistChild(len(self.buckets) + 1, self.reservoir_size))
        return child

    def observe(self, value: float, **labels: Any) -> None:
        key = _label_key(self, labels)
        idx = bisect_left(self.buckets, value)
        with self._lock:
            child = self._child(key)
            child.counts[idx] += 1
            child.sum += value
            child.count += 1
            if child.reservoir is not None:
                child.reservoir.append(value)

    def count(self, **labels: Any) -> int:
        key = _label_key(self, labels)
        with self._lock:
            child = self._children.get(key)
            return child.count if child else 0

    def sum(self, **labels: Any) -> float:
        key = _label_key(self, labels)
        with self._lock:
            child = self._children.get(key)
            return child.sum if child else 0.0

    def samples(self, **labels: Any) -> List[float]:
        """The raw reservoir (most recent samples), oldest first."""
        key = _label_key(self, labels)
        with self._lock:
            child = self._children.get(key)
            if child is None or child.reservoir is None:
                return []
            return list(child.reservoir)

    def percentile(self, q: float, **labels: Any) -> float:
        """Exact percentile over the reservoir (recent samples).

        Falls back to a bucket upper-bound estimate when the reservoir
        is disabled. Returns 0.0 with no samples.
        """
        key = _label_key(self, labels)
        with self._lock:
            child = self._children.get(key)
            if child is None or child.count == 0:
                return 0.0
            if child.reservoir:
                data = sorted(child.reservoir)
                pos = min(len(data) - 1,
                          max(0, math.ceil(q / 100.0 * len(data)) - 1))
                return data[pos]
            # bucket-based estimate: first bucket whose cumulative count
            # covers the quantile
            target = q / 100.0 * child.count
            cum = 0
            for i, c in enumerate(child.counts):
                cum += c
                if cum >= target:
                    return (self.buckets[i] if i < len(self.buckets)
                            else self.buckets[-1])
            return self.buckets[-1]

    def values(self) -> Dict[LabelKey, Tuple[int, float]]:
        with self._lock:
            return {k: (c.count, c.sum) for k, c in self._children.items()}

    def render(self) -> List[str]:
        lines: List[str] = []
        with self._lock:
            items = sorted(self._children.items())
        for key, child in items:
            cum = 0
            for i, bound in enumerate(self.buckets):
                cum += child.counts[i]
                lines.append(
                    f"{self.name}_bucket"
                    f"{self._fmt_labels(key, (('le', _num(bound)),))} {cum}")
            cum += child.counts[-1]
            lines.append(
                f"{self.name}_bucket"
                f"{self._fmt_labels(key, (('le', '+Inf'),))} {cum}")
            lines.append(
                f"{self.name}_sum{self._fmt_labels(key)} {_num(child.sum)}")
            lines.append(
                f"{self.name}_count{self._fmt_labels(key)} {child.count}")
        return lines


def _num(v: float) -> str:
    if isinstance(v, float) and v.is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(v) if isinstance(v, float) else str(v)


class MetricsRegistry:
    """Named metrics with get-or-create semantics."""

    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, label_names, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help=help, label_names=label_names, **kw)
                self._metrics[name] = m
                return m
        if not isinstance(m, cls):
            raise TypeError(f"{name} already registered as {m.kind}")
        if m.label_names != tuple(label_names):
            raise ValueError(f"{name}: label mismatch "
                             f"{m.label_names} vs {tuple(label_names)}")
        return m

    def counter(self, name: str, help: str = "",
                labels: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, tuple(labels))

    def gauge(self, name: str, help: str = "",
              labels: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, tuple(labels))

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = (),
                  buckets: Iterable[float] = DEFAULT_BUCKETS,
                  reservoir: int = 0) -> Histogram:
        return self._get(Histogram, name, help, tuple(labels),
                         buckets=buckets, reservoir=reservoir)

    def get(self, name: str) -> Optional[_Metric]:
        with self._lock:
            return self._metrics.get(name)

    def metrics(self) -> List[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def value(self, name: str, default: float = 0.0, **labels: Any) -> float:
        """Scalar read of a counter/gauge (0/default when absent)."""
        m = self.get(name)
        if m is None or not isinstance(m, (Counter, Gauge)):
            return default
        return m.value(**labels)

    def collect(self) -> Dict[str, Dict[LabelKey, Any]]:
        """Snapshot {metric_name: {label_key: value}} for tests/benches."""
        out: Dict[str, Dict[LabelKey, Any]] = {}
        for m in self.metrics():
            out[m.name] = m.values()
        return out

    def render_prometheus(self) -> str:
        return render_prometheus(self)


def render_prometheus(*registries: MetricsRegistry) -> str:
    """Prometheus text exposition across one or more registries."""
    lines: List[str] = []
    seen = set()
    for reg in registries:
        for m in sorted(reg.metrics(), key=lambda m: m.name):
            if m.name in seen:      # first registry wins on name clash
                continue
            seen.add(m.name)
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m.render())
    return "\n".join(lines) + ("\n" if lines else "")
