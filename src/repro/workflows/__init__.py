from repro.workflows.generators import (  # noqa: F401
    Workflow,
    WORKFLOW_KINDS,
    independent_tasks,
    layered_random,
    make_workflow,
    wfgen_scale,
)
