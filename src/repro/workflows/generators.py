"""Workflow (DAG) generators emulating the paper's benchmark set (§6.1).

The paper uses four nf-core pipelines (atacseq, bacass, eager, methylseq)
plus WFGen-style scale-ups of those up to 30k tasks. The real .dot exports
are not redistributable here, so each generator reproduces the published
*structure* of its pipeline: per-sample linear tool chains with stage-level
fan-out/fan-in, cross-sample merge barriers and a final QC/aggregation
chain. ``wfgen_scale`` scales any of them to a target task count the way
WFGen scales a model graph (replicating samples, preserving motif shape).

Vertex/edge weights follow the paper: normal distributions with vertex
weights generally larger than edge weights, truncated to positive ints.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workflow:
    """An immutable task DAG with computation and communication weights."""

    name: str
    node_w: np.ndarray          # [n] computation weight (normalized)
    edges: np.ndarray           # [m, 2] (u, v) precedence pairs, u -> v
    edge_w: np.ndarray          # [m] communication weight (bandwidth = 1)

    @property
    def n(self) -> int:
        return len(self.node_w)

    @property
    def m(self) -> int:
        return len(self.edges)

    def validate(self) -> None:
        n = self.n
        assert self.edges.ndim == 2 and self.edges.shape[1] == 2
        assert (self.edges >= 0).all() and (self.edges < n).all()
        assert (self.node_w >= 1).all() and (self.edge_w >= 0).all()
        # acyclicity via Kahn
        indeg = np.zeros(n, dtype=np.int64)
        np.add.at(indeg, self.edges[:, 1], 1)
        order = topological_order(n, self.edges)
        assert len(order) == n, "workflow graph has a cycle"


def topological_order(n: int, edges: np.ndarray) -> list[int]:
    """Kahn's algorithm [22]; returns a topological order (len < n => cycle)."""
    indeg = np.zeros(n, dtype=np.int64)
    succs: list[list[int]] = [[] for _ in range(n)]
    for u, v in edges:
        succs[int(u)].append(int(v))
        indeg[int(v)] += 1
    queue = [int(i) for i in np.flatnonzero(indeg == 0)]
    order: list[int] = []
    head = 0
    while head < len(queue):
        u = queue[head]
        head += 1
        order.append(u)
        for v in succs[u]:
            indeg[v] -= 1
            if indeg[v] == 0:
                queue.append(v)
    return order


def _weights(rng: np.random.Generator, n: int, m: int,
             node_mu: float = 120.0, node_sigma: float = 35.0,
             edge_mu: float = 14.0, edge_sigma: float = 5.0):
    node_w = np.maximum(rng.normal(node_mu, node_sigma, size=n), 1.0)
    edge_w = np.maximum(rng.normal(edge_mu, edge_sigma, size=m), 1.0)
    return node_w.astype(np.int64), edge_w.astype(np.int64)


# ---------------------------------------------------------------------------
# Pipeline-motif generator.
#
# A motif is a list of stages; each stage is either
#   ("chain", k)   -- per-sample linear chain of k tools
#   ("fan", w, k)  -- per-sample fan-out to w parallel chains of k tools,
#                     then fan-in
#   ("merge", g)   -- cross-sample barrier merging groups of g samples
#   ("final", k)   -- single aggregation chain of k tools over everything
# ---------------------------------------------------------------------------

_MOTIFS = {
    # nf-core/atacseq: trim/align per sample, bigwig+peak branches, merged
    # library analysis, consensus peaks + QC.
    "atacseq": [("chain", 3), ("fan", 3, 2), ("chain", 2), ("merge", 4),
                ("final", 4)],
    # nf-core/bacass: small assembly pipeline, little branching.
    "bacass": [("chain", 4), ("fan", 2, 2), ("chain", 2), ("final", 3)],
    # nf-core/eager: ancient-DNA; long per-sample chains, two analysis
    # branches, genotyping merge.
    "eager": [("chain", 5), ("fan", 2, 3), ("chain", 3), ("merge", 3),
              ("final", 5)],
    # nf-core/methylseq: align, dedup, methylation extraction branches, MQC.
    "methylseq": [("chain", 4), ("fan", 3, 1), ("chain", 2), ("final", 3)],
}

WORKFLOW_KINDS = tuple(_MOTIFS)


def _motif_tasks_per_sample(motif) -> int:
    per = 0
    for stage in motif:
        if stage[0] == "chain":
            per += stage[1]
        elif stage[0] == "fan":
            per += stage[1] * stage[2] + 1  # + fan-in node
        elif stage[0] == "merge":
            per += 0  # merge nodes are per-group, counted separately
    return per


def make_workflow(kind: str, n_samples: int, seed: int = 0,
                  name: str | None = None) -> Workflow:
    """Instantiate a pipeline motif for ``n_samples`` input samples."""
    motif = _MOTIFS[kind]
    rng = np.random.default_rng(seed)
    edges: list[tuple[int, int]] = []
    next_id = 0

    def new_node() -> int:
        nonlocal next_id
        next_id += 1
        return next_id - 1

    # frontier[i] = last task of sample-group i
    frontier = [None] * n_samples
    group_of = list(range(n_samples))  # sample -> current group id
    heads: dict[int, int | None] = {g: None for g in range(n_samples)}

    def extend(g: int, node: int) -> None:
        if heads[g] is not None:
            edges.append((heads[g], node))
        heads[g] = node

    for stage in motif:
        if stage[0] == "chain":
            for g in list(heads):
                for _ in range(stage[1]):
                    extend(g, new_node())
        elif stage[0] == "fan":
            _, width, k = stage
            for g in list(heads):
                root = heads[g]
                tails = []
                for _ in range(width):
                    prev = root
                    for _ in range(k):
                        nd = new_node()
                        if prev is not None:
                            edges.append((prev, nd))
                        prev = nd
                    tails.append(prev)
                join = new_node()
                for t in tails:
                    edges.append((t, join))
                heads[g] = join
        elif stage[0] == "merge":
            _, gsize = stage
            groups = list(heads)
            new_heads: dict[int, int | None] = {}
            for i in range(0, len(groups), gsize):
                block = groups[i:i + gsize]
                nd = new_node()
                for g in block:
                    if heads[g] is not None:
                        edges.append((heads[g], nd))
                new_heads[len(new_heads)] = nd
            heads = new_heads
        elif stage[0] == "final":
            nd = new_node()
            for g in list(heads):
                if heads[g] is not None:
                    edges.append((heads[g], nd))
            heads = {0: nd}
            for _ in range(stage[1] - 1):
                nxt = new_node()
                edges.append((heads[0], nxt))
                heads[0] = nxt

    n = next_id
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    node_w, edge_w = _weights(rng, n, len(e))
    wf = Workflow(name=name or f"{kind}-s{n_samples}", node_w=node_w,
                  edges=e, edge_w=edge_w)
    wf.validate()
    return wf


def wfgen_scale(kind: str, n_target: int, seed: int = 0) -> Workflow:
    """WFGen-style scale-up: pick n_samples so the instance has ~n_target tasks."""
    per = max(_motif_tasks_per_sample(_MOTIFS[kind]), 1)
    n_samples = max(1, round(n_target / per))
    wf = make_workflow(kind, n_samples, seed=seed,
                       name=f"{kind}-n{n_target}")
    return wf


def layered_random(n: int, n_layers: int, p_edge: float = 0.25,
                   seed: int = 0, name: str | None = None) -> Workflow:
    """Layered random DAG (used for property tests and NP-hardness probes)."""
    rng = np.random.default_rng(seed)
    layer = rng.integers(0, n_layers, size=n)
    layer.sort()
    edges = []
    for v in range(n):
        lv = layer[v]
        if lv == 0:
            continue
        prev = np.flatnonzero(layer == lv - 1)
        if len(prev) == 0:
            continue
        mask = rng.random(len(prev)) < p_edge
        chosen = prev[mask]
        if len(chosen) == 0:
            chosen = prev[rng.integers(0, len(prev), size=1)]
        for u in chosen:
            edges.append((int(u), v))
    e = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    node_w, edge_w = _weights(rng, n, len(e))
    wf = Workflow(name=name or f"rand-n{n}", node_w=node_w, edges=e,
                  edge_w=edge_w)
    wf.validate()
    return wf


def independent_tasks(durs, name: str = "independent") -> Workflow:
    """Edge-free workflow (UCAS instances of Theorem 4.3)."""
    durs = np.asarray(durs, dtype=np.int64)
    return Workflow(name=name, node_w=durs,
                    edges=np.zeros((0, 2), dtype=np.int64),
                    edge_w=np.zeros(0, dtype=np.int64))
