"""Graphviz .dot import/export for workflows.

The paper converts Nextflow pipeline definitions to .dot and strips the
Nextflow-internal pseudo-tasks; `load_dot` performs the same cleanup
(drop nodes matching ``pseudo_patterns``, reconnect their in/out edges).
Weights come from node/edge ``weight`` attributes when present, else the
usual normal distributions.
"""
from __future__ import annotations

import re

import numpy as np

from repro.workflows.generators import Workflow, _weights


def save_dot(wf: Workflow, path: str) -> None:
    with open(path, "w") as f:
        f.write(f'digraph "{wf.name}" {{\n')
        for i, w in enumerate(wf.node_w):
            f.write(f'  n{i} [weight={int(w)}];\n')
        for (u, v), w in zip(wf.edges, wf.edge_w):
            f.write(f'  n{u} -> n{v} [weight={int(w)}];\n')
        f.write("}\n")


_NODE_RE = re.compile(r'^\s*"?([\w.\-]+)"?\s*(\[(.*)\])?\s*;?\s*$')
_EDGE_RE = re.compile(
    r'^\s*"?([\w.\-]+)"?\s*->\s*"?([\w.\-]+)"?\s*(\[(.*)\])?\s*;?\s*$')
_W_RE = re.compile(r'weight\s*=\s*"?(\d+)')


def load_dot(path: str, name: str | None = None,
             pseudo_patterns: tuple[str, ...] = (),
             seed: int = 0) -> Workflow:
    names: dict[str, int] = {}
    node_w: list[int] = []
    edges: list[tuple[int, int]] = []
    edge_w: list[int] = []

    def nid(s: str) -> int:
        if s not in names:
            names[s] = len(names)
            node_w.append(0)
        return names[s]

    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith(("digraph", "}", "//", "#")):
                continue
            m = _EDGE_RE.match(line)
            if m:
                u, v = nid(m.group(1)), nid(m.group(2))
                w = _W_RE.search(m.group(3) or "")
                edges.append((u, v))
                edge_w.append(int(w.group(1)) if w else 0)
                continue
            m = _NODE_RE.match(line)
            if m and "->" not in line:
                i = nid(m.group(1))
                w = _W_RE.search(m.group(3) or "")
                if w:
                    node_w[i] = int(w.group(1))

    # drop pseudo-tasks (Nextflow internals), reconnecting through them
    pseudo = {i for s, i in names.items()
              if any(re.search(p, s) for p in pseudo_patterns)}
    if pseudo:
        preds: dict[int, list[int]] = {}
        succs: dict[int, list[int]] = {}
        for (u, v) in edges:
            succs.setdefault(u, []).append(v)
            preds.setdefault(v, []).append(u)
        new_edges = [(u, v) for (u, v) in edges
                     if u not in pseudo and v not in pseudo]
        for p in pseudo:
            for u in preds.get(p, []):
                for v in succs.get(p, []):
                    if u not in pseudo and v not in pseudo:
                        new_edges.append((u, v))
        keep = [i for i in range(len(node_w)) if i not in pseudo]
        remap = {old: new for new, old in enumerate(keep)}
        node_w = [node_w[i] for i in keep]
        edges_rw = sorted({(remap[u], remap[v]) for (u, v) in new_edges})
        edges = edges_rw
        edge_w = [0] * len(edges)

    n, m = len(node_w), len(edges)
    rnd_nw, rnd_ew = _weights(np.random.default_rng(seed), n, max(m, 1))
    nw = np.asarray([w if w > 0 else int(r)
                     for w, r in zip(node_w, rnd_nw)], dtype=np.int64)
    ew = np.asarray([w if w > 0 else int(r)
                     for w, r in zip(edge_w, rnd_ew[:m])], dtype=np.int64) \
        if m else np.zeros(0, dtype=np.int64)
    wf = Workflow(name=name or path, node_w=nw,
                  edges=np.asarray(edges, dtype=np.int64).reshape(-1, 2),
                  edge_w=ew)
    wf.validate()
    return wf
