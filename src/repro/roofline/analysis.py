"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

Terms (per step, seconds):
  compute    = HLO_FLOPs / (chips * peak_FLOPs)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes_per_chip / link_bw

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program, all
chips); collective bytes are parsed from the post-SPMD HLO text
(``compiled.as_text()``), whose shapes are *per-device*, by summing operand
sizes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.
"""
from __future__ import annotations

import dataclasses
import re

import numpy as np


@dataclasses.dataclass(frozen=True)
class HWSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 / chip
    hbm_bw: float = 819e9               # bytes/s / chip
    link_bw: float = 50e9               # bytes/s / link (ICI)
    hbm_bytes: float = 16e9


HW = HWSpec()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# operand tokens look like "f32[8,128]{1,0} %name" / "bf16[4096] param.3"
_OPERAND_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s+%?[a-z]")
_OP_RE = re.compile(
    r"=\s*.*?\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    b = _DTYPE_BYTES.get(dtype)
    if b is None:
        return 0
    if not dims:
        return b
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n * b


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes by collective kind, from post-SPMD HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        m = _OP_RE.search(ls)
        if not m:
            continue
        kind, phase = m.group(1), m.group(2)
        if phase == "-done":            # counted at -start
            continue
        # operand shapes inside the call parens ("type{layout} %name")
        paren = ls[m.end() - 1:]
        cut = paren.find("), ")
        if cut > 0:
            paren = paren[:cut + 1]
        shapes = _OPERAND_RE.findall(paren)
        if not shapes:                  # fall back to the result type
            shapes = _SHAPE_RE.findall(ls.split("=", 1)[1])[:1]
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += nbytes
        counts[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def _loop_trip_counts(hlo_text: str) -> float:
    """Best-effort: cost_analysis already multiplies through while loops;
    the HLO text does not, so collectives inside scans are undercounted.
    We extract `trip_count=N` backend hints when present (XLA CPU/TPU often
    annotate known trip counts); callers can also pass explicit factors."""
    return 1.0


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes_per_chip: float, chips: int,
                   hw: HWSpec = HW) -> dict:
    compute = flops / (chips * hw.peak_flops)
    memory = bytes_accessed / (chips * hw.hbm_bw)
    collective = coll_bytes_per_chip / hw.link_bw
    dominant = max(
        (("compute", compute), ("memory", memory),
         ("collective", collective)), key=lambda kv: kv[1])[0]
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant,
        "bound_s": max(compute, memory, collective),
    }
