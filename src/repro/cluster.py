"""Target platform model (paper §3 + §6.1, Table 1).

A cluster of ``P`` heterogeneous compute processors plus ``P*(P-1)``
fictional link processors (one per directed link of the fully connected,
full-duplex topology). Link processors execute communication tasks in the
communication-enhanced DAG ``G_c``.

Processor ids: ``0..P-1`` are compute processors; link ``(a, b)``, ``a != b``
gets id ``P + a*(P-1) + (b if b < a else b-1)``; ``num_procs = P*P``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Table 1 of the paper: (name, speed, P_idle, P_work)
PROCESSOR_TABLE = (
    ("PT1", 4, 40, 10),
    ("PT2", 6, 60, 30),
    ("PT3", 8, 80, 40),
    ("PT4", 12, 120, 50),
    ("PT5", 16, 150, 70),
    ("PT6", 32, 200, 100),
)


@dataclasses.dataclass(frozen=True)
class Platform:
    """A heterogeneous cluster with compute and link processors."""

    speed: np.ndarray        # [P] normalized compute speed
    p_idle: np.ndarray       # [P*P] idle power (compute + links)
    p_work: np.ndarray       # [P*P] active power  (compute + links)
    type_of: np.ndarray      # [P] index into PROCESSOR_TABLE (for reporting)

    @property
    def num_compute(self) -> int:
        return len(self.speed)

    @property
    def num_procs(self) -> int:
        return len(self.p_idle)

    def link_id(self, a: int, b: int) -> int:
        """Fictional processor id for directed link a -> b (a != b)."""
        P = self.num_compute
        assert a != b
        return P + a * (P - 1) + (b if b < a else b - 1)

    @property
    def idle_total(self) -> int:
        """Constant idle draw of the whole platform, per time unit.

        The paper sums P_idle of every processor at every time unit
        (Eq. (23)); since this is schedule-independent it folds into an
        *effective* green budget ``G_j - idle_total``.
        """
        return int(self.p_idle.sum())

    def exec_time(self, node_w: np.ndarray, proc: np.ndarray) -> np.ndarray:
        """Integer running times of tasks with weights node_w mapped on proc."""
        t = np.ceil(np.asarray(node_w, dtype=np.float64)
                    / self.speed[np.asarray(proc)]).astype(np.int64)
        return np.maximum(t, 1)


def make_cluster(nodes_per_type: int, seed: int = 0,
                 link_power: bool = True) -> Platform:
    """Build the paper's clusters: ``small`` = 12 nodes/type, ``large`` = 24.

    Link processors draw P_idle, P_work ~ U{1, 2} (paper §6.1); pass
    ``link_power=False`` for the UCAS-style zero-power links used in the
    complexity-reduction tests.
    """
    rng = np.random.default_rng(seed)
    P = nodes_per_type * len(PROCESSOR_TABLE)
    speed = np.empty(P, dtype=np.int64)
    type_of = np.empty(P, dtype=np.int64)
    p_idle = np.zeros(P * P, dtype=np.int64)
    p_work = np.zeros(P * P, dtype=np.int64)
    for t, (_, sp, pi, pw) in enumerate(PROCESSOR_TABLE):
        sl = slice(t * nodes_per_type, (t + 1) * nodes_per_type)
        speed[sl] = sp
        type_of[sl] = t
        p_idle[sl] = pi
        p_work[sl] = pw
    if link_power:
        n_links = P * P - P
        p_idle[P:] = rng.integers(1, 3, size=n_links)
        p_work[P:] = rng.integers(1, 3, size=n_links)
    return Platform(speed=speed, p_idle=p_idle, p_work=p_work, type_of=type_of)


def make_uniform_platform(P: int) -> Platform:
    """UCAS platform of Theorem 4.3: P_idle = 0, P_work = 1, no comm power."""
    return Platform(
        speed=np.ones(P, dtype=np.int64),
        p_idle=np.zeros(P * P, dtype=np.int64),
        p_work=np.concatenate([np.ones(P, dtype=np.int64),
                               np.zeros(P * P - P, dtype=np.int64)]),
        type_of=np.zeros(P, dtype=np.int64),
    )


SMALL_CLUSTER_NODES_PER_TYPE = 12
LARGE_CLUSTER_NODES_PER_TYPE = 24
