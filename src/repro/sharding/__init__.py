from repro.sharding.ctx import configure, reset, shard, head_plan  # noqa: F401
