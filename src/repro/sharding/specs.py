"""Parameter / activation PartitionSpecs (the parallel plan).

Baseline plan (see DESIGN.md §6):
  * TP  ("model"): attention heads (padded per head_plan), FFN hidden,
    MoE experts, mamba d_inner, vocab rows;
  * FSDP ("data"): a second weight axis, all-gathered per layer under the
    scan (ZeRO-3-style; optimizer states inherit it = ZeRO-1 for free);
  * DP  ("pod","data"): the batch.

Rules are (regex over the param path, axis-from-end for "model"); axes only
shard when divisible — non-divisible cases fall back to replication, which
keeps every assigned architecture lowerable on the same mesh.
"""
from __future__ import annotations

import re

import numpy as np
from jax.sharding import PartitionSpec as P

# (path regex, axis_from_end that takes the TP axis)
_TP_RULES: tuple[tuple[str, int], ...] = (
    (r"(^|/)embed$", -2),
    (r"(^|/)(enc_pos|dec_pos)$", -2),
    (r"moe/(w1|w3)$", -3),          # [L,E,d,ff]: experts
    (r"moe/w2$", -3),
    (r"(^|/)gate$", 99),            # replicate router
    (r"x?attn/wq$", -2),
    (r"x?attn/bq$", -2),
    (r"x?attn/(wk|wv)$", -2),
    (r"x?attn/(bk|bv)$", -2),
    (r"x?attn/wo$", -3),
    (r"(^|/)mlp/(w1|w3)$", -1),
    (r"(^|/)mlp/w2$", -2),
    (r"mamba/in_proj$", -1),
    (r"mamba/(conv_w|conv_b|dt_proj|dt_bias|D)$", -1),
    (r"mamba/(x_proj|A_log|out_proj)$", -2),
    (r"mlstm/wgate$", -1),
    (r"mlstm/(wq|wk|wv)$", -2),
    (r"mlstm/wo$", -3),
    (r"(ln\d?|final_norm|enc_final_norm|bf|wi|wf)$", 99),
)

_FSDP_MIN_SIZE = 1 << 20            # only shard weights >= 1M elements


def grid_batch_spec() -> P:
    """Spec for one row array of the scheduler's combined grid launch.

    Every row tensor of the greedy fan-out (dur, work, lp, budgets, masks,
    est, lst, orders — see ``core.greedy_jax.greedy_fanout_grid_jax``)
    stacks per-(instance, bucket) rows on its leading axis; under
    ``ctx.grid_mesh`` that axis shards over "data" and all trailing axes
    (profiles / variants / tasks / time) stay replicated within a shard.
    One spec serves all eight operands because PartitionSpecs only need to
    name the sharded prefix.
    """
    return P("data")


def _tp_axis(path: str) -> int | None:
    for pat, ax in _TP_RULES:
        if re.search(pat, path):
            return None if ax == 99 else ax
    return None


def param_spec(path: str, shape: tuple[int, ...], tp: int, dsize: int,
               fsdp: bool = True) -> P:
    spec: list = [None] * len(shape)
    ax = _tp_axis(path)
    if ax is not None and len(shape) >= abs(ax):
        i = len(shape) + ax
        if shape[i] % tp == 0 and shape[i] >= tp:
            spec[i] = "model"
    if fsdp and int(np.prod(shape)) >= _FSDP_MIN_SIZE:
        # largest remaining axis divisible by the data size
        cands = [(shape[i], i) for i in range(len(shape))
                 if spec[i] is None and shape[i] % dsize == 0
                 and shape[i] >= dsize]
        if cands:
            _, i = max(cands)
            spec[i] = "data"
    return P(*spec)


def tree_param_specs(params_shape, tp: int, dsize: int, fsdp: bool = True):
    """Map a pytree of ShapeDtypeStructs/arrays -> pytree of PartitionSpecs."""
    def walk(tree, path):
        if isinstance(tree, dict):
            return {k: walk(v, f"{path}/{k}" if path else k)
                    for k, v in tree.items()}
        return param_spec(path, tuple(tree.shape), tp, dsize, fsdp)
    return walk(params_shape, "")


def batch_specs(batch_axes: tuple[str, ...], cfg, shape_cfg):
    """PartitionSpecs for the input batch of a train/prefill step."""
    ba = batch_axes
    if cfg.family == "vlm":
        return {"embeds": P(ba, None, None), "positions": P(None, ba, None),
                "labels": P(ba, None)}
    if cfg.family == "audio":
        return {"enc_embeds": P(ba, None, None), "dec_tokens": P(ba, None),
                "labels": P(ba, None)}
    return {"tokens": P(ba, None), "labels": P(ba, None)}


def cache_specs(batch_axes: tuple[str, ...], cfg, batch: int,
                kv_shardable: bool, data_size: int):
    """Specs for the serve_step cache pytree.

    B >= data_size: shard batch; else (long-context B=1) shard the cache
    *sequence* axis over "data" (flash-decoding style partial softmax).
    """
    ba: tuple | None = batch_axes
    seq_ax = None
    if batch < data_size:
        ba = None
        seq_ax = "data"
    h_ax = "model" if kv_shardable else None

    def kv(ndim_prefix=1):
        # [L, B, S, H, hd]
        return P(None, ba, seq_ax, h_ax, None)

    specs = {"len": P()}
    if cfg.family in ("dense", "vlm", "moe", "audio"):
        specs["k"] = kv()
        specs["v"] = kv()
        if cfg.family == "audio":
            specs["xk"] = kv()
            specs["xv"] = kv()
    elif cfg.family == "hybrid":
        specs["k"] = kv()
        specs["v"] = kv()
        specs["conv"] = P(None, ba, None, "model")
        specs["ssm"] = P(None, ba, "model", None)
    elif cfg.family == "ssm":
        specs["C"] = P(None, ba, None, None, None)
        specs["n"] = P(None, ba, None, None)
        specs["c_s"] = P(None, ba, None, None)
        specs["h_s"] = P(None, ba, None, None)
    return specs
