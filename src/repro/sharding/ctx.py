"""Sharding context: logical-axis constraints that no-op without a mesh.

Model code annotates activations with *logical* axes ("batch", "tp", ...);
``configure(mesh)`` binds them to mesh axes for the dry-run / launcher,
while unit tests and single-device runs leave the context unset so every
``shard()`` is a no-op. This keeps model code mesh-agnostic.
"""
from __future__ import annotations

import math

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX: dict | None = None


def configure(mesh) -> None:
    """Bind logical axes to this mesh ('pod', 'data', 'model')."""
    global _CTX
    batch = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    _CTX = {
        "mesh": mesh,
        "rules": {
            "batch": batch,
            "data": "data",
            "tp": "model",
            "kv_tp": None,       # kv heads replicated over TP by default
            "expert": "model",
            "cap": "data",       # MoE capacity axis
            "seq_kv": "data",    # long-context: KV sequence over data
        },
    }


def reset() -> None:
    global _CTX
    _CTX = None


def grid_mesh(devices: int | None = None):
    """1-D scheduler mesh over the "data" axis for the portfolio grid.

    The scheduler's combined (instances x profiles x variants) launch
    shards its leading padded-row axis over "data"
    (:func:`repro.sharding.specs.grid_batch_spec`); this builds the mesh
    it runs under. ``devices=None`` takes every visible device; otherwise
    the first ``devices`` of :func:`jax.devices` (CPU CI forces 8 virtual
    host devices via ``--xla_force_host_platform_device_count=8``).
    """
    avail = jax.devices()
    n = len(avail) if devices is None else devices
    if not 1 <= n <= len(avail):
        raise ValueError(
            f"devices={devices} out of range: {len(avail)} visible")
    return jax.sharding.Mesh(np.asarray(avail[:n]), ("data",))


def axis_size(logical: str) -> int:
    if _CTX is None:
        return 1
    rule = _CTX["rules"].get(logical)
    if rule is None:
        return 1
    mesh = _CTX["mesh"]
    if isinstance(rule, tuple):
        return math.prod(mesh.shape[a] for a in rule)
    return mesh.shape[rule]


def shard(x, *axes):
    """with_sharding_constraint on logical axes; no-op without a mesh."""
    if _CTX is None:
        return x
    rules = _CTX["rules"]
    spec = []
    for a in axes:
        if a is None:
            spec.append(None)
        else:
            spec.append(rules.get(a))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX["mesh"], P(*spec)))


def tp_size() -> int:
    return axis_size("tp")


def head_plan(num_heads: int, kv_heads: int, tp: int = 16):
    """Baseline TP plan for attention heads.

    Returns (Hq_pad, Hkv_pad, shard_heads). Pads q heads to a multiple of
    ``tp`` and kv heads to a divisor of the padded q count, so the grouped
    (repeat-kv) einsum shards cleanly on the head axis. Tiny models
    (Hq < tp/2) replicate heads instead (their FFN still shards).
    """
    if num_heads < tp // 2:
        return num_heads, kv_heads, False
    hq = -(-num_heads // tp) * tp
    hkv = kv_heads
    while hq % hkv != 0:
        hkv += 1
    return hq, hkv, True
