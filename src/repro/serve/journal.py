"""Write-ahead ticket journal: crash-recoverable admission for the service.

:class:`~repro.serve.service.PlanService` loses every in-flight ticket on
a process death — the admission queue is memory-only. This module gives
the service a durable twin of the queue built on the checkpoint
machinery's torn-write-proof format (:func:`repro.checkpoint.ckpt
.save_checkpoint`: npz + fsynced json manifest behind an atomic rename):

* :meth:`TicketJournal.record` persists one admitted ticket's *resolved*
  planning state (instances, profile grid, variant names, solver knobs,
  budget) BEFORE the ticket enters the in-memory queue — the write-ahead
  contract: any ticket a worker can possibly pick up already has a
  journal entry.
* :meth:`TicketJournal.resolve` deletes the entry once the ticket's
  future is resolved (delivered, rejected, failed, or cancelled) — so
  the journal holds exactly the admitted-but-unfinished set.
* :meth:`TicketJournal.pending` replays that set after a restart; the
  service re-admits each entry under its original sequence number.

Semantics are **at-least-once**: a crash between delivery and
:meth:`resolve` replays an already-answered ticket (the old caller is
gone anyway — the replayed plan simply re-resolves and clears the
entry); a crash between :meth:`record` and enqueue replays a ticket
whose caller never saw an admission — same thing. What cannot happen is
a *lost* ticket: once admitted, the entry survives until some process
resolves it. Entries are self-contained (the full instance arrays
travel, not references), so a restarted service needs no caller state.
"""
from __future__ import annotations

import json
import os
import shutil

import numpy as np

from repro import obs
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.workflows.generators import Workflow

# array-valued Instance fields; everything else rides the json meta leaf
_INSTANCE_ARRAYS = ("dur", "proc", "task_work", "pred_ptr", "pred_idx",
                    "succ_ptr", "succ_idx", "chain_proc_ids", "topo",
                    "level")
# array-valued Workflow fields (mapping-mode tickets journal raw DAGs)
_WORKFLOW_ARRAYS = ("node_w", "edges", "edge_w")


def _encode_json(obj) -> np.ndarray:
    """A json document as a uint8 leaf (the checkpoint format stores
    arrays only)."""
    return np.frombuffer(json.dumps(obj).encode(), dtype=np.uint8).copy()


def _decode_json(arr):
    return json.loads(bytes(np.asarray(arr, dtype=np.uint8)).decode())


def encode_ticket(instances, grid, names, solver: str, robust: bool,
                  options: dict | None, budget: float | None,
                  mapping: str = "fixed",
                  mapping_options: dict | None = None) -> dict:
    """The journal entry of one resolved ticket: a nested dict of arrays
    (what :func:`repro.checkpoint.ckpt.save_checkpoint` accepts).

    Mapping-mode tickets (``mapping != "fixed"``) carry raw
    :class:`Workflow` DAGs in the instances slot; both shapes journal
    self-contained."""
    items = []
    for inst in instances:
        if isinstance(inst, Workflow):
            items.append({"kind": "workflow", "name": inst.name})
        else:
            items.append(
                {"name": inst.name, "num_tasks": int(inst.num_tasks),
                 "num_workflow_tasks": int(inst.num_workflow_tasks),
                 "proc_chains": [list(c) for c in inst.proc_chains],
                 "idle_total": int(inst.idle_total)})
    meta = {
        "solver": solver,
        "robust": bool(robust),
        "options": options,
        "names": list(names),
        "budget": budget,
        "mapping": mapping,
        "mapping_options": mapping_options,
        "instances": items,
        "scenarios": [[p.scenario for p in ps] for ps in grid],
    }
    state: dict = {"meta": {"json": _encode_json(meta)}}
    for i, inst in enumerate(instances):
        fields = _WORKFLOW_ARRAYS if isinstance(inst, Workflow) \
            else _INSTANCE_ARRAYS
        state[f"i{i}"] = {f: np.asarray(getattr(inst, f)) for f in fields}
        for p, prof in enumerate(grid[i]):
            state[f"i{i}p{p}"] = {"bounds": np.asarray(prof.bounds),
                                  "budget": np.asarray(prof.budget)}
    return state


class _DecodedTicket(tuple):
    """The 7-tuple decode contract plus the mapping axis as attributes
    (older callers keep unpacking seven values unchanged)."""

    mapping: str = "fixed"
    mapping_options: dict | None = None


def decode_ticket(state: dict):
    """Invert :func:`encode_ticket`.

    Returns ``(instances, grid, names, solver, robust, options, budget)``
    with fresh :class:`Instance`/:class:`PowerProfile`/:class:`Workflow`
    objects that compare array-equal to the originals; the tuple also
    carries ``.mapping`` / ``.mapping_options`` attributes (``"fixed"`` /
    ``None`` for pre-mapping journal entries).
    """
    meta = _decode_json(state["meta"]["json"])
    instances = []
    grid = []
    for i, im in enumerate(meta["instances"]):
        arrays = state[f"i{i}"]
        if im.get("kind") == "workflow":
            instances.append(Workflow(
                name=im["name"],
                **{f: np.asarray(arrays[f]) for f in _WORKFLOW_ARRAYS}))
        else:
            instances.append(Instance(
                name=im["name"], num_tasks=im["num_tasks"],
                num_workflow_tasks=im["num_workflow_tasks"],
                proc_chains=tuple(tuple(int(t) for t in c)
                                  for c in im["proc_chains"]),
                idle_total=im["idle_total"],
                **{f: np.asarray(arrays[f]) for f in _INSTANCE_ARRAYS}))
        grid.append([
            PowerProfile(bounds=np.asarray(state[f"i{i}p{p}"]["bounds"]),
                         budget=np.asarray(state[f"i{i}p{p}"]["budget"]),
                         scenario=meta["scenarios"][i][p])
            for p in range(len(meta["scenarios"][i]))])
    out = _DecodedTicket(
        (instances, grid, tuple(meta["names"]), meta["solver"],
         meta["robust"], meta["options"], meta["budget"]))
    out.mapping = meta.get("mapping", "fixed")
    out.mapping_options = meta.get("mapping_options")
    return out


class TicketJournal:
    """One directory of write-ahead ticket entries (see module doc).

    Entries are the checkpoint format's ``ckpt_{seq:08d}`` directories;
    ``seq`` is the service's admission sequence number, so replayed
    tickets keep their identity across restarts and :meth:`resolve` is
    naturally idempotent (removing a missing entry is a no-op).
    """

    def __init__(self, directory: str):
        self.directory = str(directory)
        os.makedirs(self.directory, exist_ok=True)

    def _path(self, seq: int) -> str:
        return os.path.join(self.directory, f"ckpt_{seq:08d}")

    def _seqs(self) -> list[int]:
        return sorted(
            int(d[len("ckpt_"):]) for d in os.listdir(self.directory)
            if d.startswith("ckpt_") and not d.endswith(".tmp"))

    def next_seq(self) -> int:
        """The next unused sequence number (past every live entry)."""
        seqs = self._seqs()
        return (seqs[-1] + 1) if seqs else 0

    def record(self, seq: int, state: dict) -> str:
        """Persist one entry atomically (write-ahead: call before the
        ticket becomes claimable)."""
        from repro.checkpoint.ckpt import save_checkpoint

        return save_checkpoint(state, seq, self.directory)

    def resolve(self, seq: int) -> None:
        """Drop entry ``seq`` (idempotent — the at-least-once replay of
        an already-resolved ticket resolves it again harmlessly)."""
        path = self._path(seq)
        if os.path.isdir(path):
            shutil.rmtree(path)

    def pending(self, limit: int | None = None
                ) -> list[tuple[int, dict]]:
        """Every admitted-but-unresolved entry as ``(seq, state)``, in
        admission order — what a restarted service replays. Torn or
        unreadable entries are dropped (the atomic-rename write makes
        them impossible short of manual tampering).

        ``limit`` caps the replay size: only the ``limit`` *oldest* live
        entries are loaded (admission order = fairness order); entries
        past the cap stay on disk untouched, so a later replay — or an
        operator — can still recover them.
        """
        from repro.checkpoint.ckpt import load_checkpoint

        out = []
        seqs = self._seqs()
        if limit is not None:
            seqs = seqs[:max(int(limit), 0)]
        for seq in seqs:
            try:
                state, step = load_checkpoint(self._path(seq))
            except Exception:
                shutil.rmtree(self._path(seq), ignore_errors=True)
                continue
            out.append((int(step), state))
        return out

    def __len__(self) -> int:
        return len(self._seqs())

    def compact(self) -> dict[int, int]:
        """Renumber live entries to dense sequences ``0..k-1``.

        Long-running services only ever *grow* sequence numbers — resolve
        deletes entries but never reclaims the numbering, so a fleet
        restarting from a sparse journal keeps counting from the
        high-water mark forever. Compaction rewrites each surviving entry
        under its rank (oldest first) and removes the original, returning
        the ``{old_seq: new_seq}`` mapping so a replaying service can
        re-key its in-memory tickets.

        Crash safety: the new entry is written (atomic rename) BEFORE the
        old one is removed, and ranks never collide with still-unprocessed
        originals (``new <= old`` throughout), so a mid-compact crash
        leaves at worst a duplicate entry — an at-least-once replay, the
        journal's existing contract — never a lost ticket.
        """
        from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint

        mapping: dict[int, int] = {}
        moved = 0
        with obs.span("journal_compact", directory=self.directory):
            for new, old in enumerate(self._seqs()):
                mapping[old] = new
                if new == old:
                    continue
                try:
                    state, _ = load_checkpoint(self._path(old))
                except Exception:
                    shutil.rmtree(self._path(old), ignore_errors=True)
                    del mapping[old]
                    continue
                save_checkpoint(state, new, self.directory)
                shutil.rmtree(self._path(old), ignore_errors=True)
                moved += 1
        if moved:
            obs.registry().counter(
                "journal_compacted_entries_total",
                "journal entries renumbered by compaction").inc(moved)
        return mapping
