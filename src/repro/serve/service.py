"""Resilient always-on planning service over a shared :class:`Planner`.

The ROADMAP's serving-tier robustness slice: the `Planner`/`PlanningSession`
stack is one-process, one-caller, and a single solver exception, device
``MemoryError``, or ILP overrun takes the whole call down. The paper's own
structure provides a graceful-degradation ladder — the certified exact
oracles, the 17-variant heuristic portfolio, and the §5.1 ``asap``
baseline all serve the same ``(instances x profiles)`` grid shape — so a
serving tier can *always* emit some feasible schedule before the deadline.
:class:`PlanService` wires that ladder behind a bounded admission queue:

* **Admission + coalescing** — :meth:`PlanService.submit` validates the
  request, rejects with a structured :class:`Overloaded` error when the
  queue is full, and enqueues a :class:`Ticket`. A single worker drains
  the queue and coalesces compatible tickets (same solver, engine,
  variant tuple, profile count, robust mode) into shape-bucket batches:
  one combined-grid ``Planner.plan`` launch serves many callers, and the
  per-cell results are bit-identical to solo plans (the combined-grid
  property the Planner API ships with), so coalescing is invisible to
  callers — fault-free service results equal direct ``Planner.plan``.

* **Deadline budgets + fallback chain** — every ticket carries a
  wall-clock budget; a watchdog bounds each chain-stage solve by the
  minimum remaining budget in the batch and, on timeout or failure,
  walks ``exact -> ilp (time-limited) -> heuristic -> asap``. ILP stages
  get a default ``time_limit`` clamped to the remaining budget, and a
  time-limit exit with an incumbent is a *degraded success*: the
  schedule ships with its HiGHS ``lower_bound``/``mip_gap`` certificate.
  The terminal ``asap`` stage runs untimed (it is O(N + E)), so even a
  blown budget still yields a feasible schedule. Results record
  ``degraded``, ``fallback_stage``, and the full ``attempts`` log on the
  :class:`~repro.api.result.PlanResult`.

* **Retry + blocked-LP recovery** — transient failures
  (:class:`~repro.runtime.fault.SimulatedFailure`) retry with
  exponential backoff; a device ``MemoryError`` (the dense
  ``longest_path_matrix`` envelope, or an injected OOM) retries once on
  a planner clone with a reduced ``lp_budget_bytes`` so the blocked
  longest-path form serves the request instead.

* **Validation + quarantine** — malformed instances/profiles are
  rejected at admission (:func:`repro.api.request.validate_resolved`)
  or, if corruption appears later, quarantined at batch assembly with a
  structured :class:`InvalidRequest`; a batch-mate's poison never
  reaches the shared ``PreparedGraph`` cache or fails the batch. If a
  combined solve still dies on an unexpected error, the batch is
  bisected: every ticket re-runs its chain in isolation, so exactly the
  poisoned ticket fails.

* **Fault seam + telemetry** — a
  :class:`~repro.runtime.fault.ServiceFaultInjector` can be plugged in
  to fire deterministic solver crashes, hangs, device OOMs, and profile
  corruption inside the real code paths (the chaos suite drives every
  ladder rung end-to-end); :meth:`PlanService.stats` reports queue
  depth, coalesce ratio, p50/p99 plan latency, and degradation counts.
"""
from __future__ import annotations

import collections
import concurrent.futures as _fut
import threading
import time

import numpy as np

from repro.api.planner import Planner
from repro.api.request import PlanRequest, validate_resolved
from repro.api.result import PlanResult
from repro.kernels.backend import resolve_engine
from repro.runtime.fault import SimulatedFailure, corrupt_profile

# The graceful-degradation ladder, per requested solver: every stage
# serves the same (instances x profiles) grid, each rung cheaper and more
# robust than the one above it; "asap" (O(N + E), no solver machinery)
# terminates every chain.
FALLBACK_CHAINS: dict[str, tuple[str, ...]] = {
    "exact": ("exact", "ilp", "heuristic", "asap"),
    "ilp": ("ilp", "heuristic", "asap"),
    "dp": ("dp", "heuristic", "asap"),
    "heuristic": ("heuristic", "asap"),
    "asap": ("asap",),
}


class ServiceError(RuntimeError):
    """Structured service rejection: ``code`` + machine-readable details.

    ``to_dict()`` is the wire shape (what an RPC layer would serialize);
    the message stays human-readable.
    """

    code = "error"

    def __init__(self, message: str, **details):
        super().__init__(message)
        self.details = details

    def to_dict(self) -> dict:
        return {"code": self.code, "message": str(self), **self.details}


class Overloaded(ServiceError):
    """Admission queue full — retry later / shed load upstream."""

    code = "overloaded"


class InvalidRequest(ServiceError):
    """Malformed instance/profile — rejected before touching shared
    state; never retried."""

    code = "invalid_request"


class PlanFailure(ServiceError):
    """Every chain stage failed (the request is poisoned or the service
    is badly degraded); ``details["attempts"]`` records the walk."""

    code = "plan_failure"


class ServiceClosed(ServiceError):
    """The service shut down before this ticket was served."""

    code = "closed"


class Ticket:
    """One admitted request: a future plus its admission metadata."""

    def __init__(self, request: PlanRequest, instances, grid, names,
                 engine: str, budget: float | None):
        self.request = request
        self.instances = instances            # resolved (crop applied)
        self.grid = grid
        self.names = names
        self.engine = engine
        self.solver = request.solver if request.solver else "heuristic"
        self.robust = bool(request.robust)
        self.options = request.solver_options
        self.admitted = time.monotonic()
        self.deadline = None if budget is None else self.admitted + budget
        self._fut: _fut.Future = _fut.Future()

    @property
    def cells(self) -> int:
        return len(self.instances) * len(self.grid[0])

    def remaining(self) -> float | None:
        """Seconds left in this ticket's deadline budget (None = unbounded)."""
        return None if self.deadline is None \
            else self.deadline - time.monotonic()

    def done(self) -> bool:
        return self._fut.done()

    def result(self, timeout: float | None = None) -> PlanResult:
        """Block for the plan; raises the structured :class:`ServiceError`
        subclass on rejection/failure."""
        return self._fut.result(timeout)

    def _coalesce_key(self):
        try:
            opts = tuple(sorted((self.options or {}).items()))
        except TypeError:                      # unhashable option values:
            opts = object()                    # unique key, no coalescing
        return (self.solver, self.engine, self.names, len(self.grid[0]),
                self.robust, opts)


class PlanService:
    """A long-lived, fault-tolerant planning frontend over one
    :class:`~repro.api.planner.Planner`.

    Args:
      planner: the shared facade; the service clones it per resolved
        engine (so coalescing never flips an ``auto`` resolution) and for
        the reduced-budget blocked-LP retry. Its platform/k/ls/validate
        configuration applies to every clone.
      max_queue: admission bound — ``submit`` raises :class:`Overloaded`
        when this many tickets are already waiting.
      max_batch: coalescing bound — at most this many tickets share one
        combined-grid launch.
      default_budget: seconds of wall-clock deadline budget a ticket gets
        when ``submit`` does not specify one (None = unbounded).
      retries / backoff: transient-failure policy per chain stage
        (exponential: ``backoff * 2**attempt`` seconds between tries).
      ilp_time_limit: default HiGHS time limit (seconds) for ``ilp`` /
        ``exact`` chain stages reached through the service — clamped to
        the remaining deadline budget; an explicit
        ``solver_options["time_limit"]`` on the request wins.
      lp_retry_budget_bytes: the reduced ``lp_budget_bytes`` used for the
        one blocked-LP retry after a device ``MemoryError``.
      fallback_variants: the (cheap) heuristic column set used when an
        exact chain degrades INTO the heuristic stage; heuristic-first
        requests keep their own variants.
      injector: optional :class:`~repro.runtime.fault
        .ServiceFaultInjector` — the chaos seam.
    """

    def __init__(self, planner: Planner, *, max_queue: int = 64,
                 max_batch: int = 8, default_budget: float | None = None,
                 retries: int = 2, backoff: float = 0.02,
                 ilp_time_limit: float = 30.0,
                 lp_retry_budget_bytes: int = 8 * 2**20,
                 fallback_variants: tuple[str, ...] = ("asap", "pressWR-LS"),
                 injector=None):
        self._base = planner
        self.max_queue = int(max_queue)
        self.max_batch = max(int(max_batch), 1)
        self.default_budget = default_budget
        self.retries = max(int(retries), 0)
        self.backoff = float(backoff)
        self.ilp_time_limit = float(ilp_time_limit)
        self.lp_retry_budget_bytes = int(lp_retry_budget_bytes)
        self.fallback_variants = tuple(fallback_variants)
        self.injector = injector
        self._planners: dict[tuple[str, bool], Planner] = {}
        self._cond = threading.Condition()
        self._queue: collections.deque[Ticket] = collections.deque()
        self._paused = False
        self._closed = False
        self._counts = collections.Counter()
        self._stage_counts = collections.Counter()
        self._latencies: collections.deque[float] = \
            collections.deque(maxlen=1024)
        self._stats_lock = threading.Lock()
        # abandoned (watchdog-timed-out) solves keep their worker until
        # they return; a few spare workers keep the chain walking
        self._solve_pool = _fut.ThreadPoolExecutor(
            max_workers=8, thread_name_prefix="plan-service-solve")
        self._worker = threading.Thread(target=self._run, daemon=True,
                                        name="plan-service")
        self._worker.start()

    # --- admission --------------------------------------------------------

    def submit(self, request: PlanRequest, budget: float | None = None
               ) -> Ticket:
        """Admit one request; returns a :class:`Ticket` immediately.

        Raises :class:`InvalidRequest` (malformed request — structured,
        synchronous, nothing shared was touched), :class:`Overloaded`
        (queue full), or :class:`ServiceClosed`.
        """
        if self._closed:
            raise ServiceClosed("plan service is closed")
        try:
            instances, grid, names = request.resolve()
            validate_resolved(instances, grid)
        except (ValueError, TypeError) as e:
            self._bump(rejected_invalid=1)
            raise InvalidRequest(f"rejected at admission: {e}",
                                 reason=str(e)) from e
        solver = request.solver if request.solver else "heuristic"
        engine = resolve_engine(
            self._base.engine, fanout=len(instances) * len(grid[0])) \
            if solver == "heuristic" else "numpy"
        if budget is None:
            budget = self.default_budget
        ticket = Ticket(request, instances, grid, names, engine, budget)
        with self._cond:
            if self._closed:
                raise ServiceClosed("plan service is closed")
            if len(self._queue) >= self.max_queue:
                self._bump(rejected_overloaded=1)
                raise Overloaded(
                    f"admission queue full ({len(self._queue)} waiting)",
                    queue_depth=len(self._queue), max_queue=self.max_queue)
            self._queue.append(ticket)
            self._bump(submitted=1)
            with self._stats_lock:
                self._counts["max_queue_depth"] = max(
                    self._counts["max_queue_depth"], len(self._queue))
            self._cond.notify_all()
        return ticket

    def plan(self, request: PlanRequest, budget: float | None = None
             ) -> PlanResult:
        """Synchronous convenience: ``submit(...).result()``."""
        return self.submit(request, budget=budget).result()

    # --- worker loop ------------------------------------------------------

    def _run(self) -> None:
        while True:
            with self._cond:
                while not self._closed and (self._paused or not self._queue):
                    self._cond.wait(timeout=0.1)
                if self._closed:
                    return
                drained = list(self._queue)
                self._queue.clear()
            groups: dict = {}
            order = []
            for t in drained:
                key = t._coalesce_key()
                if key not in groups:
                    groups[key] = []
                    order.append(key)
                groups[key].append(t)
            for key in order:
                tickets = groups[key]
                for i in range(0, len(tickets), self.max_batch):
                    self._serve_batch(tickets[i:i + self.max_batch])

    # --- batch assembly: corruption quarantine ----------------------------

    def _serve_batch(self, tickets: list[Ticket]) -> None:
        healthy = []
        for t in tickets:
            grid = t.grid
            if self.injector is not None and self.injector.corrupts_request():
                # the chaos seam poisons this ticket's profiles in flight
                grid = [[corrupt_profile(p) for p in ps] for ps in grid]
                t.grid = grid
            try:
                validate_resolved(t.instances, grid)
            except ValueError as e:
                self._bump(quarantined=1)
                self._reject(t, InvalidRequest(
                    f"quarantined at batch assembly: {e}", reason=str(e)))
                continue
            healthy.append(t)
        if healthy:
            self._bump(batches=1, coalesced_requests=len(healthy))
            self._run_chain(healthy)

    # --- the degradation ladder -------------------------------------------

    def _chain_for(self, solver: str) -> tuple[str, ...]:
        return FALLBACK_CHAINS.get(solver, (solver, "asap"))

    def _remaining(self, tickets) -> float | None:
        rs = [r for r in (t.remaining() for t in tickets) if r is not None]
        return min(rs) if rs else None

    def _run_chain(self, tickets: list[Ticket],
                   attempts: list[str] | None = None) -> None:
        attempts = attempts if attempts is not None else []
        chain = self._chain_for(tickets[0].solver)
        for si, stage in enumerate(chain):
            terminal = si == len(chain) - 1
            remaining = self._remaining(tickets)
            if remaining is not None and remaining <= 0 and not terminal:
                # budget exhausted: jump straight to the terminal rung,
                # which still returns a feasible schedule
                attempts.append(f"{stage}:skipped")
                continue
            blocked = False
            attempt = 0
            while attempt <= self.retries:
                remaining = self._remaining(tickets)
                timeout = None if (remaining is None or terminal) \
                    else max(remaining, 0.05)
                fut = self._solve_pool.submit(
                    self._solve_once, stage, tickets, remaining, blocked)
                try:
                    res = fut.result(timeout=timeout)
                except _fut.TimeoutError:
                    attempts.append(f"{stage}:timeout")
                    self._bump(timeouts=1)
                    break                              # next stage
                except SimulatedFailure:
                    attempts.append(f"{stage}:crash")
                    self._bump(retries=1)
                    attempt += 1
                    if attempt > self.retries:
                        break
                    time.sleep(self.backoff * (2 ** (attempt - 1)))
                    continue
                except MemoryError:
                    attempts.append(f"{stage}:oom")
                    if blocked:
                        break                          # blocked retry used
                    blocked = True
                    self._bump(oom_retries=1)
                    attempts.append(f"{stage}:oom-retry-blocked-lp")
                    continue
                except Exception as e:
                    attempts.append(f"{stage}:error")
                    if len(tickets) > 1:
                        # quarantine bisect: a poisoned batch-mate must
                        # not take the others down — every ticket re-runs
                        # its chain alone, so exactly the poison fails
                        self._bump(splits=1)
                        for t in tickets:
                            self._run_chain(
                                [t], attempts=["quarantine:split"])
                        return
                    if terminal:
                        self._fail(tickets, attempts, e)
                        return
                    break                              # next stage
                else:
                    attempts.append(f"{stage}:ok")
                    self._deliver(tickets, res, stage, attempts)
                    return
        self._fail(tickets, attempts, None)

    def _planner_for(self, engine: str, blocked: bool) -> Planner:
        key = (engine, blocked)
        p = self._planners.get(key)
        if p is None:
            p = self._base.clone(
                engine=engine,
                lp_budget_bytes=self.lp_retry_budget_bytes if blocked
                else None)
            self._planners[key] = p
        return p

    def _solve_once(self, stage: str, tickets: list[Ticket],
                    remaining: float | None, blocked: bool) -> PlanResult:
        """One chain-stage solve of the whole batch (runs on the solve
        pool so the watchdog can abandon it)."""
        if self.injector is not None:
            self.injector.on_solve(stage)
        requested = tickets[0].solver
        if stage == requested:
            variants = tickets[0].names if requested == "heuristic" else None
            options = dict(tickets[0].options or {})
        else:
            variants = self.fallback_variants if stage == "heuristic" \
                else None
            options = {}
        if stage in ("ilp", "exact"):
            limit = options.get("time_limit", self.ilp_time_limit)
            if remaining is not None:
                limit = min(float(limit), max(remaining, 0.1))
            options["time_limit"] = limit
        if stage == "heuristic":
            engine = tickets[0].engine if requested == "heuristic" else \
                resolve_engine(self._base.engine,
                               fanout=sum(t.cells for t in tickets))
        else:
            engine = "numpy"
        planner = self._planner_for(engine, blocked and stage == "heuristic")
        req = PlanRequest(
            instances=[i for t in tickets for i in t.instances],
            profiles=[ps for t in tickets for ps in t.grid],
            variants=variants, robust=tickets[0].robust, solver=stage,
            solver_options=options or None)
        return planner.plan(req)

    # --- delivery ---------------------------------------------------------

    def _deliver(self, tickets: list[Ticket], res: PlanResult, stage: str,
                 attempts: list[str]) -> None:
        requested = tickets[0].solver
        now = time.monotonic()
        i0 = 0
        for t in tickets:
            i1 = i0 + len(t.instances)
            lower = None if res.lower_bound is None else res.lower_bound[i0:i1]
            gaps = None if res.mip_gap is None else res.mip_gap[i0:i1]
            open_gap = gaps is not None and bool(
                np.any(np.nan_to_num(gaps, nan=0.0) > 1e-9))
            sub = PlanResult(
                variants=res.variants, results=res.results[i0:i1],
                costs=res.costs[i0:i1], engine=res.engine,
                seconds=res.seconds, robust_requested=res.robust_requested,
                solver=res.solver, lower_bound=lower, mip_gap=gaps,
                degraded=(stage != requested) or open_gap,
                fallback_stage=stage, attempts=tuple(attempts))
            self._bump(completed=1, degraded=1 if sub.degraded else 0)
            with self._stats_lock:
                self._stage_counts[stage] += 1
                self._latencies.append(now - t.admitted)
            if not t._fut.set_running_or_notify_cancel():
                i0 = i1
                continue
            t._fut.set_result(sub)
            i0 = i1

    def _reject(self, ticket: Ticket, err: ServiceError) -> None:
        if ticket._fut.set_running_or_notify_cancel():
            ticket._fut.set_exception(err)

    def _fail(self, tickets: list[Ticket], attempts: list[str],
              last: Exception | None) -> None:
        self._bump(failed=len(tickets))
        for t in tickets:
            self._reject(t, PlanFailure(
                "every fallback stage failed"
                + (f" (last: {last})" if last is not None else ""),
                attempts=tuple(attempts),
                last_error=repr(last) if last is not None else None))

    # --- telemetry / lifecycle --------------------------------------------

    def _bump(self, **deltas) -> None:
        with self._stats_lock:
            for k, v in deltas.items():
                self._counts[k] += v

    def stats(self) -> dict:
        """Service telemetry snapshot: admission/degradation counters,
        coalescing ratio, and plan-latency percentiles."""
        with self._stats_lock:
            c = dict(self._counts)
            lat = np.asarray(self._latencies, dtype=np.float64)
            stages = dict(self._stage_counts)
            depth = len(self._queue)
        batches = c.get("batches", 0)
        served = c.get("coalesced_requests", 0)
        return {
            **{k: c.get(k, 0) for k in (
                "submitted", "completed", "failed", "degraded",
                "rejected_overloaded", "rejected_invalid", "quarantined",
                "splits", "retries", "oom_retries", "timeouts",
                "batches", "coalesced_requests", "max_queue_depth")},
            "queue_depth": depth,
            "coalesce_ratio": served / batches if batches else None,
            "stages": stages,
            "latency": {
                "n": int(lat.size),
                "p50_ms": float(np.percentile(lat, 50) * 1e3)
                if lat.size else None,
                "p99_ms": float(np.percentile(lat, 99) * 1e3)
                if lat.size else None,
            },
        }

    def pause(self) -> None:
        """Hold the worker (drills/tests: lets callers fill the queue
        deterministically)."""
        with self._cond:
            self._paused = True

    def resume(self) -> None:
        with self._cond:
            self._paused = False
            self._cond.notify_all()

    def close(self) -> None:
        """Stop the worker; pending tickets fail with
        :class:`ServiceClosed` (in-flight batches finish first)."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = list(self._queue)
            self._queue.clear()
            self._cond.notify_all()
        self._worker.join(timeout=30.0)
        for t in pending:
            self._reject(t, ServiceClosed("plan service closed before "
                                          "this ticket was served"))
        self._solve_pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
