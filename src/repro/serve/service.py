"""Supervised multi-worker planning service over a shared :class:`Planner`.

The ROADMAP's serving-tier robustness slice. The paper's own structure
provides a graceful-degradation ladder — the certified exact oracles,
the 17-variant heuristic portfolio, and the §5.1 ``asap`` baseline all
serve the same ``(instances x profiles)`` grid shape — so a serving tier
can *always* emit some feasible schedule before the deadline.
:class:`PlanService` wires that ladder behind a supervised worker pool:

* **Priority admission + coalescing** — :meth:`PlanService.submit`
  validates the request, rejects with a structured :class:`Overloaded`
  error when the queue is full, and enqueues a :class:`Ticket` on a
  deadline-earliest-first priority heap. Budget-less tickets are aged:
  each gets a *virtual* deadline ``admitted + aging`` seconds out, so a
  ticket without a budget outranks every ticket submitted more than
  ``aging`` seconds after it — urgent work jumps the queue, but nothing
  starves. Drain workers claim the earliest-deadline ticket plus
  compatible queue-mates (same solver, engine, variant tuple, profile
  count, robust mode) into one combined-grid ``Planner.plan`` launch;
  per-cell results are bit-identical to solo plans, so coalescing — and
  the worker count — is invisible to callers: fault-free service results
  equal direct ``Planner.plan``.

* **Supervised workers** — ``workers=N`` drain workers serve distinct
  coalesce groups concurrently, each on its own per-engine
  :meth:`Planner.clone` (clone caches are private, so workers never race
  on a ``PreparedGraph``). A supervisor thread watches per-worker
  heartbeats: a dead worker thread (an escaped exception) or a wedged
  one (claimed tickets, no heartbeat for ``heartbeat_timeout``) is
  deposed — its generation is bumped so the stale thread self-exits at
  the next checkpoint, its in-flight solve is cancelled through the
  stage token, its unresolved tickets are requeued, and a fresh thread
  takes the slot.

* **Cooperative cancellation** — every chain-stage solve carries a
  :class:`repro.core.cancel.CancelToken` threaded through
  ``Planner.plan`` into the solver layers, which poll it at their chunk
  boundaries (heuristic chain rungs, ILP matrix assembly, greedy bucket
  launches, local-search commit rounds). A watchdog timeout, a deposed
  worker, or a caller's :meth:`Ticket.cancel` therefore *stops* the
  solve within one rung budget and releases its pool worker — abandoned
  threads no longer run to completion in the background. Tokens also
  self-expire at the batch's deadline, so a wedged-but-polling solve
  times itself out even if the watchdog thread is gone.

* **Deadline budgets + fallback chain** — every ticket carries a
  wall-clock budget; the watchdog bounds each chain-stage solve by the
  minimum remaining budget in the batch and, on timeout or failure,
  walks ``exact -> ilp (time-limited) -> heuristic -> asap``. ILP stages
  get a default ``time_limit`` clamped to the remaining budget, and a
  time-limit exit with an incumbent is a *degraded success*: the
  schedule ships with its HiGHS ``lower_bound``/``mip_gap`` certificate.
  The terminal ``asap`` stage runs untimed (it is O(N + E)), so even a
  blown budget still yields a feasible schedule. Results record
  ``degraded``, ``fallback_stage``, and the full ``attempts`` log on the
  :class:`~repro.api.result.PlanResult`.

* **Write-ahead ticket journal** — with ``journal_dir=`` set, every
  admitted ticket is persisted (:mod:`repro.serve.journal`) *before* it
  becomes claimable and erased when its future resolves. A service that
  dies mid-burst (a real crash, or the chaos seam's
  :meth:`PlanService.kill`) leaves exactly the admitted-but-unfinished
  set on disk; constructing a new service on the same ``journal_dir``
  replays those tickets into the queue (``service.replayed``) with
  at-least-once semantics — no admitted ticket is ever lost.

* **Retry + blocked-LP recovery** — transient failures
  (:class:`~repro.runtime.fault.SimulatedFailure`) retry with
  exponential backoff; a device ``MemoryError`` retries once on a
  planner clone with a reduced ``lp_budget_bytes`` so the blocked
  longest-path form serves the request instead.

* **Validation + quarantine** — malformed instances/profiles are
  rejected at admission (:func:`repro.api.request.validate_resolved`)
  or, if corruption appears later, quarantined at batch assembly with a
  structured :class:`InvalidRequest`. If a combined solve still dies on
  an unexpected error, the batch is bisected: every ticket re-runs its
  chain in isolation, so exactly the poisoned ticket fails.

* **Fault seam + telemetry** — a
  :class:`~repro.runtime.fault.ServiceFaultInjector` fires deterministic
  solver crashes, hangs, device OOMs, profile corruption, worker deaths,
  wedges, and mid-burst kills inside the real code paths;
  :meth:`PlanService.stats` reports queue depth, worker restarts,
  cancellation counters, coalesce ratio, and p50/p99 plan latency.
"""
from __future__ import annotations

import concurrent.futures as _fut
import heapq
import itertools
import threading
import time

import numpy as np

from repro import obs
from repro.api.planner import Planner
from repro.api.request import PlanRequest, validate_resolved
from repro.api.result import PlanResult
from repro.core.cancel import Cancelled, CancelToken
from repro.kernels.backend import enable_compilation_cache, resolve_engine
from repro.runtime.fault import SimulatedFailure, corrupt_profile
from repro.serve.journal import TicketJournal, decode_ticket, encode_ticket

# The graceful-degradation ladder, per requested solver: every stage
# serves the same (instances x profiles) grid, each rung cheaper and more
# robust than the one above it; "asap" (O(N + E), no solver machinery)
# terminates every chain.
FALLBACK_CHAINS: dict[str, tuple[str, ...]] = {
    "exact": ("exact", "ilp", "heuristic", "asap"),
    "ilp": ("ilp", "heuristic", "asap"),
    "dp": ("dp", "heuristic", "asap"),
    "heuristic": ("heuristic", "asap"),
    "asap": ("asap",),
}

# Every event-style counter the service tracks; stats() reads these out
# of the per-service metrics registry under the same wire keys the
# pre-registry Counter dict used. inflight_solves and max_queue_depth
# are gauges and handled separately.
_STAT_EVENTS = (
    "submitted", "completed", "failed", "degraded", "rejected_overloaded",
    "rejected_invalid", "quarantined", "splits", "retries", "oom_retries",
    "timeouts", "cancelled", "cancelled_solves", "worker_restarts",
    "requeued", "replayed", "replay_corrupt", "replay_deferred",
    "priority_inversions", "cancel_checks", "batches",
    "coalesced_requests", "mapping_search_shrinks",
    "mapping_heft_downgrades")

# code -> class, filled by ServiceError.__init_subclass__ so
# ServiceError.from_dict can rebuild the exact subclass off the wire
_ERROR_TYPES: dict[str, type] = {}


def _wire(value):
    """JSON-safe twin of ``value``: tuples become lists, numpy scalars
    become python scalars, recursively — what ``to_dict`` promises."""
    if isinstance(value, dict):
        return {str(k): _wire(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_wire(v) for v in value]
    if isinstance(value, np.generic):
        return value.item()
    return value


class ServiceError(RuntimeError):
    """Structured service rejection: ``code`` + machine-readable details.

    ``to_dict()`` is the wire shape: plain JSON types only (``json.dumps``
    round-trips it), and :meth:`from_dict` rebuilds the matching
    subclass losslessly — ``from_dict(e.to_dict()).to_dict() ==
    e.to_dict()``. The message stays human-readable.
    """

    code = "error"

    def __init_subclass__(cls, **kw):
        super().__init_subclass__(**kw)
        _ERROR_TYPES[cls.code] = cls

    def __init__(self, message: str, **details):
        super().__init__(message)
        self.details = details

    def to_dict(self) -> dict:
        return {"code": self.code, "message": str(self),
                **_wire(self.details)}

    @classmethod
    def from_dict(cls, d: dict) -> "ServiceError":
        d = dict(d)
        klass = _ERROR_TYPES.get(d.pop("code", "error"), ServiceError)
        return klass(d.pop("message", ""), **d)


_ERROR_TYPES[ServiceError.code] = ServiceError


class Overloaded(ServiceError):
    """Admission queue full — retry later / shed load upstream."""

    code = "overloaded"


class InvalidRequest(ServiceError):
    """Malformed instance/profile — rejected before touching shared
    state; never retried."""

    code = "invalid_request"


class PlanFailure(ServiceError):
    """Every chain stage failed (the request is poisoned or the service
    is badly degraded); ``details["attempts"]`` records the walk."""

    code = "plan_failure"


class ServiceClosed(ServiceError):
    """The service shut down before this ticket was served."""

    code = "closed"


class TicketCancelled(ServiceError):
    """The caller cancelled this ticket (:meth:`Ticket.cancel`) before
    it was served."""

    code = "cancelled"


def _try_resolve(fut: _fut.Future, result) -> bool:
    """Resolve ``fut`` if nobody beat us to it; True = this call won.

    Delivery, rejection, caller cancellation, and supervisor requeue can
    race on one ticket — each path routes through this (or
    :func:`_try_reject`) so every future resolves exactly once and the
    winner alone does the bookkeeping."""
    try:
        if not fut.set_running_or_notify_cancel():
            return False
        fut.set_result(result)
        return True
    except (RuntimeError, _fut.InvalidStateError):
        return False


def _try_reject(fut: _fut.Future, exc: Exception) -> bool:
    try:
        if not fut.set_running_or_notify_cancel():
            return False
        fut.set_exception(exc)
        return True
    except (RuntimeError, _fut.InvalidStateError):
        return False


def _swallow(fut: _fut.Future) -> None:
    """Done-callback for abandoned solve futures: consume the exception
    (the cancelled solve's ``Cancelled``) so the executor never logs it."""
    try:
        fut.exception()
    except _fut.CancelledError:
        pass


class Ticket:
    """One admitted request: a future plus its admission metadata.

    ``vdeadline`` is the priority-queue key: a ticket with a deadline
    budget sorts by its real deadline; a budget-less ticket gets the
    virtual deadline ``admitted + aging``, so it yields to urgent work
    submitted within ``aging`` seconds of it and outranks everything
    that arrives later — earliest-deadline-first with no starvation.
    """

    def __init__(self, request: PlanRequest, instances, grid, names,
                 engine: str, budget: float | None, aging: float = 30.0):
        self.request = request
        self.instances = instances            # resolved (crop applied)
        self.grid = grid
        self.names = names
        self.engine = engine
        self.solver = request.solver if request.solver else "heuristic"
        self.robust = bool(request.robust)
        self.options = request.solver_options
        self.mapping = request.mapping if request.mapping else "fixed"
        self.mapping_options = request.mapping_options
        self.admitted = time.monotonic()
        self.deadline = None if budget is None else self.admitted + budget
        self.vdeadline = self.deadline if self.deadline is not None \
            else self.admitted + float(aging)
        self.journal_seq: int | None = None
        self._fut: _fut.Future = _fut.Future()
        self._service: "PlanService | None" = None
        self._batch: "list[Ticket] | None" = None   # batch being served
        self._stage_token: CancelToken | None = None
        # tracing: the ticket's root "request" span and its "queue_wait"
        # child (NULL_SPAN unless a tracer was installed at admission)
        self.span = obs.NULL_SPAN
        self._wait_span = obs.NULL_SPAN

    @property
    def cells(self) -> int:
        return len(self.instances) * len(self.grid[0])

    def remaining(self) -> float | None:
        """Seconds left in this ticket's deadline budget (None = unbounded)."""
        return None if self.deadline is None \
            else self.deadline - time.monotonic()

    def done(self) -> bool:
        return self._fut.done()

    def result(self, timeout: float | None = None) -> PlanResult:
        """Block for the plan; raises the structured :class:`ServiceError`
        subclass on rejection/failure/cancellation."""
        return self._fut.result(timeout)

    def cancel(self, reason: str = "cancelled by caller") -> bool:
        """Cancel this ticket; True if the cancellation won (the ticket
        had not already resolved).

        Queued tickets simply never run (their journal entry is erased);
        a ticket inside an in-flight solve cancels that solve through
        its stage :class:`~repro.core.cancel.CancelToken` once every
        batch-mate is also done — the solver polls the token at its next
        chunk boundary and the pool worker goes idle within one rung
        budget. ``result()`` then raises :class:`TicketCancelled`.
        """
        won = _try_reject(self._fut, TicketCancelled(
            f"ticket cancelled: {reason}", reason=reason))
        if won and self._service is not None:
            self._service._note_cancel(self)
        return won

    def _coalesce_key(self):
        try:
            opts = tuple(sorted((self.options or {}).items()))
            mopts = tuple(sorted((self.mapping_options or {}).items()))
        except TypeError:                      # unhashable option values:
            opts = object()                    # unique key, no coalescing
            mopts = ()
        return (self.solver, self.engine, self.names, len(self.grid[0]),
                self.robust, opts, self.mapping, mopts)


class _WorkerSlot:
    """Supervision record of one drain worker.

    ``generation`` is the depose handshake: the supervisor bumps it to
    retire a wedged thread; the thread checks it at every checkpoint
    (queue wait, watchdog poll, wedge stall) and self-exits on mismatch,
    so a stale worker can never deliver over its replacement."""

    __slots__ = ("index", "thread", "generation", "heartbeat", "current",
                 "token")

    def __init__(self, index: int):
        self.index = index
        self.thread: threading.Thread | None = None
        self.generation = 0
        self.heartbeat = time.monotonic()
        self.current: list[Ticket] | None = None
        self.token: CancelToken | None = None


class PlanService:
    """A long-lived, fault-tolerant planning frontend over one
    :class:`~repro.api.planner.Planner`.

    Args:
      planner: the shared facade; the service clones it per resolved
        engine (so coalescing never flips an ``auto`` resolution) and for
        the reduced-budget blocked-LP retry. Its platform/k/ls/validate
        configuration applies to every clone.
      workers: drain-worker count — concurrent coalesce groups served at
        once. Fault-free results are bit-identical at any worker count.
      max_queue: admission bound — ``submit`` raises :class:`Overloaded`
        when this many tickets are already waiting.
      max_batch: coalescing bound — at most this many tickets share one
        combined-grid launch.
      default_budget: seconds of wall-clock deadline budget a ticket gets
        when ``submit`` does not specify one (None = unbounded).
      aging: seconds after which a budget-less ticket outranks newer
        arrivals (its virtual deadline; see :class:`Ticket`).
      heartbeat_timeout: seconds of heartbeat silence from a worker with
        claimed tickets before the supervisor deposes and replaces it.
      retries / backoff: transient-failure policy per chain stage
        (exponential: ``backoff * 2**attempt`` seconds between tries).
      ilp_time_limit: default HiGHS time limit (seconds) for ``ilp`` /
        ``exact`` chain stages reached through the service — clamped to
        the remaining deadline budget; an explicit
        ``solver_options["time_limit"]`` on the request wins.
      lp_retry_budget_bytes: the reduced ``lp_budget_bytes`` used for the
        one blocked-LP retry after a device ``MemoryError``.
      fallback_variants: the (cheap) heuristic column set used when an
        exact chain degrades INTO the heuristic stage; heuristic-first
        requests keep their own variants.
      journal_dir: write-ahead ticket journal directory (None = no
        journal). Admitted-but-unfinished tickets found there at
        construction are replayed into the queue (``self.replayed``).
      journal_replay_cap: at most this many journal entries are replayed
        at construction (oldest first — admission order); entries past
        the cap stay on disk (``stats()["replay_deferred"]``) for a
        later restart, so a huge backlog cannot wedge startup. None =
        replay everything.
      compact_journal: renumber the journal to dense sequences at
        construction (:meth:`~repro.serve.journal.TicketJournal
        .compact`) so long-lived journals do not grow sequence numbers
        without bound.
      registry: the per-service :class:`~repro.obs.MetricsRegistry`
        backing :meth:`stats` and :meth:`metrics_text` (a private one is
        created by default so two services never cross-count).
      compilation_cache: enable jax's persistent compilation cache at
        startup (:func:`repro.kernels.backend.enable_compilation_cache`)
        so a restarted service skips recompiling warm kernels; the
        resolved directory lands in ``self.compile_cache_dir``.
      injector: optional :class:`~repro.runtime.fault
        .ServiceFaultInjector` — the chaos seam.
    """

    def __init__(self, planner: Planner, *, workers: int = 1,
                 max_queue: int = 64, max_batch: int = 8,
                 default_budget: float | None = None, aging: float = 30.0,
                 heartbeat_timeout: float = 5.0,
                 retries: int = 2, backoff: float = 0.02,
                 ilp_time_limit: float = 30.0,
                 lp_retry_budget_bytes: int = 8 * 2**20,
                 fallback_variants: tuple[str, ...] = ("asap", "pressWR-LS"),
                 journal_dir: str | None = None,
                 journal_replay_cap: int | None = None,
                 compact_journal: bool = True,
                 compilation_cache: bool = True,
                 registry: obs.MetricsRegistry | None = None,
                 injector=None):
        self._base = planner
        self.workers = max(int(workers), 1)
        self.max_queue = int(max_queue)
        self.max_batch = max(int(max_batch), 1)
        self.default_budget = default_budget
        self.aging = float(aging)
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.retries = max(int(retries), 0)
        self.backoff = float(backoff)
        self.ilp_time_limit = float(ilp_time_limit)
        self.lp_retry_budget_bytes = int(lp_retry_budget_bytes)
        self.fallback_variants = tuple(fallback_variants)
        self.injector = injector
        self.compile_cache_dir = None
        if compilation_cache:
            try:
                self.compile_cache_dir = enable_compilation_cache()
            except Exception:
                self.compile_cache_dir = None
        self._planners: dict[tuple[str, bool], Planner] = {}
        self._planners_lock = threading.Lock()
        # EMA of observed per-candidate mapping-search seconds, feeding
        # the budget-aware fallback (how many candidates the remaining
        # deadline budget affords); None until the first search delivers
        self._mapping_cand_ema: float | None = None
        self._cond = threading.Condition()
        # (vdeadline, seq, ticket) min-heap; resolved tickets are removed
        # lazily on claim. seq breaks vdeadline ties FIFO.
        self._queue: list[tuple[float, int, Ticket]] = []
        self._journal = TicketJournal(journal_dir) if journal_dir else None
        self.journal_replay_cap = None if journal_replay_cap is None \
            else max(int(journal_replay_cap), 0)
        self._compact_journal = bool(compact_journal)
        # advanced past every live journal entry in _replay_journal
        self._seq = itertools.count(0)
        self._paused = False
        self._closed = False
        self._killed = False
        # per-service metrics registry: stats() is a read of these, and
        # metrics_text() renders them (merged with the process-global
        # core-layer registry) as Prometheus text exposition
        self.registry = registry if registry is not None \
            else obs.MetricsRegistry()
        self._m_events = self.registry.counter(
            "plan_service_events_total",
            "service lifecycle events (admission, degradation, "
            "supervision, cancellation)", labels=("event",))
        self._m_stages = self.registry.counter(
            "plan_service_stage_served_total",
            "deliveries per fallback-chain stage", labels=("stage",))
        self._m_inflight = self.registry.gauge(
            "plan_service_inflight_solves",
            "chain-stage solves currently on the solve pool")
        self._m_depth = self.registry.gauge(
            "plan_service_queue_depth", "live tickets waiting")
        self._m_depth_max = self.registry.gauge(
            "plan_service_max_queue_depth",
            "admission queue high-watermark")
        self._m_latency = self.registry.histogram(
            "plan_service_plan_latency_seconds",
            "admission-to-delivery latency", reservoir=1024)
        # abandoned (cancelled, still unwinding) solves keep their pool
        # worker until the next token poll; spares keep chains walking
        self._solve_pool = _fut.ThreadPoolExecutor(
            max_workers=max(8, 2 * self.workers),
            thread_name_prefix="plan-service-solve")
        self.replayed: list[Ticket] = []
        self._replay_journal()
        self._slots = [_WorkerSlot(i) for i in range(self.workers)]
        for slot in self._slots:
            slot.thread = threading.Thread(
                target=self._worker_main, args=(slot,), daemon=True,
                name=f"plan-service-worker-{slot.index}")
            slot.thread.start()
        self._supervisor = threading.Thread(
            target=self._supervise, daemon=True,
            name="plan-service-supervisor")
        self._supervisor.start()

    # --- admission --------------------------------------------------------

    def submit(self, request: PlanRequest, budget: float | None = None
               ) -> Ticket:
        """Admit one request; returns a :class:`Ticket` immediately.

        Raises :class:`InvalidRequest` (malformed request — structured,
        synchronous, nothing shared was touched), :class:`Overloaded`
        (queue full), or :class:`ServiceClosed`. With a journal, the
        ticket is persisted before it becomes claimable (write-ahead).
        """
        if self._closed:
            raise ServiceClosed("plan service is closed")
        root = obs.start_span("request")
        adm = obs.start_span("admission", parent=root)
        try:
            instances, grid, names = request.resolve()
            validate_resolved(instances, grid)
        except (ValueError, TypeError) as e:
            self._bump(rejected_invalid=1)
            adm.end(outcome="rejected_invalid")
            root.end(outcome="rejected_invalid")
            raise InvalidRequest(f"rejected at admission: {e}",
                                 reason=str(e)) from e
        solver = request.solver if request.solver else "heuristic"
        engine = resolve_engine(
            self._base.engine, fanout=len(instances) * len(grid[0])) \
            if solver == "heuristic" else "numpy"
        if budget is None:
            budget = self.default_budget
        ticket = Ticket(request, instances, grid, names, engine, budget,
                        aging=self.aging)
        ticket._service = self
        ticket.span = root.set(solver=ticket.solver, engine=engine,
                               cells=ticket.cells, budget=budget)
        with self._cond:
            if self._closed:
                adm.end(outcome="closed")
                root.end(outcome="closed")
                raise ServiceClosed("plan service is closed")
            depth = sum(1 for _, _, t in self._queue if not t.done())
            if depth >= self.max_queue:
                self._bump(rejected_overloaded=1)
                adm.end(outcome="rejected_overloaded")
                root.end(outcome="rejected_overloaded")
                raise Overloaded(
                    f"admission queue full ({depth} waiting)",
                    queue_depth=depth, max_queue=self.max_queue)
            seq = next(self._seq)
            if self._journal is not None:
                ticket.journal_seq = seq
                self._journal.record(seq, encode_ticket(
                    instances, grid, names, ticket.solver, ticket.robust,
                    ticket.options, budget, mapping=ticket.mapping,
                    mapping_options=ticket.mapping_options))
            heapq.heappush(self._queue, (ticket.vdeadline, seq, ticket))
            self._bump(submitted=1)
            self._m_depth.set(depth + 1)
            self._m_depth_max.set_max(depth + 1)
            adm.end(seq=seq, queue_depth=depth + 1)
            ticket._wait_span = obs.start_span("queue_wait", parent=root)
            self._cond.notify_all()
        return ticket

    def plan(self, request: PlanRequest, budget: float | None = None
             ) -> PlanResult:
        """Synchronous convenience: ``submit(...).result()``."""
        return self.submit(request, budget=budget).result()

    def _replay_journal(self) -> None:
        """Re-admit every admitted-but-unfinished ticket a dead service
        left in the journal (at-least-once: an entry whose answer was
        delivered but not yet erased replays too — it simply re-resolves
        and clears).

        The journal is compacted first (sequence numbers renumber to
        ``0..k-1``; replayed tickets carry the compacted numbers), and
        ``journal_replay_cap`` bounds how many entries are loaded —
        deferred entries stay on disk, counted in ``replay_deferred``,
        and are picked up (oldest first) by a later restart. Either way
        ``self._seq`` resumes past every live entry, so new admissions
        never collide with deferred ones."""
        if self._journal is None:
            return
        if self._compact_journal:
            self._journal.compact()
        pending = self._journal.pending(limit=self.journal_replay_cap)
        deferred = len(self._journal) - len(pending)
        if deferred > 0:
            self._bump(replay_deferred=deferred)
        for seq, state in pending:
            try:
                decoded = decode_ticket(state)
                (instances, grid, names, solver, robust, options,
                 budget) = decoded
                validate_resolved(instances, grid)
            except Exception:
                self._journal.resolve(seq)
                self._bump(replay_corrupt=1)
                continue
            req = PlanRequest(
                instances=instances, profiles=grid,
                variants=names if solver == "heuristic" else None,
                robust=robust, solver=solver, solver_options=options,
                mapping=decoded.mapping,
                mapping_options=decoded.mapping_options)
            engine = resolve_engine(
                self._base.engine,
                fanout=len(instances) * len(grid[0])) \
                if solver == "heuristic" else "numpy"
            ticket = Ticket(req, instances, grid, names, engine, budget,
                            aging=self.aging)
            ticket._service = self
            ticket.journal_seq = seq
            ticket.span = obs.start_span(
                "request", solver=solver, engine=engine, replayed=True,
                seq=seq)
            ticket._wait_span = obs.start_span("queue_wait",
                                               parent=ticket.span)
            heapq.heappush(self._queue, (ticket.vdeadline, seq, ticket))
            self.replayed.append(ticket)
            self._bump(submitted=1, replayed=1)
        self._seq = itertools.count(self._journal.next_seq())

    # --- worker pool ------------------------------------------------------

    def _has_work(self) -> bool:
        """Prune resolved heap heads; True if a live ticket waits.
        Caller holds ``_cond``."""
        while self._queue and self._queue[0][2].done():
            heapq.heappop(self._queue)
        return bool(self._queue)

    def _claim_batch(self) -> list[Ticket] | None:
        """Pop the earliest-deadline live ticket plus up to
        ``max_batch - 1`` coalescable queue-mates. Caller holds
        ``_cond``. Mates are taken in deadline order; claiming a mate
        *past* a non-coalescable earlier ticket is counted as a
        priority inversion (the price of batching)."""
        if not self._has_work():
            return None
        lead = heapq.heappop(self._queue)[2]
        batch = [lead]
        if self.max_batch > 1 and self._queue:
            key = lead._coalesce_key()
            keep, inversions, passed_other = [], 0, False
            for entry in sorted(self._queue):
                t = entry[2]
                if t.done():
                    continue
                if len(batch) < self.max_batch and \
                        t._coalesce_key() == key:
                    if passed_other:
                        inversions += 1
                    batch.append(t)
                else:
                    keep.append(entry)
                    passed_other = True
            self._queue[:] = keep
            heapq.heapify(self._queue)
            if inversions:
                self._bump(priority_inversions=inversions)
        return batch

    def _worker_main(self, slot: _WorkerSlot) -> None:
        try:
            self._worker_loop(slot)
        except SimulatedFailure:
            # injected worker death: die with slot.current still set so
            # the supervisor requeues the claimed tickets
            pass

    def _worker_loop(self, slot: _WorkerSlot) -> None:
        gen = slot.generation
        while True:
            with self._cond:
                while not self._closed and slot.generation == gen and \
                        (self._paused or not self._has_work()):
                    slot.heartbeat = time.monotonic()
                    self._cond.wait(timeout=0.05)
                if self._closed or slot.generation != gen:
                    return
                batch = self._claim_batch()
                if batch is None:
                    continue
                slot.current = batch
                slot.heartbeat = time.monotonic()
            spec = self.injector.on_worker() \
                if self.injector is not None else None
            if spec is not None:
                if spec.kind == "kill":
                    self.kill()
                    return
                if spec.kind == "worker-death":
                    raise SimulatedFailure("injected worker death")
                # "wedge": stall WITHOUT heartbeating until the
                # supervisor deposes this generation (or the scripted
                # stall ends first under a long heartbeat_timeout)
                stall = time.monotonic() + spec.seconds
                while time.monotonic() < stall:
                    if slot.generation != gen:
                        return          # deposed; tickets were requeued
                    time.sleep(0.005)
            try:
                self._serve_batch(batch, slot, gen)
            finally:
                with self._cond:
                    if slot.generation == gen:
                        slot.current = None
                        slot.token = None

    def _supervise(self) -> None:
        """Detect dead/wedged workers and replace them (see
        :class:`_WorkerSlot`). Healthy workers heartbeat from their
        queue wait and from the watchdog poll during solves, so only a
        genuinely stalled worker loop trips the timeout."""
        interval = max(min(self.heartbeat_timeout / 4.0, 0.05), 0.005)
        while not self._closed:
            for slot in self._slots:
                if self._closed:
                    return
                if slot.thread is not None and not slot.thread.is_alive():
                    self._restart(slot, "worker died")
                elif slot.current is not None and \
                        time.monotonic() - slot.heartbeat \
                        > self.heartbeat_timeout:
                    self._restart(slot, "worker wedged")
            time.sleep(interval)

    def _restart(self, slot: _WorkerSlot, reason: str) -> None:
        requeued = 0
        with self._cond:
            if self._closed:
                return
            slot.generation += 1
            token, current = slot.token, slot.current or []
            slot.current = None
            slot.token = None
            if token is not None:
                token.cancel(reason)
            for t in current:
                if not t.done():
                    heapq.heappush(self._queue,
                                   (t.vdeadline, next(self._seq), t))
                    requeued += 1
            slot.heartbeat = time.monotonic()
            slot.thread = threading.Thread(
                target=self._worker_main, args=(slot,), daemon=True,
                name=f"plan-service-worker-{slot.index}")
            slot.thread.start()
            self._cond.notify_all()
        self._bump(worker_restarts=1, requeued=requeued)

    # --- batch assembly: corruption quarantine ----------------------------

    def _serve_batch(self, tickets: list[Ticket], slot: _WorkerSlot,
                     gen: int) -> None:
        healthy = []
        for t in tickets:
            if t.done():                       # cancelled while queued
                continue
            t._wait_span.end()                 # claimed: the wait is over
            grid = t.grid
            if self.injector is not None and self.injector.corrupts_request():
                # the chaos seam poisons this ticket's profiles in flight
                grid = [[corrupt_profile(p) for p in ps] for ps in grid]
                t.grid = grid
            try:
                validate_resolved(t.instances, grid)
            except ValueError as e:
                self._bump(quarantined=1)
                self._reject(t, InvalidRequest(
                    f"quarantined at batch assembly: {e}", reason=str(e)))
                continue
            healthy.append(t)
        if healthy:
            self._bump(batches=1, coalesced_requests=len(healthy))
            self._run_chain(healthy, slot=slot, gen=gen)

    # --- the degradation ladder -------------------------------------------

    def _chain_for(self, solver: str) -> tuple[str, ...]:
        return FALLBACK_CHAINS.get(solver, (solver, "asap"))

    def _remaining(self, tickets) -> float | None:
        rs = [r for r in (t.remaining() for t in tickets) if r is not None]
        return min(rs) if rs else None

    def _watch(self, fut: _fut.Future, slot: _WorkerSlot | None, gen: int,
               token: CancelToken, budget: float | None):
        """Poll one stage solve to completion, heartbeating the worker
        slot. Raises TimeoutError at the budget, or ``Cancelled`` when
        this worker generation was deposed mid-solve (the supervisor
        already requeued the tickets; the solve is cancelled and
        abandoned)."""
        deadline = None if budget is None else time.monotonic() + budget
        while True:
            if slot is not None:
                slot.heartbeat = time.monotonic()
                if slot.generation != gen:
                    token.cancel("worker deposed")
                    fut.add_done_callback(_swallow)
                    raise Cancelled("worker deposed")
            left = None if deadline is None else deadline - time.monotonic()
            if left is not None and left <= 0:
                raise _fut.TimeoutError()
            step = 0.05 if left is None else min(0.05, max(left, 0.001))
            try:
                return fut.result(timeout=step)
            except _fut.TimeoutError:
                continue

    def _run_chain(self, tickets: list[Ticket],
                   attempts: list[str] | None = None,
                   slot: _WorkerSlot | None = None, gen: int = 0) -> None:
        attempts = attempts if attempts is not None else []
        chain = self._chain_for(tickets[0].solver)
        # rung spans parent to the LEAD ticket's trace (one connected
        # tree per batch); batch-mates' own roots link up at resolution
        lead = tickets[0]
        for si, stage in enumerate(chain):
            terminal = si == len(chain) - 1
            remaining = self._remaining(tickets)
            if remaining is not None and remaining <= 0 and not terminal:
                # budget exhausted: jump straight to the terminal rung,
                # which still returns a feasible schedule
                attempts.append(f"{stage}:skipped")
                obs.start_span(f"rung:{stage}", parent=lead.span,
                               stage=stage, outcome="skipped").end()
                continue
            blocked = False
            attempt = 0
            while attempt <= self.retries:
                if all(t.done() for t in tickets):
                    return                     # cancelled under us
                remaining = self._remaining(tickets)
                budget = None if (remaining is None or terminal) \
                    else max(remaining, 0.05)
                token = CancelToken.with_budget(budget)
                for t in tickets:
                    t._batch = tickets
                    t._stage_token = token
                if slot is not None:
                    slot.token = token
                rung = obs.start_span(
                    f"rung:{stage}", parent=lead.span, stage=stage,
                    attempt=attempt, tickets=len(tickets),
                    blocked_lp=blocked,
                    budget=None if budget is None else round(budget, 3))
                fut = self._solve_pool.submit(
                    self._solve_once, stage, tickets, remaining, blocked,
                    token, rung)
                try:
                    res = self._watch(fut, slot, gen, token, budget)
                except _fut.TimeoutError:
                    # cancel the abandoned solve: it unwinds at its next
                    # token poll and frees its pool worker
                    token.cancel("deadline budget exceeded")
                    fut.add_done_callback(_swallow)
                    attempts.append(f"{stage}:timeout")
                    self._bump(timeouts=1)
                    rung.end(outcome="timeout")
                    break                              # next stage
                except Cancelled:
                    if token.reason == "deadline expired":
                        # the solve timed itself out via the token's own
                        # deadline (same budget the watchdog enforces)
                        attempts.append(f"{stage}:timeout")
                        self._bump(timeouts=1)
                        rung.end(outcome="timeout")
                        break                          # next stage
                    # client cancelled every ticket, or this worker was
                    # deposed (tickets requeued) — either way the chain
                    # is no longer ours to walk
                    attempts.append(f"{stage}:cancelled")
                    self._bump(cancelled_solves=1)
                    rung.end(outcome="cancelled")
                    return
                except SimulatedFailure:
                    attempts.append(f"{stage}:crash")
                    self._bump(retries=1)
                    rung.end(outcome="crash")
                    attempt += 1
                    if attempt > self.retries:
                        break
                    time.sleep(self.backoff * (2 ** (attempt - 1)))
                    continue
                except MemoryError:
                    attempts.append(f"{stage}:oom")
                    rung.end(outcome="oom")
                    if blocked:
                        break                          # blocked retry used
                    blocked = True
                    self._bump(oom_retries=1)
                    attempts.append(f"{stage}:oom-retry-blocked-lp")
                    continue
                except Exception as e:
                    attempts.append(f"{stage}:error")
                    rung.end(outcome="error", error=type(e).__name__)
                    if len(tickets) > 1:
                        # quarantine bisect: a poisoned batch-mate must
                        # not take the others down — every ticket re-runs
                        # its chain alone, so exactly the poison fails
                        self._bump(splits=1)
                        for t in tickets:
                            self._run_chain(
                                [t], attempts=["quarantine:split"],
                                slot=slot, gen=gen)
                        return
                    if terminal:
                        self._fail(tickets, attempts, e)
                        return
                    break                              # next stage
                else:
                    attempts.append(f"{stage}:ok")
                    rung.end(outcome="ok")
                    self._deliver(tickets, res, stage, attempts)
                    return
        self._fail(tickets, attempts, None)

    def _planner_for(self, engine: str, blocked: bool) -> Planner:
        key = (engine, blocked)
        with self._planners_lock:
            p = self._planners.get(key)
            if p is None:
                p = self._base.clone(
                    engine=engine,
                    lp_budget_bytes=self.lp_retry_budget_bytes if blocked
                    else None)
                self._planners[key] = p
            return p

    def _solve_once(self, stage: str, tickets: list[Ticket],
                    remaining: float | None, blocked: bool,
                    cancel: CancelToken | None = None,
                    rung: "obs.Span | None" = None) -> PlanResult:
        """One chain-stage solve of the whole batch (runs on the solve
        pool; the watchdog can abandon it and ``cancel`` stops it).
        ``rung`` re-anchors this pool thread to the chain walker's rung
        span, so the planner/solver spans nest under the right trace."""
        self._m_inflight.inc()
        try:
            with obs.attach(rung), obs.span(
                    "solve", stage=stage, tickets=len(tickets),
                    cells=sum(t.cells for t in tickets)):
                if self.injector is not None:
                    self.injector.on_solve(stage, cancel=cancel)
                requested = tickets[0].solver
                # mapping modes ride every chain stage (the instances
                # are raw Workflows); non-requested fallback stages
                # degrade "search" budget-aware — shrink the search to
                # what the remaining deadline budget affords, dropping
                # to the cheap deterministic "heft" only when even a
                # minimal search does not fit (see _degrade_mapping)
                mapping = tickets[0].mapping
                mapping_options = tickets[0].mapping_options
                if stage == requested:
                    variants = tickets[0].names \
                        if requested == "heuristic" else None
                    options = dict(tickets[0].options or {})
                else:
                    variants = self.fallback_variants \
                        if stage == "heuristic" else None
                    options = {}
                    if mapping != "fixed":
                        mapping, mapping_options = self._degrade_mapping(
                            stage, mapping, mapping_options,
                            remaining, n_workflows=sum(
                                len(t.instances) for t in tickets))
                if stage in ("ilp", "exact"):
                    limit = options.get("time_limit", self.ilp_time_limit)
                    if remaining is not None:
                        limit = min(float(limit), max(remaining, 0.1))
                    options["time_limit"] = limit
                if stage == "heuristic":
                    engine = tickets[0].engine \
                        if requested == "heuristic" else \
                        resolve_engine(self._base.engine,
                                       fanout=sum(t.cells
                                                  for t in tickets))
                else:
                    engine = "numpy"
                planner = self._planner_for(
                    engine, blocked and stage == "heuristic")
                req = PlanRequest(
                    instances=[i for t in tickets for i in t.instances],
                    profiles=[ps for t in tickets for ps in t.grid],
                    variants=variants, robust=tickets[0].robust,
                    solver=stage, solver_options=options or None,
                    mapping=mapping, mapping_options=mapping_options)
                return planner.plan(req, cancel=cancel)
        finally:
            self._m_inflight.dec()
            self._bump(cancel_checks=cancel.checks
                       if cancel is not None else 0)

    # --- budget-aware mapping degradation ---------------------------------

    # per-candidate seconds assumed before any search has delivered, the
    # budget fraction the mapping phase may spend (the schedule solve
    # needs the rest), and the EMA smoothing of observed costs
    _MAPPING_CAND_DEFAULT = 0.25
    _MAPPING_BUDGET_FRACTION = 0.5
    _MAPPING_EMA_ALPHA = 0.3
    # candidate cap for budget-less fallback rungs: the rung was reached
    # on a solver error, not deadline pressure, so keep a small search
    _MAPPING_FALLBACK_CAP = 8

    def _degrade_mapping(self, stage: str, mapping: str, mapping_options,
                         remaining: float | None, n_workflows: int
                         ) -> tuple[str, object]:
        """Mapping mode for a non-requested fallback rung.

        ``mapping="search"`` is shrunk to the candidate count the
        remaining deadline budget affords (per-candidate cost = EMA of
        delivered searches, split across the batch's workflows) via
        :meth:`MappingOptions.shrunk_to`, and only drops to plain HEFT
        when even a 2-candidate search does not fit — or on the terminal
        ``asap`` rung, which must stay worst-case cheap. The delivered
        result surfaces the choice: ``attempts`` carries a
        ``mapping:<mode>`` marker and ``mapping_info`` shows the shrunk
        search's real candidate count.
        """
        if mapping != "search" or stage == "asap":
            return "heft", None
        from repro.mapping.options import MappingOptions

        opts = MappingOptions.from_dict(mapping_options)
        if remaining is None:
            afford = self._MAPPING_FALLBACK_CAP
        else:
            per_cand = self._mapping_cand_ema \
                if self._mapping_cand_ema is not None \
                else self._MAPPING_CAND_DEFAULT
            afford = int(max(remaining, 0.0) * self._MAPPING_BUDGET_FRACTION
                         / (per_cand * max(n_workflows, 1)))
        shrunk = opts.shrunk_to(afford)
        if shrunk is None:
            self._bump(mapping_heft_downgrades=1)
            return "heft", None
        if shrunk is not opts:
            self._bump(mapping_search_shrinks=1)
        return "search", shrunk.to_dict()

    def _note_mapping_cost(self, res: PlanResult) -> None:
        """Fold a delivered search's per-candidate seconds into the EMA
        the budget-aware fallback plans with."""
        for info in (res.mapping_info or ()):
            if getattr(info, "mode", None) == "search" and info.candidates:
                per = info.seconds / info.candidates
                a = self._MAPPING_EMA_ALPHA
                self._mapping_cand_ema = per \
                    if self._mapping_cand_ema is None \
                    else (1 - a) * self._mapping_cand_ema + a * per

    # --- delivery ---------------------------------------------------------

    def _deliver(self, tickets: list[Ticket], res: PlanResult, stage: str,
                 attempts: list[str]) -> None:
        requested = tickets[0].solver
        if getattr(res, "mapping_mode", "fixed") != "fixed":
            # surface the rung's mapping decision (search kept/shrunk vs
            # downgraded to heft) next to the stage markers
            attempts = attempts + [f"mapping:{res.mapping_mode}"]
            self._note_mapping_cost(res)
        now = time.monotonic()
        i0 = 0
        for t in tickets:
            i1 = i0 + len(t.instances)
            lower = None if res.lower_bound is None \
                else res.lower_bound[i0:i1]
            gaps = None if res.mip_gap is None else res.mip_gap[i0:i1]
            open_gap = gaps is not None and bool(
                np.any(np.nan_to_num(gaps, nan=0.0) > 1e-9))
            sub = PlanResult(
                variants=res.variants, results=res.results[i0:i1],
                costs=res.costs[i0:i1], engine=res.engine,
                seconds=res.seconds, robust_requested=res.robust_requested,
                solver=res.solver, lower_bound=lower, mip_gap=gaps,
                degraded=(stage != requested) or open_gap,
                fallback_stage=stage, attempts=tuple(attempts),
                mapping_mode=res.mapping_mode,
                mappings=None if res.mappings is None
                else res.mappings[i0:i1],
                mapping_info=None if res.mapping_info is None
                else res.mapping_info[i0:i1])
            if _try_resolve(t._fut, sub):
                self._bump(completed=1, degraded=1 if sub.degraded else 0)
                self._m_stages.inc(stage=stage)
                self._m_latency.observe(now - t.admitted)
                self._journal_resolve(t)
                t._wait_span.end()
                obs.start_span("resolution", parent=t.span, stage=stage,
                               degraded=sub.degraded,
                               coalesced=len(tickets)).end()
                t.span.end(outcome="completed", stage=stage,
                           degraded=sub.degraded)
            i0 = i1

    def _journal_resolve(self, ticket: Ticket) -> None:
        if self._journal is not None and ticket.journal_seq is not None:
            try:
                self._journal.resolve(ticket.journal_seq)
            except OSError:
                pass

    def _note_cancel(self, ticket: Ticket) -> None:
        """Bookkeeping after a won :meth:`Ticket.cancel`: drop the
        journal entry and, when every batch-mate of an in-flight solve
        is also done, cancel the solve itself through the stage token."""
        self._bump(cancelled=1)
        self._journal_resolve(ticket)
        ticket._wait_span.end()
        ticket.span.end(outcome="cancelled")
        batch, token = ticket._batch, ticket._stage_token
        if batch is not None and token is not None and \
                all(t.done() for t in batch):
            token.cancel("all batch tickets cancelled")
        with self._cond:
            self._cond.notify_all()

    def _reject(self, ticket: Ticket, err: ServiceError) -> bool:
        won = _try_reject(ticket._fut, err)
        if won:
            self._journal_resolve(ticket)
            ticket._wait_span.end()
            ticket.span.end(outcome=err.code)
        return won

    def _fail(self, tickets: list[Ticket], attempts: list[str],
              last: Exception | None) -> None:
        for t in tickets:
            if self._reject(t, PlanFailure(
                    "every fallback stage failed"
                    + (f" (last: {last})" if last is not None else ""),
                    attempts=tuple(attempts),
                    last_error=repr(last) if last is not None else None)):
                self._bump(failed=1)

    # --- telemetry / lifecycle --------------------------------------------

    def _bump(self, **deltas) -> None:
        """Shim from the pre-registry ``Counter`` spelling onto the
        per-service metrics registry (one labeled counter per event)."""
        for k, v in deltas.items():
            if v:
                self._m_events.inc(v, event=k)

    def stats(self) -> dict:
        """Service telemetry snapshot: admission/degradation counters,
        worker supervision counters, cancellation counters, coalescing
        ratio, and plan-latency percentiles.

        This is a read of ``self.registry`` — the wire shape predates
        the registry and is preserved exactly; :meth:`metrics_text`
        exposes the same numbers as Prometheus text exposition."""
        with self._cond:
            depth = sum(1 for _, _, t in self._queue if not t.done())
        self._m_depth.set(depth)
        c = {k: int(self._m_events.value(event=k)) for k in _STAT_EVENTS}
        lat = np.asarray(self._m_latency.samples(), dtype=np.float64)
        stages = {key[0]: int(v)
                  for key, v in self._m_stages.values().items()}
        batches = c.get("batches", 0)
        served = c.get("coalesced_requests", 0)
        return {
            **c,
            "inflight_solves": int(self._m_inflight.value()),
            "max_queue_depth": int(self._m_depth_max.value()),
            "workers": self.workers,
            "queue_depth": depth,
            "coalesce_ratio": served / batches if batches else None,
            "stages": stages,
            "latency": {
                "n": int(lat.size),
                "p50_ms": float(np.percentile(lat, 50) * 1e3)
                if lat.size else None,
                "p99_ms": float(np.percentile(lat, 99) * 1e3)
                if lat.size else None,
            },
        }

    def metrics_text(self) -> str:
        """Prometheus text exposition of this service's registry merged
        with the process-global core-layer registry — serve it verbatim
        as a ``/metrics`` body."""
        return obs.render_prometheus(self.registry, obs.registry())

    def pause(self) -> None:
        """Hold the workers (drills/tests: lets callers fill the queue
        deterministically)."""
        with self._cond:
            self._paused = True

    def resume(self) -> None:
        with self._cond:
            self._paused = False
            self._cond.notify_all()

    def kill(self) -> None:
        """Die abruptly, as a crashed process would: workers are deposed
        mid-flight, unresolved ticket futures never resolve, and the
        journal keeps every admitted-but-unfinished entry — a new
        service on the same ``journal_dir`` replays them. The chaos
        seam's ``"kill"`` fault routes here; safe to call from a worker
        thread (no joins)."""
        with self._cond:
            if self._closed:
                return
            self._killed = True
            self._closed = True
            for slot in self._slots:
                slot.generation += 1
                if slot.token is not None:
                    slot.token.cancel("service killed")
            self._cond.notify_all()
        self._solve_pool.shutdown(wait=False, cancel_futures=True)

    def close(self) -> None:
        """Stop gracefully: in-flight batches finish, then pending
        tickets fail with :class:`ServiceClosed` — a resolution, so
        their journal entries are erased (a clean close leaves an empty
        journal; only :meth:`kill` leaves replayable entries)."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            pending = [t for _, _, t in self._queue if not t.done()]
            self._queue.clear()
            self._cond.notify_all()
        for slot in self._slots:
            if slot.thread is not None:
                slot.thread.join(timeout=30.0)
        self._supervisor.join(timeout=5.0)
        for t in pending:
            self._reject(t, ServiceClosed("plan service closed before "
                                          "this ticket was served"))
        self._solve_pool.shutdown(wait=False, cancel_futures=True)

    def __enter__(self) -> "PlanService":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False
