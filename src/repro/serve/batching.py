"""Continuous batching over the single-token decode step.

Fixed B decode slots; finished/empty slots are refilled from the request
queue each iteration (tokens of dead slots still step but are masked out).
Greedy sampling; per-request max_tokens/eos.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_tokens: int
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, model, params, batch_size: int, max_len: int,
                 eos: int = 1):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_len = max_len
        self.eos = eos
        self.cache = model.init_cache(batch_size, max_len)
        self.slots: list[Request | None] = [None] * batch_size
        self.queue: list[Request] = []
        self.cur = np.zeros(batch_size, dtype=np.int32)
        self.budget = np.zeros(batch_size, dtype=np.int32)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _fill_slots(self) -> None:
        for i in range(self.B):
            if (self.slots[i] is None or self.slots[i].done) and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # simple prompt handling: feed prompt tokens step by step
                self.cur[i] = req.prompt[0] if req.prompt else self.eos
                self.budget[i] = req.max_tokens + len(req.prompt)

    def step(self) -> None:
        self._fill_slots()
        logits, self.cache = self.model.decode_step(
            self.params, self.cache, jnp.asarray(self.cur))
        nxt = np.asarray(logits.argmax(-1), dtype=np.int32)
        for i, req in enumerate(self.slots):
            if req is None or req.done:
                continue
            consumed = len(req.out) + 1
            if consumed < len(req.prompt):          # still teacher-forcing
                self.cur[i] = req.prompt[consumed]
                req.out.append(int(self.cur[i]))
                continue
            tok = int(nxt[i])
            req.out.append(tok)
            self.cur[i] = tok
            self.budget[i] -= 1
            if tok == self.eos or self.budget[i] <= 0:
                req.done = True

    def run(self, max_steps: int = 1000) -> list[Request]:
        done: list[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(r is None or r.done for r in self.slots):
                break
            self.step()
        return [r for r in self.slots if r is not None] + self.queue
