from repro.serve.batching import ContinuousBatcher, Request  # noqa: F401
from repro.serve.journal import (  # noqa: F401
    TicketJournal,
    decode_ticket,
    encode_ticket,
)
from repro.serve.service import (  # noqa: F401
    FALLBACK_CHAINS,
    InvalidRequest,
    Overloaded,
    PlanFailure,
    PlanService,
    ServiceClosed,
    ServiceError,
    Ticket,
    TicketCancelled,
)
