from repro.serve.batching import ContinuousBatcher, Request  # noqa: F401
