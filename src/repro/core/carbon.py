"""Power profiles and carbon-cost oracles (paper §3, §6.1, Appendix A.1).

A profile is a partition of the horizon ``[0, T)`` into ``J`` intervals with
a constant green budget per time unit. The schedule-independent idle draw
``sum_i P_idle^i`` folds into an *effective* budget ``g_eff = G_j - idle``;
profile generation guarantees ``G_j >= idle`` (paper §6.1), so
``cost_t = max(work_power(t) - g_eff(t), 0)``.

Three cost oracles, all exact and mutually validated:
  * :func:`schedule_cost`      -- numpy, subinterval sweep of Appendix A.1;
  * :func:`cost_timeline`      -- numpy, per-time-unit (pseudo-polynomial);
  * :func:`schedule_cost_jnp`  -- jittable jnp breakpoint formulation used on
                                  device (and as the Pallas kernels' oracle).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.dag import Instance


@dataclasses.dataclass(frozen=True)
class PowerProfile:
    """Green power budget, piecewise constant over J intervals."""

    bounds: np.ndarray   # [J+1] interval boundaries, bounds[0]=0, bounds[J]=T
    budget: np.ndarray   # [J] raw green budget per time unit
    scenario: str = "custom"

    @property
    def T(self) -> int:
        return int(self.bounds[-1])

    @property
    def J(self) -> int:
        return len(self.budget)

    def effective(self, idle_total: int) -> np.ndarray:
        """Effective green budget (work power the profile can absorb)."""
        return self.budget - idle_total

    def unit_budget(self, idle_total: int) -> np.ndarray:
        """Per-time-unit effective budget, shape [T] (pseudo-poly; tests/kernels)."""
        g = self.effective(idle_total)
        lens = np.diff(self.bounds)
        return np.repeat(g, lens)


SCENARIOS = ("S1", "S2", "S3", "S4")


def generate_profile(scenario: str, T: int, platform, J: int = 48,
                     seed: int = 0, perturb: float = 0.1,
                     work_capacity: int | None = None) -> PowerProfile:
    """Paper §6.1 profiles: S1 x^2-bump, S2 midday-shifted, S3 sin, S4 const.

    Budgets span ``[idle, idle + 0.8 * work_capacity]`` so that scheduling
    decisions matter (paper's rationale). ``work_capacity`` defaults to the
    platform's total work power; benchmarks pass the workload's ASAP peak
    draw instead, which reproduces the paper's tightness on scaled-down
    matrices.
    """
    rng = np.random.default_rng(seed)
    J = min(J, T)
    bounds = np.round(np.linspace(0, T, J + 1)).astype(np.int64)
    bounds = np.unique(bounds)
    J = len(bounds) - 1
    x = (np.arange(J) + 0.5) / J
    if scenario == "S1":
        frac = 1.0 - (2.0 * x - 1.0) ** 2          # parabola peaking mid-day
    elif scenario == "S2":
        xs = (x + 0.5) % 1.0                        # same, starting from midday
        frac = 1.0 - (2.0 * xs - 1.0) ** 2
    elif scenario == "S3":
        frac = 0.5 * (1.0 + np.sin(2.0 * np.pi * x - 0.5 * np.pi))
    elif scenario == "S4":
        frac = np.full(J, 0.55)
    else:
        raise ValueError(f"unknown scenario {scenario!r}")
    frac = np.clip(frac + rng.normal(0.0, perturb, size=J), 0.0, 1.0)
    idle = platform.idle_total
    work_total = int(platform.p_work.sum()) if work_capacity is None \
        else int(work_capacity)
    budget = (idle + np.round(frac * 0.8 * work_total)).astype(np.int64)
    return PowerProfile(bounds=bounds, budget=budget, scenario=scenario)


# ---------------------------------------------------------------------------
# Cost oracles
# ---------------------------------------------------------------------------

def schedule_cost(inst: Instance, profile: PowerProfile,
                  start: np.ndarray) -> int:
    """Exact total carbon cost, polynomial subinterval sweep (Appendix A.1).

    Breakpoints = interval bounds + every task start/end; the active work
    power is constant between consecutive breakpoints.
    """
    start = np.asarray(start, dtype=np.int64)
    end = start + inst.dur
    pts = np.concatenate([profile.bounds, start, end])
    pts = np.unique(np.clip(pts, 0, profile.T))
    # work power delta encoding
    deltas = np.zeros(len(pts), dtype=np.int64)
    si = np.searchsorted(pts, np.minimum(start, profile.T))
    ei = np.searchsorted(pts, np.minimum(end, profile.T))
    np.add.at(deltas, si, inst.task_work)
    np.add.at(deltas, ei, -inst.task_work)
    power = np.cumsum(deltas)[:-1]                       # per segment
    seg_len = np.diff(pts)
    g = profile.effective(inst.idle_total)
    seg_budget = g[np.searchsorted(profile.bounds, pts[:-1], side="right") - 1]
    return int((seg_len * np.maximum(power - seg_budget, 0)).sum())


def work_timeline(inst: Instance, T: int, start: np.ndarray) -> np.ndarray:
    """Per-time-unit total active work power, shape [T] (pseudo-polynomial)."""
    start = np.asarray(start, dtype=np.int64)
    deltas = np.zeros(T + 1, dtype=np.int64)
    s = np.clip(start, 0, T)
    e = np.clip(start + inst.dur, 0, T)
    np.add.at(deltas, s, inst.task_work)
    np.add.at(deltas, e, -inst.task_work)
    return np.cumsum(deltas[:-1])


def cost_timeline(inst: Instance, profile: PowerProfile,
                  start: np.ndarray) -> int:
    """Exact cost via the per-unit timeline (cross-check oracle)."""
    P = work_timeline(inst, profile.T, start)
    g = profile.unit_budget(inst.idle_total)
    return int(np.maximum(P - g, 0).sum())


def validate_schedule(inst: Instance, profile: PowerProfile,
                      start: np.ndarray) -> None:
    """Assert precedence + deadline feasibility of a schedule."""
    start = np.asarray(start, dtype=np.int64)
    end = start + inst.dur
    assert (start >= 0).all(), "negative start time"
    assert (end <= profile.T).all(), "deadline violated"
    u = np.repeat(np.arange(inst.num_tasks),
                  np.diff(inst.succ_ptr))
    v = inst.succ_idx
    assert (start[v] >= end[u]).all(), "precedence violated"


# ---------------------------------------------------------------------------
# jnp breakpoint oracle (fixed shapes, jittable; device path + kernel oracle)
# ---------------------------------------------------------------------------

def schedule_cost_jnp(start, dur, work, bounds, g_eff, T):
    """Jittable exact carbon cost (same math as :func:`schedule_cost`).

    All arguments are arrays; shapes are static under jit:
      start, dur, work: [N];  bounds: [J+1];  g_eff: [J].
    """
    import jax.numpy as jnp

    start = jnp.asarray(start)
    end = jnp.clip(start + dur, 0, T)
    s = jnp.clip(start, 0, T)
    pts = jnp.concatenate([jnp.asarray(bounds), s, end])
    pts = jnp.sort(pts)                                   # [K], duplicates ok
    deltas = jnp.zeros(pts.shape[0] + 1, dtype=jnp.float32)
    si = jnp.searchsorted(pts, s, side="left")
    ei = jnp.searchsorted(pts, end, side="left")
    w = jnp.asarray(work, dtype=jnp.float32)
    deltas = deltas.at[si].add(w)
    deltas = deltas.at[ei].add(-w)
    power = jnp.cumsum(deltas[:-1])[:-1]                  # per segment [K-1]
    seg_len = jnp.diff(pts).astype(jnp.float32)
    idx = jnp.clip(
        jnp.searchsorted(jnp.asarray(bounds), pts[:-1], side="right") - 1,
        0, len(g_eff) - 1)
    seg_budget = jnp.asarray(g_eff, dtype=jnp.float32)[idx]
    return (seg_len * jnp.maximum(power - seg_budget, 0.0)).sum()
