"""CaWoSched greedy scheduler (paper §5.2), paper-faithful numpy path.

Processes tasks in score order; each task starts at the beginning of the
feasible (refined) interval with the highest remaining green budget
(earliest on ties), budgets are decremented where the task runs, intervals
are split at the task's endpoints, and EST/LST of unscheduled tasks are
updated through the DAG.

Times are integers, so interval state is kept on per-unit timelines:
``rem[t]`` = remaining effective budget at time ``t`` and a candidate-start
mask. This is exactly the paper's dynamically split interval list (budget is
constant on each split interval and equals ``rem`` at its start point).
"""
from __future__ import annotations

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.core.estlst import (
    compute_est,
    compute_lst,
    lower_lst_from,
    raise_est_from,
)
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask


def greedy_schedule(inst: Instance, profile: PowerProfile, platform: Platform,
                    score: str = "press", weighted: bool = False,
                    refined: bool = False, k: int = 3) -> np.ndarray:
    """Compute a greedy carbon-aware schedule. Returns start times [N]."""
    T = profile.T
    est = compute_est(inst)
    lst = compute_lst(inst, T)
    if (est > lst).any():
        raise ValueError("infeasible: deadline below ASAP makespan")

    order = task_order(inst, est, lst, score, weighted, platform)
    mask = candidate_mask(inst, profile, refined=refined, k=k)
    rem = profile.unit_budget(inst.idle_total).astype(np.int64).copy()

    start = np.zeros(inst.num_tasks, dtype=np.int64)
    scheduled = np.zeros(inst.num_tasks, dtype=bool)

    for v in order:
        a, b = int(est[v]), int(lst[v])
        cand = np.flatnonzero(mask[a:b + 1])
        if len(cand) == 0:
            s = a
        else:
            cand = cand + a
            # budget of the interval starting at candidate point t is rem[t];
            # argmax returns the first (earliest) maximum — the paper's tie
            # break.
            s = int(cand[np.argmax(rem[cand])])
        e = s + int(inst.dur[v])
        start[v] = s
        scheduled[v] = True
        # decrement budgets where the task runs; its endpoints split the
        # intervals, becoming candidate start points for later tasks.
        rem[s:e] -= int(inst.task_work[v])
        mask[s] = True
        if e <= T:
            mask[e] = True
        raise_est_from(inst, est, int(v), s, scheduled)
        lower_lst_from(inst, lst, int(v), s, scheduled)

    return start
