"""CaWoSched greedy scheduler (paper §5.2), paper-faithful numpy path.

Processes tasks in score order; each task starts at the beginning of the
feasible (refined) interval with the highest remaining green budget
(earliest on ties), budgets are decremented where the task runs, intervals
are split at the task's endpoints, and EST/LST of unscheduled tasks are
updated through the DAG.

Two interval representations, bit-identical by construction (and by test):

* :func:`greedy_schedule` — per-unit timelines: ``rem[t]`` = remaining
  effective budget at time ``t`` and a candidate-start mask over ``[0, T]``.
  O(T) per task; the pseudo-polynomial reference.
* :func:`greedy_schedule_segments` — the paper's actual data structure: a
  sorted breakpoint list (candidate points) with the budget value of the
  segment starting at each point. Budgets are constant between breakpoints
  (profile bounds and all task endpoints are breakpoints), so placement is
  an argmax over the breakpoints inside ``[EST, LST]`` and a task placement
  inserts its two endpoints and decrements the covered breakpoints —
  O((n + |E|)·log + |candidates in window|) instead of O(n·T). This is the
  big-horizon fast path the portfolio engine uses.
"""
from __future__ import annotations

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.core.estlst import (
    compute_est,
    compute_lst,
    lower_lst_from,
    raise_est_from,
)
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask


def greedy_schedule(inst: Instance, profile: PowerProfile, platform: Platform,
                    score: str = "press", weighted: bool = False,
                    refined: bool = False, k: int = 3) -> np.ndarray:
    """Compute a greedy carbon-aware schedule. Returns start times [N]."""
    T = profile.T
    est = compute_est(inst)
    lst = compute_lst(inst, T)
    if (est > lst).any():
        raise ValueError("infeasible: deadline below ASAP makespan")

    order = task_order(inst, est, lst, score, weighted, platform)
    mask = candidate_mask(inst, profile, refined=refined, k=k)
    rem = profile.unit_budget(inst.idle_total).astype(np.int64).copy()

    start = np.zeros(inst.num_tasks, dtype=np.int64)
    scheduled = np.zeros(inst.num_tasks, dtype=bool)

    for v in order:
        a, b = int(est[v]), int(lst[v])
        cand = np.flatnonzero(mask[a:b + 1])
        if len(cand) == 0:
            s = a
        else:
            cand = cand + a
            # budget of the interval starting at candidate point t is rem[t];
            # argmax returns the first (earliest) maximum — the paper's tie
            # break.
            s = int(cand[np.argmax(rem[cand])])
        e = s + int(inst.dur[v])
        start[v] = s
        scheduled[v] = True
        # decrement budgets where the task runs; its endpoints split the
        # intervals, becoming candidate start points for later tasks.
        rem[s:e] -= int(inst.task_work[v])
        mask[s] = True
        if e <= T:
            mask[e] = True
        raise_est_from(inst, est, int(v), s, scheduled)
        lower_lst_from(inst, lst, int(v), s, scheduled)

    return start


def segment_state(inst: Instance, profile: PowerProfile,
                  refined: bool = False, k: int = 3, mask=None):
    """Initial (breakpoints, values) of the segment timeline.

    Breakpoints are exactly the candidate-mask points; the value at point
    ``p`` is the effective budget of the unit at ``p`` (constant on the
    segment up to the next breakpoint). ``mask`` optionally reuses a
    precomputed candidate mask (the profile overlay's bounds-keyed cache).
    """
    if mask is None:
        mask = candidate_mask(inst, profile, refined=refined, k=k)
    pts = np.flatnonzero(mask).astype(np.int64)
    g = profile.effective(inst.idle_total).astype(np.int64)
    seg = np.clip(np.searchsorted(profile.bounds, pts, side="right") - 1,
                  0, profile.J - 1)
    return pts, g[seg]


def adjacency_lists(inst: Instance) -> tuple[list[list[int]], list[list[int]]]:
    """(successor, predecessor) python adjacency — fast worklist iteration."""
    succ_l = [inst.succs(v).tolist() for v in range(inst.num_tasks)]
    pred_l = [inst.preds(v).tolist() for v in range(inst.num_tasks)]
    return succ_l, pred_l


def greedy_core_segments(inst: Instance, T: int, est: np.ndarray,
                         lst: np.ndarray, order: np.ndarray,
                         pts0: np.ndarray, vals0: np.ndarray,
                         adj: tuple[list[list[int]], list[list[int]]]
                         | None = None) -> np.ndarray:
    """Segment-list greedy over precomputed state (portfolio fast path).

    Inputs are not mutated (EST/LST evolve on private copies), so a
    :class:`~repro.core.portfolio.PreparedInstance` can hand the same arrays
    to every variant. Bit-identical to :func:`greedy_schedule`; the EST/LST
    worklist updates are the reference's, inlined over python adjacency.
    """
    N = inst.num_tasks
    cap = len(pts0) + 2 * N
    pts = np.empty(cap, dtype=np.int64)
    vals = np.empty(cap, dtype=np.int64)
    m = len(pts0)
    pts[:m] = pts0
    vals[:m] = vals0

    succ_l, pred_l = adj or adjacency_lists(inst)
    dur = inst.dur.tolist()
    work = inst.task_work.tolist()
    est_l = [int(x) for x in est]
    lst_l = [int(x) for x in lst]
    start = np.zeros(N, dtype=np.int64)
    scheduled = [False] * N
    searchsorted = np.searchsorted

    for v in order:
        v = int(v)
        a, b = est_l[v], lst_l[v]
        i0 = int(searchsorted(pts[:m], a))
        i1 = int(searchsorted(pts[:m], b, side="right"))
        if i0 == i1:
            s = a
            js = i0                                 # insertion slot of s
            s_present = False
        else:
            # budget of the interval starting at breakpoint p is vals[p];
            # argmax returns the first (earliest) maximum — the paper's tie
            # break.
            js = i0 + int(np.argmax(vals[i0:i1]))
            s = int(pts[js])
            s_present = True
        e = s + dur[v]
        start[v] = s
        scheduled[v] = True
        # the task's endpoints split their intervals (e only inside the
        # horizon), then every breakpoint it covers loses its work power.
        if not s_present:
            pts[js + 1:m + 1] = pts[js:m]           # overlap-safe right shift
            vals[js + 1:m + 1] = vals[js:m]
            pts[js] = s
            vals[js] = vals[js - 1] if js > 0 else 0   # pts[0]==0 covers s
            m += 1
        if e <= T:
            je = js + int(searchsorted(pts[js:m], e))
            if je == m or pts[je] != e:
                pts[je + 1:m + 1] = pts[je:m]
                vals[je + 1:m + 1] = vals[je:m]
                pts[je] = e
                vals[je] = vals[je - 1]             # je > js >= 0
                m += 1
        else:
            je = m - 1                              # pts[m-1] == T always
        vals[js:je] -= work[v]
        # pin v and propagate EST up / LST down (== raise_est_from /
        # lower_lst_from on the reference path, over python adjacency)
        if s > est_l[v]:
            est_l[v] = s
        stack = [v]
        while stack:
            u = stack.pop()
            ready = est_l[u] + dur[u]
            for t in succ_l[u]:
                if ready > est_l[t]:
                    est_l[t] = ready
                    if not scheduled[t]:
                        stack.append(t)
        if s < lst_l[v]:
            lst_l[v] = s
        stack = [v]
        while stack:
            u = stack.pop()
            lu = lst_l[u]
            for t in pred_l[u]:
                bound = lu - dur[t]
                if bound < lst_l[t]:
                    lst_l[t] = bound
                    if not scheduled[t]:
                        stack.append(t)

    return start


def greedy_schedule_segments(inst: Instance, profile: PowerProfile,
                             platform: Platform, score: str = "press",
                             weighted: bool = False, refined: bool = False,
                             k: int = 3) -> np.ndarray:
    """Segment-list greedy; same contract (and output) as
    :func:`greedy_schedule`."""
    T = profile.T
    est = compute_est(inst)
    lst = compute_lst(inst, T)
    if (est > lst).any():
        raise ValueError("infeasible: deadline below ASAP makespan")
    order = task_order(inst, est, lst, score, weighted, platform)
    pts0, vals0 = segment_state(inst, profile, refined=refined, k=k)
    return greedy_core_segments(inst, T, est, lst, order, pts0, vals0)
