"""Communication-enhanced DAG ``G_c`` and the scheduling Instance (paper §3).

Given a workflow, a fixed mapping (task -> processor) and a fixed per-
processor order, every cross-processor edge ``(u, v)`` becomes a fictional
communication task on the link processor of ``(proc(u), proc(v))``; chain
edges encode the fixed order on every (compute or link) processor.

The resulting ``Instance`` is the single input format of every algorithm in
this package: dense numpy arrays + CSR adjacency, integer time units.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cluster import Platform
from repro.workflows.generators import Workflow, topological_order


@dataclasses.dataclass(frozen=True)
class FixedMapping:
    """Fixed mapping + ordering, e.g. produced by HEFT (core/heft.py)."""

    proc: np.ndarray                 # [n] compute processor per task
    order: tuple[tuple[int, ...], ...]   # per compute proc: ordered task ids
    # per link id: ordered (u, v) workflow edges communicated on that link
    comm_order: dict[int, tuple[tuple[int, int], ...]]


@dataclasses.dataclass(frozen=True)
class Instance:
    """Scheduling instance on the communication-enhanced DAG G_c."""

    name: str
    num_tasks: int                   # N = n + |E'|
    num_workflow_tasks: int          # n (tasks 0..n-1 are original)
    dur: np.ndarray                  # [N] integer durations  (>= 1)
    proc: np.ndarray                 # [N] processor id (compute or link)
    task_work: np.ndarray            # [N] P_work of the task's processor
    # CSR adjacency of G_c
    pred_ptr: np.ndarray
    pred_idx: np.ndarray
    succ_ptr: np.ndarray
    succ_idx: np.ndarray
    proc_chains: tuple[tuple[int, ...], ...]  # per used proc: ordered tasks
    chain_proc_ids: np.ndarray       # processor id per chain
    idle_total: int                  # sum of P_idle over all P^2 processors
    topo: np.ndarray                 # [N] a topological order of G_c
    level: np.ndarray                # [N] longest-path level (for jnp relaxation)

    def preds(self, v: int) -> np.ndarray:
        return self.pred_idx[self.pred_ptr[v]:self.pred_ptr[v + 1]]

    def succs(self, v: int) -> np.ndarray:
        return self.succ_idx[self.succ_ptr[v]:self.succ_ptr[v + 1]]

    @property
    def total_work_power(self) -> int:
        return int(self.task_work.sum() * 0 + self.task_work.max(initial=0))

    def validate(self) -> None:
        assert (self.dur >= 1).all()
        assert len(self.topo) == self.num_tasks


def _csr(n: int, pairs: np.ndarray, by_col: bool) -> tuple[np.ndarray, np.ndarray]:
    """CSR of (u, v) pairs: by_col=True -> predecessors of v, else succs of u."""
    if len(pairs) == 0:
        return np.zeros(n + 1, dtype=np.int64), np.zeros(0, dtype=np.int64)
    key = pairs[:, 1] if by_col else pairs[:, 0]
    val = pairs[:, 0] if by_col else pairs[:, 1]
    order = np.argsort(key, kind="stable")
    key, val = key[order], val[order]
    ptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(ptr, key + 1, 1)
    np.cumsum(ptr, out=ptr)
    return ptr, val


def build_instance(wf: Workflow, mapping: FixedMapping,
                   platform: Platform, dur: np.ndarray | None = None,
                   name: str | None = None) -> Instance:
    """Build the communication-enhanced instance from workflow + mapping.

    ``dur`` optionally overrides computed running times (w / speed).
    """
    n = wf.n
    proc_n = np.asarray(mapping.proc, dtype=np.int64)
    if dur is None:
        dur_n = platform.exec_time(wf.node_w, proc_n)
    else:
        dur_n = np.asarray(dur, dtype=np.int64)

    # communication tasks for cross-processor edges, in comm_order
    comm_id: dict[tuple[int, int], int] = {}
    comm_dur: list[int] = []
    comm_proc: list[int] = []
    next_id = n
    chain_edges: list[tuple[int, int]] = []
    for link, pairs in sorted(mapping.comm_order.items()):
        prev = None
        for (u, v) in pairs:
            cid = next_id
            next_id += 1
            comm_id[(u, v)] = cid
            eidx = _edge_index(wf, u, v)
            comm_dur.append(max(int(wf.edge_w[eidx]), 1))
            comm_proc.append(link)
            if prev is not None:          # E'': fixed order on the link
                chain_edges.append((prev, cid))
            prev = cid

    N = next_id
    dur_all = np.concatenate([dur_n, np.asarray(comm_dur, dtype=np.int64)])
    proc_all = np.concatenate([proc_n, np.asarray(comm_proc, dtype=np.int64)])

    # edges of G_c
    edges: list[tuple[int, int]] = list(chain_edges)
    for (u, v), w in zip(wf.edges, wf.edge_w):
        u, v = int(u), int(v)
        if proc_n[u] == proc_n[v]:
            edges.append((u, v))
        else:
            cid = comm_id[(u, v)]
            edges.append((u, cid))
            edges.append((cid, v))
    # fixed order on compute processors
    for p, tasks in enumerate(mapping.order):
        for a, b in zip(tasks[:-1], tasks[1:]):
            edges.append((int(a), int(b)))

    e = np.unique(np.asarray(edges, dtype=np.int64).reshape(-1, 2), axis=0)
    pred_ptr, pred_idx = _csr(N, e, by_col=True)
    succ_ptr, succ_idx = _csr(N, e, by_col=False)

    # per-processor chains (compute procs from mapping.order, links from comm)
    chains: list[tuple[int, ...]] = []
    chain_pids: list[int] = []
    for p, tasks in enumerate(mapping.order):
        if tasks:
            chains.append(tuple(int(t) for t in tasks))
            chain_pids.append(p)
    for link, pairs in sorted(mapping.comm_order.items()):
        if pairs:
            chains.append(tuple(comm_id[(u, v)] for (u, v) in pairs))
            chain_pids.append(link)

    topo = np.asarray(topological_order(N, e), dtype=np.int64)
    assert len(topo) == N, "G_c has a cycle: mapping order conflicts with DAG"
    level = np.zeros(N, dtype=np.int64)
    for v in topo:
        ps = pred_idx[pred_ptr[v]:pred_ptr[v + 1]]
        if len(ps):
            level[v] = level[ps].max() + 1

    inst = Instance(
        name=name or wf.name,
        num_tasks=N,
        num_workflow_tasks=n,
        dur=dur_all,
        proc=proc_all,
        task_work=platform.p_work[proc_all],
        pred_ptr=pred_ptr, pred_idx=pred_idx,
        succ_ptr=succ_ptr, succ_idx=succ_idx,
        proc_chains=tuple(chains),
        chain_proc_ids=np.asarray(chain_pids, dtype=np.int64),
        idle_total=platform.idle_total,
        topo=topo,
        level=level,
    )
    inst.validate()
    return inst


def _edge_index(wf: Workflow, u: int, v: int) -> int:
    hits = np.flatnonzero((wf.edges[:, 0] == u) & (wf.edges[:, 1] == v))
    assert len(hits) >= 1
    return int(hits[0])


def trivial_mapping(wf: Workflow, platform: Platform,
                    by: str = "round_robin") -> FixedMapping:
    """Cheap mappings for tests: round-robin or all-on-one processor."""
    n = wf.n
    P = platform.num_compute
    topo = topological_order(n, wf.edges)
    if by == "single":
        proc = np.zeros(n, dtype=np.int64)
    else:
        proc = np.zeros(n, dtype=np.int64)
        for i, v in enumerate(topo):
            proc[v] = i % P
    order: list[list[int]] = [[] for _ in range(P)]
    for v in topo:
        order[proc[v]].append(int(v))
    comm_order: dict[int, list[tuple[int, int]]] = {}
    pos = {int(v): i for i, v in enumerate(topo)}
    for (u, v) in sorted(map(tuple, wf.edges), key=lambda p: (pos[p[0]], pos[p[1]])):
        if proc[u] != proc[v]:
            link = platform.link_id(int(proc[u]), int(proc[v]))
            comm_order.setdefault(link, []).append((int(u), int(v)))
    return FixedMapping(
        proc=proc,
        order=tuple(tuple(o) for o in order),
        comm_order={k: tuple(v) for k, v in comm_order.items()},
    )
