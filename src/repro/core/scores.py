"""Task scores (paper §5.2): slack, pressure, and power-weighted variants."""
from __future__ import annotations

import numpy as np

from repro.core.dag import Instance


def weight_factor(inst: Instance, platform) -> np.ndarray:
    """wf(i) = (P_idle^i + P_work^i) / max_j (P_idle^j + P_work^j), per task."""
    total = platform.p_idle + platform.p_work
    return total[inst.proc] / total.max()


def task_order(inst: Instance, est: np.ndarray, lst: np.ndarray,
               score: str, weighted: bool, platform) -> np.ndarray:
    """Processing order of tasks for the greedy (most urgent first).

    slack:    s(v) = LST - EST, sorted non-decreasing;
    pressure: rho(v) = w / (s + w), sorted non-increasing.
    Weighted versions multiply pressure by wf(i) and slack by 1/wf(i).
    Ties break by task id (the paper's "basic implementation without special
    tie-breaking").
    """
    slack = (lst - est).astype(np.float64)
    if score == "slack":
        val = slack
        if weighted:
            val = val / weight_factor(inst, platform)
        key = val                      # ascending
    elif score == "press":
        val = inst.dur / (slack + inst.dur)
        if weighted:
            val = val * weight_factor(inst, platform)
        key = -val                     # descending
    else:
        raise ValueError(f"unknown score {score!r}")
    return np.lexsort((np.arange(inst.num_tasks), key))
