"""HEFT (Topcuoglu et al. [35]) — produces the fixed mapping + ordering.

Basic implementation "without special techniques for tie-breaking" (paper
§6.1): upward ranks with mean execution/communication costs, then earliest-
finish-time processor selection with insertion. Communication order on each
link follows the communications' ready times (source finish times).
"""
from __future__ import annotations

import numpy as np

from repro.cluster import Platform
from repro.core.dag import FixedMapping
from repro.workflows.generators import Workflow, topological_order


def heft_mapping(wf: Workflow, platform: Platform) -> FixedMapping:
    n = wf.n
    P = platform.num_compute
    exec_t = np.ceil(wf.node_w[:, None] / platform.speed[None, :]).astype(np.int64)
    exec_t = np.maximum(exec_t, 1)
    mean_exec = exec_t.mean(axis=1)

    succs: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    preds: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (u, v), cw in zip(wf.edges, wf.edge_w):
        succs[int(u)].append((int(v), int(cw)))
        preds[int(v)].append((int(u), int(cw)))

    topo = topological_order(n, wf.edges)
    rank = np.zeros(n, dtype=np.float64)
    # mean comm cost: bandwidth 1, zero if same processor; expected over
    # uniformly random placement -> (P-1)/P * c. The basic variant just uses c.
    for v in reversed(topo):
        best = 0.0
        for (s, cw) in succs[v]:
            best = max(best, cw + rank[s])
        rank[v] = mean_exec[v] + best

    order_tasks = sorted(range(n), key=lambda v: (-rank[v], v))

    proc = np.full(n, -1, dtype=np.int64)
    aft = np.zeros(n, dtype=np.int64)          # actual finish time
    ast = np.zeros(n, dtype=np.int64)          # actual start time
    # busy slots per processor: sorted list of (start, end)
    slots: list[list[tuple[int, int]]] = [[] for _ in range(P)]

    for v in order_tasks:
        best = None
        for p in range(P):
            ready = 0
            for (u, cw) in preds[v]:
                arr = aft[u] + (cw if proc[u] != p else 0)
                ready = max(ready, int(arr))
            w = int(exec_t[v, p])
            # insertion policy: earliest hole >= ready of length w
            t = ready
            for (s0, e0) in slots[p]:
                if t + w <= s0:
                    break
                t = max(t, e0)
            eft = t + w
            if best is None or eft < best[0]:
                best = (eft, p, t)
        eft, p, t = best
        proc[v] = p
        ast[v] = t
        aft[v] = eft
        slots[p].append((t, eft))
        slots[p].sort()

    order: list[list[int]] = [[] for _ in range(P)]
    for p in range(P):
        tasks_p = [v for v in range(n) if proc[v] == p]
        tasks_p.sort(key=lambda v: (ast[v], v))
        order[p] = tasks_p

    comm_order: dict[int, list[tuple[int, int]]] = {}
    cross = [(int(u), int(v)) for (u, v) in wf.edges if proc[u] != proc[v]]
    cross.sort(key=lambda e: (aft[e[0]], ast[e[1]], e))
    for (u, v) in cross:
        link = platform.link_id(int(proc[u]), int(proc[v]))
        comm_order.setdefault(link, []).append((u, v))

    return FixedMapping(
        proc=proc,
        order=tuple(tuple(o) for o in order),
        comm_order={k: tuple(vs) for k, vs in comm_order.items()},
    )
