"""Refined interval subdivision (paper §5.2, "Subdivision of the intervals").

On each processor chain, every block of at most ``k`` consecutive tasks is
tentatively aligned to start or end at each original interval boundary; the
induced task start times become additional candidate interval boundaries.

Times are integers in ``[0, T]``, so the candidate set is returned as a
boolean mask over ``[0, T]`` (equivalent to the paper's sorted subdivision,
cheaper to maintain).
"""
from __future__ import annotations

import numpy as np

from repro.core.dag import Instance
from repro.core.carbon import PowerProfile


def candidate_mask(inst: Instance, profile: PowerProfile,
                   refined: bool, k: int = 3) -> np.ndarray:
    """Boolean mask over [0, T]: True where a task may be started."""
    T = profile.T
    mask = np.zeros(T + 1, dtype=bool)
    mask[np.clip(profile.bounds, 0, T)] = True
    if not refined:
        return mask
    bounds = profile.bounds.astype(np.int64)
    for chain in inst.proc_chains:
        durs = inst.dur[np.asarray(chain, dtype=np.int64)]
        pref = np.concatenate([[0], np.cumsum(durs)])       # [len+1]
        n = len(chain)
        for size in range(1, k + 1):
            if n < size:
                break
            # blocks (i .. i+size-1); member offset within the block starting
            # at i is pref[i+j] - pref[i]
            i = np.arange(n - size + 1)[:, None]
            j = np.arange(size)[None, :]
            off = pref[i + j] - pref[i]                     # [B, size]
            L = (pref[i + size] - pref[i])                  # [B, 1] block length
            # block starts at boundary e: member start = e + off
            p1 = bounds[None, None, :] + off[:, :, None]
            # block ends at boundary e: member start = e - (L - off)
            p2 = bounds[None, None, :] - (L - off)[:, :, None]
            pts = np.concatenate([p1.ravel(), p2.ravel()])
            pts = pts[(pts >= 0) & (pts <= T)]
            mask[pts] = True
    return mask
