"""Exact time-indexed ILP (paper §4.3 / Appendix A.4), solved with HiGHS.

Variables: binary start indicators ``s[v,t]`` (t in [0, T - w_v]) and
continuous brown-power ``bu[t] >= 0``. The paper's ``e``/``r``/``alpha``
variables and Big-M machinery are eliminated without changing the integer
optimum:

* running indicator  r(v,t) = sum_{tau in (t-w_v, t]} s[v,tau]  (linear);
* ``bu_t >= gamma_t - G_t`` with a min-objective pins bu_t to
  max(0, gamma_t - G_t) at any optimum, so no alpha/epsilon/M is needed;
* precedence uses the aggregated start-time form
  sum_t t*s[v,t] >= sum_t (t + w_u)*s[u,t], valid and integral-equivalent
  (weaker LP bound, dramatically fewer nonzeros than Eq. (12)).

Paper's own scope note applies: exact solves are only run on small
instances (<= ~200 tasks).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import scipy.sparse as sp
from scipy.optimize import LinearConstraint, milp

from repro import obs
from repro.core.cancel import checkpoint
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance


@dataclasses.dataclass
class ILPResult:
    cost: float
    start: np.ndarray
    status: int
    message: str
    # HiGHS dual bound: a valid lower bound on the optimal cost even when
    # the solve exits on time_limit/mip_gap; == cost at a proven optimum.
    lower_bound: float = float("-inf")
    mip_gap: float = float("nan")


def solve_ilp(inst: Instance, profile: PowerProfile,
              time_limit: float = 300.0, mip_gap: float = 0.0,
              cancel=None) -> ILPResult:
    # Cooperative cancellation: scipy's milp wrapper exposes no HiGHS
    # interrupt callback, so an in-flight MILP cannot be stopped from
    # outside — the token's deadline therefore CLAMPS time_limit before
    # the solve starts (the solve can never outlive the budget by more
    # than HiGHS's limit-check granularity), and the model build below
    # polls the token between row families.
    checkpoint(cancel)
    if cancel is not None and cancel.deadline is not None:
        time_limit = min(float(time_limit),
                         max(cancel.remaining() or 0.0, 0.1))
    build_span = obs.start_span("ilp_build", N=int(inst.num_tasks),
                                T=int(profile.T))
    try:
        return _build_and_solve(inst, profile, cancel, build_span,
                                time_limit, mip_gap)
    finally:
        build_span.end()      # idempotent: normal path already ended it


def _build_and_solve(inst: Instance, profile: PowerProfile, cancel,
                     build_span, time_limit: float,
                     mip_gap: float) -> ILPResult:
    N = inst.num_tasks
    T = profile.T
    dur = inst.dur
    w = inst.task_work.astype(np.float64)
    g_unit = profile.unit_budget(inst.idle_total).astype(np.float64)

    # variable layout: s[v, t] for t in [0, T - dur_v]  |  bu[t]
    offs = np.zeros(N + 1, dtype=np.int64)
    for v in range(N):
        n_t = T - int(dur[v]) + 1
        if n_t <= 0:
            raise ValueError("task longer than horizon")
        offs[v + 1] = offs[v] + n_t
    n_s = int(offs[N])
    n_var = n_s + T

    def svar(v: int, t: int) -> int:
        return int(offs[v]) + t

    rows, cols, vals = [], [], []
    lo, hi = [], []
    r = 0

    # (5)-(6): each task starts exactly once, in time
    for v in range(N):
        for t in range(T - int(dur[v]) + 1):
            rows.append(r); cols.append(svar(v, t)); vals.append(1.0)
        lo.append(1.0); hi.append(1.0)
        r += 1

    # precedence (aggregated start-time form), one row per edge of G_c
    checkpoint(cancel)
    for v in range(N):
        for u in inst.preds(v):
            u = int(u)
            for t in range(T - int(dur[v]) + 1):
                rows.append(r); cols.append(svar(v, t)); vals.append(float(t))
            for t in range(T - int(dur[u]) + 1):
                rows.append(r); cols.append(svar(u, t))
                vals.append(-float(t + int(dur[u])))
            lo.append(0.0); hi.append(np.inf)
            r += 1

    # power rows: bu_t - sum_v w_v * r(v,t) >= -g_unit[t]
    checkpoint(cancel)
    for t in range(T):
        rows.append(r); cols.append(n_s + t); vals.append(1.0)
        for v in range(N):
            if w[v] == 0:
                continue
            t_lo = max(0, t - int(dur[v]) + 1)
            t_hi = min(t, T - int(dur[v]))
            for tau in range(t_lo, t_hi + 1):
                rows.append(r); cols.append(svar(v, tau)); vals.append(-w[v])
        lo.append(-float(g_unit[t])); hi.append(np.inf)
        r += 1

    checkpoint(cancel)                    # last poll before the MILP
    A = sp.csr_matrix((vals, (rows, cols)), shape=(r, n_var))
    c = np.concatenate([np.zeros(n_s), np.ones(T)])
    integrality = np.concatenate([np.ones(n_s), np.zeros(T)])
    bounds_lo = np.zeros(n_var)
    bounds_hi = np.concatenate([np.ones(n_s), np.full(T, np.inf)])
    build_span.end(rows=int(r), n_var=int(n_var), nnz=len(vals))

    with obs.span("ilp_milp", N=int(N), T=int(T), rows=int(r),
                  time_limit=round(time_limit, 3)) as milp_span:
        res = milp(
            c,
            constraints=LinearConstraint(A, np.asarray(lo), np.asarray(hi)),
            integrality=integrality,
            bounds=(bounds_lo, bounds_hi),
            options={"time_limit": time_limit, "mip_rel_gap": mip_gap},
        )
        milp_span.set(status=int(res.status))
    obs.registry().counter(
        "ilp_solves_total", "HiGHS MILP solves, by exit status",
        labels=("status",)).inc(status=int(res.status))
    dual = getattr(res, "mip_dual_bound", None)
    gap = getattr(res, "mip_gap", None)
    if res.x is None:
        return ILPResult(cost=np.inf, start=np.zeros(N, dtype=np.int64),
                         status=res.status, message=res.message,
                         lower_bound=float(dual) if dual is not None
                         else float("-inf"),
                         mip_gap=float(gap) if gap is not None
                         else float("nan"))
    x = res.x[:n_s]
    start = np.zeros(N, dtype=np.int64)
    for v in range(N):
        seg = x[offs[v]:offs[v + 1]]
        start[v] = int(np.argmax(seg))
    # a proven optimum (status 0, no gap slack) certifies bound == cost
    lb = float(dual) if dual is not None else (
        float(res.fun) if res.status == 0 else float("-inf"))
    return ILPResult(cost=float(res.fun), start=start, status=res.status,
                     message=res.message, lower_bound=lb,
                     mip_gap=float(gap) if gap is not None
                     else float("nan"))
