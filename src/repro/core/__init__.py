"""CaWoSched core: the paper's contribution (scheduling on G_c)."""
from repro.core.cawosched import (  # noqa: F401
    ALL_VARIANTS,
    VARIANTS_BY_NAME,
    ScheduleResult,
    Variant,
    deadline_from_asap,
    schedule,
    schedule_reference,
)
from repro.core.carbon import (  # noqa: F401
    PowerProfile,
    SCENARIOS,
    generate_profile,
    schedule_cost,
    schedule_cost_jnp,
    validate_schedule,
)
from repro.core.cancel import Cancelled, CancelToken  # noqa: F401
from repro.core.dag import FixedMapping, Instance, build_instance, trivial_mapping  # noqa: F401
from repro.core.estlst import asap_schedule, compute_est, compute_lst, makespan  # noqa: F401
from repro.core.greedy_jax import (  # noqa: F401
    BlockedLP,
    LP_MAX_BYTES,
    longest_path_matrix,
    lp_block_bytes,
    lp_for,
    lp_matrix_bytes,
)
from repro.core.heft import heft_mapping  # noqa: F401
from repro.core.portfolio import (  # noqa: F401
    PORTFOLIO_VARIANTS,
    PreparedGraph,
    PreparedInstance,
    ProfileOverlay,
    overlay_profile,
    portfolio_cost_matrix,
    prepare_graph,
    prepare_instance,
    robust_pick,
    schedule_portfolio,
    schedule_portfolio_grid,
    schedule_portfolio_multi,
)
from repro.core.solvers import (  # noqa: F401
    SolveOutput,
    Solver,
    get_solver,
    register_solver,
    solver_names,
)
