"""Batched local search: the Pallas gain kernel proposes, exact math commits.

The paper's local search walks tasks sequentially and applies the first
improving +-mu shift. On TPU we instead evaluate *all* (task, shift) gains
at once with ``kernels.gain_scan`` (one kernel launch per round), then
commit proposals in gain order with exact re-evaluation (`move_gain`) —
re-evaluation is O(mu) per move, so commits are cheap while the O(N*mu*W)
sweep runs on device. Cost is monotonically non-increasing, like the paper's
hill climber; tests check both climbers against each other.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import PowerProfile, work_timeline
from repro.core.dag import Instance
from repro.core.local_search import apply_move, dyn_bounds, move_gain
from repro.kernels.ops import ls_gains


def local_search_batched(inst: Instance, profile: PowerProfile,
                         start: np.ndarray, mu: int = 10,
                         max_rounds: int = 200,
                         interpret: bool = True) -> np.ndarray:
    T = profile.T
    start = np.asarray(start, dtype=np.int64).copy()
    rem = (profile.unit_budget(inst.idle_total)
           - work_timeline(inst, T, start)).astype(np.int64)
    N = inst.num_tasks
    dur = inst.dur
    work = inst.task_work

    # edge arrays for vectorized dynamic bounds
    v_of_pred = np.repeat(np.arange(N), np.diff(inst.pred_ptr))
    u_pred = inst.pred_idx
    u_of_succ = np.repeat(np.arange(N), np.diff(inst.succ_ptr))
    v_succ = inst.succ_idx

    for _ in range(max_rounds):
        # dynamic legal start-time windows from the *current* schedule
        lo = np.zeros(N, dtype=np.int64)
        np.maximum.at(lo, v_of_pred, start[u_pred] + dur[u_pred])
        hi = np.full(N, np.iinfo(np.int64).max // 4, dtype=np.int64)
        np.minimum.at(hi, u_of_succ, start[v_succ])
        hi = np.minimum(hi - dur, T - dur)

        gains = np.asarray(ls_gains(
            rem.astype(np.float32), start.astype(np.float32),
            dur.astype(np.float32), work.astype(np.float32),
            lo.astype(np.float32), hi.astype(np.float32),
            mu=mu, interpret=interpret))

        best_delta = np.argmax(gains, axis=1) - mu
        best_gain = gains.max(axis=1)
        cand = np.flatnonzero(best_gain > 0)
        if len(cand) == 0:
            return start
        # commit in gain order; every commit re-validated exactly
        committed = False
        for v in cand[np.argsort(-best_gain[cand], kind="stable")]:
            v = int(v)
            s = int(start[v])
            e = s + int(dur[v])
            new_s = s + int(best_delta[v])
            dlo, dhi = dyn_bounds(inst, start, v, T)
            new_s = min(max(new_s, dlo), dhi)
            if new_s == s or dlo > dhi:
                continue
            g = move_gain(rem, s, e, new_s, int(work[v]))
            if g <= 0:
                continue
            apply_move(rem, s, e, new_s, int(work[v]))
            start[v] = new_s
            committed = True
        if not committed:
            return start
    return start
