"""Batched local search: the Pallas gain kernel proposes, exact math commits.

The paper's local search walks tasks sequentially and applies the first
improving +-mu shift. On TPU we instead evaluate *all* (task, shift) gains
at once with ``kernels.gain_scan`` (one kernel launch per round), then
commit proposals in gain order with exact re-evaluation (`move_gain`) —
re-evaluation is O(mu) per move, so commits are cheap while the O(N*mu*W)
sweep runs on device. Cost is monotonically non-increasing, like the paper's
hill climber; tests check both climbers against each other.

:func:`local_search_portfolio` is the portfolio engine's variant: the hill
climbs of ALL ``-LS`` variants advance together, one
``kernels.gain_scan_batched`` launch per round for the whole [V, N, 2mu+1]
gain tensor (instead of V launches), with per-variant exact commits;
variants that converge early are frozen in place until the rest finish.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import PowerProfile, work_timeline
from repro.core.dag import Instance
from repro.core.local_search import apply_move, dyn_bounds, move_gain
from repro.core.local_search import dyn_bounds_all as _dyn_windows
from repro.kernels.ops import ls_gains, ls_gains_batched


def _commit_round(inst, T, rem, start, gains, mu) -> bool:
    """Commit this round's kernel proposals in gain order, exactly."""
    dur = inst.dur
    work = inst.task_work
    best_delta = np.argmax(gains, axis=1) - mu
    best_gain = gains.max(axis=1)
    cand = np.flatnonzero(best_gain > 0)
    committed = False
    for v in cand[np.argsort(-best_gain[cand], kind="stable")]:
        v = int(v)
        s = int(start[v])
        e = s + int(dur[v])
        new_s = s + int(best_delta[v])
        dlo, dhi = dyn_bounds(inst, start, v, T)
        new_s = min(max(new_s, dlo), dhi)
        if new_s == s or dlo > dhi:
            continue
        g = move_gain(rem, s, e, new_s, int(work[v]))
        if g <= 0:
            continue
        apply_move(rem, s, e, new_s, int(work[v]))
        start[v] = new_s
        committed = True
    return committed


def local_search_batched(inst: Instance, profile: PowerProfile,
                         start: np.ndarray, mu: int = 10,
                         max_rounds: int = 200,
                         interpret: bool | None = None) -> np.ndarray:
    T = profile.T
    start = np.asarray(start, dtype=np.int64).copy()
    rem = (profile.unit_budget(inst.idle_total)
           - work_timeline(inst, T, start)).astype(np.int64)
    dur = inst.dur
    work = inst.task_work
    N = inst.num_tasks

    # edge arrays for vectorized dynamic bounds
    edges = (np.repeat(np.arange(N), np.diff(inst.pred_ptr)), inst.pred_idx,
             np.repeat(np.arange(N), np.diff(inst.succ_ptr)), inst.succ_idx)

    for _ in range(max_rounds):
        lo, hi = _dyn_windows(start, dur, T, edges)
        gains = np.asarray(ls_gains(
            rem.astype(np.float32), start.astype(np.float32),
            dur.astype(np.float32), work.astype(np.float32),
            lo.astype(np.float32), hi.astype(np.float32),
            mu=mu, interpret=interpret))
        if not _commit_round(inst, T, rem, start, gains, mu):
            return start
    return start


def local_search_portfolio(inst: Instance, profile: PowerProfile,
                           starts: np.ndarray, mu: int = 10,
                           max_rounds: int = 200,
                           interpret: bool | None = None,
                           ctx: dict | None = None) -> np.ndarray:
    """Hill-climb a whole portfolio of schedules of one instance at once.

    Args:
      starts: int [V, N] — one greedy schedule per ``-LS`` variant.
    Returns:
      int64 [V, N] improved schedules; each row's cost is monotonically
      non-increasing over rounds (same climber as
      :func:`local_search_batched`, fanned out over the variant axis with a
      single batched kernel launch per round).
    """
    T = profile.T
    starts = np.asarray(starts, dtype=np.int64).copy()
    V, N = starts.shape
    dur = inst.dur
    work = inst.task_work
    if ctx is not None:
        unit_budget = ctx["unit_budget"]
        edges = ctx["edges"]
    else:
        unit_budget = profile.unit_budget(inst.idle_total).astype(np.int64)
        edges = (np.repeat(np.arange(N), np.diff(inst.pred_ptr)),
                 inst.pred_idx,
                 np.repeat(np.arange(N), np.diff(inst.succ_ptr)),
                 inst.succ_idx)
    rems = np.stack([unit_budget - work_timeline(inst, T, starts[i])
                     for i in range(V)])
    active = np.ones(V, dtype=bool)

    for _ in range(max_rounds):
        lo = np.empty((V, N), dtype=np.int64)
        hi = np.empty((V, N), dtype=np.int64)
        for i in range(V):
            lo[i], hi[i] = _dyn_windows(starts[i], dur, T, edges)
        gains = np.asarray(ls_gains_batched(
            rems.astype(np.float32), starts.astype(np.float32),
            dur.astype(np.float32), work.astype(np.float32),
            lo.astype(np.float32), hi.astype(np.float32),
            mu=mu, interpret=interpret))
        for i in range(V):
            if active[i]:
                active[i] = _commit_round(inst, T, rems[i], starts[i],
                                          gains[i], mu)
        if not active.any():
            break
    return starts
