"""Batched local search: device-resident gain/commit rounds + exact polish.

The paper's local search walks tasks sequentially and applies the first
improving +-mu shift. The device climbers instead evaluate *all*
(task, shift) gains at once (``kernels.gain_scan``) and commit proposals in
gain order with exact integer re-evaluation. Two generations live here:

* :func:`local_search_batched` — the host-loop version: one gain launch
  per round, commits on host (``_commit_round``). One schedule at a time.
* :func:`local_search_portfolio` / :func:`local_search_portfolio_multi` —
  the portfolio engine's climber: ALL rows (``-LS`` variants x ensemble
  profiles) advance together, and the whole gain/commit round loop runs
  device-resident as ONE jitted ``lax.while_loop`` (gains via the jnp
  prefix-sum twin of the Pallas kernel, commits as an in-loop top-K scan
  with exact integer re-evaluation) — one host sync per hill climb, not
  one per round. Rows carry per-variant round budgets and deactivate
  individually when a round commits nothing.

After the device climb converges, every row is *polished* with the exact
sequential reference (:func:`repro.core.local_search.reference_round`)
until a full reference round commits nothing. Termination therefore
implies the sequential reference cannot improve the result either — no
variant stops earlier than its sequential reference would (tested), while
cost stays monotonically non-increasing throughout.
"""
from __future__ import annotations

import functools

import numpy as np

from repro import obs
from repro.core.cancel import checkpoint
from repro.core.carbon import PowerProfile, work_timeline
from repro.core.dag import Instance
from repro.core.local_search import apply_move, dyn_bounds, \
    ls_graph_context, move_gain, reference_round
from repro.core.local_search import dyn_bounds_all as _dyn_windows
from repro.kernels.ops import ls_gains

_COMMIT_K = 32       # default device commits per row per round
# (the rest wait a round; expose per call as LocalSearchConfig.commit_k)


def auto_commit_k(n_candidates: int,
                  lo: int = 8, hi: int = 128) -> int:
    """Pick the device commit width from instance gain density.

    The ROADMAP's "nothing *chooses* K" item, closed at the small end
    with a simple rule: one commit slot per ~4 candidate segments
    (``n_candidates`` = the instance's candidate-point count, the size of
    the greedy's segment skeleton), clamped to [lo, hi]. Dense-gain
    instances (many candidate segments -> many independent improving
    shifts per round) get wide commits and fewer device rounds; sparse
    instances stay narrow so one round's commits rarely invalidate each
    other. Any width keeps the termination guarantee — the
    sequential-reference polish runs regardless.
    """
    return int(np.clip(int(n_candidates) // 4, lo, hi))


def _commit_round(inst, T, rem, start, gains, mu) -> bool:
    """Commit this round's kernel proposals in gain order, exactly."""
    dur = inst.dur
    work = inst.task_work
    best_delta = np.argmax(gains, axis=1) - mu
    best_gain = gains.max(axis=1)
    cand = np.flatnonzero(best_gain > 0)
    committed = False
    for v in cand[np.argsort(-best_gain[cand], kind="stable")]:
        v = int(v)
        s = int(start[v])
        e = s + int(dur[v])
        new_s = s + int(best_delta[v])
        dlo, dhi = dyn_bounds(inst, start, v, T)
        new_s = min(max(new_s, dlo), dhi)
        if new_s == s or dlo > dhi:
            continue
        g = move_gain(rem, s, e, new_s, int(work[v]))
        if g <= 0:
            continue
        apply_move(rem, s, e, new_s, int(work[v]))
        start[v] = new_s
        committed = True
    return committed


def local_search_batched(inst: Instance, profile: PowerProfile,
                         start: np.ndarray, mu: int = 10,
                         max_rounds: int = 200,
                         interpret: bool | None = None,
                         cancel=None) -> np.ndarray:
    T = profile.T
    start = np.asarray(start, dtype=np.int64).copy()
    rem = (profile.unit_budget(inst.idle_total)
           - work_timeline(inst, T, start)).astype(np.int64)
    dur = inst.dur
    work = inst.task_work
    N = inst.num_tasks

    # edge arrays for vectorized dynamic bounds
    edges = (np.repeat(np.arange(N), np.diff(inst.pred_ptr)), inst.pred_idx,
             np.repeat(np.arange(N), np.diff(inst.succ_ptr)), inst.succ_idx)

    for _ in range(max_rounds):
        checkpoint(cancel)               # per-round cancellation rung
        lo, hi = _dyn_windows(start, dur, T, edges)
        gains = np.asarray(ls_gains(
            rem.astype(np.float32), start.astype(np.float32),
            dur.astype(np.float32), work.astype(np.float32),
            lo.astype(np.float32), hi.astype(np.float32),
            mu=mu, interpret=interpret))
        if not _commit_round(inst, T, rem, start, gains, mu):
            return start
    return start


# ---------------------------------------------------------------------------
# Device-resident portfolio climb
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _climb_impl(mu: int, max_rounds: int, commit_k: int = _COMMIT_K,
                padded: bool = False):
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.kernels.gain_scan import gains_windows_auto, gather_windows

    f32 = jnp.float32

    def climb_row(rem, start, t_real, dur, work, pred_a, succ_a):
        """One row's full hill climb: rounds loop on device, no host sync.

        rem int32 [T], start int32 [N]; pred_a/succ_a describe the direct
        G_c edges — bool [N, N] masks (``padded=False``, the dense form)
        or ``(idx [N, D], ok [N, D])`` padded-CSR gather tables
        (``padded=True``, the blocked big-instance form, bit-identical
        bounds); t_real = the real horizon (T may be padded).
        """
        T = rem.shape[0]
        tgrid = jnp.arange(T, dtype=jnp.int32)
        durf = dur.astype(f32)
        workf = work.astype(f32)

        if padded:
            pidx, pok = pred_a
            sidx, sok = succ_a

            def pred_lo(start):           # max over preds of start + dur
                return jnp.max(jnp.where(pok, (start + dur)[pidx], 0),
                               axis=-1)

            def succ_hi(start):           # min over succs of start
                return jnp.min(jnp.where(sok, start[sidx], t_real),
                               axis=-1)

            def pred_lo_v(start, v):
                return jnp.max(jnp.where(pok[v], (start + dur)[pidx[v]], 0))

            def succ_hi_v(start, v):
                return jnp.min(jnp.where(sok[v], start[sidx[v]], t_real))
        else:
            pred_mask, succ_mask = pred_a, succ_a

            def pred_lo(start):
                return jnp.max(
                    jnp.where(pred_mask, (start + dur)[None, :], 0), axis=1)

            def succ_hi(start):
                return jnp.min(
                    jnp.where(succ_mask, start[None, :], t_real), axis=1)

            def pred_lo_v(start, v):
                return jnp.max(jnp.where(pred_mask[v], start + dur, 0))

            def succ_hi_v(start, v):
                return jnp.min(jnp.where(succ_mask[v], start, t_real))

        def round_gains(rem, start):
            # round-start dynamic bounds, as in dyn_bounds_all
            lo = pred_lo(start)
            hi = succ_hi(start) - dur
            win_s, win_e = gather_windows(rem.astype(f32), start, dur, mu=mu)
            # mode-dispatched oracle: jnp prefix-sum twin on CPU, the
            # compiled tiled Pallas kernel on TPU (bit-identical paths)
            return gains_windows_auto(
                win_s, win_e, workf, durf,
                (lo - start).astype(f32), (hi - start).astype(f32), mu=mu)

        def commit_step(carry, v):
            rem, start, any_commit, best_delta, best_gain = carry
            s = start[v]
            d_v = dur[v]
            w_v = work[v]
            e = s + d_v
            # current-state legal bounds (commits earlier in this scan may
            # have moved neighbours), exactly _commit_round's clamp
            dlo = pred_lo_v(start, v)
            dhi = succ_hi_v(start, v) - d_v
            new_s = jnp.clip(s + best_delta[v], dlo, dhi)
            dd = new_s - s
            ln = jnp.minimum(jnp.abs(dd), d_v)
            # symmetric difference of old/new windows (move_gain identities)
            vac_lo = jnp.where(dd > 0, s, e - ln)
            occ_hi = jnp.where(dd > 0, new_s + d_v, new_s + ln)
            vac = (tgrid >= vac_lo) & (tgrid < vac_lo + ln)
            occ = (tgrid >= occ_hi - ln) & (tgrid < occ_hi)
            released = jnp.sum(jnp.where(
                vac, jnp.minimum(jnp.maximum(-rem, 0), w_v), 0))
            incurred = jnp.sum(jnp.where(
                occ, jnp.minimum(jnp.maximum(w_v - jnp.maximum(rem, 0), 0),
                                 w_v), 0))
            ok = ((best_gain[v] > 0) & (dlo <= dhi) & (dd != 0)
                  & (released - incurred > 0))
            old = (tgrid >= s) & (tgrid < e)
            new = (tgrid >= new_s) & (tgrid < new_s + d_v)
            rem = jnp.where(ok, rem + w_v * old.astype(rem.dtype)
                            - w_v * new.astype(rem.dtype), rem)
            start = jnp.where(ok, start.at[v].set(new_s), start)
            return (rem, start, any_commit | ok, best_delta, best_gain), None

        def round_body(state):
            rem, start, rounds, _ = state
            g = round_gains(rem, start)
            best_delta = jnp.argmax(g, axis=1).astype(jnp.int32) - mu
            best_gain = g.max(axis=1)
            order = jnp.argsort(-best_gain).astype(jnp.int32)
            k = min(commit_k, order.shape[0])
            carry = (rem, start, jnp.bool_(False), best_delta, best_gain)
            carry, _ = lax.scan(commit_step, carry, order[:k])
            return (carry[0], carry[1], rounds + 1, carry[2])

        def cond(state):
            return state[3] & (state[2] < max_rounds)

        state = (rem, start, jnp.int32(0), jnp.bool_(True))
        state = lax.while_loop(cond, round_body, state)
        # (starts, rounds): the round count is the climb's own
        # observability signal (obs `ls_device_rounds`), surfaced from the
        # device loop at no extra sync — the arrays come back together
        return state[1], state[2]

    rows = jax.vmap(climb_row,
                    in_axes=(0, 0, None, None, None, None, None))
    return jax.jit(rows)


def _dense_adjacency(inst: Instance, ctx: dict | None):
    """bool [N, N] (pred, succ) masks of the direct G_c edges, cached."""
    if ctx is not None and "adj_dense" in ctx:
        return ctx["adj_dense"]
    N = inst.num_tasks
    u = np.repeat(np.arange(N), np.diff(inst.succ_ptr))
    v = inst.succ_idx
    pred = np.zeros((N, N), dtype=bool)
    succ = np.zeros((N, N), dtype=bool)
    pred[v, u] = True
    succ[u, v] = True
    if ctx is not None:
        ctx["adj_dense"] = (pred, succ)
    return pred, succ


def _padded_adjacency(inst: Instance, ctx: dict | None):
    """Padded-CSR gather tables of the direct G_c edges, cached.

    Returns ``(pidx, pok, sidx, sok)``: int32/bool [N, D] with D the max
    degree bucketed up to a multiple of 8 (fewer distinct jit shapes
    across instances). O(N * D) memory — the blocked big-instance twin of
    :func:`_dense_adjacency`'s O(N^2) masks, cached under its own key so
    a graph serving both climb forms keeps both."""
    if ctx is not None and "adj_padded" in ctx:
        return ctx["adj_padded"]
    from repro.core.greedy_jax import _bucket_up

    N = inst.num_tasks
    pdeg = np.diff(inst.pred_ptr)
    sdeg = np.diff(inst.succ_ptr)
    D = _bucket_up(max(int(pdeg.max(initial=1)),
                       int(sdeg.max(initial=1)), 1), 8)
    pidx = np.zeros((N, D), dtype=np.int32)
    pok = np.zeros((N, D), dtype=bool)
    sidx = np.zeros((N, D), dtype=np.int32)
    sok = np.zeros((N, D), dtype=bool)
    r = np.repeat(np.arange(N), pdeg)
    c = np.arange(len(inst.pred_idx)) - np.repeat(inst.pred_ptr[:-1], pdeg)
    pidx[r, c] = inst.pred_idx
    pok[r, c] = True
    r = np.repeat(np.arange(N), sdeg)
    c = np.arange(len(inst.succ_idx)) - np.repeat(inst.succ_ptr[:-1], sdeg)
    sidx[r, c] = inst.succ_idx
    sok[r, c] = True
    out = (pidx, pok, sidx, sok)
    if ctx is not None:
        ctx["adj_padded"] = out
    return out


def local_search_portfolio_multi(inst: Instance, T: int,
                                 unit_budgets: np.ndarray,
                                 starts: np.ndarray, mu: int = 10,
                                 max_rounds: int = 200,
                                 interpret: bool | None = None,
                                 ctx: dict | None = None,
                                 polish: bool = True,
                                 commit_k: int | None = None,
                                 adjacency: str | None = None,
                                 cancel=None) -> np.ndarray:
    """Hill-climb a batch of schedule rows of one instance at once.

    The portfolio engine's climber: rows are any mix of ``-LS`` variants
    and ensemble profiles (each row has its own budget timeline). The whole
    round loop runs device-resident (ONE host sync), then each row is
    polished to sequential-reference local optimality with its own round
    budget.

    Args:
      unit_budgets: int [R, T] per-row effective budget timelines.
      starts:       int [R, N] one greedy schedule per row.
      interpret:    unused (the device loop's gain oracle is always the
        jnp prefix-sum twin); kept for climber-signature compatibility.
      ctx:          optional shared graph context (``ls_graph_context``;
        extra keys such as ``unit_budget`` are ignored).
      commit_k:     device commits per row per round (None = the module
        default ``_COMMIT_K``); any value yields the same termination
        guarantee — the sequential-reference polish runs regardless — but
        a profile-tuned K can cut device round counts on dense-gain
        instances.
      adjacency:    ``"dense"`` (None, the default) keeps the O(N^2) bool
        edge masks on device; ``"padded"`` uses the O(N * D) padded-CSR
        gather tables instead (:func:`_padded_adjacency`) — bit-identical
        bounds, the form the blocked-lp big-instance path uses so no
        dense N x N tensor exists anywhere in the climb.
      cancel:       optional :class:`repro.core.cancel.CancelToken`,
        polled before the device climb launch and between sequential
        polish rounds (the device ``while_loop`` itself is one
        uninterruptible launch bounded by ``max_rounds``).
    Returns:
      int64 [R, N] improved schedules; per-row cost is monotonically
      non-increasing, and no row terminates while a sequential reference
      round could still improve it.
    """
    import jax.numpy as jnp

    from repro.core.greedy_jax import N_BUCKET, T_BUCKET, _bucket_up

    if adjacency not in (None, "dense", "padded"):
        raise ValueError(f"unknown adjacency form {adjacency!r}")
    padded = adjacency == "padded"
    starts = np.asarray(starts, dtype=np.int64).copy()
    R, N = starts.shape
    unit_budgets = np.asarray(unit_budgets, dtype=np.int64)
    ctx = ctx if ctx is not None else ls_graph_context(inst)

    rems = unit_budgets - np.stack(
        [work_timeline(inst, T, starts[i]) for i in range(R)])

    # bucket-padded device inputs: padded tasks have work 0 (never legal),
    # padded rows repeat row 0 (computed, discarded), padded time units are
    # unreachable (moves clamp to the real horizon t_real)
    Np = _bucket_up(N, N_BUCKET)
    Tp = _bucket_up(T, T_BUCKET)
    Rp = _bucket_up(R, 8)
    rem_p = np.zeros((Rp, Tp), dtype=np.int32)
    rem_p[:R, :T] = rems
    rem_p[R:] = rem_p[0]
    start_p = np.zeros((Rp, Np), dtype=np.int32)
    start_p[:R, :N] = starts
    start_p[R:] = start_p[0]
    dur_p = np.zeros(Np, dtype=np.int32)
    dur_p[:N] = inst.dur
    work_p = np.zeros(Np, dtype=np.int32)
    work_p[:N] = inst.task_work
    if padded:
        pidx, pok, sidx, sok = _padded_adjacency(inst, ctx)
        D = pidx.shape[1]
        pidx_p = np.zeros((Np, D), dtype=np.int32)
        pidx_p[:N] = pidx
        pok_p = np.zeros((Np, D), dtype=bool)
        pok_p[:N] = pok
        sidx_p = np.zeros((Np, D), dtype=np.int32)
        sidx_p[:N] = sidx
        sok_p = np.zeros((Np, D), dtype=bool)
        sok_p[:N] = sok
        adj_args = ((jnp.asarray(pidx_p), jnp.asarray(pok_p)),
                    (jnp.asarray(sidx_p), jnp.asarray(sok_p)))
    else:
        pred, succ = _dense_adjacency(inst, ctx)
        pred_p = np.zeros((Np, Np), dtype=bool)
        pred_p[:N, :N] = pred
        succ_p = np.zeros((Np, Np), dtype=bool)
        succ_p[:N, :N] = succ
        adj_args = (jnp.asarray(pred_p), jnp.asarray(succ_p))

    checkpoint(cancel)                   # last rung before the device climb
    ck = _COMMIT_K if commit_k is None else int(commit_k)
    with obs.span("ls_device_climb", rows=int(R), N=int(N), T=int(T),
                  commit_k=ck, padded=padded) as climb_span:
        climbed, rounds_dev = _climb_impl(mu, max_rounds, ck, padded)(
            jnp.asarray(rem_p), jnp.asarray(start_p), jnp.int32(T),
            jnp.asarray(dur_p), jnp.asarray(work_p), *adj_args)
        climbed = np.asarray(climbed)
        rounds_dev = np.asarray(rounds_dev)[:R]
        climb_span.set(rounds_max=int(rounds_dev.max(initial=0)))
    rounds_hist = obs.registry().histogram(
        "ls_device_rounds", "device while_loop rounds per climb row",
        buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256), reservoir=256)
    for r in rounds_dev:
        rounds_hist.observe(int(r))
    starts = climbed[:R, :N].astype(np.int64)

    if polish:
        pad = mu
        polish_rounds = 0
        with obs.span("ls_polish", rows=int(R)) as polish_span:
            for i in range(R):
                rem_pad = np.zeros(T + 2 * pad, dtype=np.int64)
                rem_pad[pad:pad + T] = unit_budgets[i] - work_timeline(
                    inst, T, starts[i])
                budget = max_rounds               # per-variant round budget
                while budget > 0 and reference_round(inst, T, rem_pad, pad,
                                                     starts[i], mu, ctx):
                    budget -= 1
                    polish_rounds += 1
                    checkpoint(cancel)   # per-polish-round rung
            polish_span.set(rounds=polish_rounds)
        obs.registry().counter(
            "ls_polish_rounds_total",
            "sequential-reference polish rounds run after device climbs"
        ).inc(polish_rounds)
    return starts


def local_search_portfolio(inst: Instance, profile: PowerProfile,
                           starts: np.ndarray, mu: int = 10,
                           max_rounds: int = 200,
                           interpret: bool | None = None,
                           ctx: dict | None = None,
                           polish: bool = True,
                           commit_k: int | None = None,
                           adjacency: str | None = None,
                           cancel=None) -> np.ndarray:
    """Hill-climb a whole portfolio of schedules of one instance at once.

    Args:
      starts: int [V, N] — one greedy schedule per ``-LS`` variant.
    Returns:
      int64 [V, N] improved schedules (see
      :func:`local_search_portfolio_multi`; this is the single-profile
      slice of it).
    """
    starts = np.asarray(starts, dtype=np.int64)
    V = starts.shape[0]
    if ctx is not None and "unit_budget" in ctx:
        unit = np.asarray(ctx["unit_budget"], dtype=np.int64)
    else:
        unit = profile.unit_budget(inst.idle_total).astype(np.int64)
    budgets = np.broadcast_to(unit, (V, profile.T))
    return local_search_portfolio_multi(
        inst, profile.T, budgets, starts, mu=mu, max_rounds=max_rounds,
        interpret=interpret, ctx=ctx, polish=polish, commit_k=commit_k,
        adjacency=adjacency, cancel=cancel)
