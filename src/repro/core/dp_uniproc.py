"""Uniprocessor dynamic programs (paper §4.1, Appendix A.2).

* :func:`dp_pseudo` — the pseudo-polynomial DP over all t in [0, T]
  (Eq. (1)); oracle for tests.
* :func:`dp_poly` — the fully polynomial DP restricted to the E'-schedule
  end-time set of size O(n^3 J) (Lemma 4.2).

Both return (optimal cost, optimal start times). The instance must map all
tasks on one processor; the fixed order is the processor chain.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import PowerProfile
from repro.core.dag import Instance


def is_uniprocessor(inst: Instance) -> bool:
    """True when the fixed mapping is one processor chain covering every
    task AND all tasks share one work power — the §4.1 DP regime (the
    DP's cost prefix assumes a single active draw; a one-processor
    mapping gives uniform work by construction, the explicit check only
    guards hand-built instances). The dispatch test of ``solver="exact"``
    (:class:`repro.core.solvers.ExactSolver`): DP here, ILP otherwise."""
    chains = [c for c in inst.proc_chains if len(c)]
    if len(chains) != 1 or len(chains[0]) != inst.num_tasks:
        return False
    w = inst.task_work
    return bool((w == w[0]).all()) if len(w) else True


def _chain(inst: Instance) -> np.ndarray:
    if not is_uniprocessor(inst):
        raise ValueError("dp_uniproc requires a single processor chain "
                         "covering every task with one shared work power "
                         "(see is_uniprocessor)")
    return np.asarray([c for c in inst.proc_chains if len(c)][0],
                      dtype=np.int64)


def _unit_task_cost(inst: Instance, profile: PowerProfile) -> np.ndarray:
    """prefix[t] = cost of one active task during [0, t) (single processor)."""
    w = int(inst.task_work.max())
    assert (inst.task_work == w).all(), "single processor => single work power"
    g = profile.unit_budget(inst.idle_total)
    per_unit = np.maximum(w - g, 0)
    return np.concatenate([[0], np.cumsum(per_unit)])


def dp_pseudo(inst: Instance, profile: PowerProfile):
    """Pseudo-polynomial DP (Eq. (1)): Opt(i, t), t in [0, T]."""
    chain = _chain(inst)
    T = profile.T
    pref = _unit_task_cost(inst, profile)
    INF = np.iinfo(np.int64).max // 4

    durs = inst.dur[chain]
    n = len(chain)
    # opt[t] = best cost with tasks 0..i-1 done, task i-1 ending exactly at t
    prev = np.zeros(T + 1, dtype=np.int64)       # virtual task 0 ends anywhere
    prev_min = np.zeros(T + 1, dtype=np.int64)   # prefix-min over end times
    choice = np.full((n, T + 1), -1, dtype=np.int64)
    for i in range(n):
        w = int(durs[i])
        cur = np.full(T + 1, INF, dtype=np.int64)
        t = np.arange(int(durs[:i + 1].sum()), T + 1)
        if len(t):
            cc = pref[t] - pref[t - w]
            best_prev = prev_min[t - w]
            cur[t] = np.where(best_prev >= INF, INF, best_prev + cc)
        # argmin bookkeeping: earliest prefix-min position
        pos = np.zeros(T + 1, dtype=np.int64)
        best = prev[0]
        b_at = 0
        for tt in range(T + 1):
            if prev[tt] < best:
                best = prev[tt]
                b_at = tt
            pos[tt] = b_at
        if len(t):
            choice[i, t] = pos[t - w]
        prev = cur
        prev_min = np.minimum.accumulate(cur)
    best_t = int(np.argmin(prev))
    best_cost = int(prev[best_t])
    assert best_cost < INF, "infeasible deadline"
    # backtrack
    start = np.zeros(inst.num_tasks, dtype=np.int64)
    t = best_t
    for i in range(n - 1, -1, -1):
        v = int(chain[i])
        start[v] = t - int(durs[i])
        t = int(choice[i, t])
    return best_cost, start


def _candidate_end_times(inst: Instance, profile: PowerProfile,
                         chain: np.ndarray) -> list[np.ndarray]:
    """Appendix A.2: E'-aligned candidate end times per task, O(n^2 J) each."""
    T = profile.T
    E = profile.bounds
    durs = inst.dur[chain]
    n = len(chain)
    pref = np.concatenate([[0], np.cumsum(durs)])
    cands: list[set[int]] = [set() for _ in range(n)]
    for r in range(n):
        for s in range(r, n):
            # block chain[r..s]; u in block ends at:
            #   block starts at e: e + (pref[u+1] - pref[r])
            #   block ends at e:   e - (pref[s+1] - pref[u+1])
            for u in range(r, s + 1):
                off_s = int(pref[u + 1] - pref[r])
                off_e = int(pref[s + 1] - pref[u + 1])
                for e in E:
                    for t in (int(e) + off_s, int(e) - off_e):
                        if int(durs[u]) <= t <= T:
                            cands[u].add(t)
    return [np.asarray(sorted(c), dtype=np.int64) for c in cands]


def dp_poly(inst: Instance, profile: PowerProfile):
    """Fully polynomial DP over the restricted end-time set E' (Lemma 4.2)."""
    chain = _chain(inst)
    T = profile.T
    pref = _unit_task_cost(inst, profile)
    INF = np.iinfo(np.int64).max // 4
    durs = inst.dur[chain]
    n = len(chain)
    ends = _candidate_end_times(inst, profile, chain)

    prev_t = np.asarray([0], dtype=np.int64)     # end times of "task -1"
    prev_c = np.asarray([0], dtype=np.int64)
    back: list[np.ndarray] = []
    for i in range(n):
        w = int(durs[i])
        t = ends[i]
        # prefix-min of prev costs over non-decreasing end time
        pm = np.minimum.accumulate(prev_c)
        # earliest index achieving each prefix-min (for backtracking)
        arg = np.zeros(len(prev_c), dtype=np.int64)
        bi = 0
        for j in range(1, len(prev_c)):
            if prev_c[j] < prev_c[bi]:
                bi = j
            arg[j] = bi
        k = np.searchsorted(prev_t, t - w, side="right") - 1
        ok = k >= 0
        cost = np.full(len(t), INF, dtype=np.int64)
        cc = pref[t] - pref[t - w]
        cost[ok] = pm[k[ok]] + cc[ok]
        back.append(np.where(ok, arg[np.maximum(k, 0)], -1))
        keep = cost < INF
        prev_t, prev_c = t[keep], cost[keep]
        back[-1] = back[-1][keep]
        ends[i] = t[keep]
        if len(prev_t) == 0:
            raise ValueError("infeasible deadline")
    bi = int(np.argmin(prev_c))
    best_cost = int(prev_c[bi])
    start = np.zeros(inst.num_tasks, dtype=np.int64)
    idx = bi
    for i in range(n - 1, -1, -1):
        v = int(chain[i])
        start[v] = int(ends[i][idx]) - int(durs[i])
        idx = int(back[i][idx])
    return best_cost, start
