"""Portfolio scheduling engine: every CaWoSched variant of an instance in
one pass (paper §6's 17-algorithm experimental matrix as a single call).

The per-variant :func:`repro.core.cawosched.schedule` entry point pays the
shared per-instance work — EST/LST, candidate masks, score orders, the
budget timeline, local-search adjacency — once *per variant*. This engine
amortizes it once *per instance* and fans the variants out:

* :class:`PreparedInstance` — the amortized precompute. Contract: every
  field is a pure function of ``(inst, profile, platform, k)`` and is never
  mutated by the schedulers (greedy runs copy EST/LST internally; local
  search copies the budget timeline), so one object is shared by all 16
  variants, by local search, and by the jax fan-out, and may be cached
  across repeated ``schedule_portfolio`` calls.
* :func:`schedule_portfolio` — the numpy engine. Bit-identical to looping
  ``schedule()`` over variants (tests assert equality): the 8 unique greedy
  configurations run once each on the segment-list fast path
  (:func:`repro.core.greedy.greedy_core_segments`) and are shared by their
  plain and ``-LS`` variants; each ``-LS`` variant then runs the exact
  sequential local search with the shared :func:`ls_context`.
* ``engine="jax"`` — device fan-out: one jitted vmapped ``lax.scan``
  produces all greedy variants (:func:`repro.core.greedy_jax
  .greedy_fanout_jax`), and all ``-LS`` hill climbs advance together with
  ONE batched gain-kernel launch per round
  (:func:`repro.core.local_search_jax.local_search_portfolio`). Greedy
  starts are bit-identical to numpy; the batched hill climb is monotone but
  commits moves in gain order, so ``-LS`` costs may differ from the
  sequential reference.
* :func:`portfolio_starts_batch` — shape-bucketed instance batching: the
  scan core vmaps a second time over instances whose padded shapes match,
  so one jitted call schedules a whole bucket x all variants.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile, schedule_cost, validate_schedule
from repro.core.cawosched import ALL_VARIANTS, VARIANTS_BY_NAME, \
    ScheduleResult
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.greedy import adjacency_lists, greedy_core_segments, \
    segment_state
from repro.core.local_search import local_search, ls_context
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask

PORTFOLIO_VARIANTS: tuple[str, ...] = \
    ("asap",) + tuple(v.name for v in ALL_VARIANTS)

# the 8 unique greedy configurations behind the 16 variants
_COMBOS: tuple[tuple[str, bool, bool], ...] = tuple(
    (s, w, r) for s in ("slack", "press") for w in (False, True)
    for r in (False, True))


@dataclasses.dataclass
class PreparedInstance:
    """Amortized per-(instance, profile, platform, k) scheduling state."""

    inst: Instance
    profile: PowerProfile
    platform: Platform
    k: int
    est0: np.ndarray                  # [N] EST  (== the ASAP schedule)
    lst0: np.ndarray                  # [N] LST
    feasible: bool                    # est0 <= lst0 everywhere
    orders: dict                      # (score, weighted) -> int64 [N]
    masks: dict                       # refined -> bool [T+1] candidate mask
    segs: dict                        # refined -> (pts0, vals0) segment state
    adj: tuple                        # (succ_lists, pred_lists)
    ls: dict                          # ls_context() shared by -LS variants
    _buckets: tuple | None = None     # lazy level buckets (jax fan-out)

    def buckets(self):
        if self._buckets is None:
            from repro.core.greedy_jax import _level_buckets
            self._buckets = _level_buckets(self.inst)
        return self._buckets


def prepare_instance(inst: Instance, profile: PowerProfile,
                     platform: Platform, k: int = 3) -> PreparedInstance:
    """Run the shared precompute once; see :class:`PreparedInstance`."""
    T = profile.T
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    feasible = bool((est0 <= lst0).all())
    orders = {}
    if feasible:
        for score in ("slack", "press"):
            for weighted in (False, True):
                orders[(score, weighted)] = task_order(
                    inst, est0, lst0, score, weighted, platform)
    masks = {r: candidate_mask(inst, profile, refined=r, k=k)
             for r in (False, True)}
    segs = {r: segment_state(inst, profile, refined=r, k=k)
            for r in (False, True)}
    return PreparedInstance(
        inst=inst, profile=profile, platform=platform, k=k,
        est0=est0, lst0=lst0, feasible=feasible, orders=orders,
        masks=masks, segs=segs, adj=adjacency_lists(inst),
        ls=ls_context(inst, profile, platform))


def _greedy_starts_numpy(prep: PreparedInstance, combos) -> dict:
    """One segment-greedy run per unique (score, weighted, refined)."""
    out = {}
    for (score, weighted, refined) in combos:
        t0 = time.perf_counter()
        pts0, vals0 = prep.segs[refined]
        start = greedy_core_segments(
            prep.inst, prep.profile.T, prep.est0, prep.lst0,
            prep.orders[(score, weighted)], pts0, vals0, prep.adj)
        out[(score, weighted, refined)] = (start, time.perf_counter() - t0)
    return out


def _greedy_starts_jax(prep: PreparedInstance, combos) -> dict:
    """All unique greedy configurations in one vmapped device call."""
    from repro.core.greedy_jax import greedy_fanout_jax

    t0 = time.perf_counter()
    masks = np.stack([prep.masks[r] for (_, _, r) in combos])
    orders = np.stack([prep.orders[(s, w)] for (s, w, _) in combos])
    starts = np.asarray(greedy_fanout_jax(
        prep.inst, prep.profile, prep.est0, prep.lst0, masks, orders,
        prep.buckets()), dtype=np.int64)
    dt = (time.perf_counter() - t0) / max(len(combos), 1)
    return {c: (starts[i], dt) for i, c in enumerate(combos)}


def schedule_portfolio(inst: Instance, profile: PowerProfile,
                       platform: Platform, variants=None, k: int = 3,
                       mu: int = 10, validate: bool = True,
                       engine: str = "numpy",
                       prep: PreparedInstance | None = None
                       ) -> dict[str, ScheduleResult]:
    """Schedule all requested variants (default: asap + all 16) in one pass.

    ``engine="numpy"`` is bit-identical to the per-variant ``schedule()``
    loop; ``engine="jax"`` fans the greedy out on device and batches the
    local-search rounds (monotone, but ``-LS`` results may differ from the
    sequential reference). ``prep`` may be passed to reuse the precompute
    across calls (it must match ``(inst, profile, platform, k)``).
    """
    names = PORTFOLIO_VARIANTS if variants is None else tuple(variants)
    if prep is None:
        prep = prepare_instance(inst, profile, platform, k=k)
    if not prep.feasible and any(n != "asap" for n in names):
        raise ValueError("infeasible: deadline below ASAP makespan")

    need = []
    for name in names:
        if name == "asap":
            continue
        v = VARIANTS_BY_NAME[name]
        key = (v.score, v.weighted, v.refined)
        if key not in need:
            need.append(key)
    if engine == "numpy":
        greedy = _greedy_starts_numpy(prep, need)
    elif engine == "jax":
        greedy = _greedy_starts_jax(prep, need) if need else {}
    else:
        raise ValueError(f"unknown engine {engine!r}")

    out: dict[str, ScheduleResult] = {}
    ls_names = [n for n in names
                if n != "asap" and VARIANTS_BY_NAME[n].ls]
    ls_done: dict[str, tuple[np.ndarray, float]] = {}
    if engine == "jax" and ls_names:
        from repro.core.local_search_jax import local_search_portfolio
        t0 = time.perf_counter()
        keys = [VARIANTS_BY_NAME[n] for n in ls_names]
        stack = np.stack([greedy[(v.score, v.weighted, v.refined)][0]
                          for v in keys])
        improved = local_search_portfolio(inst, profile, stack, mu=mu,
                                          ctx=prep.ls)
        dt = (time.perf_counter() - t0) / len(ls_names)
        ls_done = {n: (improved[i], dt) for i, n in enumerate(ls_names)}

    for name in names:
        if name == "asap":
            t0 = time.perf_counter()
            start = prep.est0.copy()
            secs = time.perf_counter() - t0
        else:
            v = VARIANTS_BY_NAME[name]
            start, secs = greedy[(v.score, v.weighted, v.refined)]
            if v.ls:
                if name in ls_done:
                    ls_start, ls_secs = ls_done[name]
                    start, secs = ls_start, secs + ls_secs
                else:
                    t0 = time.perf_counter()
                    start = local_search(inst, profile, platform, start,
                                         mu=mu, ctx=prep.ls)
                    secs += time.perf_counter() - t0
        if validate:
            validate_schedule(inst, profile, start)
        out[name] = ScheduleResult(
            variant=name, start=start,
            cost=schedule_cost(inst, profile, start), seconds=secs)
    return out


# ---------------------------------------------------------------------------
# Shape-bucketed instance batching (jax engine, second vmap level)
# ---------------------------------------------------------------------------

def _shape_key(prep: PreparedInstance) -> tuple:
    (eu, _, _), (fu, _, _) = prep.buckets()
    return (prep.inst.num_tasks, prep.profile.T, eu.shape, fu.shape)


def portfolio_starts_batch(preps: list[PreparedInstance],
                           combos=_COMBOS) -> list[np.ndarray]:
    """Greedy starts for a batch of instances x all variants on device.

    Instances are grouped by padded shape key (N, T, level-bucket shapes);
    each group runs as ONE doubly-vmapped jitted call. Returns, aligned with
    ``preps``, int64 arrays of shape [len(combos), N].
    """
    import jax.numpy as jnp

    from repro.core.greedy_jax import _device_inputs, _impl

    results: list[np.ndarray | None] = [None] * len(preps)
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(preps):
        groups.setdefault(_shape_key(p), []).append(i)
    for idx in groups.values():
        rows = []
        for i in idx:
            p = preps[i]
            shared = _device_inputs(p.inst, p.profile, p.est0, p.lst0,
                                    p.buckets())
            masks = jnp.asarray(np.stack(
                [p.masks[r] for (_, _, r) in combos]))
            orders = jnp.asarray(np.stack(
                [p.orders[(s, w)] for (s, w, _) in combos]), jnp.int32)
            (dur, work, eu, ev, eok, fu, fv, fok, rem0, est_j, lst_j) = shared
            rows.append((dur, work, eu, ev, eok, fu, fv, fok,
                         rem0, masks, est_j, lst_j, orders))
        stacked = tuple(jnp.stack([r[a] for r in rows])
                        for a in range(13))
        starts = np.asarray(_impl()["batch"](*stacked), dtype=np.int64)
        for b, i in enumerate(idx):
            results[i] = starts[b]
    return results
