"""Portfolio scheduling engine: every CaWoSched variant of an instance,
against one carbon forecast or a whole ensemble of them, in one pass.

The precompute behind the paper's §6 17-algorithm matrix splits cleanly
along the profile axis, and this module's layering follows that split:

* :class:`PreparedGraph` — the profile-INDEPENDENT half, a pure function of
  ``(inst, platform, T, k)``: EST/LST, the four score orders, adjacency
  lists, the graph half of the local-search context, and (lazily) the
  longest-path matrix + padded device tensors of the jax fan-out. One graph
  serves every profile sharing the horizon ``T``.
* :class:`ProfileOverlay` — the cheap per-profile remainder: candidate
  masks and the segment skeleton (functions of the profile's interval
  *bounds*, cached on the graph so an ensemble sharing a grid pays them
  once), segment budget values and the per-unit budget timeline (functions
  of the profile's *budget*), and the completed local-search context.
* :class:`PreparedInstance` — graph + overlay glued back together; the
  amortized per-(instance, profile) state every scheduler consumes.
  Contract: no field is ever mutated by the schedulers (greedy runs copy
  EST/LST internally; local search copies the budget timeline), so one
  object is shared by all 16 variants, by local search, and by the jax
  fan-out, and may be cached across calls. ``prepare_graph(inst) +
  overlay_profile(profile)`` is bit-identical to
  ``prepare_instance(inst, profile)`` by construction (and by test).

Engines:

* :func:`schedule_portfolio` — the numpy engine. Bit-identical to looping
  ``schedule()`` over variants (tests assert equality): the 8 unique greedy
  configurations run once each on the segment-list fast path and are shared
  by their plain and ``-LS`` variants; each ``-LS`` variant then runs the
  exact sequential local search with the shared context.
* ``engine="jax"`` — device fan-out: one jitted vmapped ``lax.scan``
  produces all greedy variants (:func:`repro.core.greedy_jax
  .greedy_fanout_jax`, bit-identical to numpy), and all ``-LS`` hill climbs
  advance on device together (:func:`repro.core.local_search_jax
  .local_search_portfolio`: device-resident gain/commit rounds, then an
  exact sequential polish, so ``-LS`` costs may differ from — never trail —
  the batched reference's stopping point).
* :func:`schedule_portfolio_multi` — the replanning engine: one instance
  against N profiles (forecast ensemble members, rolling-horizon windows).
  Prepares the graph once, overlays each profile, and under ``engine="jax"``
  fans profiles x variants out as ONE device launch
  (:func:`repro.core.greedy_jax.greedy_fanout_multi_jax`) plus one batched
  hill climb over all (profile, ``-LS``-variant) rows. Per profile, results
  are bit-identical to calling :func:`schedule_portfolio` with the same
  engine on that profile alone.
* :func:`portfolio_starts_batch` — shape-bucketed instance batching: the
  scan core vmaps over instances whose padded shapes match, so one jitted
  call schedules a whole bucket x all variants.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile, schedule_cost, validate_schedule
from repro.core.cawosched import ALL_VARIANTS, VARIANTS_BY_NAME, \
    ScheduleResult
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.greedy import adjacency_lists, greedy_core_segments, \
    segment_state
from repro.core.local_search import local_search, ls_graph_context
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask

PORTFOLIO_VARIANTS: tuple[str, ...] = \
    ("asap",) + tuple(v.name for v in ALL_VARIANTS)

# the 8 unique greedy configurations behind the 16 variants
_COMBOS: tuple[tuple[str, bool, bool], ...] = tuple(
    (s, w, r) for s in ("slack", "press") for w in (False, True)
    for r in (False, True))


@dataclasses.dataclass
class PreparedGraph:
    """Profile-independent scheduling state of ``(inst, platform, T, k)``."""

    inst: Instance
    platform: Platform
    T: int
    k: int
    est0: np.ndarray                  # [N] EST  (== the ASAP schedule)
    lst0: np.ndarray                  # [N] LST
    feasible: bool                    # est0 <= lst0 everywhere
    orders: dict                      # lazy (score, weighted) -> int64 [N]
    adj: tuple                        # (succ_lists, pred_lists)
    ls_graph: dict                    # ls_graph_context() (no unit_budget)
    _masks: dict = dataclasses.field(default_factory=dict)
    _lp: np.ndarray | None = None     # lazy longest-path matrix (jax path)
    _shared: tuple | None = None      # lazy padded device tensors

    _MASK_CACHE = 8                   # bounds keys kept (FIFO)

    def masks_for(self, profile: PowerProfile) -> dict:
        """refined -> bool [T+1] candidate masks; cached by interval bounds
        (an ensemble of budget perturbations over one grid computes them
        once). The cache is bounded so a long-lived graph replanning over
        rolling grids does not grow without limit."""
        key = profile.bounds.tobytes()
        if key not in self._masks:
            while len(self._masks) >= self._MASK_CACHE:
                self._masks.pop(next(iter(self._masks)))
            self._masks[key] = {
                r: candidate_mask(self.inst, profile, refined=r, k=self.k)
                for r in (False, True)}
        return self._masks[key]

    def order_for(self, score: str, weighted: bool) -> np.ndarray:
        """The (score, weighted) task order, computed on first use (a
        pinned-variant caller pays for one order, not all four)."""
        if not self.feasible:
            raise ValueError("infeasible: deadline below ASAP makespan")
        key = (score, weighted)
        if key not in self.orders:
            self.orders[key] = task_order(
                self.inst, self.est0, self.lst0, score, weighted,
                self.platform)
        return self.orders[key]

    def lp(self) -> np.ndarray:
        if self._lp is None:
            from repro.core.greedy_jax import longest_path_matrix
            self._lp = longest_path_matrix(self.inst)
        return self._lp

    def shared(self):
        """Bucket-padded device tensors, resident across fan-out calls."""
        if self._shared is None:
            from repro.core.greedy_jax import padded_shared
            self._shared = padded_shared(self.inst, self.est0, self.lst0,
                                         self.lp())
        return self._shared


@dataclasses.dataclass
class ProfileOverlay:
    """Per-profile overlay completing a :class:`PreparedGraph`."""

    profile: PowerProfile
    masks: dict                       # refined -> bool [T+1] candidate mask
    segs: dict                        # refined -> (pts0, vals0) segment state
    unit_budget: np.ndarray           # int64 [T] effective per-unit budget
    ls: dict                          # completed ls_context()


def prepare_graph(inst: Instance, platform: Platform, T: int,
                  k: int = 3) -> PreparedGraph:
    """Run the profile-independent precompute once per (instance, horizon)."""
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    feasible = bool((est0 <= lst0).all())
    return PreparedGraph(
        inst=inst, platform=platform, T=T, k=k,
        est0=est0, lst0=lst0, feasible=feasible, orders={},
        adj=adjacency_lists(inst), ls_graph=ls_graph_context(inst, platform))


def overlay_profile(graph: PreparedGraph,
                    profile: PowerProfile) -> ProfileOverlay:
    """Complete ``graph`` for one profile; see :class:`ProfileOverlay`."""
    if profile.T != graph.T:
        raise ValueError(
            f"profile horizon {profile.T} != prepared horizon {graph.T}")
    masks = graph.masks_for(profile)
    segs = {r: segment_state(graph.inst, profile, mask=mask)
            for r, mask in masks.items()}
    unit_budget = profile.unit_budget(graph.inst.idle_total).astype(np.int64)
    ls = dict(graph.ls_graph)
    ls["unit_budget"] = unit_budget
    return ProfileOverlay(profile=profile, masks=masks, segs=segs,
                          unit_budget=unit_budget, ls=ls)


@dataclasses.dataclass
class PreparedInstance:
    """Amortized per-(instance, profile, platform, k) scheduling state.

    A thin composition of :class:`PreparedGraph` and
    :class:`ProfileOverlay`; the flat attribute surface (``est0``,
    ``orders``, ``masks``, ``ls``, ...) is kept for every scheduler and
    test that consumes the amortized state directly.
    """

    graph: PreparedGraph
    overlay: ProfileOverlay

    inst = property(lambda self: self.graph.inst)
    platform = property(lambda self: self.graph.platform)
    k = property(lambda self: self.graph.k)
    est0 = property(lambda self: self.graph.est0)
    lst0 = property(lambda self: self.graph.lst0)
    feasible = property(lambda self: self.graph.feasible)
    orders = property(lambda self: self.graph.orders)
    adj = property(lambda self: self.graph.adj)
    profile = property(lambda self: self.overlay.profile)
    masks = property(lambda self: self.overlay.masks)
    segs = property(lambda self: self.overlay.segs)
    ls = property(lambda self: self.overlay.ls)


def prepare_instance(inst: Instance, profile: PowerProfile,
                     platform: Platform, k: int = 3) -> PreparedInstance:
    """Graph + overlay in one call; see :class:`PreparedInstance`."""
    graph = prepare_graph(inst, platform, profile.T, k=k)
    return PreparedInstance(graph=graph,
                            overlay=overlay_profile(graph, profile))


def _greedy_starts_numpy(prep: PreparedInstance, combos) -> dict:
    """One segment-greedy run per unique (score, weighted, refined)."""
    out = {}
    for (score, weighted, refined) in combos:
        t0 = time.perf_counter()
        pts0, vals0 = prep.segs[refined]
        start = greedy_core_segments(
            prep.inst, prep.profile.T, prep.est0, prep.lst0,
            prep.graph.order_for(score, weighted), pts0, vals0, prep.adj)
        out[(score, weighted, refined)] = (start, time.perf_counter() - t0)
    return out


def _greedy_starts_jax(prep: PreparedInstance, combos) -> dict:
    """All unique greedy configurations in one vmapped device call."""
    from repro.core.greedy_jax import greedy_fanout_jax

    t0 = time.perf_counter()
    masks = np.stack([prep.masks[r] for (_, _, r) in combos])
    orders = np.stack([prep.graph.order_for(s, w) for (s, w, _) in combos])
    starts = np.asarray(greedy_fanout_jax(
        prep.inst, prep.profile, prep.est0, prep.lst0, masks, orders,
        shared=prep.graph.shared()), dtype=np.int64)
    dt = (time.perf_counter() - t0) / max(len(combos), 1)
    return {c: (starts[i], dt) for i, c in enumerate(combos)}


def _needed_combos(names) -> list[tuple[str, bool, bool]]:
    need = []
    for name in names:
        if name == "asap":
            continue
        v = VARIANTS_BY_NAME[name]
        key = (v.score, v.weighted, v.refined)
        if key not in need:
            need.append(key)
    return need


def _assemble(names, prep: PreparedInstance, greedy: dict, ls_done: dict,
              mu: int, validate: bool) -> dict[str, ScheduleResult]:
    """Finish a portfolio pass: -LS fallbacks, validation, costs."""
    out: dict[str, ScheduleResult] = {}
    for name in names:
        if name == "asap":
            t0 = time.perf_counter()
            start = prep.est0.copy()
            secs = time.perf_counter() - t0
        else:
            v = VARIANTS_BY_NAME[name]
            start, secs = greedy[(v.score, v.weighted, v.refined)]
            if v.ls:
                if name in ls_done:
                    ls_start, ls_secs = ls_done[name]
                    start, secs = ls_start, secs + ls_secs
                else:
                    t0 = time.perf_counter()
                    start = local_search(prep.inst, prep.profile,
                                         prep.platform, start, mu=mu,
                                         ctx=prep.ls)
                    secs += time.perf_counter() - t0
        if validate:
            validate_schedule(prep.inst, prep.profile, start)
        out[name] = ScheduleResult(
            variant=name, start=start,
            cost=schedule_cost(prep.inst, prep.profile, start), seconds=secs)
    return out


def schedule_portfolio(inst: Instance, profile: PowerProfile,
                       platform: Platform, variants=None, k: int = 3,
                       mu: int = 10, validate: bool = True,
                       engine: str = "numpy",
                       prep: PreparedInstance | None = None
                       ) -> dict[str, ScheduleResult]:
    """Schedule all requested variants (default: asap + all 16) in one pass.

    ``engine="numpy"`` is bit-identical to the per-variant ``schedule()``
    loop; ``engine="jax"`` fans the greedy out on device and batches the
    local-search rounds (monotone, polished to sequential-reference local
    optimality, but ``-LS`` results may differ from the sequential
    reference). ``prep`` may be passed to reuse the precompute across calls
    (it must match ``(inst, profile, platform, k)``).
    """
    names = PORTFOLIO_VARIANTS if variants is None else tuple(variants)
    if prep is None:
        prep = prepare_instance(inst, profile, platform, k=k)
    if not prep.feasible and any(n != "asap" for n in names):
        raise ValueError("infeasible: deadline below ASAP makespan")

    need = _needed_combos(names)
    if engine == "numpy":
        greedy = _greedy_starts_numpy(prep, need)
    elif engine == "jax":
        greedy = _greedy_starts_jax(prep, need) if need else {}
    else:
        raise ValueError(f"unknown engine {engine!r}")

    ls_names = [n for n in names
                if n != "asap" and VARIANTS_BY_NAME[n].ls]
    ls_done: dict[str, tuple[np.ndarray, float]] = {}
    if engine == "jax" and ls_names:
        from repro.core.local_search_jax import local_search_portfolio_multi
        t0 = time.perf_counter()
        keys = [VARIANTS_BY_NAME[n] for n in ls_names]
        stack = np.stack([greedy[(v.score, v.weighted, v.refined)][0]
                          for v in keys])
        budgets = np.broadcast_to(prep.overlay.unit_budget,
                                  (len(ls_names), profile.T))
        # ctx = the graph dict, so the dense-adjacency cache of the device
        # climb survives across profiles (the overlay's ls dict is a
        # per-profile copy)
        improved = local_search_portfolio_multi(
            inst, profile.T, budgets, stack, mu=mu, ctx=prep.graph.ls_graph)
        dt = (time.perf_counter() - t0) / len(ls_names)
        ls_done = {n: (improved[i], dt) for i, n in enumerate(ls_names)}

    return _assemble(names, prep, greedy, ls_done, mu, validate)


def schedule_portfolio_multi(inst: Instance, profiles, platform: Platform,
                             variants=None, k: int = 3, mu: int = 10,
                             validate: bool = True, engine: str = "numpy",
                             graph: PreparedGraph | None = None
                             ) -> list[dict[str, ScheduleResult]]:
    """One instance x N profiles x all variants; the replanning fan-out.

    The profile-independent precompute runs once; each profile only pays
    its overlay. Under ``engine="jax"`` ALL (profile, variant) greedy runs
    are one device launch and all (profile, ``-LS``-variant) hill climbs
    advance as one batched climb. Returns one ``{variant: ScheduleResult}``
    dict per profile, each bit-identical to ``schedule_portfolio(inst,
    profile_i, platform, engine=engine)``.
    """
    profiles = list(profiles)
    if not profiles:
        return []
    names = PORTFOLIO_VARIANTS if variants is None else tuple(variants)
    if graph is None:
        graph = prepare_graph(inst, platform, profiles[0].T, k=k)
    overlays = [overlay_profile(graph, p) for p in profiles]
    preps = [PreparedInstance(graph=graph, overlay=ov) for ov in overlays]
    if not graph.feasible and any(n != "asap" for n in names):
        raise ValueError("infeasible: deadline below ASAP makespan")

    if engine == "numpy":
        return [schedule_portfolio(inst, p.profile, platform,
                                   variants=names, k=k, mu=mu,
                                   validate=validate, prep=p)
                for p in preps]
    if engine != "jax":
        raise ValueError(f"unknown engine {engine!r}")

    from repro.core.greedy_jax import greedy_fanout_multi_jax
    from repro.core.local_search_jax import local_search_portfolio_multi

    need = _needed_combos(names)
    P = len(profiles)
    greedys: list[dict] = [{} for _ in range(P)]
    if need:
        t0 = time.perf_counter()
        budgets = np.stack([ov.unit_budget for ov in overlays])
        masks = np.stack([np.stack([ov.masks[r] for (_, _, r) in need])
                          for ov in overlays])
        orders = np.stack([graph.order_for(s, w) for (s, w, _) in need])
        starts = np.asarray(greedy_fanout_multi_jax(
            inst, graph.T, budgets, masks, orders,
            shared=graph.shared()), dtype=np.int64)
        dt = (time.perf_counter() - t0) / (P * len(need))
        for pi in range(P):
            greedys[pi] = {c: (starts[pi, i], dt)
                           for i, c in enumerate(need)}

    ls_names = [n for n in names
                if n != "asap" and VARIANTS_BY_NAME[n].ls]
    ls_dones: list[dict] = [{} for _ in range(P)]
    if ls_names:
        t0 = time.perf_counter()
        keys = [VARIANTS_BY_NAME[n] for n in ls_names]
        rows = np.stack([greedys[pi][(v.score, v.weighted, v.refined)][0]
                         for pi in range(P) for v in keys])
        row_budgets = np.stack([overlays[pi].unit_budget
                                for pi in range(P) for _ in keys])
        improved = local_search_portfolio_multi(
            inst, graph.T, row_budgets, rows, mu=mu, ctx=graph.ls_graph)
        dt = (time.perf_counter() - t0) / len(rows)
        for pi in range(P):
            ls_dones[pi] = {n: (improved[pi * len(keys) + i], dt)
                            for i, n in enumerate(ls_names)}

    return [_assemble(names, preps[pi], greedys[pi], ls_dones[pi], mu,
                      validate)
            for pi in range(P)]


def portfolio_cost_matrix(results, variants=None):
    """[P, V] cost matrix from :func:`schedule_portfolio_multi` output.

    Returns ``(costs, names)``; ``costs[p, v]`` is profile p's carbon cost
    under variant ``names[v]``. The robust (min over variants of max over
    profiles) pick is ``names[costs.max(axis=0).argmin()]``.
    """
    if not results:
        return np.zeros((0, 0), dtype=np.int64), ()
    names = tuple(variants) if variants is not None else tuple(results[0])
    costs = np.array([[res[n].cost for n in names] for res in results],
                     dtype=np.int64)
    return costs, names


def robust_pick(costs: np.ndarray, names) -> tuple[str, int]:
    """The min-max variant of an ensemble cost matrix.

    Returns ``(variant, worst_cost)``: the heuristic variant whose worst
    cost across the ensemble rows is smallest. The ``asap`` baseline only
    competes when it is the sole variant requested (a gate pinned to the
    baseline still gets a plan).
    """
    names = tuple(names)
    if not names or not len(costs):
        raise ValueError("empty cost matrix")
    heur = [i for i, n in enumerate(names) if n != "asap"] \
        or list(range(len(names)))
    worst = np.asarray(costs)[:, heur].max(axis=0)
    j = int(worst.argmin())
    return names[heur[j]], int(worst[j])


# ---------------------------------------------------------------------------
# Shape-bucketed instance batching (jax engine, second vmap level)
# ---------------------------------------------------------------------------

def _shape_key(prep: PreparedInstance) -> tuple:
    from repro.core.greedy_jax import pad_dims
    return pad_dims(prep.inst.num_tasks, prep.profile.T)


def portfolio_starts_batch(preps: list[PreparedInstance],
                           combos=_COMBOS) -> list[np.ndarray]:
    """Greedy starts for a batch of instances x all variants on device.

    Instances are grouped by padded shape bucket (:func:`repro.core
    .greedy_jax.pad_dims`); each group runs as ONE doubly-vmapped jitted
    call. Returns, aligned with ``preps``, int64 arrays of shape
    [len(combos), N_i].
    """
    import jax.numpy as jnp

    from repro.core.greedy_jax import _impl, pad_budget, pad_masks, \
        pad_orders

    results: list[np.ndarray | None] = [None] * len(preps)
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(preps):
        groups.setdefault(_shape_key(p), []).append(i)
    for (_, Tp), idx in groups.items():
        rows = []
        for i in idx:
            p = preps[i]
            dur, work, lp, est_j, lst_j, tail = p.graph.shared()
            masks = pad_masks(np.stack(
                [p.masks[r] for (_, _, r) in combos]), Tp)
            orders = pad_orders(np.stack(
                [p.graph.order_for(s, w) for (s, w, _) in combos]), tail)
            rem0 = pad_budget(
                p.profile.unit_budget(p.inst.idle_total), Tp)
            rows.append((dur, work, lp, jnp.asarray(rem0),
                         jnp.asarray(masks), est_j, lst_j,
                         jnp.asarray(orders)))
        stacked = tuple(jnp.stack([r[a] for r in rows])
                        for a in range(8))
        starts = np.asarray(_impl()["batch"](*stacked), dtype=np.int64)
        for b, i in enumerate(idx):
            results[i] = starts[b][:, :preps[i].inst.num_tasks]
    return results
