"""Portfolio scheduling engine: every CaWoSched variant of an instance,
against one carbon forecast or a whole ensemble of them, in one pass.

The precompute behind the paper's §6 17-algorithm matrix splits cleanly
along the profile axis, and this module's layering follows that split:

* :class:`PreparedGraph` — the profile-INDEPENDENT half, a pure function of
  ``(inst, platform, T, k)``: EST/LST, the four score orders, adjacency
  lists, the graph half of the local-search context, and (lazily) the
  longest-path relaxation + padded device tensors of the jax fan-out —
  the dense matrix when it fits ``lp_budget_bytes``, the streamed
  ``greedy_jax.BlockedLP`` form past it. One graph serves every profile
  sharing the horizon ``T``.
* :class:`ProfileOverlay` — the cheap per-profile remainder: candidate
  masks and the segment skeleton (functions of the profile's interval
  *bounds*, cached on the graph so an ensemble sharing a grid pays them
  once), segment budget values and the per-unit budget timeline (functions
  of the profile's *budget*), and the completed local-search context.
* :class:`PreparedInstance` — graph + overlay glued back together; the
  amortized per-(instance, profile) state every scheduler consumes.
  Contract: no field is ever mutated by the schedulers (greedy runs copy
  EST/LST internally; local search copies the budget timeline), so one
  object is shared by all 16 variants, by local search, and by the jax
  fan-out, and may be cached across calls. ``prepare_graph(inst) +
  overlay_profile(profile)`` is bit-identical to
  ``prepare_instance(inst, profile)`` by construction (and by test).

Engines and entry points:

* :func:`schedule_portfolio_grid` — THE scheduling pass: an I x P x V
  (instances x profiles x variants) grid in one call, every request shape
  of the public surface normalizes to it. ``engine="numpy"`` runs the 8
  unique greedy configurations once per cell on the segment-list fast path
  (bit-identical to looping ``schedule()`` over variants) and the exact
  sequential local search for each ``-LS`` variant; ``engine="jax"``
  launches the greedy fan-out ONCE per padded shape bucket — all
  (instance, profile, variant) rows of a bucket ride one triple-vmapped
  ``lax.scan`` — and advances each instance's (profile, ``-LS``-variant)
  rows as one device-resident batched hill climb
  (:func:`repro.core.local_search_jax.local_search_portfolio_multi`:
  gain/commit rounds on device, then an exact sequential polish, so
  ``-LS`` costs may differ from — never trail — the sequential
  reference's stopping point).
* :func:`schedule_portfolio` / :func:`schedule_portfolio_multi` — legacy
  single-instance slices of the grid, kept as thin deprecation shims over
  :class:`repro.api.Planner` (property-tested bit-identical per engine).
* :func:`portfolio_starts_batch` — shape-bucketed instance batching of the
  greedy starts alone (the second vmap level, no assembly).

:class:`repro.api.Planner` is the typed facade over this module:
``PlanRequest -> PlanResult`` with graph caching, ``engine="auto"``
resolution, and the async rolling-horizon ``PlanningSession``.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.cluster import Platform
from repro.core.cancel import checkpoint
from repro.core.carbon import PowerProfile, schedule_cost, validate_schedule
from repro.core.cawosched import ALL_VARIANTS, VARIANTS_BY_NAME, \
    ScheduleResult
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.greedy import adjacency_lists, greedy_core_segments, \
    segment_state
from repro.core.local_search import local_search, ls_graph_context
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask

PORTFOLIO_VARIANTS: tuple[str, ...] = \
    ("asap",) + tuple(v.name for v in ALL_VARIANTS)

# the 8 unique greedy configurations behind the 16 variants
_COMBOS: tuple[tuple[str, bool, bool], ...] = tuple(
    (s, w, r) for s in ("slack", "press") for w in (False, True)
    for r in (False, True))


@dataclasses.dataclass
class PreparedGraph:
    """Profile-independent scheduling state of ``(inst, platform, T, k)``."""

    inst: Instance
    platform: Platform
    T: int
    k: int
    est0: np.ndarray                  # [N] EST  (== the ASAP schedule)
    lst0: np.ndarray                  # [N] LST
    feasible: bool                    # est0 <= lst0 everywhere
    orders: dict                      # lazy (score, weighted) -> int64 [N]
    adj: tuple                        # (succ_lists, pred_lists)
    lp_budget_bytes: int | None = None   # None -> greedy_jax.LP_MAX_BYTES
    _ls_graph: dict | None = None     # lazy ls_graph_context()
    _masks: dict = dataclasses.field(default_factory=dict)
    _lp: object | None = None         # lazy dense matrix OR BlockedLP
    _shared: tuple | None = None      # lazy padded device tensors

    _MASK_CACHE = 8                   # bounds keys kept (FIFO)

    @property
    def ls_graph(self) -> dict:
        """ls_graph_context() (no unit_budget), computed on first use (a
        request with no ``-LS`` variant never pays for it)."""
        if self._ls_graph is None:
            self._ls_graph = ls_graph_context(self.inst, self.platform)
        return self._ls_graph

    def masks_for(self, profile: PowerProfile,
                  refined_values=(False, True)) -> dict:
        """refined -> bool [T+1] candidate masks; cached by interval bounds
        (an ensemble of budget perturbations over one grid computes them
        once), and only for the requested ``refined_values`` (a pinned
        single-variant caller pays for one mask, not two). The cache is
        bounded so a long-lived graph replanning over rolling grids does
        not grow without limit."""
        key = profile.bounds.tobytes()
        if key not in self._masks:
            while len(self._masks) >= self._MASK_CACHE:
                self._masks.pop(next(iter(self._masks)))
            self._masks[key] = {}
        masks = self._masks[key]
        for r in refined_values:
            if r not in masks:
                masks[r] = candidate_mask(self.inst, profile, refined=r,
                                          k=self.k)
        return masks

    def order_for(self, score: str, weighted: bool) -> np.ndarray:
        """The (score, weighted) task order, computed on first use (a
        pinned-variant caller pays for one order, not all four)."""
        if not self.feasible:
            raise ValueError("infeasible: deadline below ASAP makespan")
        key = (score, weighted)
        if key not in self.orders:
            self.orders[key] = task_order(
                self.inst, self.est0, self.lst0, score, weighted,
                self.platform)
        return self.orders[key]

    def lp(self):
        """The longest-path relaxation of the jax path: the dense matrix
        when it fits ``lp_budget_bytes``
        (:func:`repro.kernels.backend.resolve_lp_form`), else a streamed
        :class:`repro.core.greedy_jax.BlockedLP` handle — the fan-outs
        accept either."""
        if self._lp is None:
            from repro.core.greedy_jax import lp_for
            self._lp = lp_for(self.inst, self.lp_budget_bytes)
        return self._lp

    @property
    def lp_is_blocked(self) -> bool:
        """Whether the jax path streams this graph's longest paths in
        blocks (the big-instance form) instead of holding the dense
        matrix on device."""
        from repro.core.greedy_jax import BlockedLP
        return isinstance(self.lp(), BlockedLP)

    def shared(self):
        """Bucket-padded device tensors, resident across fan-out calls."""
        if self._shared is None:
            from repro.core.greedy_jax import padded_shared
            self._shared = padded_shared(self.inst, self.est0, self.lst0,
                                         self.lp())
        return self._shared


@dataclasses.dataclass
class ProfileOverlay:
    """Per-profile overlay completing a :class:`PreparedGraph`."""

    profile: PowerProfile
    masks: dict                       # refined -> bool [T+1] candidate mask
    segs: dict                        # refined -> (pts0, vals0) segment state
    unit_budget: np.ndarray           # int64 [T] effective per-unit budget
    graph: PreparedGraph | None = None
    _ls: dict | None = None           # lazy completed ls_context()

    @property
    def ls(self) -> dict:
        """Completed ls_context(): the graph context + this profile's
        budget timeline, built on first use (non-``-LS`` requests skip
        the graph-context precompute entirely)."""
        if self._ls is None:
            ls = dict(self.graph.ls_graph)
            ls["unit_budget"] = self.unit_budget
            self._ls = ls
        return self._ls


def prepare_graph(inst: Instance, platform: Platform, T: int,
                  k: int = 3,
                  lp_budget_bytes: int | None = None) -> PreparedGraph:
    """Run the profile-independent precompute once per (instance, horizon).

    ``lp_budget_bytes`` bounds the jax path's longest-path memory (None =
    :data:`repro.core.greedy_jax.LP_MAX_BYTES`); instances whose dense
    matrix exceeds it stream through the blocked form instead of failing.
    """
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    feasible = bool((est0 <= lst0).all())
    return PreparedGraph(
        inst=inst, platform=platform, T=T, k=k,
        est0=est0, lst0=lst0, feasible=feasible, orders={},
        adj=adjacency_lists(inst), lp_budget_bytes=lp_budget_bytes)


def overlay_profile(graph: PreparedGraph, profile: PowerProfile,
                    refined_values=(False, True)) -> ProfileOverlay:
    """Complete ``graph`` for one profile; see :class:`ProfileOverlay`.

    ``refined_values`` restricts the candidate-mask/segment precompute to
    the interval subdivisions the caller's variants actually use (the
    grid passes the needed set; an asap-only request skips both).
    """
    if profile.T != graph.T:
        raise ValueError(
            f"profile horizon {profile.T} != prepared horizon {graph.T}")
    masks = graph.masks_for(profile, refined_values)
    segs = {r: segment_state(graph.inst, profile, mask=masks[r])
            for r in refined_values}
    unit_budget = profile.unit_budget(graph.inst.idle_total).astype(np.int64)
    return ProfileOverlay(profile=profile, masks=masks, segs=segs,
                          unit_budget=unit_budget, graph=graph)


@dataclasses.dataclass
class PreparedInstance:
    """Amortized per-(instance, profile, platform, k) scheduling state.

    A thin composition of :class:`PreparedGraph` and
    :class:`ProfileOverlay`; the flat attribute surface (``est0``,
    ``orders``, ``masks``, ``ls``, ...) is kept for every scheduler and
    test that consumes the amortized state directly.
    """

    graph: PreparedGraph
    overlay: ProfileOverlay

    inst = property(lambda self: self.graph.inst)
    platform = property(lambda self: self.graph.platform)
    k = property(lambda self: self.graph.k)
    est0 = property(lambda self: self.graph.est0)
    lst0 = property(lambda self: self.graph.lst0)
    feasible = property(lambda self: self.graph.feasible)
    orders = property(lambda self: self.graph.orders)
    adj = property(lambda self: self.graph.adj)
    profile = property(lambda self: self.overlay.profile)
    masks = property(lambda self: self.overlay.masks)
    segs = property(lambda self: self.overlay.segs)
    ls = property(lambda self: self.overlay.ls)


def prepare_instance(inst: Instance, profile: PowerProfile,
                     platform: Platform, k: int = 3) -> PreparedInstance:
    """Graph + overlay in one call; see :class:`PreparedInstance`."""
    graph = prepare_graph(inst, platform, profile.T, k=k)
    return PreparedInstance(graph=graph,
                            overlay=overlay_profile(graph, profile))


def _greedy_starts_numpy(prep: PreparedInstance, combos) -> dict:
    """One segment-greedy run per unique (score, weighted, refined)."""
    out = {}
    for (score, weighted, refined) in combos:
        t0 = time.perf_counter()
        pts0, vals0 = prep.segs[refined]
        start = greedy_core_segments(
            prep.inst, prep.profile.T, prep.est0, prep.lst0,
            prep.graph.order_for(score, weighted), pts0, vals0, prep.adj)
        out[(score, weighted, refined)] = (start, time.perf_counter() - t0)
    return out


def jit_entries_total() -> int:
    """Total compiled signatures across the engine's jit launchers —
    sampled before/after a bucket launch, the delta IS the retrace count
    the bench used to assert by hand (and the mapping search records per
    evaluation batch to prove candidates ride the cached launch)."""
    from repro.obs import jax_hooks
    return sum(jax_hooks.jit_cache_entries().values())


_jit_entries_total = jit_entries_total


def _needed_combos(names) -> list[tuple[str, bool, bool]]:
    need = []
    for name in names:
        if name == "asap":
            continue
        v = VARIANTS_BY_NAME[name]
        key = (v.score, v.weighted, v.refined)
        if key not in need:
            need.append(key)
    return need


def _assemble(names, prep: PreparedInstance, greedy: dict, ls_done: dict,
              mu: int, validate: bool,
              cancel=None) -> dict[str, ScheduleResult]:
    """Finish a portfolio pass: -LS fallbacks, validation, costs."""
    checkpoint(cancel)    # per-cell rung (numpy -LS climbs run below)
    out: dict[str, ScheduleResult] = {}
    for name in names:
        if name == "asap":
            t0 = time.perf_counter()
            start = prep.est0.copy()
            secs = time.perf_counter() - t0
        else:
            v = VARIANTS_BY_NAME[name]
            start, secs = greedy[(v.score, v.weighted, v.refined)]
            if v.ls:
                if name in ls_done:
                    ls_start, ls_secs = ls_done[name]
                    start, secs = ls_start, secs + ls_secs
                else:
                    t0 = time.perf_counter()
                    start = local_search(prep.inst, prep.profile,
                                         prep.platform, start, mu=mu,
                                         ctx=prep.ls)
                    secs += time.perf_counter() - t0
        if validate:
            validate_schedule(prep.inst, prep.profile, start)
        out[name] = ScheduleResult(
            variant=name, start=start,
            cost=schedule_cost(prep.inst, prep.profile, start), seconds=secs)
    return out


def schedule_portfolio_grid(instances, profile_grid, platform: Platform,
                            variants=None, k: int = 3, mu: int = 10,
                            validate: bool = True, engine: str = "numpy",
                            graphs=None,
                            commit_k: int | str | None = None,
                            ls_max_rounds: int = 200,
                            lp_budget_bytes: int | None = None,
                            cancel=None,
                            devices: int | None = None
                            ) -> list[list[dict[str, ScheduleResult]]]:
    """THE (instances x profiles x variants) scheduling pass.

    Every request shape of the public surface — one variant of one
    instance, the full 17-variant portfolio, a forecast ensemble, a whole
    instance suite x ensemble grid — runs through this one function; the
    legacy entry points and :meth:`repro.api.Planner.plan` are shims over
    it. ``profile_grid[i]`` lists instance i's profiles; every instance
    carries the same number P of profiles (the dense result grid), and an
    instance's profiles share its horizon T (horizons may differ across
    instances).

    Returns an I x P nested list of ``{variant: ScheduleResult}`` dicts;
    each cell is bit-identical to the historical single-cell
    ``schedule_portfolio(instances[i], profile_grid[i][p], ...)`` on the
    same engine (property-tested).

    Engines: ``"numpy"`` runs the segment-list greedy + exact sequential
    local search per cell. ``"jax"`` launches the greedy fan-out ONCE per
    padded shape bucket (:func:`repro.core.greedy_jax.pad_dims`) — all
    (instance, profile, variant) rows of a bucket ride one triple-vmapped
    device call — and advances each instance's (profile, ``-LS``-variant)
    rows as one batched device-resident hill climb (committing up to
    ``commit_k`` proposals per row per round; ``"auto"`` scales the width
    with the instance's candidate-segment count via
    :func:`repro.core.local_search_jax.auto_commit_k`), polished to
    sequential-reference local optimality.

    ``lp_budget_bytes`` (None = ``greedy_jax.LP_MAX_BYTES``) bounds the
    jax engine's per-instance longest-path memory: instances whose dense
    O(N^2) matrix fits ride the resident fast path; bigger ones stream
    the blocked form (``greedy_jax.BlockedLP`` fan-out + padded-CSR
    climb adjacency) bit-identically, so big instances schedule instead
    of raising ``MemoryError``. Applies to graphs built here — prebuilt
    ``graphs`` carry their own budget.

    In the solver registry (:mod:`repro.core.solvers`) this pass is the
    ``"heuristic"`` backend — one of several solvers behind
    ``PlanRequest(solver=...)``, alongside the exact DP/ILP oracles and
    the asap baseline.

    ``cancel`` (an optional :class:`repro.core.cancel.CancelToken`) is
    polled between greedy cells (numpy) / device bucket launches (jax)
    and before every per-instance local-search climb, so a cancelled
    grid stops within one chunk of work instead of finishing I x P x V.

    ``devices`` shards the jax engine's combined bucket launch over that
    many devices (``shard_map`` over the instance-row axis, see
    :func:`repro.core.greedy_jax.greedy_fanout_grid_jax`); None / 1 is
    the single-device launch. Bitwise-identical results either way.

    Rows whose ``(instance, profile row)`` repeats earlier entries BY
    IDENTITY (e.g. the mapping search's candidate-bucket pad rows, which
    repeat the last candidate object) are deduped host-side: graphs,
    overlays, local-search climbs, assembly, and validation run once per
    unique row, and duplicates alias the results. The padded device
    launch keeps its bucket shape — vmap cost is set by shape, and
    shrinking the row count would compile a fresh jit signature per
    batch size — so only the per-row host work is eliminated.
    """
    if engine not in ("numpy", "jax"):
        raise ValueError(f"unknown engine {engine!r}")
    instances = list(instances)
    I = len(instances)
    if I == 0:
        return []
    profile_grid = [list(ps) for ps in profile_grid]
    if len(profile_grid) != I:
        raise ValueError("profile_grid must list one profile set "
                         "per instance")
    P = len(profile_grid[0])
    if any(len(ps) != P for ps in profile_grid):
        raise ValueError("every instance needs the same number of "
                         "profiles (dense grid)")
    if P == 0:
        return [[] for _ in range(I)]
    names = PORTFOLIO_VARIANTS if variants is None else tuple(variants)
    heur = any(n != "asap" for n in names)

    # identity dedupe (see docstring): dup_of[i] == i marks a unique row;
    # duplicates point at the first occurrence (always a lower index)
    uniq: dict[tuple, int] = {}
    dup_of: list[int] = []
    for inst, ps in zip(instances, profile_grid):
        key = (id(inst), tuple(id(p) for p in ps))
        dup_of.append(uniq.setdefault(key, len(dup_of)))
    n_dup = sum(1 for i, d in enumerate(dup_of) if d != i)
    if n_dup:
        obs.registry().counter(
            "portfolio_rows_deduped_total",
            "duplicate (instance, profile-row) grid rows aliased to a "
            "unique row's results instead of recomputed host-side").inc(
                n_dup)

    if graphs is None:
        graphs = [None] * I
    graphs = list(graphs)
    for i, (inst, ps) in enumerate(zip(instances, profile_grid)):
        if graphs[i] is None:
            graphs[i] = graphs[dup_of[i]] if dup_of[i] != i else \
                prepare_graph(inst, platform, ps[0].T, k=k,
                              lp_budget_bytes=lp_budget_bytes)
    need = _needed_combos(names)
    # overlays only precompute the interval subdivisions the requested
    # variants use (an asap-only request skips masks/segments entirely)
    rvals = tuple(sorted({r for (_, _, r) in need}))
    overlays: list = []
    for i, (g, ps) in enumerate(zip(graphs, profile_grid)):
        overlays.append(
            overlays[dup_of[i]] if dup_of[i] != i else
            [overlay_profile(g, p, refined_values=rvals) for p in ps])
    if heur and not all(g.feasible for g in graphs):
        raise ValueError("infeasible: deadline below ASAP makespan")

    # --- greedy: all (instance, profile, unique-combo) starts -------------
    greedys: list[list[dict]] = [[{} for _ in range(P)] for _ in range(I)]
    if need and engine == "numpy":
        with obs.span("greedy_numpy", cells=I * P, combos=len(need)):
            for i in range(I):
                if dup_of[i] != i:
                    greedys[i] = greedys[dup_of[i]]
                    continue
                for p in range(P):
                    checkpoint(cancel)   # per-cell cancellation rung
                    prep = PreparedInstance(graph=graphs[i],
                                            overlay=overlays[i][p])
                    greedys[i][p] = _greedy_starts_numpy(prep, need)
    elif need:                                     # engine == "jax"
        from repro.core.greedy_jax import greedy_fanout_grid_jax, \
            pad_budget, pad_dims, pad_masks, pad_orders

        buckets: dict[tuple, list[int]] = {}
        for i, (inst, g) in enumerate(zip(instances, graphs)):
            buckets.setdefault(pad_dims(inst.num_tasks, g.T), []).append(i)
        for (Npad, Tp), idx in buckets.items():
            checkpoint(cancel)           # per-bucket-launch rung
            t0 = time.perf_counter()
            launch_span = obs.start_span(
                "bucket_launch", bucket=f"{Npad}x{Tp}",
                instances=len(idx), rows=len(idx) * P * len(need))
            misses0 = _jit_entries_total()
            # duplicate rows reuse the unique row's host-built tuple (the
            # launch keeps its bucket shape; only row prep is skipped —
            # the dedupe target shares the instance object, hence the
            # bucket, so it was built earlier in this idx walk)
            row_cache: dict[int, tuple] = {}
            rows = []
            for i in idx:
                if dup_of[i] in row_cache:
                    rows.append(row_cache[dup_of[i]])
                    continue
                g = graphs[i]
                dur, work, lp, est_j, lst_j, tail = g.shared()
                budgets = pad_budget(np.stack(
                    [ov.unit_budget for ov in overlays[i]]), Tp)
                masks = pad_masks(np.stack(
                    [np.stack([ov.masks[r] for (_, _, r) in need])
                     for ov in overlays[i]]), Tp)
                orders = pad_orders(np.stack(
                    [g.order_for(s, w) for (s, w, _) in need]), tail)
                row_cache[dup_of[i]] = (dur, work, lp, budgets, masks,
                                        est_j, lst_j, orders)
                rows.append(row_cache[dup_of[i]])
            try:
                starts = np.asarray(
                    greedy_fanout_grid_jax(rows, devices=devices),
                    dtype=np.int64)
            finally:
                misses = max(_jit_entries_total() - misses0, 0)
                if misses:
                    obs.registry().counter(
                        "jax_jit_cache_misses_total",
                        "new compiled signatures per fan-out bucket "
                        "launch (steady state stays at 0)",
                        labels=("bucket",)).inc(misses,
                                                bucket=f"{Npad}x{Tp}")
                launch_span.end(cache_misses=misses)
            dt = (time.perf_counter() - t0) / (len(idx) * P * len(need))
            for b, i in enumerate(idx):
                N = instances[i].num_tasks
                for p in range(P):
                    greedys[i][p] = {c: (starts[b, p, ci, :N], dt)
                                     for ci, c in enumerate(need)}

    # --- local search: one batched climb per instance (jax), else exact
    # sequential search inside _assemble (numpy) --------------------------
    ls_names = [n for n in names
                if n != "asap" and VARIANTS_BY_NAME[n].ls]
    ls_dones: list[list[dict]] = [[{} for _ in range(P)] for _ in range(I)]
    if ls_names and engine == "jax":
        from repro.core.local_search_jax import auto_commit_k, \
            local_search_portfolio_multi

        keys = [VARIANTS_BY_NAME[n] for n in ls_names]
        for i in range(I):
            if dup_of[i] != i:
                ls_dones[i] = ls_dones[dup_of[i]]
                continue
            checkpoint(cancel)           # per-climb-launch rung
            ck = commit_k
            if ck == "auto":
                # commit width from this instance's gain density: scale
                # with its candidate-segment count (max over the grid row)
                ck = auto_commit_k(max(
                    len(overlays[i][p].segs[r][0])
                    for p in range(P) for r in rvals))
            t0 = time.perf_counter()
            rows = np.stack(
                [greedys[i][p][(v.score, v.weighted, v.refined)][0]
                 for p in range(P) for v in keys])
            row_budgets = np.stack([overlays[i][p].unit_budget
                                    for p in range(P) for _ in keys])
            # ctx = the graph dict, so the adjacency cache of the device
            # climb survives across profiles (the overlay's ls dict is a
            # per-profile copy); blocked-lp instances use the padded-CSR
            # adjacency so the climb holds no dense N x N tensor either
            with obs.span("ls_climb", instance=i, rows=len(rows)):
                improved = local_search_portfolio_multi(
                    instances[i], graphs[i].T, row_budgets, rows, mu=mu,
                    max_rounds=ls_max_rounds, ctx=graphs[i].ls_graph,
                    commit_k=ck,
                    adjacency="padded" if graphs[i].lp_is_blocked
                    else "dense",
                    cancel=cancel)
            dt = (time.perf_counter() - t0) / len(rows)
            for p in range(P):
                ls_dones[i][p] = {n: (improved[p * len(keys) + j], dt)
                                  for j, n in enumerate(ls_names)}

    obs.registry().counter(
        "portfolio_cells_total",
        "grid cells served by the portfolio pass, by engine",
        labels=("engine",)).inc(I * P, engine=engine)
    out_rows: list = []
    for i in range(I):
        if dup_of[i] != i:
            out_rows.append(out_rows[dup_of[i]])
            continue
        out_rows.append(
            [_assemble(names,
                       PreparedInstance(graph=graphs[i],
                                        overlay=overlays[i][p]),
                       greedys[i][p], ls_dones[i][p], mu, validate,
                       cancel=cancel)
             for p in range(P)])
    return out_rows


def schedule_portfolio(inst: Instance, profile: PowerProfile,
                       platform: Platform, variants=None, k: int = 3,
                       mu: int = 10, validate: bool = True,
                       engine: str = "numpy",
                       prep: PreparedInstance | None = None
                       ) -> dict[str, ScheduleResult]:
    """Schedule all requested variants (default: asap + all 16) in one pass.

    .. deprecated:: legacy shim over :class:`repro.api.Planner` (the 1
       instance x 1 profile slice of one :meth:`~repro.api.Planner.plan`
       call); prefer ``Planner(platform).plan(PlanRequest(...))``.
       Bit-identical to the Planner per engine by construction (and by
       test). ``prep`` may be passed to reuse the precompute across calls
       (it must match ``(inst, profile, platform, k)``).
    """
    from repro.api import LocalSearchConfig, Planner, PlanRequest

    planner = Planner(platform, engine=engine, k=k,
                      ls=LocalSearchConfig(mu=mu), validate=validate)
    if prep is not None:
        planner.seed_graph(prep.graph)
    res = planner.plan(PlanRequest(instances=inst, profiles=profile,
                                   variants=variants))
    return res.results[0][0]


def schedule_portfolio_multi(inst: Instance, profiles, platform: Platform,
                             variants=None, k: int = 3, mu: int = 10,
                             validate: bool = True, engine: str = "numpy",
                             graph: PreparedGraph | None = None
                             ) -> list[dict[str, ScheduleResult]]:
    """One instance x N profiles x all variants; the replanning fan-out.

    .. deprecated:: legacy shim over :class:`repro.api.Planner` (the 1
       instance x P profiles slice of one :meth:`~repro.api.Planner.plan`
       call); prefer ``Planner(platform).plan(PlanRequest(...))``.
       Returns one ``{variant: ScheduleResult}`` dict per profile, each
       bit-identical to ``schedule_portfolio(inst, profile_i, platform,
       engine=engine)`` (property-tested).
    """
    from repro.api import LocalSearchConfig, Planner, PlanRequest

    profiles = list(profiles)
    if not profiles:
        return []
    planner = Planner(platform, engine=engine, k=k,
                      ls=LocalSearchConfig(mu=mu), validate=validate)
    if graph is not None:
        planner.seed_graph(graph)
    res = planner.plan(PlanRequest(instances=inst, profiles=profiles,
                                   variants=variants))
    return res.results[0]


def portfolio_cost_matrix(results, variants=None):
    """[P, V] cost matrix from :func:`schedule_portfolio_multi` output.

    Returns ``(costs, names)``; ``costs[p, v]`` is profile p's carbon cost
    under variant ``names[v]``. The robust (min over variants of max over
    profiles) pick is ``names[costs.max(axis=0).argmin()]``.
    """
    if not results:
        return np.zeros((0, 0), dtype=np.int64), ()
    names = tuple(variants) if variants is not None else tuple(results[0])
    costs = np.array([[res[n].cost for n in names] for res in results],
                     dtype=np.int64)
    return costs, names


def heuristic_indices(names) -> list[int]:
    """Variant columns competing for best/robust picks: the heuristics,
    unless ``asap`` is the sole variant requested (a caller pinned to the
    baseline still gets a pick). THE convention — shared by
    :func:`robust_pick` and :class:`repro.api.PlanResult`."""
    heur = [i for i, n in enumerate(names) if n != "asap"]
    return heur or list(range(len(names)))


def robust_pick(costs: np.ndarray, names) -> tuple[str, int]:
    """The min-max variant of an ensemble cost matrix.

    Returns ``(variant, worst_cost)``: the heuristic variant whose worst
    cost across the ensemble rows is smallest (competing columns per
    :func:`heuristic_indices`).
    """
    names = tuple(names)
    if not names or not len(costs):
        raise ValueError("empty cost matrix")
    heur = heuristic_indices(names)
    worst = np.asarray(costs)[:, heur].max(axis=0)
    j = int(worst.argmin())
    return names[heur[j]], int(worst[j])


# ---------------------------------------------------------------------------
# Shape-bucketed instance batching (jax engine, second vmap level)
# ---------------------------------------------------------------------------

def _shape_key(prep: PreparedInstance) -> tuple:
    from repro.core.greedy_jax import pad_dims
    return pad_dims(prep.inst.num_tasks, prep.profile.T)


def portfolio_starts_batch(preps: list[PreparedInstance],
                           combos=_COMBOS) -> list[np.ndarray]:
    """Greedy starts for a batch of instances x all variants on device.

    Instances are grouped by padded shape bucket (:func:`repro.core
    .greedy_jax.pad_dims`); each group runs as ONE doubly-vmapped jitted
    call. Returns, aligned with ``preps``, int64 arrays of shape
    [len(combos), N_i].
    """
    import jax.numpy as jnp

    from repro.core.greedy_jax import _impl, pad_budget, pad_masks, \
        pad_orders

    results: list[np.ndarray | None] = [None] * len(preps)
    groups: dict[tuple, list[int]] = {}
    for i, p in enumerate(preps):
        groups.setdefault(_shape_key(p), []).append(i)
    for (_, Tp), idx in groups.items():
        rows = []
        for i in idx:
            p = preps[i]
            dur, work, lp, est_j, lst_j, tail = p.graph.shared()
            if p.graph.lp_is_blocked:
                raise TypeError(
                    "portfolio_starts_batch batches dense-lp instances "
                    "only; blocked-lp (big) instances go through "
                    "greedy_fanout_grid_jax / schedule_portfolio_grid")
            masks = pad_masks(np.stack(
                [p.masks[r] for (_, _, r) in combos]), Tp)
            orders = pad_orders(np.stack(
                [p.graph.order_for(s, w) for (s, w, _) in combos]), tail)
            rem0 = pad_budget(
                p.profile.unit_budget(p.inst.idle_total), Tp)
            rows.append((dur, work, lp, jnp.asarray(rem0),
                         jnp.asarray(masks), est_j, lst_j,
                         jnp.asarray(orders)))
        stacked = tuple(jnp.stack([r[a] for r in rows])
                        for a in range(8))
        starts = np.asarray(_impl()["batch"](*stacked), dtype=np.int64)
        for b, i in enumerate(idx):
            results[i] = starts[b][:, :preps[i].inst.num_tasks]
    return results
