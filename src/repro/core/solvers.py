"""Pluggable solver layer: one registry behind ``PlanRequest(solver=...)``.

The paper's central experiment compares the 16 CaWoSched heuristics
against a carbon-unaware baseline and exact oracles. This module turns
that comparison into a first-class request axis: every solver consumes
the same ``(instances x profiles)`` grid and returns the same per-cell
``{variant: ScheduleResult}`` shape, so
:func:`repro.core.portfolio.schedule_portfolio_grid` becomes ONE of
several registered backends rather than THE code path.

Registered solvers:

* ``heuristic`` — the portfolio engine (greedy fan-out + local search);
  the only solver with a variant axis wider than one column, and the only
  one the ``engine=`` knob (numpy/jax/auto) applies to.
* ``exact``     — the dispatching oracle: the §4.1 polynomial DP when an
  instance maps onto a single processor chain, the time-indexed ILP
  otherwise. Fills :attr:`SolveOutput.lower` so
  :meth:`repro.api.PlanResult.gap` can report heuristic-vs-optimal ratios.
* ``ilp``       — the time-indexed HiGHS MILP (paper §4.3) per cell;
  ``options={"time_limit": s, "mip_gap": g}`` plumb through, and the
  HiGHS dual bound is kept as a valid lower bound even on time-limit
  exits (``lower == cost`` certifies a proven optimum).
* ``dp``        — the §4.1 fully polynomial uniprocessor DP
  (:func:`repro.core.dp_uniproc.dp_poly`); ``options={"check": True}``
  cross-validates every cell against the pseudo-polynomial oracle
  :func:`~repro.core.dp_uniproc.dp_pseudo`.
* ``asap``      — the paper's §5.1 earliest-start baseline, the
  regression floor every heuristic must beat.

``repro.kernels.backend.resolve_solver`` is the lookup the Planner uses
(the solver-axis generalization of ``resolve_engine``); third-party
solvers join via :func:`register_solver`.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.core.cancel import checkpoint
from repro.core.carbon import PowerProfile, schedule_cost, validate_schedule
from repro.core.cawosched import ScheduleResult
from repro.core.dag import Instance
from repro.core.dp_uniproc import dp_poly, dp_pseudo, is_uniprocessor
from repro.core.estlst import asap_schedule
from repro.core.portfolio import PORTFOLIO_VARIANTS, schedule_portfolio_grid


@dataclasses.dataclass
class SolveOutput:
    """What every solver returns: the dense cell grid + optional bounds.

    ``cells[i][p]`` maps variant name -> :class:`ScheduleResult` (the
    portfolio engine's historical shape, now the inter-solver contract);
    ``lower[i, p]`` is a valid int64 lower bound on cell (i, p)'s optimal
    cost, or ``None`` for solvers that cannot certify one (heuristic,
    asap). ``lower == cost`` certifies a proven optimum for that cell.
    ``mip_gap[i, p]`` is the relative optimality gap the MILP backend
    reported for the cell (0.0 at a proven optimum, >0 on a time-limit /
    mip-gap exit, NaN where the sub-solver reports none) — the bound
    certificate a degraded-but-not-failed exact solve carries.
    """

    cells: list                        # I x P of {variant: ScheduleResult}
    lower: np.ndarray | None = None    # int64 [I, P] or None
    mip_gap: np.ndarray | None = None  # float [I, P] or None (ilp/exact)

    def cost_tensor(self, names) -> np.ndarray:
        """Dense int64 cost tensor ``[I, P, V]`` over the cell grid."""
        names = tuple(names)
        I = len(self.cells)
        P = len(self.cells[0]) if I else 0
        return np.array(
            [[[self.cells[i][p][n].cost for n in names] for p in range(P)]
             for i in range(I)],
            dtype=np.int64).reshape(I, P, len(names))


class Solver:
    """One scheduling backend serving the (instances x profiles) grid.

    Subclasses set ``name`` (the registry key and ``PlanRequest.solver``
    spelling) and ``exact`` (whether :attr:`SolveOutput.lower` certifies
    optimality), and implement :meth:`solve_grid`. ``default_variants``
    is the variant tuple a request gets when it does not pin one — the
    full 17-variant portfolio for the heuristic solver, the solver's own
    single column for everything else.
    """

    name: str = "?"
    exact: bool = False
    # whether solve_grid consumes the Planner's PreparedGraph precompute
    # (the exact oracles solve from the raw instance; the Planner skips
    # graph preparation entirely for solvers that don't want it)
    uses_graphs: bool = True

    def default_variants(self) -> tuple[str, ...]:
        return (self.name,)

    def solve_grid(self, instances, profile_grid, platform, names, *,
                   k: int = 3, mu: int = 10, validate: bool = True,
                   engine: str = "numpy", graphs=None, commit_k=None,
                   ls_max_rounds: int = 200,
                   options: dict | None = None, cancel=None,
                   devices: int | None = None) -> SolveOutput:
        """Serve the grid. ``cancel`` is an optional
        :class:`repro.core.cancel.CancelToken` every solver polls at its
        chain-rung boundaries (between grid cells for the per-cell
        solvers) — a cancelled token makes the solve raise
        :class:`~repro.core.cancel.Cancelled` within one cell of work
        instead of running the rest of the grid. ``devices`` shards the
        device-resident grid launch (the heuristic jax engine); the
        per-cell host solvers accept and ignore it."""
        raise NotImplementedError

    # -- shared per-cell driver for the single-column solvers -------------

    def _solve_cells(self, instances, profile_grid, names, validate,
                     cell_fn, cancel=None) -> SolveOutput:
        """Run ``cell_fn(i, inst, profile) -> (start, lower|None[, gap])``
        over the grid and assemble the common single-column output shape."""
        label = _single_label(names, self)
        I, P = len(instances), len(profile_grid[0]) if instances else 0
        lower = np.zeros((I, P), dtype=np.int64)
        gaps = np.full((I, P), np.nan)
        any_lower = any_gap = False
        cells = []
        for i, inst in enumerate(instances):
            row = []
            for p, profile in enumerate(profile_grid[i]):
                checkpoint(cancel)        # per-cell cancellation rung
                t0 = time.perf_counter()
                with obs.span("solve_cell", solver=self.name, i=i, p=p):
                    out = cell_fn(i, inst, profile)
                _CELLS.inc(solver=self.name)
                start, lb = out[0], out[1]
                gap = out[2] if len(out) > 2 else None
                secs = time.perf_counter() - t0
                start = np.asarray(start, dtype=np.int64)
                if validate:
                    validate_schedule(inst, profile, start)
                cost = schedule_cost(inst, profile, start)
                if lb is not None:
                    lower[i, p] = min(int(lb), cost)
                    any_lower = True
                if gap is not None and np.isfinite(gap):
                    gaps[i, p] = float(gap)
                    any_gap = True
                row.append({label: ScheduleResult(
                    variant=label, start=start, cost=cost, seconds=secs)})
            cells.append(row)
        return SolveOutput(cells=cells,
                           lower=lower if any_lower else None,
                           mip_gap=gaps if any_gap else None)


_CELLS = obs.registry().counter(
    "solver_cells_total", "grid cells served, by solver backend",
    labels=("solver",))


def _single_label(names, solver: Solver) -> str:
    names = tuple(names)
    if len(names) != 1:
        raise ValueError(
            f"solver {solver.name!r} produces exactly one variant column, "
            f"got {names!r}")
    return names[0]


class HeuristicSolver(Solver):
    """The portfolio engine (:func:`schedule_portfolio_grid`) as one
    registered backend: asap + the 16 paper variants, numpy or jax."""

    name = "heuristic"
    exact = False

    def default_variants(self) -> tuple[str, ...]:
        return tuple(PORTFOLIO_VARIANTS)

    def solve_grid(self, instances, profile_grid, platform, names, *,
                   k=3, mu=10, validate=True, engine="numpy", graphs=None,
                   commit_k=None, ls_max_rounds=200, options=None,
                   cancel=None, devices=None) -> SolveOutput:
        cells = schedule_portfolio_grid(
            instances, profile_grid, platform, variants=names, k=k, mu=mu,
            validate=validate, engine=engine, graphs=graphs,
            commit_k=commit_k, ls_max_rounds=ls_max_rounds, cancel=cancel,
            devices=devices)
        return SolveOutput(cells=cells, lower=None)


class AsapSolver(Solver):
    """The paper's §5.1 baseline: start every task at its EST.

    Independent of the portfolio machinery (it needs no profile overlay,
    no score orders, no masks) — the regression floor stays meaningful
    even when the heuristic engine changes underneath it.
    """

    name = "asap"
    exact = False

    def solve_grid(self, instances, profile_grid, platform, names, *,
                   k=3, mu=10, validate=True, engine="numpy", graphs=None,
                   commit_k=None, ls_max_rounds=200, options=None,
                   cancel=None, devices=None) -> SolveOutput:
        ests = [graphs[i].est0 if graphs is not None
                else asap_schedule(inst)
                for i, inst in enumerate(instances)]

        def cell(i, inst, profile):
            return ests[i].copy(), None

        return self._solve_cells(instances, profile_grid, names, validate,
                                 cell, cancel=cancel)


class DpUniprocSolver(Solver):
    """The §4.1 fully polynomial uniprocessor DP (:func:`dp_poly`).

    Exact on any instance whose fixed mapping is a single processor
    chain; ``options={"check": True}`` re-solves every cell with the
    pseudo-polynomial oracle :func:`dp_pseudo` and asserts agreement.
    """

    name = "dp"
    exact = True
    uses_graphs = False

    def solve_grid(self, instances, profile_grid, platform, names, *,
                   k=3, mu=10, validate=True, engine="numpy", graphs=None,
                   commit_k=None, ls_max_rounds=200, options=None,
                   cancel=None, devices=None) -> SolveOutput:
        check = bool((options or {}).get("check", False))
        for inst in instances:
            if not is_uniprocessor(inst):
                raise ValueError(
                    "solver='dp' requires a single-processor-chain "
                    "instance with one shared work power; use "
                    "solver='exact' (auto-dispatch) or 'ilp' for "
                    "multiprocessor instances")

        def cell(i, inst, profile):
            cost, start = dp_poly(inst, profile)
            if check:    # explicit raises: must survive python -O
                ref_cost, ref_start = dp_pseudo(inst, profile)
                if ref_cost != cost:
                    raise AssertionError(
                        f"dp_poly={cost} != dp_pseudo={ref_cost} "
                        f"(instance {i})")
                if schedule_cost(inst, profile, ref_start) != ref_cost:
                    raise AssertionError(
                        f"dp_pseudo schedule does not cost {ref_cost} "
                        f"(instance {i})")
            return start, cost

        return self._solve_cells(instances, profile_grid, names, validate,
                                 cell, cancel=cancel)


class IlpSolver(Solver):
    """The time-indexed HiGHS MILP (paper §4.3), one solve per cell.

    ``options``: ``time_limit`` (seconds, default
    :data:`IlpSolver.DEFAULT_TIME_LIMIT`) and ``mip_gap`` (relative,
    default 0) plumb straight into HiGHS. The reported cost is
    the exact integer cost of the incumbent schedule; the per-cell lower
    bound is the HiGHS dual bound (rounded up — costs are integral), so a
    time-limited solve still yields a certified gap, and ``lower == cost``
    certifies optimality. A time-limit exit WITH an incumbent is a
    degraded success, not a failure: the cell's ``mip_gap`` carries the
    HiGHS relative gap so the serving tier can flag the result degraded
    while still returning the schedule + bound certificate. Paper's own
    scope note applies: exact solves are only run on small instances.
    """

    name = "ilp"
    exact = True
    uses_graphs = False
    DEFAULT_TIME_LIMIT = 300.0

    def solve_grid(self, instances, profile_grid, platform, names, *,
                   k=3, mu=10, validate=True, engine="numpy", graphs=None,
                   commit_k=None, ls_max_rounds=200, options=None,
                   cancel=None, devices=None) -> SolveOutput:
        from repro.core.ilp import solve_ilp    # lazy: needs scipy/HiGHS

        opts = options or {}
        time_limit = float(opts.get("time_limit", self.DEFAULT_TIME_LIMIT))
        mip_gap = float(opts.get("mip_gap", 0.0))

        def cell(i, inst, profile):
            res = solve_ilp(inst, profile, time_limit=time_limit,
                            mip_gap=mip_gap, cancel=cancel)
            if not np.isfinite(res.cost):
                raise ValueError(
                    f"ILP produced no feasible schedule for instance "
                    f"{i} within time_limit={time_limit}s (raise it to "
                    f"keep the rest of the grid): {res.message}")
            lb = res.lower_bound
            if not np.isfinite(lb):
                # no dual-bound progress: only a HiGHS-proven optimum may
                # certify itself; otherwise 0 is the honest valid bound
                # (never falsely reports lower == cost on an unproven
                # incumbent)
                lb = res.cost if res.status == 0 else 0.0
            gap = res.mip_gap
            if not np.isfinite(gap):
                # a proven optimum has zero gap even when HiGHS omits the
                # field; an unproven incumbent keeps NaN (gap unknown)
                gap = 0.0 if res.status == 0 else float("nan")
            # integral costs: round the continuous dual bound up
            return res.start, int(np.ceil(lb - 1e-6)), gap

        return self._solve_cells(instances, profile_grid, names, validate,
                                 cell, cancel=cancel)


class ExactSolver(Solver):
    """The auto-dispatching oracle: DP on uniprocessor chains, ILP else.

    Per-instance dispatch (one request may mix both regimes); every cell
    carries the sub-solver's lower bound under the shared ``"exact"``
    column, so one ``plan(solver="exact")`` call serves the paper's full
    gap-to-optimal evaluation regardless of the mapping shape.
    """

    name = "exact"
    exact = True
    uses_graphs = False

    def solve_grid(self, instances, profile_grid, platform, names, *,
                   k=3, mu=10, validate=True, engine="numpy", graphs=None,
                   commit_k=None, ls_max_rounds=200, options=None,
                   cancel=None, devices=None) -> SolveOutput:
        label = _single_label(names, self)
        I = len(instances)
        P = len(profile_grid[0]) if instances else 0
        cells: list = [None] * I
        lower = np.zeros((I, P), dtype=np.int64)
        gaps = np.full((I, P), np.nan)
        any_gap = False
        for i, inst in enumerate(instances):
            checkpoint(cancel)           # per-instance dispatch rung
            sub = DP if is_uniprocessor(inst) else ILP
            out = sub.solve_grid(
                [inst], [profile_grid[i]], platform, (label,), k=k, mu=mu,
                validate=validate, engine=engine,
                graphs=None if graphs is None else [graphs[i]],
                commit_k=commit_k, ls_max_rounds=ls_max_rounds,
                options=options, cancel=cancel)
            cells[i] = out.cells[0]
            lower[i] = out.lower[0]
            if out.mip_gap is not None:
                gaps[i] = out.mip_gap[0]
                any_gap = True
        return SolveOutput(cells=cells, lower=lower,
                           mip_gap=gaps if any_gap else None)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Solver] = {}


def register_solver(solver: Solver) -> Solver:
    """Add a solver to the registry (``PlanRequest(solver=name)``)."""
    if not solver.name or solver.name == "?":
        raise ValueError("solver needs a name")
    _REGISTRY[solver.name] = solver
    return solver


def get_solver(name: str) -> Solver:
    """Registry lookup; raises with the known names on a miss."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown solver {name!r}; registered: {solver_names()}"
        ) from None


def solver_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


HEURISTIC = register_solver(HeuristicSolver())
ASAP = register_solver(AsapSolver())
DP = register_solver(DpUniprocSolver())
ILP = register_solver(IlpSolver())
EXACT = register_solver(ExactSolver())
