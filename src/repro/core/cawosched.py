"""CaWoSched public API: the baseline + all 16 heuristic variants (paper §5).

Variant names follow the paper: ``{slack|press}[W][R][-LS]``
  W  = power-weighted score,  R = refined interval subdivision,
  -LS = local search applied after the greedy.
``asap`` is the carbon-unaware baseline.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile, schedule_cost, validate_schedule
from repro.core.dag import Instance
from repro.core.estlst import asap_schedule, makespan
from repro.core.greedy import greedy_schedule
from repro.core.local_search import local_search


@dataclasses.dataclass(frozen=True)
class Variant:
    score: str          # "slack" | "press"
    weighted: bool
    refined: bool
    ls: bool

    @property
    def name(self) -> str:
        return (self.score + ("W" if self.weighted else "")
                + ("R" if self.refined else "")
                + ("-LS" if self.ls else ""))


ALL_VARIANTS: tuple[Variant, ...] = tuple(
    Variant(score=s, weighted=w, refined=r, ls=l)
    for s, w, r, l in itertools.product(
        ("slack", "press"), (False, True), (False, True), (False, True))
)

VARIANTS_BY_NAME = {v.name: v for v in ALL_VARIANTS}


@dataclasses.dataclass
class ScheduleResult:
    variant: str
    start: np.ndarray
    cost: int
    seconds: float


def schedule(inst: Instance, profile: PowerProfile, platform: Platform,
             variant: str = "pressWR-LS", k: int = 3, mu: int = 10,
             validate: bool = True) -> ScheduleResult:
    """Run one algorithm variant (or ``asap``) on an instance.

    .. deprecated:: legacy shim over :class:`repro.api.Planner` (the
       1 x 1 x 1 request shape); prefer ``Planner(platform)
       .plan(PlanRequest(...))``. The sequential per-variant reference it
       used to implement lives on as :func:`schedule_reference` (the
       equivalence oracle of the engine tests).
    """
    from repro.api import LocalSearchConfig, Planner, PlanRequest

    res = Planner(platform, engine="numpy", k=k,
                  ls=LocalSearchConfig(mu=mu), validate=validate).plan(
        PlanRequest(instances=inst, profiles=profile, variants=(variant,)))
    return res.results[0][0][variant]


def schedule_reference(inst: Instance, profile: PowerProfile,
                       platform: Platform, variant: str = "pressWR-LS",
                       k: int = 3, mu: int = 10,
                       validate: bool = True) -> ScheduleResult:
    """The paper's sequential per-variant pipeline, verbatim.

    Kept as an independent oracle: no shared precompute, no segment lists,
    no device fan-out — the per-unit greedy plus the sequential local
    search exactly as §5 states them. The Planner/portfolio engines are
    property-tested bit-identical to this.
    """
    t0 = time.perf_counter()
    if variant == "asap":
        start = asap_schedule(inst)
    else:
        v = VARIANTS_BY_NAME[variant]
        start = greedy_schedule(inst, profile, platform, score=v.score,
                                weighted=v.weighted, refined=v.refined, k=k)
        if v.ls:
            start = local_search(inst, profile, platform, start, mu=mu)
    dt = time.perf_counter() - t0
    if validate:
        validate_schedule(inst, profile, start)
    return ScheduleResult(variant=variant, start=start,
                          cost=schedule_cost(inst, profile, start),
                          seconds=dt)


def deadline_from_asap(inst: Instance, factor: float) -> int:
    """Deadline = factor * ASAP makespan (paper's D, 1.5D, 2D, 3D)."""
    return int(np.ceil(factor * makespan(inst, asap_schedule(inst))))
