"""Device-path greedy: the paper's §5.2 loop as a single ``lax.scan``.

Semantically identical to ``core.greedy.greedy_schedule`` (same score order,
same max-budget/earliest-tie placement, same dynamic splits, same endpoint
rule: a task end ``e`` becomes a candidate point only when ``e <= T``), but
the per-step EST/LST relaxation is *closed-form*: a host-precomputed
longest-path matrix ``lp`` (:func:`longest_path_matrix`, profile-independent,
cached on :class:`~repro.core.portfolio.PreparedGraph`) turns the paper's
worklist update into two vectorized ops per placement::

    est = max(est, s + lp[v, :])      # descendants of v move right
    lst = min(lst, s - lp[:, v])      # ancestors of v move left

which equals the worklist fixpoint because ``lp[u, t]`` is the maximum
path weight over *all* u->t paths (any transitive propagation is dominated
by the direct matrix entry). The scan step is O(N + T) with no nested
scans, so the program compiles in a fraction of the old level-relax
formulation's time and executes orders of magnitude faster on CPU.

Three vmap levels over the same scan core, all served by one jit cache:

* variants — score orders and candidate masks batched (``greedy_fanout_jax``);
* profiles — budget timelines and masks batched on an outer axis
  (``greedy_fanout_multi_jax``; same shapes by construction, the
  multi-profile replanning fan-out);
* instances — shape-bucketed batches
  (``repro.core.portfolio.portfolio_starts_batch``).

Retracing discipline: all inputs are padded to shape buckets
(:func:`pad_dims` — N to multiples of 128, T to multiples of 256) before
they reach the jitted entry points, so instances whose real shapes differ
hit the same compiled executable; the jit cache is effectively keyed on the
bucket tuple. Padding is output-invariant: padded tasks have zero
duration/work and place at t=0 (a candidate point on every profile), padded
time units are never feasible starts (mask False, and every real LST is
below the real horizon), and the big per-call buffers (budget timeline,
candidate masks) are donated to the runtime off-CPU so repeat calls reuse
device memory.

Two longest-path representations serve the scan, chosen by
:func:`repro.kernels.backend.resolve_lp_form` against an ``lp_budget_bytes``
envelope (default :data:`LP_MAX_BYTES`):

* dense — the O(N^2) int32 matrix above, resident on device; the fast path
  for the replanning regime (N ~ 10^2-10^3);
* blocked (:class:`BlockedLP`) — the big-instance path: the scan streams
  the placement order in fixed-width chunks, and per chunk a host-side
  block-wise max-plus sweep over the level-ordered adjacency produces just
  that chunk's lp rows (descendant distances of the placed tasks) and
  columns (ancestor distances), fed to the chunked scan as ``lax.scan``
  inputs while the greedy state stays device-resident between chunk
  launches. Peak lp memory is O(N * B) for chunk width B
  (:meth:`BlockedLP.chunk_width` picks B from the budget), so instances far
  past the dense envelope schedule on ``engine="jax"`` — bit-identical to
  the dense path by construction (and by ``tests/test_lp_blocked.py``).

Intended for on-device replanning (CarbonGate-scale instances, N ~ 10^2-10^3,
T ~ 10^3-10^4); bigger instances stream through :class:`BlockedLP` or use
the numpy path (no matrix at all).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro import obs
from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask

NEG_PATH = -(1 << 30)                  # "no path" marker in lp (int32-safe)

N_BUCKET = 128                         # task-axis shape bucket
T_BUCKET = 256                         # time-axis shape bucket

# Device envelope for the dense longest-path matrix: the matrix is
# O(N^2) int32 (64 MiB at N=4000), fine for the device path's
# N ~ 10^2-10^3 regime but a silent multi-hundred-MiB allocation beyond
# it. 128 MiB admits N ~ 5800; bigger instances stream through the
# blocked form (BlockedLP) or use engine="numpy" (no matrix at all).
LP_MAX_BYTES = 128 * 2**20


def lp_matrix_bytes(num_tasks: int) -> int:
    """Bytes the dense int32 longest-path matrix of ``num_tasks`` needs."""
    return 4 * int(num_tasks) * int(num_tasks)


def lp_block_bytes(block: int, n_orders: int, num_tasks: int) -> int:
    """Bytes one streamed chunk of the blocked form needs on device:
    ``block`` scan steps x ``n_orders`` score orders x an lp row AND an lp
    column of padded width ``num_tasks``, int32 each."""
    return 2 * 4 * int(block) * int(n_orders) * int(num_tasks)


def longest_path_matrix(inst: Instance,
                        max_bytes: int | None = None) -> np.ndarray:
    """``lp[u, t]`` = max over u->t paths of the path's duration sum
    (excluding ``dur[t]``); ``lp[v, v] = 0``; unreachable = ``NEG_PATH``
    exactly (canonical: every no-path entry holds the sentinel, so the
    dense matrix is bit-comparable with :class:`BlockedLP` blocks, whose
    backward column sweeps would otherwise drift the phantom values
    differently — semantics-free either way, since the scan's est/lst
    updates cannot be won by any value below 0).

    Profile-independent: one O(E*N) host sweep per instance serves every
    profile, variant and replanning round of the device path. The byte
    cost is checked up front against ``max_bytes`` (default
    :data:`LP_MAX_BYTES`) so an oversized instance fails loudly instead
    of silently allocating O(N^2) device memory.
    """
    N = inst.num_tasks
    limit = LP_MAX_BYTES if max_bytes is None else int(max_bytes)
    need = lp_matrix_bytes(N)
    if need > limit:
        raise MemoryError(
            f"longest-path matrix needs {need / 2**20:.1f} MiB "
            f"(N={N} tasks, O(N^2) int32), over the "
            f"{limit / 2**20:.0f} MiB lp budget; the jax engine streams "
            f"such instances through the blocked form instead — raise "
            f"lp_budget_bytes (prepare_graph / schedule_portfolio_grid / "
            f"Planner) or build a BlockedLP(inst) directly; engine="
            f"'numpy' needs no matrix at all")
    # the dense matrix IS the all-rows block of the blocked form — one
    # sweep implementation (BlockedLP.rows) serves both representations,
    # so their bitwise agreement cannot drift
    return BlockedLP(inst, budget_bytes=limit).rows(np.arange(N))


@dataclasses.dataclass
class BlockedLP:
    """Blocked longest-path relaxation: the O(N*B) streaming form.

    Holds no matrix at all — :meth:`rows` and :meth:`cols` run the
    forward/backward max-plus sweep over the topo-ordered adjacency for
    just the requested tasks, and :meth:`chunk_tensors` assembles the
    bucket-padded per-chunk scan inputs the blocked device scan consumes
    (``repro.core.greedy_jax._blocked_impl``). Values are bit-identical
    to the canonical dense :func:`longest_path_matrix` entries
    (``materialize`` assembles the full matrix for differential tests).

    ``budget_bytes`` bounds the streamed chunk buffers
    (:func:`lp_block_bytes`); :meth:`chunk_width` turns it into the scan
    chunk width and raises ``MemoryError`` when even a single-step chunk
    (the O(N) floor) does not fit.
    """

    inst: Instance
    budget_bytes: int = LP_MAX_BYTES

    def rows(self, tasks) -> np.ndarray:
        """``lp[tasks, :N]`` — descendant distances, one forward sweep."""
        inst = self.inst
        tasks = np.asarray(tasks, dtype=np.int64)
        N = inst.num_tasks
        d = np.full((len(tasks), N), NEG_PATH, dtype=np.int32)
        d[np.arange(len(tasks)), tasks] = 0
        dur = inst.dur.astype(np.int32)
        for v in inst.topo:
            ps = inst.preds(v)
            if len(ps):
                cand = d[:, ps] + dur[ps][None, :]
                np.maximum(d[:, v], cand.max(axis=1), out=d[:, v])
        # canonicalize: phantom entries (sentinel plus dur drift picked up
        # along no-path chains) all become NEG_PATH; true path values are
        # >= 0 (durations are positive, diagonal is 0)
        d[d < 0] = NEG_PATH
        d[np.arange(len(tasks)), tasks] = 0
        return d

    def cols(self, tasks) -> np.ndarray:
        """``lp[:N, tasks].T`` — ancestor distances, one backward sweep."""
        inst = self.inst
        tasks = np.asarray(tasks, dtype=np.int64)
        N = inst.num_tasks
        d = np.full((len(tasks), N), NEG_PATH, dtype=np.int32)
        d[np.arange(len(tasks)), tasks] = 0
        dur = inst.dur.astype(np.int32)
        for v in inst.topo[::-1]:
            ss = inst.succs(v)
            if len(ss):
                cand = d[:, ss] + dur[v]
                np.maximum(d[:, v], cand.max(axis=1), out=d[:, v])
        d[d < 0] = NEG_PATH
        d[np.arange(len(tasks)), tasks] = 0
        return d

    def chunk_width(self, n_orders: int, padded_n: int) -> int:
        """Scan chunk width B for ``n_orders`` score orders at padded task
        count ``padded_n``: the largest width whose chunk buffers fit
        ``budget_bytes``, clamped to a divisor of ``padded_n`` so every
        chunk launch shares one compiled shape."""
        floor = lp_block_bytes(1, n_orders, padded_n)
        width = int(self.budget_bytes) // floor
        if width < 1:
            raise MemoryError(
                f"blocked longest-path streaming needs at least {floor} "
                f"bytes (one scan step x {n_orders} orders x 2 lp "
                f"vectors of padded width {padded_n}, int32), over the "
                f"{self.budget_bytes} byte lp budget; raise "
                f"lp_budget_bytes or use engine='numpy'")
        if width >= padded_n:
            return padded_n
        B = 1
        while B * 2 <= width and padded_n % (B * 2) == 0:
            B *= 2
        return B

    def chunk_tensors(self, vs: np.ndarray, padded_n: int):
        """Per-chunk scan inputs for order chunk ``vs`` [V, B]: int32
        (rows, cols), each [V, B, padded_n]. Padded task ids (>= N) get
        the padded identity row/column (``NEG_PATH`` off-diagonal, 0 on
        it), exactly the dense padded matrix's entries."""
        V, B = vs.shape
        flat = np.asarray(vs, dtype=np.int64).ravel()
        N = self.inst.num_tasks
        rows = np.full((V * B, padded_n), NEG_PATH, dtype=np.int32)
        cols = np.full((V * B, padded_n), NEG_PATH, dtype=np.int32)
        real = flat < N
        if real.any():
            uniq, inv = np.unique(flat[real], return_inverse=True)
            rows[real, :N] = self.rows(uniq)[inv]
            cols[real, :N] = self.cols(uniq)[inv]
        rows[np.arange(V * B), flat] = 0
        cols[np.arange(V * B), flat] = 0
        return rows.reshape(V, B, padded_n), cols.reshape(V, B, padded_n)

    def materialize(self, block: int = 64) -> np.ndarray:
        """Assemble the full dense matrix from row blocks of width
        ``block`` (differential tests / diagnostics only — this is the
        O(N^2) allocation the streaming path exists to avoid)."""
        N = self.inst.num_tasks
        out = np.empty((N, N), dtype=np.int32)
        for c in range(0, N, max(int(block), 1)):
            idx = np.arange(c, min(c + max(int(block), 1), N))
            out[idx] = self.rows(idx)
        return out


def lp_for(inst: Instance, budget_bytes: int | None = None):
    """The dense-or-blocked union: the dense matrix when it fits the
    budget (:func:`repro.kernels.backend.resolve_lp_form`), else a
    :class:`BlockedLP` handle — every lp consumer accepts either."""
    from repro.kernels.backend import resolve_lp_form

    limit = LP_MAX_BYTES if budget_bytes is None else int(budget_bytes)
    if resolve_lp_form(inst.num_tasks, limit) == "dense":
        return longest_path_matrix(inst, max_bytes=limit)
    return BlockedLP(inst, budget_bytes=limit)


def _bucket_up(x: int, q: int) -> int:
    return max(((int(x) + q - 1) // q) * q, q)


def pad_dims(N: int, T: int) -> tuple[int, int]:
    """Shape bucket for an (N tasks, T horizon) instance."""
    return _bucket_up(N, N_BUCKET), _bucket_up(T, T_BUCKET)


def _placement_step(jnp, dur, work):
    """THE §5.2 placement step, shared by the dense scan (which looks
    ``row``/``col`` up in the resident lp matrix) and the chunked blocked
    scan (which receives them as scan inputs) — one body, so the
    blocked==dense bit-identity contract cannot drift."""
    big = jnp.int32(np.iinfo(np.int32).max // 4)

    def step(state, v, row, col):
        rem, mask, est, lst, start = state
        T = rem.shape[0]
        tgrid = jnp.arange(T, dtype=jnp.int32)
        feas = mask[:-1] & (tgrid >= est[v]) & (tgrid <= lst[v])
        any_f = feas.any()
        val = jnp.where(feas, rem, -big)
        s = jnp.where(any_f, jnp.argmax(val).astype(jnp.int32),
                      est[v].astype(jnp.int32))
        e = s + dur[v]
        run = (tgrid >= s) & (tgrid < e)
        rem = rem - jnp.where(run, work[v], 0).astype(rem.dtype)
        mask = mask.at[s].set(True)
        # numpy endpoint rule: e splits an interval only when e <= T; an
        # overrunning task must not spuriously mark T a candidate point.
        eidx = jnp.minimum(e, T)
        mask = mask.at[eidx].set(mask[eidx] | (e <= T))
        est = jnp.maximum(est, s + row)
        lst = jnp.minimum(lst, s - col)
        start = start.at[v].set(s)
        return (rem, mask, est, lst, start)

    return step


@functools.lru_cache(maxsize=1)
def _build_fns():
    """Unjitted greedy launchers (scan / variant-vmap / profile-vmap).

    Shared by :func:`_impl` (which jits them) and
    :func:`_grid_sharded_impl` (which wraps the instance-level vmap in a
    ``shard_map`` before jitting), so both launch paths trace the SAME
    closures and stay bit-identical by construction.
    """
    import jax
    import jax.numpy as jnp
    from jax import lax

    def greedy_scan(dur, work, lp, rem0, mask0, est0, lst0, order):
        """One variant's §5.2 greedy over precomputed inputs (vmappable)."""
        core = _placement_step(jnp, dur, work)

        def step(state, v):
            return core(state, v, lp[v], lp[:, v]), None

        N = est0.shape[0]
        state0 = (rem0, mask0, est0, lst0, jnp.zeros(N, jnp.int32))
        (_, _, _, _, start), _ = lax.scan(step, state0, order)
        return start

    # axis spec per argument: (dur, work, lp, rem0, mask0, est0, lst0, order)
    variant_axes = (None, None, None, None, 0, None, None, 0)
    profile_axes = (None, None, None, 0, 0, None, None, None)
    fanout = jax.vmap(greedy_scan, in_axes=variant_axes)
    multi = jax.vmap(fanout, in_axes=profile_axes)
    return greedy_scan, fanout, multi


def _donate():
    import jax
    # donate the big per-call buffers (budget timeline, masks) so repeat
    # calls reuse device memory; on CPU donation is a no-op and only warns,
    # so it is enabled off-CPU only.
    return (3, 4) if jax.default_backend() != "cpu" else ()


@functools.lru_cache(maxsize=1)
def _impl():
    import jax

    greedy_scan, fanout, multi = _build_fns()
    don = _donate()
    return {
        "single": jax.jit(greedy_scan, donate_argnums=don),
        "fanout": jax.jit(fanout, donate_argnums=don),
        "multi": jax.jit(multi, donate_argnums=don),
        "batch": jax.jit(jax.vmap(fanout, in_axes=(0,) * 8),
                         donate_argnums=don),
        "grid": jax.jit(jax.vmap(multi, in_axes=(0,) * 8),
                        donate_argnums=don),
    }


@functools.lru_cache(maxsize=8)
def _grid_sharded_impl(ndev: int):
    """The grid launcher sharded over ``ndev`` devices.

    The instance-row axis of the combined (instances x profiles x
    variants) launch is embarrassingly parallel, so the sharded form is a
    ``shard_map`` of the same instance-level vmap over a 1-D "data" mesh
    (``sharding.ctx.grid_mesh``): every device runs ``rows/ndev`` full
    greedy scans with zero cross-device communication, and the result is
    bitwise-identical to the single-device grid (rows are independent and
    the per-row closure is literally the same traced function).
    ``check_rep=False``: no replicated outputs to verify, and the scan
    body trips the conservative replication checker.
    """
    import jax
    from jax.experimental.shard_map import shard_map

    from repro.sharding.ctx import grid_mesh
    from repro.sharding.specs import grid_batch_spec

    _, _, multi = _build_fns()
    grid = jax.vmap(multi, in_axes=(0,) * 8)
    spec = grid_batch_spec()
    sharded = shard_map(grid, mesh=grid_mesh(ndev), in_specs=(spec,) * 8,
                        out_specs=spec, check_rep=False)
    return jax.jit(sharded, donate_argnums=_donate())


def _grid_launch(stacked, devices):
    """Dispatch one stacked dense-bucket grid launch, sharding the
    instance-row axis over ``devices`` when asked (padding the row count
    to a multiple of the device count by repeating the last row, sliced
    off after — shard_map needs equal per-device block sizes)."""
    if devices is None or devices <= 1:
        return _impl()["grid"](*stacked)
    import jax.numpy as jnp

    n = stacked[0].shape[0]
    pad = -n % devices
    if pad:
        stacked = tuple(
            jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)])
            for a in stacked)
    out = _grid_sharded_impl(devices)(*stacked)
    return out[:n] if pad else out


@functools.lru_cache(maxsize=1)
def _blocked_impl():
    """The chunked twin of :func:`_impl`: one ``lax.scan`` over a chunk of
    the placement order, lp rows/cols arriving as scan inputs instead of a
    device-resident matrix, full greedy state (rem, mask, est, lst, start)
    returned so the host chunk loop keeps it device-resident between
    launches. The step body IS :func:`_placement_step` — the same closure
    the dense scan runs — with ``row``/``col`` arriving as scan inputs
    instead of matrix lookups, so chunked results are bit-identical to
    the dense scan's by construction."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    def chunk_scan(dur, work, rem, mask, est, lst, start, vs, rows, cols):
        core = _placement_step(jnp, dur, work)

        def step(state, xs):
            return core(state, *xs), None

        state, _ = lax.scan(step, (rem, mask, est, lst, start),
                            (vs, rows, cols))
        return state

    # per-argument axes: (dur, work, rem, mask, est, lst, start, vs, rows,
    # cols); unlike the dense scan, est/lst are per-row STATE here (they
    # diverge across variants and profiles between chunk launches)
    variant_axes = (None, None, 0, 0, 0, 0, 0, 0, 0, 0)
    profile_axes = (None, None, 0, 0, 0, 0, 0, None, None, None)
    fanout = jax.vmap(chunk_scan, in_axes=variant_axes)
    multi = jax.vmap(fanout, in_axes=profile_axes)
    # donate the state buffers so chained chunk launches reuse device
    # memory (no-op + warning on CPU, so off-CPU only, as in _impl)
    don = tuple(range(2, 7)) if jax.default_backend() != "cpu" else ()
    return {
        "fanout": jax.jit(fanout, donate_argnums=don),
        "multi": jax.jit(multi, donate_argnums=don),
    }


def _blocked_fanout_padded(dur, work, blp: BlockedLP, budgets, masks,
                           est, lst, orders) -> np.ndarray:
    """All (profile, variant) greedy schedules of one blocked-lp instance,
    chunk-streamed; every input already bucket-padded.

    Args:
      budgets: int [P, Tp]; masks: bool [P, V, Tp+1]; orders: int [V, Np];
      dur/work/est/lst: [Np] (jnp or np).
    Returns:
      int32 np [P, V, Np] start times.
    """
    import jax.numpy as jnp

    budgets = np.asarray(budgets, dtype=np.int32)
    masks = np.asarray(masks, dtype=bool)
    orders = np.asarray(orders, dtype=np.int32)
    P, Tp = budgets.shape
    V, Np = orders.shape
    B = blp.chunk_width(V, Np)
    est = np.asarray(est, dtype=np.int32)
    lst = np.asarray(lst, dtype=np.int32)
    state = (
        jnp.asarray(np.repeat(budgets[:, None, :], V, axis=1)),
        jnp.asarray(masks),
        jnp.asarray(np.broadcast_to(est, (P, V, Np)).copy()),
        jnp.asarray(np.broadcast_to(lst, (P, V, Np)).copy()),
        jnp.asarray(np.zeros((P, V, Np), dtype=np.int32)),
    )
    impl = _blocked_impl()["multi"]
    dur_j, work_j = jnp.asarray(dur), jnp.asarray(work)
    n_chunks = -(-Np // B)
    with obs.span("blocked_chunk_sweep", N=int(Np), chunk_width=int(B),
                  chunks=n_chunks, rows=int(P * V)):
        for c in range(0, Np, B):
            vs = orders[:, c:c + B]
            rows, cols = blp.chunk_tensors(vs, Np)
            state = impl(dur_j, work_j, *state, jnp.asarray(vs),
                         jnp.asarray(rows), jnp.asarray(cols))
    obs.registry().counter(
        "blocked_lp_chunks_total",
        "device chunk launches of the blocked longest-path sweep"
    ).inc(n_chunks)
    return np.asarray(state[4])


def padded_shared(inst: Instance, est0, lst0, lp=None):
    """Bucket-padded profile-independent device tensors (jnp).

    Returns ``(dur, work, lp, est, lst, order_tail)`` at the
    :func:`pad_dims` bucket of ``inst``; ``order_tail`` is the suffix of
    padded task ids every padded score order must end with. ``lp`` may be
    a precomputed dense matrix OR a :class:`BlockedLP` — the blocked
    handle passes through in the lp slot (no device matrix exists) and
    the fan-outs route accordingly.
    """
    import jax.numpy as jnp

    N = inst.num_tasks
    Np, _ = pad_dims(N, 1)
    if lp is None:
        lp = longest_path_matrix(inst)
    if isinstance(lp, BlockedLP):
        lp_j = lp
    else:
        lp_p = np.full((Np, Np), NEG_PATH, dtype=np.int32)
        lp_p[:N, :N] = lp
        np.fill_diagonal(lp_p[N:, N:], 0)
        lp_j = jnp.asarray(lp_p)
    dur_p = np.zeros(Np, dtype=np.int32)
    dur_p[:N] = inst.dur
    work_p = np.zeros(Np, dtype=np.int32)
    work_p[:N] = inst.task_work
    est_p = np.zeros(Np, dtype=np.int32)
    est_p[:N] = est0
    lst_p = np.zeros(Np, dtype=np.int32)
    lst_p[:N] = lst0
    return (jnp.asarray(dur_p), jnp.asarray(work_p), lp_j,
            jnp.asarray(est_p), jnp.asarray(lst_p),
            np.arange(N, Np, dtype=np.int32))


def pad_orders(orders: np.ndarray, order_tail: np.ndarray) -> np.ndarray:
    """[V, N] score orders -> [V, Np]: padded tasks placed last (no-ops)."""
    V = orders.shape[0]
    return np.concatenate(
        [np.asarray(orders, np.int32),
         np.broadcast_to(order_tail, (V, len(order_tail)))], axis=1)


def pad_masks(masks: np.ndarray, Tp: int) -> np.ndarray:
    """[..., T+1] candidate masks -> [..., Tp+1]: padded units never start."""
    T = masks.shape[-1] - 1
    pad = [(0, 0)] * (masks.ndim - 1) + [(0, Tp - T)]
    return np.pad(np.asarray(masks, bool), pad)


def pad_budget(unit_budget: np.ndarray, Tp: int) -> np.ndarray:
    """[..., T] per-unit budgets -> [..., Tp] (padding value is never read)."""
    T = unit_budget.shape[-1]
    pad = [(0, 0)] * (unit_budget.ndim - 1) + [(0, Tp - T)]
    return np.pad(np.asarray(unit_budget, np.int32), pad)


def greedy_schedule_jax(inst: Instance, profile: PowerProfile,
                        platform: Platform, score: str = "press",
                        weighted: bool = False, refined: bool = False,
                        k: int = 3, lp_budget_bytes: int | None = None):
    """Jittable greedy; returns start times (int32 [N]). Instances past
    the ``lp_budget_bytes`` dense envelope stream through the blocked
    form (:class:`BlockedLP`), bit-identically."""
    import jax.numpy as jnp

    T = profile.T
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    if (est0 > lst0).any():
        raise ValueError("infeasible: deadline below ASAP makespan")
    order = task_order(inst, est0, lst0, score, weighted, platform)
    mask0 = candidate_mask(inst, profile, refined=refined, k=k)
    _, Tp = pad_dims(inst.num_tasks, T)
    dur, work, lp, est_j, lst_j, tail = padded_shared(
        inst, est0, lst0, lp_for(inst, lp_budget_bytes))
    rem0 = pad_budget(profile.unit_budget(inst.idle_total), Tp)
    order_p = pad_orders(np.asarray(order, np.int32)[None], tail)
    if isinstance(lp, BlockedLP):
        starts = _blocked_fanout_padded(
            dur, work, lp, rem0[None], pad_masks(mask0, Tp)[None, None],
            est_j, lst_j, order_p)
        return starts[0, 0, :inst.num_tasks]
    start = _impl()["single"](dur, work, lp, jnp.asarray(rem0),
                              jnp.asarray(pad_masks(mask0, Tp)),
                              est_j, lst_j, jnp.asarray(order_p[0]))
    return start[:inst.num_tasks]


def greedy_fanout_jax(inst: Instance, profile: PowerProfile, est0, lst0,
                      masks: np.ndarray, orders: np.ndarray, lp=None,
                      shared=None):
    """All variants of one instance in one jitted vmapped scan.

    Args:
      masks:  bool [V, T+1] per-variant candidate masks.
      orders: int  [V, N] per-variant score orders.
      lp:     optional precomputed :func:`longest_path_matrix`.
      shared: optional :func:`padded_shared` output (device-resident reuse).
    Returns:
      int32 [V, N] start times.
    """
    import jax.numpy as jnp

    _, Tp = pad_dims(inst.num_tasks, profile.T)
    dur, work, lp_j, est_j, lst_j, tail = \
        shared if shared is not None else padded_shared(inst, est0, lst0, lp)
    rem0 = pad_budget(profile.unit_budget(inst.idle_total), Tp)
    if isinstance(lp_j, BlockedLP):
        starts = _blocked_fanout_padded(
            dur, work, lp_j, rem0[None], pad_masks(masks, Tp)[None],
            est_j, lst_j, pad_orders(orders, tail))
        return starts[0, :, :inst.num_tasks]
    starts = _impl()["fanout"](
        dur, work, lp_j, jnp.asarray(rem0),
        jnp.asarray(pad_masks(masks, Tp)), est_j, lst_j,
        jnp.asarray(pad_orders(orders, tail)))
    return starts[:, :inst.num_tasks]


def greedy_fanout_grid_jax(bucket_rows, devices: int | None = None):
    """All (instance, profile, variant) greedy schedules of one shape bucket
    in ONE launch — the third vmap level (instances) over ``multi``.

    Args:
      bucket_rows: per-instance tuples of bucket-padded device inputs in
        ``greedy_scan`` argument order ``(dur, work, lp, rem0 [P, Tp],
        mask0 [P, V, Tp+1], est0, lst0, order [V, Np])``; every row must
        already be padded to the same :func:`pad_dims` bucket (same P, V).
        A row's ``lp`` slot may hold a :class:`BlockedLP` instead of the
        dense matrix — such rows stream through the chunked scan (one
        sequence of launches per blocked row; the dense rows of the
        bucket still ride one grid launch together).
      devices: shard the instance-row axis of the dense launch over this
        many devices (``shard_map`` over ``sharding.ctx.grid_mesh``);
        None / 1 = single-device grid. Results are bitwise-identical
        either way. Blocked rows always stream unsharded (their chunk
        loop is host-driven).
    Returns:
      int32 [I, P, V, Np] start times (caller slices off the task
      padding); a numpy array when any row is blocked, a device array
      otherwise.
    """
    import jax.numpy as jnp

    rows = list(bucket_rows)
    blocked = [isinstance(r[2], BlockedLP) for r in rows]
    if not any(blocked):
        stacked = tuple(jnp.stack([jnp.asarray(r[a]) for r in rows])
                        for a in range(8))
        return _grid_launch(stacked, devices)
    out: list = [None] * len(rows)
    dense_idx = [i for i, b in enumerate(blocked) if not b]
    if dense_idx:
        stacked = tuple(jnp.stack([jnp.asarray(rows[i][a])
                                   for i in dense_idx]) for a in range(8))
        dense_starts = np.asarray(_grid_launch(stacked, devices))
        for j, i in enumerate(dense_idx):
            out[i] = dense_starts[j]
    for i, r in enumerate(rows):
        if blocked[i]:
            dur, work, blp, budgets, masks, est_j, lst_j, orders = r
            out[i] = _blocked_fanout_padded(dur, work, blp, budgets,
                                            masks, est_j, lst_j, orders)
    return np.stack([np.asarray(o) for o in out])


def greedy_fanout_multi_jax(inst: Instance, T: int, unit_budgets: np.ndarray,
                            masks: np.ndarray, orders: np.ndarray,
                            est0=None, lst0=None, lp=None, shared=None):
    """All (profile, variant) greedy schedules of one instance in ONE launch.

    Args:
      unit_budgets: int [P, T] per-profile effective budget timelines.
      masks:        bool [P, V, T+1] per-(profile, variant) candidate masks.
      orders:       int [V, N] score orders (profile-independent given T).
    Returns:
      int32 [P, V, N] start times.
    """
    import jax.numpy as jnp

    _, Tp = pad_dims(inst.num_tasks, T)
    if shared is None:
        shared = padded_shared(inst, est0, lst0, lp)
    dur, work, lp_j, est_j, lst_j, tail = shared
    if isinstance(lp_j, BlockedLP):
        starts = _blocked_fanout_padded(
            dur, work, lp_j, pad_budget(unit_budgets, Tp),
            pad_masks(masks, Tp), est_j, lst_j, pad_orders(orders, tail))
        return starts[:, :, :inst.num_tasks]
    starts = _impl()["multi"](
        dur, work, lp_j, jnp.asarray(pad_budget(unit_budgets, Tp)),
        jnp.asarray(pad_masks(masks, Tp)), est_j, lst_j,
        jnp.asarray(pad_orders(orders, tail)))
    return starts[:, :, :inst.num_tasks]
