"""Device-path greedy: the paper's §5.2 loop as a single ``lax.scan``.

Semantically identical to ``core.greedy.greedy_schedule`` (same score order,
same max-budget/earliest-tie placement, same dynamic splits): the scan state
is (remaining per-unit budget, candidate mask, EST, LST); each step places
one task and re-relaxes EST/LST over the precomputed topological levels with
placed tasks pinned (the fixpoint equals the reference's worklist update).

Intended for on-device replanning (CarbonGate-scale instances, N ~ 10^2-10^3,
T ~ 10^3-10^4); the numpy path remains the big-instance scheduler.
"""
from __future__ import annotations

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask


def _level_buckets(inst: Instance):
    N = inst.num_tasks
    u = np.repeat(np.arange(N), np.diff(inst.succ_ptr))
    v = inst.succ_idx.copy()
    n_levels = int(inst.level.max(initial=0)) + 1

    def bucket(key, uu, vv):
        order = np.argsort(key, kind="stable")
        uu, vv = uu[order], vv[order]
        counts = np.bincount(key, minlength=n_levels)
        mb = max(int(counts.max(initial=1)), 1)
        eu = np.zeros((n_levels, mb), dtype=np.int32)
        ev = np.zeros((n_levels, mb), dtype=np.int32)
        ok = np.zeros((n_levels, mb), dtype=bool)
        off = 0
        for lv in range(n_levels):
            c = counts[lv]
            eu[lv, :c], ev[lv, :c], ok[lv, :c] = uu[off:off + c], \
                vv[off:off + c], True
            off += c
        return eu, ev, ok

    fwd = bucket(inst.level[v], u, v)
    rev = bucket((n_levels - 1 - inst.level[u]), u, v)
    return fwd, rev


def greedy_schedule_jax(inst: Instance, profile: PowerProfile,
                        platform: Platform, score: str = "press",
                        weighted: bool = False, refined: bool = False,
                        k: int = 3):
    """Jittable greedy; returns start times (jnp int32 [N])."""
    import jax
    import jax.numpy as jnp
    from jax import lax

    T = profile.T
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    if (est0 > lst0).any():
        raise ValueError("infeasible: deadline below ASAP makespan")
    order = task_order(inst, est0, lst0, score, weighted, platform)
    mask0 = candidate_mask(inst, profile, refined=refined, k=k)
    rem0 = profile.unit_budget(inst.idle_total).astype(np.int32)
    (eu, ev, eok), (fu, fv, fok) = _level_buckets(inst)

    dur = jnp.asarray(inst.dur, jnp.int32)
    work = jnp.asarray(inst.task_work, jnp.int32)
    tgrid = jnp.arange(T, dtype=jnp.int32)
    pgrid = jnp.arange(T + 1, dtype=jnp.int32)
    big = jnp.int32(np.iinfo(np.int32).max // 4)

    eu_j, ev_j, eok_j = map(jnp.asarray, (eu, ev, eok))
    fu_j, fv_j, fok_j = map(jnp.asarray, (fu, fv, fok))

    def relax(est, lst, placed, start):
        est = jnp.where(placed, start, est)
        lst = jnp.where(placed, start, lst)

        def fwd(e, args):
            uu, vv, ok = args
            cand = jnp.where(ok, e[uu] + dur[uu], 0)
            return e.at[vv].max(cand), None

        est, _ = lax.scan(fwd, est, (eu_j, ev_j, eok_j))

        def bwd(l, args):
            uu, vv, ok = args
            cand = jnp.where(ok, l[vv] - dur[uu], big)
            return l.at[uu].min(cand), None

        lst, _ = lax.scan(bwd, lst, (fu_j, fv_j, fok_j))
        est = jnp.where(placed, start, est)
        lst = jnp.where(placed, start, lst)
        return est, lst

    def step(state, v):
        rem, mask, est, lst, placed, start = state
        feas = mask[:-1] & (pgrid[:-1] >= est[v]) & (pgrid[:-1] <= lst[v])
        any_f = feas.any()
        val = jnp.where(feas, rem, jnp.int32(-(1 << 30)))
        s = jnp.where(any_f, jnp.argmax(val).astype(jnp.int32),
                      est[v].astype(jnp.int32))
        e = s + dur[v]
        run = (tgrid >= s) & (tgrid < e)
        rem = rem - jnp.where(run, work[v], 0).astype(rem.dtype)
        mask = mask.at[s].set(True)
        mask = mask.at[jnp.minimum(e, T)].set(True)
        placed = placed.at[v].set(True)
        start = start.at[v].set(s)
        est, lst = relax(est, lst, placed, start)
        return (rem, mask, est, lst, placed, start), None

    state0 = (jnp.asarray(rem0), jnp.asarray(mask0),
              jnp.asarray(est0, jnp.int32), jnp.asarray(lst0, jnp.int32),
              jnp.zeros(inst.num_tasks, bool),
              jnp.zeros(inst.num_tasks, jnp.int32))
    (rem, mask, est, lst, placed, start), _ = jax.lax.scan(
        step, state0, jnp.asarray(order, jnp.int32))
    return start
