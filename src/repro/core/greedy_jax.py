"""Device-path greedy: the paper's §5.2 loop as a single ``lax.scan``.

Semantically identical to ``core.greedy.greedy_schedule`` (same score order,
same max-budget/earliest-tie placement, same dynamic splits, same endpoint
rule: a task end ``e`` becomes a candidate point only when ``e <= T``): the
scan state is (remaining per-unit budget, candidate mask, EST, LST); each
step places one task and re-relaxes EST/LST over the precomputed topological
levels with placed tasks pinned (the fixpoint equals the reference's
worklist update).

The scan core is *vmappable over the variant axis*: score orders and
candidate masks become batched inputs while the instance tensors (durations,
work powers, level buckets, budget timeline) are shared, so one jitted call
produces the whole 16-variant portfolio (``greedy_fanout_jax``) — and a
second vmap level runs shape-bucketed instance batches
(``repro.core.portfolio.portfolio_starts_batch``, via ``_impl()["batch"]``).
``repro.core.portfolio`` builds the batched inputs from a
:class:`~repro.core.portfolio.PreparedInstance`.

Intended for on-device replanning (CarbonGate-scale instances, N ~ 10^2-10^3,
T ~ 10^3-10^4); the numpy path remains the big-instance scheduler.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask


def _level_buckets(inst: Instance):
    N = inst.num_tasks
    u = np.repeat(np.arange(N), np.diff(inst.succ_ptr))
    v = inst.succ_idx.copy()
    n_levels = int(inst.level.max(initial=0)) + 1

    def bucket(key, uu, vv):
        order = np.argsort(key, kind="stable")
        uu, vv = uu[order], vv[order]
        counts = np.bincount(key, minlength=n_levels)
        mb = max(int(counts.max(initial=1)), 1)
        eu = np.zeros((n_levels, mb), dtype=np.int32)
        ev = np.zeros((n_levels, mb), dtype=np.int32)
        ok = np.zeros((n_levels, mb), dtype=bool)
        off = 0
        for lv in range(n_levels):
            c = counts[lv]
            eu[lv, :c], ev[lv, :c], ok[lv, :c] = uu[off:off + c], \
                vv[off:off + c], True
            off += c
        return eu, ev, ok

    fwd = bucket(inst.level[v], u, v)
    rev = bucket((n_levels - 1 - inst.level[u]), u, v)
    return fwd, rev


# Argument order of the scan core; the first _N_SHARED are per-instance
# tensors shared by every variant, the rest carry the variant axis when
# vmapped (rem0/est0/lst0 stay shared on the variant axis, batched on the
# instance axis).
_N_SHARED = 8


@functools.lru_cache(maxsize=1)
def _impl():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def greedy_scan(dur, work, eu, ev, eok, fu, fv, fok,
                    rem0, mask0, est0, lst0, order):
        """One variant's §5.2 greedy over precomputed inputs (vmappable)."""
        T = rem0.shape[0]
        tgrid = jnp.arange(T, dtype=jnp.int32)
        pgrid = jnp.arange(T + 1, dtype=jnp.int32)
        big = jnp.int32(np.iinfo(np.int32).max // 4)

        def relax(est, lst, placed, start):
            est = jnp.where(placed, start, est)
            lst = jnp.where(placed, start, lst)

            def fwd(e, args):
                uu, vv, ok = args
                cand = jnp.where(ok, e[uu] + dur[uu], 0)
                return e.at[vv].max(cand), None

            est, _ = lax.scan(fwd, est, (eu, ev, eok))

            def bwd(l, args):
                uu, vv, ok = args
                cand = jnp.where(ok, l[vv] - dur[uu], big)
                return l.at[uu].min(cand), None

            lst, _ = lax.scan(bwd, lst, (fu, fv, fok))
            est = jnp.where(placed, start, est)
            lst = jnp.where(placed, start, lst)
            return est, lst

        def step(state, v):
            rem, mask, est, lst, placed, start = state
            feas = mask[:-1] & (pgrid[:-1] >= est[v]) & (pgrid[:-1] <= lst[v])
            any_f = feas.any()
            val = jnp.where(feas, rem, jnp.int32(-(1 << 30)))
            s = jnp.where(any_f, jnp.argmax(val).astype(jnp.int32),
                          est[v].astype(jnp.int32))
            e = s + dur[v]
            run = (tgrid >= s) & (tgrid < e)
            rem = rem - jnp.where(run, work[v], 0).astype(rem.dtype)
            mask = mask.at[s].set(True)
            # numpy endpoint rule: e splits an interval only when e <= T; an
            # overrunning task must not spuriously mark T a candidate point.
            eidx = jnp.minimum(e, T)
            mask = mask.at[eidx].set(mask[eidx] | (e <= T))
            placed = placed.at[v].set(True)
            start = start.at[v].set(s)
            est, lst = relax(est, lst, placed, start)
            return (rem, mask, est, lst, placed, start), None

        N = est0.shape[0]
        state0 = (rem0, mask0, est0, lst0,
                  jnp.zeros(N, bool), jnp.zeros(N, jnp.int32))
        (_, _, _, _, _, start), _ = lax.scan(step, state0, order)
        return start

    variant_axes = (None,) * _N_SHARED + (None, 0, None, None, 0)
    fanout = jax.vmap(greedy_scan, in_axes=variant_axes)
    return {
        "single": jax.jit(greedy_scan),
        "fanout": jax.jit(fanout),
        "batch": jax.jit(jax.vmap(fanout, in_axes=(0,) * 13)),
    }


def _device_inputs(inst: Instance, profile: PowerProfile, est0, lst0,
                   buckets=None):
    """Shared per-instance device tensors (jnp), from host precompute."""
    import jax.numpy as jnp

    (eu, ev, eok), (fu, fv, fok) = buckets or _level_buckets(inst)
    return (jnp.asarray(inst.dur, jnp.int32),
            jnp.asarray(inst.task_work, jnp.int32),
            jnp.asarray(eu), jnp.asarray(ev), jnp.asarray(eok),
            jnp.asarray(fu), jnp.asarray(fv), jnp.asarray(fok),
            jnp.asarray(profile.unit_budget(inst.idle_total)
                        .astype(np.int32)),
            jnp.asarray(est0, jnp.int32), jnp.asarray(lst0, jnp.int32))


def greedy_schedule_jax(inst: Instance, profile: PowerProfile,
                        platform: Platform, score: str = "press",
                        weighted: bool = False, refined: bool = False,
                        k: int = 3):
    """Jittable greedy; returns start times (jnp int32 [N])."""
    import jax.numpy as jnp

    T = profile.T
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    if (est0 > lst0).any():
        raise ValueError("infeasible: deadline below ASAP makespan")
    order = task_order(inst, est0, lst0, score, weighted, platform)
    mask0 = candidate_mask(inst, profile, refined=refined, k=k)
    (dur, work, eu, ev, eok, fu, fv, fok, rem0, est_j, lst_j) = \
        _device_inputs(inst, profile, est0, lst0)
    return _impl()["single"](dur, work, eu, ev, eok, fu, fv, fok,
                             rem0, jnp.asarray(mask0), est_j, lst_j,
                             jnp.asarray(order, jnp.int32))


def greedy_fanout_jax(inst: Instance, profile: PowerProfile, est0, lst0,
                      masks: np.ndarray, orders: np.ndarray, buckets=None):
    """All variants of one instance in one jitted vmapped scan.

    Args:
      masks:  bool [V, T+1] per-variant candidate masks.
      orders: int  [V, N] per-variant score orders.
    Returns:
      int32 [V, N] start times.
    """
    import jax.numpy as jnp

    (dur, work, eu, ev, eok, fu, fv, fok, rem0, est_j, lst_j) = \
        _device_inputs(inst, profile, est0, lst0, buckets)
    return _impl()["fanout"](dur, work, eu, ev, eok, fu, fv, fok,
                             rem0, jnp.asarray(masks), est_j, lst_j,
                             jnp.asarray(orders, jnp.int32))
