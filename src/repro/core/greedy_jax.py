"""Device-path greedy: the paper's §5.2 loop as a single ``lax.scan``.

Semantically identical to ``core.greedy.greedy_schedule`` (same score order,
same max-budget/earliest-tie placement, same dynamic splits, same endpoint
rule: a task end ``e`` becomes a candidate point only when ``e <= T``), but
the per-step EST/LST relaxation is *closed-form*: a host-precomputed
longest-path matrix ``lp`` (:func:`longest_path_matrix`, profile-independent,
cached on :class:`~repro.core.portfolio.PreparedGraph`) turns the paper's
worklist update into two vectorized ops per placement::

    est = max(est, s + lp[v, :])      # descendants of v move right
    lst = min(lst, s - lp[:, v])      # ancestors of v move left

which equals the worklist fixpoint because ``lp[u, t]`` is the maximum
path weight over *all* u->t paths (any transitive propagation is dominated
by the direct matrix entry). The scan step is O(N + T) with no nested
scans, so the program compiles in a fraction of the old level-relax
formulation's time and executes orders of magnitude faster on CPU.

Three vmap levels over the same scan core, all served by one jit cache:

* variants — score orders and candidate masks batched (``greedy_fanout_jax``);
* profiles — budget timelines and masks batched on an outer axis
  (``greedy_fanout_multi_jax``; same shapes by construction, the
  multi-profile replanning fan-out);
* instances — shape-bucketed batches
  (``repro.core.portfolio.portfolio_starts_batch``).

Retracing discipline: all inputs are padded to shape buckets
(:func:`pad_dims` — N to multiples of 128, T to multiples of 256) before
they reach the jitted entry points, so instances whose real shapes differ
hit the same compiled executable; the jit cache is effectively keyed on the
bucket tuple. Padding is output-invariant: padded tasks have zero
duration/work and place at t=0 (a candidate point on every profile), padded
time units are never feasible starts (mask False, and every real LST is
below the real horizon), and the big per-call buffers (budget timeline,
candidate masks) are donated to the runtime off-CPU so repeat calls reuse
device memory.

Intended for on-device replanning (CarbonGate-scale instances, N ~ 10^2-10^3,
T ~ 10^3-10^4); the numpy path remains the big-instance scheduler.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import Instance
from repro.core.estlst import compute_est, compute_lst
from repro.core.scores import task_order
from repro.core.subdivide import candidate_mask

NEG_PATH = -(1 << 30)                  # "no path" marker in lp (int32-safe)

N_BUCKET = 128                         # task-axis shape bucket
T_BUCKET = 256                         # time-axis shape bucket

# Device envelope for the dense longest-path matrix: the matrix is
# O(N^2) int32 (64 MiB at N=4000), fine for the device path's
# N ~ 10^2-10^3 regime but a silent multi-hundred-MiB allocation beyond
# it. 128 MiB admits N ~ 5800; bigger instances must either use
# engine="numpy" (no matrix) or wait for the blocked/sparse-reachability
# form (ROADMAP: "Longest-path matrix memory").
LP_MAX_BYTES = 128 * 2**20


def lp_matrix_bytes(num_tasks: int) -> int:
    """Bytes the dense int32 longest-path matrix of ``num_tasks`` needs."""
    return 4 * int(num_tasks) * int(num_tasks)


def longest_path_matrix(inst: Instance,
                        max_bytes: int | None = None) -> np.ndarray:
    """``lp[u, t]`` = max over u->t paths of the path's duration sum
    (excluding ``dur[t]``); ``lp[v, v] = 0``; unreachable ~ ``NEG_PATH``.

    Profile-independent: one O(E*N) host sweep per instance serves every
    profile, variant and replanning round of the device path. The byte
    cost is checked up front against ``max_bytes`` (default
    :data:`LP_MAX_BYTES`) so an oversized instance fails loudly instead
    of silently allocating O(N^2) device memory.
    """
    N = inst.num_tasks
    limit = LP_MAX_BYTES if max_bytes is None else int(max_bytes)
    need = lp_matrix_bytes(N)
    if need > limit:
        raise MemoryError(
            f"longest-path matrix needs {need / 2**20:.1f} MiB "
            f"(N={N} tasks, O(N^2) int32), over the "
            f"{limit / 2**20:.0f} MiB device envelope; use "
            f"engine='numpy' for this instance or pass a larger "
            f"max_bytes — the blocked / sparse-reachability form is the "
            f"open ROADMAP item 'Longest-path matrix memory'")
    lp = np.full((N, N), NEG_PATH, dtype=np.int32)
    np.fill_diagonal(lp, 0)
    dur = inst.dur.astype(np.int32)
    for v in inst.topo:
        ps = inst.preds(v)
        if len(ps):
            cand = lp[:, ps] + dur[ps][None, :]
            np.maximum(lp[:, v], cand.max(axis=1), out=lp[:, v])
    return lp


def _bucket_up(x: int, q: int) -> int:
    return max(((int(x) + q - 1) // q) * q, q)


def pad_dims(N: int, T: int) -> tuple[int, int]:
    """Shape bucket for an (N tasks, T horizon) instance."""
    return _bucket_up(N, N_BUCKET), _bucket_up(T, T_BUCKET)


@functools.lru_cache(maxsize=1)
def _impl():
    import jax
    import jax.numpy as jnp
    from jax import lax

    def greedy_scan(dur, work, lp, rem0, mask0, est0, lst0, order):
        """One variant's §5.2 greedy over precomputed inputs (vmappable)."""
        T = rem0.shape[0]
        tgrid = jnp.arange(T, dtype=jnp.int32)
        big = jnp.int32(np.iinfo(np.int32).max // 4)

        def step(state, v):
            rem, mask, est, lst, start = state
            feas = mask[:-1] & (tgrid >= est[v]) & (tgrid <= lst[v])
            any_f = feas.any()
            val = jnp.where(feas, rem, -big)
            s = jnp.where(any_f, jnp.argmax(val).astype(jnp.int32),
                          est[v].astype(jnp.int32))
            e = s + dur[v]
            run = (tgrid >= s) & (tgrid < e)
            rem = rem - jnp.where(run, work[v], 0).astype(rem.dtype)
            mask = mask.at[s].set(True)
            # numpy endpoint rule: e splits an interval only when e <= T; an
            # overrunning task must not spuriously mark T a candidate point.
            eidx = jnp.minimum(e, T)
            mask = mask.at[eidx].set(mask[eidx] | (e <= T))
            est = jnp.maximum(est, s + lp[v])
            lst = jnp.minimum(lst, s - lp[:, v])
            start = start.at[v].set(s)
            return (rem, mask, est, lst, start), None

        N = est0.shape[0]
        state0 = (rem0, mask0, est0, lst0, jnp.zeros(N, jnp.int32))
        (_, _, _, _, start), _ = lax.scan(step, state0, order)
        return start

    # axis spec per argument: (dur, work, lp, rem0, mask0, est0, lst0, order)
    variant_axes = (None, None, None, None, 0, None, None, 0)
    profile_axes = (None, None, None, 0, 0, None, None, None)
    fanout = jax.vmap(greedy_scan, in_axes=variant_axes)
    multi = jax.vmap(fanout, in_axes=profile_axes)
    # donate the big per-call buffers (budget timeline, masks) so repeat
    # calls reuse device memory; on CPU donation is a no-op and only warns,
    # so it is enabled off-CPU only.
    don = (3, 4) if jax.default_backend() != "cpu" else ()
    return {
        "single": jax.jit(greedy_scan, donate_argnums=don),
        "fanout": jax.jit(fanout, donate_argnums=don),
        "multi": jax.jit(multi, donate_argnums=don),
        "batch": jax.jit(jax.vmap(fanout, in_axes=(0,) * 8),
                         donate_argnums=don),
        "grid": jax.jit(jax.vmap(multi, in_axes=(0,) * 8),
                        donate_argnums=don),
    }


def padded_shared(inst: Instance, est0, lst0, lp=None):
    """Bucket-padded profile-independent device tensors (jnp).

    Returns ``(dur, work, lp, est, lst, order_tail)`` at the
    :func:`pad_dims` bucket of ``inst``; ``order_tail`` is the suffix of
    padded task ids every padded score order must end with.
    """
    import jax.numpy as jnp

    N = inst.num_tasks
    Np, _ = pad_dims(N, 1)
    if lp is None:
        lp = longest_path_matrix(inst)
    lp_p = np.full((Np, Np), NEG_PATH, dtype=np.int32)
    lp_p[:N, :N] = lp
    np.fill_diagonal(lp_p[N:, N:], 0)
    dur_p = np.zeros(Np, dtype=np.int32)
    dur_p[:N] = inst.dur
    work_p = np.zeros(Np, dtype=np.int32)
    work_p[:N] = inst.task_work
    est_p = np.zeros(Np, dtype=np.int32)
    est_p[:N] = est0
    lst_p = np.zeros(Np, dtype=np.int32)
    lst_p[:N] = lst0
    return (jnp.asarray(dur_p), jnp.asarray(work_p), jnp.asarray(lp_p),
            jnp.asarray(est_p), jnp.asarray(lst_p),
            np.arange(N, Np, dtype=np.int32))


def pad_orders(orders: np.ndarray, order_tail: np.ndarray) -> np.ndarray:
    """[V, N] score orders -> [V, Np]: padded tasks placed last (no-ops)."""
    V = orders.shape[0]
    return np.concatenate(
        [np.asarray(orders, np.int32),
         np.broadcast_to(order_tail, (V, len(order_tail)))], axis=1)


def pad_masks(masks: np.ndarray, Tp: int) -> np.ndarray:
    """[..., T+1] candidate masks -> [..., Tp+1]: padded units never start."""
    T = masks.shape[-1] - 1
    pad = [(0, 0)] * (masks.ndim - 1) + [(0, Tp - T)]
    return np.pad(np.asarray(masks, bool), pad)


def pad_budget(unit_budget: np.ndarray, Tp: int) -> np.ndarray:
    """[..., T] per-unit budgets -> [..., Tp] (padding value is never read)."""
    T = unit_budget.shape[-1]
    pad = [(0, 0)] * (unit_budget.ndim - 1) + [(0, Tp - T)]
    return np.pad(np.asarray(unit_budget, np.int32), pad)


def greedy_schedule_jax(inst: Instance, profile: PowerProfile,
                        platform: Platform, score: str = "press",
                        weighted: bool = False, refined: bool = False,
                        k: int = 3):
    """Jittable greedy; returns start times (jnp int32 [N])."""
    import jax.numpy as jnp

    T = profile.T
    est0 = compute_est(inst)
    lst0 = compute_lst(inst, T)
    if (est0 > lst0).any():
        raise ValueError("infeasible: deadline below ASAP makespan")
    order = task_order(inst, est0, lst0, score, weighted, platform)
    mask0 = candidate_mask(inst, profile, refined=refined, k=k)
    _, Tp = pad_dims(inst.num_tasks, T)
    dur, work, lp, est_j, lst_j, tail = padded_shared(inst, est0, lst0)
    rem0 = pad_budget(profile.unit_budget(inst.idle_total), Tp)
    order_p = pad_orders(np.asarray(order, np.int32)[None], tail)[0]
    start = _impl()["single"](dur, work, lp, jnp.asarray(rem0),
                              jnp.asarray(pad_masks(mask0, Tp)),
                              est_j, lst_j, jnp.asarray(order_p))
    return start[:inst.num_tasks]


def greedy_fanout_jax(inst: Instance, profile: PowerProfile, est0, lst0,
                      masks: np.ndarray, orders: np.ndarray, lp=None,
                      shared=None):
    """All variants of one instance in one jitted vmapped scan.

    Args:
      masks:  bool [V, T+1] per-variant candidate masks.
      orders: int  [V, N] per-variant score orders.
      lp:     optional precomputed :func:`longest_path_matrix`.
      shared: optional :func:`padded_shared` output (device-resident reuse).
    Returns:
      int32 [V, N] start times.
    """
    import jax.numpy as jnp

    _, Tp = pad_dims(inst.num_tasks, profile.T)
    dur, work, lp_j, est_j, lst_j, tail = \
        shared if shared is not None else padded_shared(inst, est0, lst0, lp)
    rem0 = pad_budget(profile.unit_budget(inst.idle_total), Tp)
    starts = _impl()["fanout"](
        dur, work, lp_j, jnp.asarray(rem0),
        jnp.asarray(pad_masks(masks, Tp)), est_j, lst_j,
        jnp.asarray(pad_orders(orders, tail)))
    return starts[:, :inst.num_tasks]


def greedy_fanout_grid_jax(bucket_rows):
    """All (instance, profile, variant) greedy schedules of one shape bucket
    in ONE launch — the third vmap level (instances) over ``multi``.

    Args:
      bucket_rows: per-instance tuples of bucket-padded device inputs in
        ``greedy_scan`` argument order ``(dur, work, lp, rem0 [P, Tp],
        mask0 [P, V, Tp+1], est0, lst0, order [V, Np])``; every row must
        already be padded to the same :func:`pad_dims` bucket (same P, V).
    Returns:
      int32 [I, P, V, Np] start times (caller slices off the task padding).
    """
    import jax.numpy as jnp

    stacked = tuple(jnp.stack([jnp.asarray(r[a]) for r in bucket_rows])
                    for a in range(8))
    return _impl()["grid"](*stacked)


def greedy_fanout_multi_jax(inst: Instance, T: int, unit_budgets: np.ndarray,
                            masks: np.ndarray, orders: np.ndarray,
                            est0=None, lst0=None, lp=None, shared=None):
    """All (profile, variant) greedy schedules of one instance in ONE launch.

    Args:
      unit_budgets: int [P, T] per-profile effective budget timelines.
      masks:        bool [P, V, T+1] per-(profile, variant) candidate masks.
      orders:       int [V, N] score orders (profile-independent given T).
    Returns:
      int32 [P, V, N] start times.
    """
    import jax.numpy as jnp

    _, Tp = pad_dims(inst.num_tasks, T)
    if shared is None:
        shared = padded_shared(inst, est0, lst0, lp)
    dur, work, lp_j, est_j, lst_j, tail = shared
    starts = _impl()["multi"](
        dur, work, lp_j, jnp.asarray(pad_budget(unit_budgets, Tp)),
        jnp.asarray(pad_masks(masks, Tp)), est_j, lst_j,
        jnp.asarray(pad_orders(orders, tail)))
    return starts[:, :, :inst.num_tasks]
