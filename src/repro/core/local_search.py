"""Local search (paper §5.3): hill-climbing task shifts of up to ±mu units.

Numpy reference implements the paper exactly: processors in non-increasing
P_work order, tasks left-to-right per processor, candidate new starts
scanned earliest-to-latest, *first* improving legal move applied, rounds
until a full gainless round.

Legality of a move uses the current schedule: the new execution window must
respect the current start times of DAG neighbours (which include the fixed
per-processor chains) and the deadline.

`repro.core.local_search_jax` provides the batched device version that
uses the Pallas gain kernel as a move proposer and this module's
`move_gain`/`apply_move` arithmetic for exact commits.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import PowerProfile, work_timeline
from repro.core.dag import Instance


def dyn_bounds(inst: Instance, start: np.ndarray, v: int,
               T: int) -> tuple[int, int]:
    """Legal start-time range of task v given the rest of the schedule."""
    lo, hi = 0, T - int(inst.dur[v])
    ps = inst.preds(v)
    if len(ps):
        lo = max(lo, int((start[ps] + inst.dur[ps]).max()))
    ss = inst.succs(v)
    if len(ss):
        hi = min(hi, int(start[ss].min()) - int(inst.dur[v]))
    return lo, hi


def move_gain(rem: np.ndarray, s: int, e: int, new_s: int, w: int) -> int:
    """Exact cost gain of moving a task from [s,e) to [new_s,new_s+e-s).

    ``rem`` is the remaining-budget timeline *including* the task at its old
    position. Positive gain = cost decreases. Only the symmetric difference
    of the two windows contributes.
    """
    d = new_s - s
    if d == 0:
        return 0
    ln = min(abs(d), e - s)
    if d > 0:
        vac_lo, vac_hi = s, s + ln              # vacated units
        occ_hi = new_s + (e - s)                # newly occupied units
        occ_lo = occ_hi - ln
    else:
        vac_lo, vac_hi = e - ln, e
        occ_lo, occ_hi = new_s, new_s + ln
    # cost released on vacated units: deficit drops by up to w
    rv = rem[vac_lo:vac_hi]
    released = np.minimum(np.maximum(-rv, 0), w).sum()
    # cost incurred on newly occupied units
    ro = rem[occ_lo:occ_hi]
    incurred = np.minimum(np.maximum(w - np.maximum(ro, 0), 0), w).sum()
    return int(released - incurred)


def apply_move(rem: np.ndarray, s: int, e: int, new_s: int, w: int) -> None:
    """Update the remaining-budget timeline for the move."""
    rem[s:e] += w
    rem[new_s:new_s + (e - s)] -= w


def local_search(inst: Instance, profile: PowerProfile, platform,
                 start: np.ndarray, mu: int = 10,
                 max_rounds: int | None = None) -> np.ndarray:
    """Paper §5.3 local search; returns improved start times."""
    T = profile.T
    start = np.asarray(start, dtype=np.int64).copy()
    rem = (profile.unit_budget(inst.idle_total)
           - work_timeline(inst, T, start)).astype(np.int64)

    # processors by non-increasing P_work (compute + link processors)
    chain_order = np.argsort(
        -platform.p_work[inst.chain_proc_ids], kind="stable")

    rounds = 0
    while True:
        any_gain = False
        for ci in chain_order:
            chain = inst.proc_chains[ci]
            for v in chain:
                w = int(inst.task_work[v])
                if w == 0:
                    continue
                s = int(start[v])
                e = s + int(inst.dur[v])
                lo, hi = dyn_bounds(inst, start, v, T)
                lo = max(lo, s - mu)
                hi = min(hi, s + mu)
                for new_s in range(lo, hi + 1):   # earliest to latest
                    if new_s == s:
                        continue
                    g = move_gain(rem, s, e, new_s, w)
                    if g > 0:                     # first improving move
                        apply_move(rem, s, e, new_s, w)
                        start[v] = new_s
                        any_gain = True
                        break
        rounds += 1
        if not any_gain or (max_rounds is not None and rounds >= max_rounds):
            break
    return start


def timeline_cost(rem: np.ndarray) -> int:
    """Cost of a remaining-budget timeline: sum of per-unit deficits."""
    return int(np.maximum(-rem, 0).sum())
