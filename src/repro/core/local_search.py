"""Local search (paper §5.3): hill-climbing task shifts of up to ±mu units.

Numpy reference implements the paper exactly: processors in non-increasing
P_work order, tasks left-to-right per processor, candidate new starts
scanned earliest-to-latest, *first* improving legal move applied, rounds
until a full gainless round.

The implementation is round-batched but *bit-identical* to the scalar
reference (tests assert equality): at the head of each round, one vectorized
pass (:func:`_batch_proposals`) computes every task's first improving legal
shift against the round-start timeline — all ±mu gains fall out of four
prefix sums over released/incurred unit contributions around each task's
start and end, the same integer arithmetic as :func:`move_gain`. The
sequential visit then commits a task's cached proposal only while it is
provably fresh: a commit dirties the touched time window and marks the task
moved, and any later task whose ±mu window intersects a dirty interval — or
that has a moved DAG neighbour (legal bounds changed) — is re-evaluated
exactly (:func:`_first_improving`). Commits are rare after the first rounds,
so almost every visit is a cache hit; this is what makes the 17-variant
portfolio engine fast on CPU.

Legality of a move uses the current schedule: the new execution window must
respect the current start times of DAG neighbours (which include the fixed
per-processor chains) and the deadline.

`repro.core.local_search_jax` provides the batched device version that
uses the Pallas gain kernel as a move proposer and this module's
`move_gain`/`apply_move` arithmetic for exact commits.
"""
from __future__ import annotations

import numpy as np

from repro.core.carbon import PowerProfile, work_timeline
from repro.core.dag import Instance


def dyn_bounds(inst: Instance, start: np.ndarray, v: int,
               T: int) -> tuple[int, int]:
    """Legal start-time range of task v given the rest of the schedule."""
    lo, hi = 0, T - int(inst.dur[v])
    ps = inst.preds(v)
    if len(ps):
        lo = max(lo, int((start[ps] + inst.dur[ps]).max()))
    ss = inst.succs(v)
    if len(ss):
        hi = min(hi, int(start[ss].min()) - int(inst.dur[v]))
    return lo, hi


def move_gain(rem: np.ndarray, s: int, e: int, new_s: int, w: int) -> int:
    """Exact cost gain of moving a task from [s,e) to [new_s,new_s+e-s).

    ``rem`` is the remaining-budget timeline *including* the task at its old
    position. Positive gain = cost decreases. Only the symmetric difference
    of the two windows contributes.
    """
    d = new_s - s
    if d == 0:
        return 0
    ln = min(abs(d), e - s)
    if d > 0:
        vac_lo, vac_hi = s, s + ln              # vacated units
        occ_hi = new_s + (e - s)                # newly occupied units
        occ_lo = occ_hi - ln
    else:
        vac_lo, vac_hi = e - ln, e
        occ_lo, occ_hi = new_s, new_s + ln
    # cost released on vacated units: deficit drops by up to w
    rv = rem[vac_lo:vac_hi]
    released = np.minimum(np.maximum(-rv, 0), w).sum()
    # cost incurred on newly occupied units
    ro = rem[occ_lo:occ_hi]
    incurred = np.minimum(np.maximum(w - np.maximum(ro, 0), 0), w).sum()
    return int(released - incurred)


def apply_move(rem: np.ndarray, s: int, e: int, new_s: int, w: int) -> None:
    """Update the remaining-budget timeline for the move."""
    rem[s:e] += w
    rem[new_s:new_s + (e - s)] -= w


def _first_improving(rem_pad, pad, s, e, dur, w, lo, hi, mu, dpos, dneg):
    """Earliest improving legal shift of the task at [s, e), or None.

    Bit-identical to scanning ``new_s = lo..hi`` ascending with
    :func:`move_gain`: the released/incurred unit contributions around the
    start and end are prefix-summed once, giving every shift's exact integer
    gain; the first positive one wins. ``rem_pad`` is the remaining-budget
    timeline padded by ``pad >= mu`` zeros on both sides (zero padding
    contributes 0 released — matching the reference's silent slice clipping —
    and out-of-horizon incurred units only arise for illegal shifts).
    """
    m1 = min(mu, dur)
    o = pad
    rel_s = np.minimum(np.maximum(-rem_pad[o + s:o + s + m1], 0), w)
    inc_e = np.minimum(np.maximum(
        w - np.maximum(rem_pad[o + e:o + e + mu], 0), 0), w)
    rel_e = np.minimum(np.maximum(-rem_pad[o + e - m1:o + e], 0), w)
    inc_s = np.minimum(np.maximum(
        w - np.maximum(rem_pad[o + s - mu:o + s], 0), 0), w)
    pr_s = np.concatenate(([0], np.cumsum(rel_s)))
    pi_e = np.concatenate(([0], np.cumsum(inc_e)))
    pr_e = np.concatenate(([0], np.cumsum(rel_e)))
    pi_s = np.concatenate(([0], np.cumsum(inc_s)))

    g = np.empty(2 * mu + 1, dtype=np.int64)
    ln_p = np.minimum(dpos, dur)                  # shift right by dpos
    g[mu + 1:] = pr_s[ln_p] - (pi_e[dpos] - pi_e[dpos - ln_p])
    ln_n = np.minimum(-dneg, dur)                 # shift left by -dneg
    g[:mu] = (pr_e[m1] - pr_e[m1 - ln_n]) \
        - (pi_s[mu + dneg + ln_n] - pi_s[mu + dneg])
    g[mu] = 0

    lo_i = lo - s + mu                            # legal window in delta grid
    hi_i = hi - s + mu
    window = g[lo_i:hi_i + 1] > 0
    if lo_i <= mu <= hi_i:
        window[mu - lo_i] = False                 # delta == 0
    j = int(np.argmax(window))
    if not window[j]:
        return None
    return s + (lo_i + j - mu), int(g[lo_i + j])


def _batch_proposals(rem_pad, pad, start, dur, work, lo, hi, mu, T):
    """Every task's first improving legal shift vs the current timeline.

    Vectorized over (task, shift): same prefix-sum identities as
    :func:`_first_improving`, all tasks at once. Returns (proposal, fresh):
    ``proposal[v]`` = first improving new start (or -1 = none), ``fresh[v]``
    False marks rows the batch could not evaluate (out-of-horizon tasks).
    """
    N = len(start)
    s = start
    e = start + dur
    okrow = e <= T                      # out-of-horizon rows -> scalar path
    m1 = np.minimum(mu, dur)
    j = np.arange(mu)[None, :]
    top = rem_pad.shape[0] - 1

    win = rem_pad[np.minimum(pad + s[:, None] + j, top)]
    rel_s = np.where(j < m1[:, None],
                     np.minimum(np.maximum(-win, 0), work[:, None]), 0)
    win = rem_pad[np.minimum(pad + e[:, None] + j, top)]
    inc_e = np.minimum(np.maximum(
        work[:, None] - np.maximum(win, 0), 0), work[:, None])
    win = rem_pad[np.minimum(pad + (e - m1)[:, None] + j, top)]
    rel_e = np.where(j < m1[:, None],
                     np.minimum(np.maximum(-win, 0), work[:, None]), 0)
    win = rem_pad[np.maximum(pad + (s - mu)[:, None] + j, 0)]
    inc_s = np.minimum(np.maximum(
        work[:, None] - np.maximum(win, 0), 0), work[:, None])

    z = np.zeros((N, 1), dtype=np.int64)
    pr_s = np.concatenate([z, np.cumsum(rel_s, axis=1)], axis=1)
    pi_e = np.concatenate([z, np.cumsum(inc_e, axis=1)], axis=1)
    pr_e = np.concatenate([z, np.cumsum(rel_e, axis=1)], axis=1)
    pi_s = np.concatenate([z, np.cumsum(inc_s, axis=1)], axis=1)

    g = np.zeros((N, 2 * mu + 1), dtype=np.int64)
    dpos = np.arange(1, mu + 1)[None, :]
    ln_p = np.minimum(dpos, dur[:, None])
    g[:, mu + 1:] = (np.take_along_axis(pr_s, ln_p, 1)
                     - (pi_e[:, 1:] - np.take_along_axis(pi_e, dpos - ln_p, 1)))
    dneg = np.arange(-mu, 0)[None, :]
    ln_n = np.minimum(-dneg, dur[:, None])
    g[:, :mu] = ((np.take_along_axis(pr_e, m1[:, None], 1)
                  - np.take_along_axis(pr_e, m1[:, None] - ln_n, 1))
                 - (np.take_along_axis(pi_s, mu + dneg + ln_n, 1)
                    - pi_s[:, :mu]))

    dgrid = np.arange(-mu, mu + 1)[None, :]
    legal = ((dgrid >= (lo - s)[:, None]) & (dgrid <= (hi - s)[:, None])
             & (dgrid != 0) & (g > 0) & okrow[:, None]
             & (work > 0)[:, None])
    first = np.argmax(legal, axis=1)
    has = legal[np.arange(N), first]
    proposal = np.where(has, s + first - mu, -1)
    return proposal, okrow


def dyn_bounds_all(start, dur, T, edges):
    """Vectorized :func:`dyn_bounds` for every task at once.

    ``edges`` is the ``(v_of_pred, u_pred, u_of_succ, v_succ)`` tuple from
    :func:`ls_context` (shared with the batched device climbers).
    """
    v_of_pred, u_pred, u_of_succ, v_succ = edges
    N = len(start)
    lo = np.zeros(N, dtype=np.int64)
    np.maximum.at(lo, v_of_pred, start[u_pred] + dur[u_pred])
    hi = np.full(N, np.iinfo(np.int64).max // 4, dtype=np.int64)
    np.minimum.at(hi, u_of_succ, start[v_succ])
    hi = np.minimum(hi, T) - dur
    return lo, hi


def ls_graph_context(inst: Instance, platform=None) -> dict:
    """The profile-independent half of :func:`ls_context`.

    A :class:`~repro.core.portfolio.PreparedGraph` computes this once; each
    profile overlay completes it with its own ``unit_budget``. ``platform``
    is optional: a chain's P_work equals the task_work of any of its tasks
    (``task_work[v] == p_work[proc[v]]`` by construction), so the visit
    order is derivable from the instance alone.
    """
    N = inst.num_tasks
    if platform is not None:
        chain_power = platform.p_work[inst.chain_proc_ids]
    else:
        chain_power = np.asarray(
            [inst.task_work[c[0]] for c in inst.proc_chains], dtype=np.int64)
    chain_order = np.argsort(-chain_power, kind="stable")
    return {
        "visit": [int(v) for ci in chain_order
                  for v in inst.proc_chains[ci]],
        "edges": (np.repeat(np.arange(N), np.diff(inst.pred_ptr)),
                  inst.pred_idx,
                  np.repeat(np.arange(N), np.diff(inst.succ_ptr)),
                  inst.succ_idx),
        "nbrs": [inst.preds(v).tolist() + inst.succs(v).tolist()
                 for v in range(N)],
        "work_l": inst.task_work.tolist(),
        "dur_l": inst.dur.tolist(),
    }


def ls_context(inst: Instance, profile: PowerProfile, platform) -> dict:
    """Schedule-independent local-search state, reusable across variants.

    A :class:`~repro.core.portfolio.PreparedInstance` computes this once and
    every ``-LS`` variant's :func:`local_search` call shares it.
    """
    ctx = ls_graph_context(inst, platform)
    ctx["unit_budget"] = profile.unit_budget(inst.idle_total).astype(np.int64)
    return ctx


def local_search(inst: Instance, profile: PowerProfile, platform,
                 start: np.ndarray, mu: int = 10,
                 max_rounds: int | None = None,
                 ctx: dict | None = None) -> np.ndarray:
    """Paper §5.3 local search; returns improved start times.

    ``ctx`` optionally reuses :func:`ls_context` precompute (the portfolio
    engine's amortization); results are identical with or without it.
    """
    T = profile.T
    ctx = ctx or ls_context(inst, profile, platform)
    start = np.asarray(start, dtype=np.int64).copy()
    pad = mu
    rem_pad = np.zeros(T + 2 * pad, dtype=np.int64)
    rem_pad[pad:pad + T] = ctx["unit_budget"] - work_timeline(inst, T, start)

    rounds = 0
    while True:
        any_gain = reference_round(inst, T, rem_pad, pad, start, mu, ctx)
        rounds += 1
        if not any_gain or (max_rounds is not None and rounds >= max_rounds):
            break
    return start


def reference_round(inst: Instance, T: int, rem_pad: np.ndarray, pad: int,
                    start: np.ndarray, mu: int, ctx: dict) -> bool:
    """ONE round of the paper's §5.3 hill climb, in place.

    Exactly the loop body of :func:`local_search` (which delegates here):
    batch-propose every task's first improving legal shift against the
    round-start timeline, then visit tasks in processor order, committing
    fresh proposals and re-evaluating stale ones exactly. Mutates ``start``
    and the timeline behind ``rem_pad``; returns whether any move committed.

    Shared with the batched device climbers
    (:mod:`repro.core.local_search_jax`), whose per-variant termination rule
    is "a reference round commits nothing" — the same criterion that ends
    the sequential climb, so no variant stops while the sequential reference
    could still improve it.
    """
    dur = inst.dur
    work = inst.task_work
    rem = rem_pad[pad:pad + T]                    # writes go through the view
    dpos = np.arange(1, mu + 1)
    dneg = np.arange(-mu, 0)
    # processors visited in non-increasing P_work order (compute + links);
    # edge arrays for the vectorized dynamic bounds; DAG neighbour lists
    # (which include the chain edges) for the moved-neighbour staleness check
    visit = ctx["visit"]
    edges = ctx["edges"]
    nbrs = ctx["nbrs"]
    work_l = ctx["work_l"]
    dur_l = ctx["dur_l"]

    any_gain = False
    # round-start snapshot: cached proposals valid until invalidated
    lo_all, hi_all = dyn_bounds_all(start, dur, T, edges)
    lo_all = np.maximum(lo_all, start - mu)
    hi_all = np.minimum(hi_all, start + mu)
    proposal, fresh_row = _batch_proposals(
        rem_pad, pad, start, dur, work, lo_all, hi_all, mu, T)
    prop_l = proposal.tolist()
    fresh_l = fresh_row.tolist()
    start_l = start.tolist()
    moved: set[int] = set()
    dirty: list[tuple[int, int]] = []             # committed-move windows

    for v in visit:
        w = work_l[v]
        if w == 0:
            continue
        s = start_l[v]
        e = s + dur_l[v]
        stale = (not fresh_l[v]
                 or any(u in moved for u in nbrs[v])
                 or any(a < e + mu and s - mu < b for a, b in dirty))
        if not stale:
            new_s = prop_l[v]
            if new_s < 0:
                continue
        else:
            lo, hi = dyn_bounds(inst, start, v, T)
            lo = max(lo, s - mu)
            hi = min(hi, s + mu)
            if lo > hi:
                continue
            if e <= T:
                got = _first_improving(rem_pad, pad, s, e, dur_l[v], w,
                                       lo, hi, mu, dpos, dneg)
                if got is None:
                    continue
                new_s = got[0]
            else:
                # out-of-horizon task (pathological placements): keep the
                # reference scalar scan, whose slices clip at T.
                new_s = -1
                for cand_s in range(lo, hi + 1):
                    if cand_s == s:
                        continue
                    if move_gain(rem, s, e, cand_s, w) > 0:
                        new_s = cand_s
                        break
                if new_s < 0:
                    continue
        apply_move(rem, s, e, new_s, w)
        start[v] = new_s
        any_gain = True
        moved.add(v)
        dirty.append((min(s, new_s), max(e, new_s + dur_l[v])))
    return any_gain


def timeline_cost(rem: np.ndarray) -> int:
    """Cost of a remaining-budget timeline: sum of per-unit deficits."""
    return int(np.maximum(-rem, 0).sum())
