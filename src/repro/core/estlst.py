"""Earliest/latest start times on G_c (paper §5.1/§5.2).

Two implementations:
  * numpy Kahn-style propagation (the paper's algorithm, the reference);
  * a jittable level-synchronous edge-relaxation (`est_lst_jnp`) — the
    TPU-native adaptation: topological levels are precomputed once, then one
    ``segment_max`` per level relaxes all in-edges of that level at once.
"""
from __future__ import annotations

import numpy as np

from repro.core.dag import Instance


def compute_est(inst: Instance, start_fixed: np.ndarray | None = None,
                fixed_mask: np.ndarray | None = None) -> np.ndarray:
    """EST(v) = max over preds (EST(u) + dur(u)); fixed tasks pin their start."""
    est = np.zeros(inst.num_tasks, dtype=np.int64)
    for v in inst.topo:
        ps = inst.preds(v)
        if len(ps):
            est[v] = int((est[ps] + inst.dur[ps]).max())
        if fixed_mask is not None and fixed_mask[v]:
            est[v] = start_fixed[v]
    return est


def compute_lst(inst: Instance, T: int, start_fixed: np.ndarray | None = None,
                fixed_mask: np.ndarray | None = None) -> np.ndarray:
    """LST(v) = min over succs LST(s) - dur(v); init T - dur(v)."""
    lst = T - inst.dur
    for v in inst.topo[::-1]:
        ss = inst.succs(v)
        if len(ss):
            lst[v] = min(int(lst[ss].min() - inst.dur[v]), int(lst[v]))
        if fixed_mask is not None and fixed_mask[v]:
            lst[v] = start_fixed[v]
    return lst


def asap_schedule(inst: Instance) -> np.ndarray:
    """The ASAP baseline (paper §5.1): start every task at its EST.

    Served on the Planner's solver axis as ``PlanRequest(solver="asap")``
    (:class:`repro.core.solvers.AsapSolver`, the regression floor of the
    heuristics-vs-baseline-vs-exact evaluation)."""
    return compute_est(inst)


def makespan(inst: Instance, start: np.ndarray) -> int:
    return int((np.asarray(start) + inst.dur).max())


# ---------------------------------------------------------------------------
# Incremental worklist updates used inside the greedy (paper: "updates have
# to be made possibly for the whole graph ... O(n + |E_c|)"). We propagate
# only where values actually change, which is equivalent but cheaper.
# ---------------------------------------------------------------------------

def raise_est_from(inst: Instance, est: np.ndarray, v: int,
                   new_start: int, scheduled: np.ndarray) -> None:
    """Pin task v's start and push the EST increase through its successors."""
    if new_start > est[v]:
        est[v] = new_start
    work = [v]
    while work:
        u = work.pop()
        ready = est[u] + inst.dur[u]
        for s in inst.succs(u):
            if ready > est[s]:
                est[s] = ready
                if not scheduled[s]:
                    work.append(int(s))


def lower_lst_from(inst: Instance, lst: np.ndarray, v: int,
                   new_start: int, scheduled: np.ndarray) -> None:
    """Pin task v's start and push the LST decrease through its predecessors."""
    if new_start < lst[v]:
        lst[v] = new_start
    work = [v]
    while work:
        u = work.pop()
        for p in inst.preds(u):
            bound = lst[u] - inst.dur[p]
            if bound < lst[p]:
                lst[p] = bound
                if not scheduled[p]:
                    work.append(int(p))


# ---------------------------------------------------------------------------
# jnp level-synchronous relaxation
# ---------------------------------------------------------------------------

def est_lst_jnp(inst: Instance, T: int):
    """Jittable EST/LST: one segment-max per topological level.

    Returns (est, lst) as jnp arrays. Edge list is grouped by the *target's*
    level (for EST) / the source's level (for LST); a lax.scan over levels
    applies ``max``-relaxations with fixed shapes per level bucket (padded).
    """
    import jax
    import jax.numpy as jnp

    N = inst.num_tasks
    u = np.repeat(np.arange(N), np.diff(inst.succ_ptr))
    v = inst.succ_idx.copy()
    n_levels = int(inst.level.max(initial=0)) + 1

    # bucket edges by target level
    tgt_level = inst.level[v]
    order = np.argsort(tgt_level, kind="stable")
    u_s, v_s = u[order], v[order]
    counts = np.bincount(tgt_level, minlength=n_levels)
    max_bucket = int(counts.max(initial=1))
    # pad each level bucket to max_bucket with self-loops on a dummy slot
    eu = np.zeros((n_levels, max_bucket), dtype=np.int64)
    ev = np.zeros((n_levels, max_bucket), dtype=np.int64)
    evalid = np.zeros((n_levels, max_bucket), dtype=bool)
    off = 0
    for lvl in range(n_levels):
        c = counts[lvl]
        eu[lvl, :c] = u_s[off:off + c]
        ev[lvl, :c] = v_s[off:off + c]
        evalid[lvl, :c] = True
        off += c

    dur = jnp.asarray(inst.dur.astype(np.int32))

    def est_body(est, args):
        eu_l, ev_l, valid_l = args
        cand = jnp.where(valid_l, est[eu_l] + dur[eu_l], 0)
        est = est.at[ev_l].max(cand)
        return est, None

    est0 = jnp.zeros(N, dtype=jnp.int32)
    est, _ = jax.lax.scan(
        est_body, est0,
        (jnp.asarray(eu), jnp.asarray(ev), jnp.asarray(evalid)))

    # LST: relax in reverse level order, keyed by source level
    src_level = inst.level[u]
    order2 = np.argsort(-src_level, kind="stable")
    u2, v2 = u[order2], v[order2]
    counts2 = np.bincount(n_levels - 1 - src_level, minlength=n_levels)
    mb2 = int(counts2.max(initial=1))
    fu = np.zeros((n_levels, mb2), dtype=np.int64)
    fv = np.zeros((n_levels, mb2), dtype=np.int64)
    fvalid = np.zeros((n_levels, mb2), dtype=bool)
    off = 0
    for i in range(n_levels):
        c = counts2[i]
        fu[i, :c] = u2[off:off + c]
        fv[i, :c] = v2[off:off + c]
        fvalid[i, :c] = True
        off += c

    big = jnp.asarray(np.iinfo(np.int32).max // 4, dtype=jnp.int32)

    def lst_body(lst, args):
        fu_l, fv_l, valid_l = args
        cand = jnp.where(valid_l, lst[fv_l] - dur[fu_l], big)
        lst = lst.at[fu_l].min(cand)
        return lst, None

    lst0 = jnp.asarray((T - inst.dur).astype(np.int32))
    lst, _ = jax.lax.scan(
        lst_body, lst0,
        (jnp.asarray(fu), jnp.asarray(fv), jnp.asarray(fvalid)))
    return est, lst
