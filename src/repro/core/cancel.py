"""Cooperative cancellation for long-running solves.

The serving tier's watchdog (PR 6) could *abandon* a timed-out solve but
never *stop* it: the orphaned chain stage kept burning a solve-pool
worker until it finished on its own. A :class:`CancelToken` closes that
gap cooperatively — the token is threaded from the watchdog (or a
client's :meth:`~repro.serve.service.Ticket.cancel`) down into every
solver layer, and the layers poll it at their natural chunk boundaries:

* :mod:`repro.core.solvers` — between grid cells (every per-cell solver);
* :mod:`repro.core.ilp` — before each HiGHS solve, and the solve itself
  is bounded by the token's deadline (scipy's ``milp`` exposes no
  interrupt callback, so the deadline-clamped ``time_limit`` IS the
  interrupt surface for one in-flight MILP);
* :mod:`repro.core.portfolio` — between greedy cells / device chunk
  launches and before each local-search climb;
* :mod:`repro.core.local_search_jax` — between device commit rounds'
  host syncs and between sequential polish rounds.

Every poll increments :attr:`CancelToken.checks`, so tests (and the
service's ``cancel_checks`` telemetry) can assert cancellation is real —
a cancelled solve observed the token and stopped, rather than running to
completion unobserved.

Tokens are cheap, thread-safe, and single-shot: once cancelled they stay
cancelled. ``deadline`` (a ``time.monotonic()`` timestamp) makes a token
self-expiring — :meth:`check` raises once the deadline passes even if
nobody called :meth:`cancel` — which is how a ticket's wall-clock budget
reaches solver layers that only ever see the token.
"""
from __future__ import annotations

import threading
import time

from repro import obs

# How long a cancelled solve keeps running before a poll point notices:
# observed once per cancellation, on the (rare) raising path of check().
_CANCEL_LATENCY = obs.registry().histogram(
    "cancel_observe_latency_seconds",
    "delay between CancelToken.cancel() and the poll that observed it",
    reservoir=256)


class Cancelled(Exception):
    """Raised by :meth:`CancelToken.check` inside a cancelled solve.

    Deliberately NOT a :class:`RuntimeError` subclass: retry/backoff
    handlers for transient faults must never catch a cancellation (a
    cancelled solve is *done*, not degraded)."""


class CancelToken:
    """One cancellable scope: a flag, an optional deadline, and counters.

    Args:
      deadline: optional ``time.monotonic()`` timestamp after which
        :meth:`check` raises on its own (the wall-clock budget spelling).

    Attributes:
      checks: how many times a solver layer polled this token — the
        "cancellation is real" observability counter.
      reason: why the token was cancelled (None while live).
    """

    __slots__ = ("deadline", "checks", "reason", "_cancelled", "_lock",
                 "cancelled_at", "_latency_done")

    def __init__(self, deadline: float | None = None):
        self.deadline = deadline
        self.checks = 0
        self.reason: str | None = None
        self._cancelled = False
        self._lock = threading.Lock()
        self.cancelled_at: float | None = None
        self._latency_done = False

    @classmethod
    def with_budget(cls, budget: float | None) -> "CancelToken":
        """A token expiring ``budget`` seconds from now (None = never)."""
        return cls(None if budget is None else time.monotonic() + budget)

    def cancel(self, reason: str = "cancelled") -> bool:
        """Cancel the scope; returns False if it already was cancelled."""
        with self._lock:
            if self._cancelled:
                return False
            self._cancelled = True
            self.reason = reason
            self.cancelled_at = time.monotonic()
            return True

    @property
    def cancelled(self) -> bool:
        """True once :meth:`cancel` fired or the deadline passed."""
        if self._cancelled:
            return True
        if self.deadline is not None and time.monotonic() >= self.deadline:
            self.cancel("deadline expired")
            return True
        return False

    def remaining(self) -> float | None:
        """Seconds until the deadline (None = no deadline)."""
        return None if self.deadline is None \
            else self.deadline - time.monotonic()

    def check(self) -> None:
        """Poll point: count the observation, raise if cancelled.

        Solver layers call this at chunk boundaries; it is the ONLY way a
        solve learns it was cancelled, so every layer's loop must reach a
        ``check()`` within one chunk of work.
        """
        self.checks += 1        # benign race: a lost increment only
        # undercounts telemetry, never correctness
        if self.cancelled:
            # rare path: record cancel -> observation latency once
            if not self._latency_done and self.cancelled_at is not None:
                self._latency_done = True
                _CANCEL_LATENCY.observe(time.monotonic() - self.cancelled_at)
            raise Cancelled(self.reason or "cancelled")


def checkpoint(cancel: "CancelToken | None") -> None:
    """``cancel.check()`` tolerating ``None`` — the call sites' spelling
    (every solver-layer ``cancel=`` parameter defaults to None)."""
    if cancel is not None:
        cancel.check()
