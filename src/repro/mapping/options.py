"""Validated knobs for the mapping search.

Kept dependency-free so `api.request` can validate `mapping_options`
at admission time without pulling in the search machinery.
"""

from __future__ import annotations

from dataclasses import dataclass, fields

_OBJECTIVES = ("auto", "best", "robust")


@dataclass(frozen=True)
class MappingOptions:
    """Knobs for ``PlanRequest(mapping="search", mapping_options=...)``.

    seeds      -- size of the seed mapping population (HEFT + carbon-aware
                  variants + random perturbations), >= 1
    rounds     -- max neighborhood-improvement rounds, >= 0 (0 = seeds only)
    neighbors  -- candidate mappings generated per round, >= 1
    elite      -- elite set size carried between rounds, >= 1
    patience   -- stop after this many rounds without improvement, >= 1
    seed       -- RNG seed; the whole search is bit-reproducible per seed
    objective  -- elite ranking: "best" (min over profiles), "robust"
                  (minimax over profiles), or "auto" (follow the
                  request's `robust` flag)
    """

    seeds: int = 6
    rounds: int = 4
    neighbors: int = 12
    elite: int = 3
    patience: int = 2
    seed: int = 0
    objective: str = "auto"

    def __post_init__(self):
        for name, lo in (("seeds", 1), ("rounds", 0), ("neighbors", 1),
                         ("elite", 1), ("patience", 1), ("seed", 0)):
            val = getattr(self, name)
            if not isinstance(val, int) or isinstance(val, bool) or val < lo:
                raise ValueError(
                    f"mapping_options[{name!r}] must be an int >= {lo}, "
                    f"got {val!r}")
        if self.objective not in _OBJECTIVES:
            raise ValueError(
                f"mapping_options['objective'] must be one of "
                f"{_OBJECTIVES}, got {self.objective!r}")

    def max_candidates(self) -> int:
        """Upper bound on mappings this search evaluates: the seed
        population plus every improvement round's full neighborhood."""
        return self.seeds + self.rounds * self.neighbors

    def shrunk_to(self, budget: int) -> "MappingOptions | None":
        """The largest version of this search evaluating <= ``budget``
        candidates — the serving tier's budget-aware degradation knob
        (fallback rungs shrink the search to the remaining deadline
        budget before dropping to plain HEFT).

        Shrinks ``rounds`` first (keep the seed population, run fewer
        improvement passes), then ``neighbors``, then ``seeds``.
        Returns ``self`` when it already fits, ``None`` when even a
        2-candidate search (HEFT seed + one alternative) does not —
        callers should fall back to plain HEFT then.
        """
        if budget >= self.max_candidates():
            return self
        if budget < 2:
            return None
        seeds = min(self.seeds, budget)
        left = budget - seeds
        neighbors = min(self.neighbors, max(left, 1))
        rounds = min(self.rounds, left // neighbors)
        return MappingOptions(
            seeds=seeds, rounds=rounds, neighbors=neighbors,
            elite=min(self.elite, seeds), patience=self.patience,
            seed=self.seed, objective=self.objective)

    @classmethod
    def from_dict(cls, options: "dict | MappingOptions | None") -> "MappingOptions":
        """Build from a request-supplied dict, rejecting unknown keys."""
        if options is None:
            return cls()
        if isinstance(options, cls):
            return options
        if not isinstance(options, dict):
            raise ValueError(
                f"mapping_options must be a dict, got {type(options).__name__}")
        known = {f.name for f in fields(cls)}
        unknown = sorted(set(options) - known)
        if unknown:
            raise ValueError(
                f"unknown mapping_options keys {unknown}; "
                f"allowed: {sorted(known)}")
        return cls(**options)

    def to_dict(self) -> dict:
        return {f.name: getattr(self, f.name) for f in fields(self)}
