"""Alternating mapping x scheduling search over the batched grid.

Each round evaluates a *batch* of candidate mappings by handing them to
the request's solver as the instance axis of one ``solve_grid`` call —
under the jax engine that is the portfolio's shape-bucketed triple-vmap
launch with mappings x profiles x variants fanned out together, so a
round of C candidates costs one (cached-compile) device launch, not C
solves.  The elite set is kept by best/robust carbon cost; the loop
stops on convergence (``patience`` stale rounds), the round cap, or a
:class:`~repro.core.cancel.CancelToken` firing (deadline budgets from
the serving tier land here).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import obs
from repro.core.cancel import checkpoint
from repro.core.cawosched import deadline_from_asap
from repro.core.dag import FixedMapping, Instance, build_instance
from repro.core.heft import heft_mapping
from repro.core.portfolio import (heuristic_indices, jit_entries_total,
                                  prepare_graph)
from repro.kernels.backend import resolve_engine
from repro.mapping.moves import (mapping_from_assignment, neighborhood,
                                 rank_priority)
from repro.mapping.options import MappingOptions
from repro.mapping.seeds import seed_mappings
from repro.workflows.generators import Workflow

_C_BUCKET = 8                          # candidate-axis shape bucket (jax)

_CANDIDATES = obs.registry().counter(
    "mapping_candidates_total",
    "candidate mappings evaluated through the grid", labels=("workflow",))
_ROUNDS = obs.registry().counter(
    "mapping_rounds_total", "mapping-search improvement rounds",
    labels=("workflow",))
_IMPROVEMENTS = obs.registry().counter(
    "mapping_improvements_total",
    "rounds that improved the elite best cost", labels=("workflow",))


@dataclasses.dataclass(frozen=True)
class MappingSearchInfo:
    """Search provenance carried on :class:`repro.api.PlanResult`.

    ``trace`` is the elite best score after the seed round and after
    every improvement round; ``candidate_costs`` aligns with
    ``candidate_labels`` (the per-mapping cost tensor reduced to the
    search objective); ``cache_misses`` samples the jit-entry delta of
    each evaluation batch — steady state, later batches add zero.
    """

    mode: str
    objective: str = "best"
    label: str = ""                      # winning candidate's label
    rounds: int = 0                      # improvement rounds actually run
    candidates: int = 0                  # mappings evaluated
    infeasible: int = 0                  # mappings rejected by EST/LST
    trace: tuple = ()                    # int per round: elite best score
    cache_misses: tuple = ()             # int per evaluation batch
    candidate_labels: tuple = ()
    candidate_costs: tuple = ()          # int per evaluated candidate
    seconds: float = 0.0

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        for key in ("trace", "cache_misses", "candidate_labels",
                    "candidate_costs"):
            d[key] = list(d[key])
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "MappingSearchInfo":
        kw = dict(d)
        for key in ("trace", "cache_misses", "candidate_labels",
                    "candidate_costs"):
            kw[key] = tuple(kw.get(key, ()))
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class MappingOutcome:
    """Winner of the mapping resolution for one workflow."""

    mapping: FixedMapping
    instance: Instance
    graph: object | None                 # winner's PreparedGraph, if built
    cost: int                            # objective score (-1: unevaluated)
    info: MappingSearchInfo


@dataclasses.dataclass
class _Candidate:
    label: str
    mapping: FixedMapping
    instance: Instance
    graph: object
    score: int
    seq: int                             # deterministic tie-break


def _mapping_key(m: FixedMapping) -> tuple:
    return (m.proc.tobytes(), m.order, tuple(sorted(m.comm_order.items())))


def _score(costs_pv: np.ndarray, cols: list, objective: str) -> int:
    if objective == "robust":
        return int(costs_pv[:, cols].max(axis=0).min())
    return int(costs_pv[:, cols].min())


class _Evaluator:
    """Batch-evaluates labeled mappings through the request's solver."""

    def __init__(self, wf, platform, row, planner, solver, names,
                 objective, solver_options, cancel, devices=None):
        self.wf, self.platform, self.row = wf, platform, tuple(row)
        self.planner, self.solver, self.names = planner, solver, tuple(names)
        self.objective = objective
        self.solver_options, self.cancel = solver_options, cancel
        self.devices = devices
        self.cols = heuristic_indices(self.names)
        self.T = int(row[0].T)
        self.infeasible = 0
        self.cache_misses: list[int] = []
        self.evaluated: list[_Candidate] = []
        self._seq = 0

    def run(self, labeled: "list[tuple[str, FixedMapping]]") -> list[_Candidate]:
        built = []
        for label, m in labeled:
            inst = build_instance(self.wf, m, self.platform,
                                  name=f"{self.wf.name}|{label}")
            g = prepare_graph(inst, self.platform, self.T, k=self.planner.k,
                              lp_budget_bytes=self.planner.lp_budget_bytes)
            if not g.feasible:           # deadline below this mapping's ASAP
                self.infeasible += 1
                continue
            built.append((label, m, inst, g))
        if not built:
            return []
        insts = [b[2] for b in built]
        graphs = [b[3] for b in built] if self.solver.uses_graphs else None
        fanout = len(insts) * len(self.row)
        engine = resolve_engine(self.planner.engine, fanout=fanout) \
            if self.solver.name == "heuristic" else "numpy"
        if engine == "jax":
            # The grid launch jits over the bucket's instance axis, so every
            # distinct batch size would compile a fresh signature.  Pad the
            # candidate batch to a multiple of _C_BUCKET by repeating the
            # last candidate — all rounds then ride one compiled launch.
            # The repeats are BY IDENTITY, so the portfolio pass dedupes
            # their host-side cost (graphs/overlays/climbs/assembly run
            # once; only the shape-stable vmap rows repeat) and nothing
            # below this point sees the pad rows: ``built`` stops at the
            # real candidates, so ``evaluated`` / ``candidates`` /
            # ``candidate_costs`` count only real ones.
            pad = -len(insts) % _C_BUCKET
            insts = insts + [insts[-1]] * pad
            if graphs is not None:
                graphs = graphs + [graphs[-1]] * pad
        j0 = jit_entries_total()
        out = self.solver.solve_grid(
            insts, [self.row] * len(insts), self.platform, self.names,
            k=self.planner.k, mu=self.planner.ls.mu,
            validate=self.planner.validate, engine=engine, graphs=graphs,
            commit_k=self.planner.ls.commit_k,
            ls_max_rounds=self.planner.ls.max_rounds,
            options=self.solver_options, cancel=self.cancel,
            devices=self.devices)
        self.cache_misses.append(max(jit_entries_total() - j0, 0))
        costs = out.cost_tensor(self.names)          # [C, P, V]
        batch = []
        for c, (label, m, inst, g) in enumerate(built):
            cand = _Candidate(label=label, mapping=m, instance=inst, graph=g,
                              score=_score(costs[c], self.cols,
                                           self.objective),
                              seq=self._seq)
            self._seq += 1
            batch.append(cand)
        self.evaluated.extend(batch)
        _CANDIDATES.inc(len(batch), workflow=self.wf.name)
        return batch


def search_mapping(wf: Workflow, platform, row, *, planner, solver, names,
                   options: MappingOptions, robust: bool = False,
                   solver_options: dict | None = None,
                   cancel=None, devices: int | None = None) -> MappingOutcome:
    """Run the alternating search for one workflow over one profile row."""
    t0 = time.perf_counter()
    objective = options.objective
    if objective == "auto":
        objective = "robust" if robust else "best"
    ev = _Evaluator(wf, platform, row, planner, solver, names, objective,
                    solver_options, cancel, devices=devices)
    trace: list[int] = []
    with obs.span("mapping_search", workflow=wf.name, mode="search",
                  objective=objective):
        checkpoint(cancel)
        seen: set = set()
        seeds = []
        for label, m in seed_mappings(wf, platform, list(row), options):
            key = _mapping_key(m)
            if key not in seen:
                seen.add(key)
                seeds.append((label, m))
        with obs.span("mapping_round", round=0, candidates=len(seeds)):
            batch = ev.run(seeds)
        if not batch:
            raise ValueError(
                f"mapping search: every seed mapping of {wf.name!r} is "
                f"infeasible for horizon T={ev.T} (deadline below ASAP "
                f"makespan) — raise the deadline")
        elite = sorted(batch, key=lambda c: (c.score, c.seq))[:options.elite]
        trace.append(elite[0].score)
        rng = np.random.default_rng(options.seed + 1)
        priority = rank_priority(wf, platform)
        stall = rounds_run = 0
        for r in range(1, options.rounds + 1):
            if stall >= options.patience:
                break
            checkpoint(cancel)
            fresh = []
            for kind, vec in neighborhood(wf, platform,
                                          [c.mapping.proc for c in elite],
                                          rng, options.neighbors):
                key = (vec.tobytes(),)   # canonical completion: proc is key
                if key in seen:
                    continue
                seen.add(key)
                fresh.append((f"r{r}:{kind}",
                              mapping_from_assignment(wf, platform, vec,
                                                      priority)))
            with obs.span("mapping_round", round=r, candidates=len(fresh)):
                batch = ev.run(fresh)
            rounds_run += 1
            _ROUNDS.inc(workflow=wf.name)
            best_before = elite[0].score
            elite = sorted(elite + batch,
                           key=lambda c: (c.score, c.seq))[:options.elite]
            trace.append(elite[0].score)
            if elite[0].score < best_before:
                _IMPROVEMENTS.inc(workflow=wf.name)
                stall = 0
            else:
                stall += 1
    winner = elite[0]
    info = MappingSearchInfo(
        mode="search", objective=objective, label=winner.label,
        rounds=rounds_run, candidates=len(ev.evaluated),
        infeasible=ev.infeasible, trace=tuple(trace),
        cache_misses=tuple(ev.cache_misses),
        candidate_labels=tuple(c.label for c in ev.evaluated),
        candidate_costs=tuple(c.score for c in ev.evaluated),
        seconds=time.perf_counter() - t0)
    return MappingOutcome(mapping=winner.mapping, instance=winner.instance,
                          graph=winner.graph, cost=winner.score, info=info)


def resolve_mappings(planner, workflows, grid, names, solver, *,
                     mode: str, options=None, robust: bool = False,
                     solver_options: dict | None = None,
                     cancel=None, deadline_scale: float | None = None,
                     devices: int | None = None
                     ) -> tuple[list[MappingOutcome], list]:
    """Resolve one mapping per workflow for the mapping-mode plan path.

    ``mode="heft"`` maps each workflow with exact HEFT (no evaluation);
    ``mode="search"`` runs :func:`search_mapping`.  The returned
    instances feed the planner's normal fixed-mapping path; winner
    graphs are pre-built so the planner's cache sees them for free.

    Returns ``(outcomes, grid)``: the resolved mappings plus the profile
    grid the schedule solve must run on.  With ``deadline_scale`` set,
    each workflow's deadline is ``scale x ASAP-makespan`` of a reference
    exact-HEFT mapping — the mapping being decided cannot define its own
    horizon, so the reference anchors it the way the pre-built Instance
    does in fixed mode — and the workflow's profile row is cropped to
    that horizon BEFORE candidates are evaluated, so search candidates
    compete under the same deadline the winner is scheduled with
    (candidates whose own ASAP overruns it are rejected as infeasible,
    like any too-tight mapping).  ``devices`` shards the candidate
    batches' grid launches (see ``Planner.devices``).
    """
    from repro.api.request import crop_profile   # lazy: api imports us

    opts = MappingOptions.from_dict(options)
    outcomes: list[MappingOutcome] = []
    out_grid: list = []
    for wf, row in zip(workflows, grid):
        m_ref = inst_ref = None
        if mode == "heft" or deadline_scale is not None:
            m_ref = heft_mapping(wf, planner.platform)
            inst_ref = build_instance(wf, m_ref, planner.platform,
                                      name=f"{wf.name}|heft")
        if deadline_scale is not None:
            T = deadline_from_asap(inst_ref, deadline_scale)
            row = [crop_profile(p, T) for p in row]
        out_grid.append(list(row))
        if mode == "heft":
            outcomes.append(MappingOutcome(
                mapping=m_ref, instance=inst_ref, graph=None, cost=-1,
                info=MappingSearchInfo(mode="heft", label="heft")))
        elif mode == "search":
            outcomes.append(search_mapping(
                wf, planner.platform, row, planner=planner, solver=solver,
                names=names, options=opts, robust=robust,
                solver_options=solver_options, cancel=cancel,
                devices=devices))
        else:
            raise ValueError(f"unknown mapping mode {mode!r}")
    return outcomes, out_grid
