"""Neighborhood moves over a mapping, as processor-assignment vectors.

A candidate mapping is just a ``proc`` vector ``[n]`` plus one shared,
topologically consistent task priority: the HEFT upward rank (mean exec
cost), which strictly decreases along every workflow edge, so ordering
each processor's tasks — and each link's communications — by priority can
never create a cycle in ``G_c``.  `mapping_from_assignment` is the
canonical (deterministic) completion of an assignment into a full
`FixedMapping`; the three move kinds (single-task reassign, pairwise
swap, critical-path-segment migration) perturb only the vector.
"""
from __future__ import annotations

import numpy as np

from repro.cluster import Platform
from repro.core.dag import FixedMapping
from repro.workflows.generators import Workflow, topological_order


def upward_ranks(wf: Workflow, rank_exec: np.ndarray) -> np.ndarray:
    """HEFT upward ranks from per-task rank costs (``rank_exec`` [n]).

    ``rank[v] = rank_exec[v] + max over edges (v, s) of (c_vs + rank[s])``.
    """
    n = wf.n
    succs: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (u, v), cw in zip(wf.edges, wf.edge_w):
        succs[int(u)].append((int(v), int(cw)))
    rank = np.zeros(n, dtype=np.float64)
    for v in reversed(topological_order(n, wf.edges)):
        best = 0.0
        for (s, cw) in succs[v]:
            best = max(best, cw + rank[s])
        rank[v] = float(rank_exec[v]) + best
    return rank


def rank_priority(wf: Workflow, platform: Platform) -> np.ndarray:
    """Dense priority positions [n] by descending mean-exec upward rank.

    Since every task's rank cost is >= 1, ``rank[u] > rank[v]`` for every
    edge ``(u, v)`` — the priority is a topological order of the workflow,
    independent of any candidate assignment.
    """
    exec_t = np.maximum(
        np.ceil(wf.node_w[:, None] / platform.speed[None, :]), 1)
    rank = upward_ranks(wf, exec_t.mean(axis=1))
    order = sorted(range(wf.n), key=lambda v: (-rank[v], v))
    pos = np.empty(wf.n, dtype=np.int64)
    pos[order] = np.arange(wf.n)
    return pos


def mapping_from_assignment(wf: Workflow, platform: Platform,
                            proc: np.ndarray,
                            priority: np.ndarray) -> FixedMapping:
    """Deterministic `FixedMapping` from an assignment vector.

    Per-processor orders sort by ``priority``; per-link communication
    orders sort by ``(priority[u], priority[v])``.  Acyclicity of the
    resulting ``G_c``: map compute task v to key ``(priority[v], -1)``
    and communication task (u, v) to ``(priority[u], priority[v])`` —
    every edge of ``G_c`` (workflow, comm in/out, compute chain, link
    chain) strictly increases the key, so no cycle exists.
    """
    proc = np.asarray(proc, dtype=np.int64)
    P = platform.num_compute
    order: list[list[int]] = [[] for _ in range(P)]
    for v in sorted(range(wf.n), key=lambda v: int(priority[v])):
        order[proc[v]].append(v)
    comm_order: dict[int, list[tuple[int, int]]] = {}
    cross = [(int(u), int(v)) for (u, v) in wf.edges if proc[u] != proc[v]]
    cross.sort(key=lambda e: (int(priority[e[0]]), int(priority[e[1]])))
    for (u, v) in cross:
        link = platform.link_id(int(proc[u]), int(proc[v]))
        comm_order.setdefault(link, []).append((u, v))
    return FixedMapping(
        proc=proc,
        order=tuple(tuple(o) for o in order),
        comm_order={k: tuple(v) for k, v in comm_order.items()},
    )


def critical_path(wf: Workflow, platform: Platform,
                  proc: np.ndarray) -> list[int]:
    """Longest path (exec + cross-proc comm) under an assignment, as a
    task-id list from a source to the latest-finishing sink."""
    proc = np.asarray(proc, dtype=np.int64)
    exec_t = platform.exec_time(wf.node_w, proc)
    n = wf.n
    preds: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (u, v), cw in zip(wf.edges, wf.edge_w):
        u, v = int(u), int(v)
        comm = int(cw) if proc[u] != proc[v] else 0
        preds[v].append((u, comm))
    est = np.zeros(n, dtype=np.int64)
    topo = topological_order(n, wf.edges)
    for v in topo:
        for (u, comm) in preds[v]:
            est[v] = max(est[v], est[u] + exec_t[u] + comm)
    finish = est + exec_t
    v = int(finish.argmax())
    path = [v]
    while preds[path[-1]]:
        v = path[-1]
        u_best = max(preds[v],
                     key=lambda uc: (int(est[uc[0]] + exec_t[uc[0]] + uc[1]),
                                     -uc[0]))
        if est[u_best[0]] + exec_t[u_best[0]] + u_best[1] != est[v]:
            break                     # v starts at 0 / not pred-bound
        path.append(u_best[0])
    path.reverse()
    return path


_MOVE_KINDS = ("reassign", "swap", "migrate")


def neighborhood(wf: Workflow, platform: Platform,
                 elites: list[np.ndarray], rng: np.random.Generator,
                 count: int) -> list[tuple[str, np.ndarray]]:
    """``count`` labeled candidate assignments perturbing the elite set.

    Cycles through the three move kinds; every move starts from a
    round-robin elite so the neighborhood covers the whole front.
    """
    n, P = wf.n, platform.num_compute
    out: list[tuple[str, np.ndarray]] = []
    for j in range(count):
        base = elites[j % len(elites)].copy()
        kind = _MOVE_KINDS[j % len(_MOVE_KINDS)]
        if kind == "swap" and (P < 2 or n < 2):
            kind = "reassign"
        if kind == "reassign":
            v = int(rng.integers(n))
            p = int(rng.integers(P))
            if P > 1:
                while p == base[v]:
                    p = int(rng.integers(P))
            base[v] = p
        elif kind == "swap":
            a = int(rng.integers(n))
            b = int(rng.integers(n))
            tries = 0
            while base[a] == base[b] and tries < 8:
                b = int(rng.integers(n))
                tries += 1
            if base[a] == base[b]:     # all picks co-located: reassign a
                p = int(rng.integers(P))
                while P > 1 and p == base[a]:
                    p = int(rng.integers(P))
                base[a] = p
            else:
                base[a], base[b] = base[b], base[a]
        else:                          # migrate a critical-path segment
            path = critical_path(wf, platform, base)
            L = int(rng.integers(2, 6)) if len(path) > 1 else 1
            L = min(L, len(path))
            i0 = int(rng.integers(len(path) - L + 1))
            target = int(rng.integers(P))
            while P > 1 and target == base[path[i0]]:
                target = int(rng.integers(P))
            for v in path[i0:i0 + L]:
                base[v] = target
        out.append((kind, base))
    return out
