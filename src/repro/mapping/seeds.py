"""Seed mapping population: HEFT plus carbon-aware HEFT variants.

`heft_generic` is a parametrized twin of `core/heft.py` (which stays
byte-stable as the paper's reference): the rank cost can be weighted by
green-window availability and the EFT selection can be restricted to a
processor subset or penalized per processor.  `seed_mappings` combines
the exact HEFT mapping, a green-availability-weighted variant,
speed-tiered affinity variants, round-robin, and RNG perturbations of
HEFT into a diverse population for the search to start from.
"""
from __future__ import annotations

import numpy as np

from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import FixedMapping, trivial_mapping
from repro.core.heft import heft_mapping
from repro.mapping.moves import (mapping_from_assignment, rank_priority,
                                 upward_ranks)
from repro.mapping.options import MappingOptions
from repro.workflows.generators import Workflow


def green_availability(platform: Platform,
                       profiles: "list[PowerProfile]") -> np.ndarray:
    """Per compute processor: fraction of the horizon whose effective
    green budget covers that processor's work draw, averaged over the
    profile ensemble.  High availability = the processor can usually run
    for free."""
    P = platform.num_compute
    avail = np.zeros(P, dtype=np.float64)
    for prof in profiles:
        g = prof.unit_budget(platform.idle_total)          # [T] effective
        avail += (g[None, :] >= platform.p_work[:P, None]).mean(axis=1)
    return avail / max(len(profiles), 1)


def heft_generic(wf: Workflow, platform: Platform, *,
                 allowed: np.ndarray | None = None,
                 rank_weight: np.ndarray | None = None,
                 select_penalty: np.ndarray | None = None) -> FixedMapping:
    """HEFT with a parametrized rank cost and processor selection.

    allowed        -- bool [P]: processors admitted to EFT selection
    rank_weight    -- float [P]: multiplies exec time in the rank mean
    select_penalty -- float [P]: EFT score becomes eft + w_vp * penalty[p]
                      (carbon bias: penalize processors that rarely fit
                      the green windows)

    With all three at their defaults this reproduces `heft_mapping`.
    """
    n, P = wf.n, platform.num_compute
    mask = np.ones(P, dtype=bool) if allowed is None \
        else np.asarray(allowed, dtype=bool)
    assert mask.any(), "heft_generic: empty allowed processor set"
    procs = np.flatnonzero(mask)
    exec_t = np.maximum(
        np.ceil(wf.node_w[:, None] / platform.speed[None, :]), 1
    ).astype(np.int64)
    weight = np.ones(P) if rank_weight is None \
        else np.asarray(rank_weight, dtype=np.float64)
    penalty = np.zeros(P) if select_penalty is None \
        else np.asarray(select_penalty, dtype=np.float64)

    rank = upward_ranks(wf, (exec_t[:, procs] * weight[procs]).mean(axis=1))
    order_tasks = sorted(range(n), key=lambda v: (-rank[v], v))

    preds: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for (u, v), cw in zip(wf.edges, wf.edge_w):
        preds[int(v)].append((int(u), int(cw)))

    proc = np.full(n, -1, dtype=np.int64)
    aft = np.zeros(n, dtype=np.int64)
    ast = np.zeros(n, dtype=np.int64)
    slots: list[list[tuple[int, int]]] = [[] for _ in range(P)]
    for v in order_tasks:
        best = None
        for p in procs:
            ready = 0
            for (u, cw) in preds[v]:
                arr = aft[u] + (cw if proc[u] != p else 0)
                ready = max(ready, int(arr))
            w = int(exec_t[v, p])
            t = ready
            for (s0, e0) in slots[p]:
                if t + w <= s0:
                    break
                t = max(t, e0)
            score = t + w + w * penalty[p]
            if best is None or (score, int(p)) < (best[0], best[1]):
                best = (score, int(p), t, t + w)
        _, p, t, eft = best
        proc[v] = p
        ast[v] = t
        aft[v] = eft
        slots[p].append((t, eft))
        slots[p].sort()

    order: list[list[int]] = [[] for _ in range(P)]
    for p in range(P):
        tasks_p = [v for v in range(n) if proc[v] == p]
        tasks_p.sort(key=lambda v: (ast[v], v))
        order[p] = tasks_p
    comm_order: dict[int, list[tuple[int, int]]] = {}
    cross = [(int(u), int(v)) for (u, v) in wf.edges if proc[u] != proc[v]]
    cross.sort(key=lambda e: (aft[e[0]], ast[e[1]], e))
    for (u, v) in cross:
        link = platform.link_id(int(proc[u]), int(proc[v]))
        comm_order.setdefault(link, []).append((u, v))
    return FixedMapping(
        proc=proc,
        order=tuple(tuple(o) for o in order),
        comm_order={k: tuple(vs) for k, vs in comm_order.items()},
    )


def seed_mappings(wf: Workflow, platform: Platform,
                  profiles: "list[PowerProfile]",
                  options: MappingOptions) -> list[tuple[str, FixedMapping]]:
    """A diverse, deterministic seed population of size >= options.seeds.

    Always starts with exact HEFT (so the search's round-0 elite is never
    worse than `mapping="heft"`); fills up with carbon-aware variants and
    rank-priority perturbations of the HEFT assignment.
    """
    P = platform.num_compute
    seeds: list[tuple[str, FixedMapping]] = [
        ("seed:heft", heft_mapping(wf, platform))]

    avail = green_availability(platform, profiles)
    pen = 1.0 / np.maximum(avail, 0.05) - 1.0      # 0 when always green
    seeds.append(("seed:green", heft_generic(
        wf, platform, rank_weight=1.0 + pen, select_penalty=pen)))

    med = float(np.median(platform.speed))
    slow = platform.speed <= med
    fast = platform.speed >= med
    if slow.any() and not slow.all():
        seeds.append(("seed:tier_slow", heft_generic(wf, platform, allowed=slow)))
    if fast.any() and not fast.all():
        seeds.append(("seed:tier_fast", heft_generic(wf, platform, allowed=fast)))
    seeds.append(("seed:round_robin", trivial_mapping(wf, platform)))

    priority = rank_priority(wf, platform)
    base = seeds[0][1].proc
    rng = np.random.default_rng(options.seed)
    j = 0
    while len(seeds) < options.seeds:
        cand = base.copy()
        flips = rng.integers(wf.n, size=max(1, wf.n // 8))
        cand[flips] = rng.integers(P, size=len(flips))
        seeds.append((f"seed:perturb{j}",
                      mapping_from_assignment(wf, platform, cand, priority)))
        j += 1
    return seeds[:max(options.seeds, 2)]
