"""Joint mapping x scheduling: candidate-mapping search over the grid.

The paper fixes the task-to-processor mapping; this subsystem makes it
a decision variable.  `seeds` builds a diverse population of
`FixedMapping`s (HEFT plus carbon-aware variants), `moves` perturbs
them (reassign / swap / critical-path migration), and `search` runs an
alternating map/schedule improvement loop that evaluates each round's
candidates as one extra fan-out dimension of the batched portfolio
grid (mappings x profiles x variants in a single shape-bucketed
launch).  Surfaced through ``PlanRequest(mapping=..., mapping_options=...)``.
"""

from repro.mapping.options import MappingOptions
from repro.mapping.moves import (critical_path, mapping_from_assignment,
                                 neighborhood, rank_priority, upward_ranks)
from repro.mapping.seeds import green_availability, heft_generic, seed_mappings
from repro.mapping.search import (MappingOutcome, MappingSearchInfo,
                                  resolve_mappings, search_mapping)

__all__ = [
    "MappingOptions",
    "MappingOutcome",
    "MappingSearchInfo",
    "critical_path",
    "green_availability",
    "heft_generic",
    "mapping_from_assignment",
    "neighborhood",
    "rank_priority",
    "resolve_mappings",
    "search_mapping",
    "seed_mappings",
    "upward_ranks",
]
