"""Model / shape configuration system.

One ``ModelConfig`` per assigned architecture lives in
``src/repro/configs/<arch>.py``; the registry in ``__init__`` resolves
``--arch <id>``. ``reduced()`` produces the CPU-smoke-test variant of any
config (same family/topology, tiny widths).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    every: int = 1              # MoE layer every `every` layers (else dense)
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    dispatch: str = "global"    # global | sharded (hierarchical, see moe.py)


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | vlm | moe | audio | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope: str = "std"           # std | mrope
    mrope_sections: tuple[int, int, int] = (16, 24, 24)
    norm_eps: float = 1e-6
    moe: MoEConfig | None = None
    mamba: MambaConfig | None = None
    # hybrid (jamba): layers per group and attention position within group
    attn_every: int = 0         # 0 = all layers attention; k = 1 attn per k
    # xlstm: indices of sLSTM blocks (others are mLSTM)
    slstm_layers: tuple[int, ...] = ()
    # whisper: encoder layers (decoder = num_layers)
    encoder_layers: int = 0
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch run the 500k-token long-context decode shape?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True             # no encoder-only archs in the assignment


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    L = min(cfg.num_layers, 4)
    slstm = tuple(i for i in cfg.slstm_layers if i < L) or (
        (0,) if cfg.slstm_layers else ())
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
                                  top_k=min(cfg.moe.top_k, 2), d_ff_expert=64)
    mamba = None
    if cfg.mamba is not None:
        mamba = dataclasses.replace(cfg.mamba, d_state=8, chunk=16)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-reduced",
        num_layers=L,
        d_model=64,
        num_heads=4,
        kv_heads=min(cfg.kv_heads, 2) if cfg.kv_heads < cfg.num_heads else 4,
        d_ff=128 if cfg.d_ff else 0,
        vocab=512,
        head_dim=16,
        mrope_sections=(2, 3, 3),
        moe=moe,
        mamba=mamba,
        slstm_layers=slstm,
        encoder_layers=min(cfg.encoder_layers, 2),
        attn_every=min(cfg.attn_every, 2) if cfg.attn_every else 0,
        dtype="float32",
    )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str                   # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable?, reason-if-skipped) — DESIGN.md §5 skip rules."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, ("pure full-attention arch: 500k-token B=1 decode "
                       "requires sub-quadratic attention (skip per spec)")
    return True, ""
