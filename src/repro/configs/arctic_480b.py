"""Snowflake Arctic 480B [hf:Snowflake/snowflake-arctic-base].
Dense-MoE hybrid: 128-expert top-2 MoE + dense residual FFN every layer."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    num_layers=35, d_model=7168, num_heads=56, kv_heads=8,
    d_ff=4864, vocab=32000, head_dim=128, rope_theta=1e4,
    moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864,
                  every=1, dense_residual=True),
)
