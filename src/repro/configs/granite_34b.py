"""Granite-34B-Code [arXiv:2405.04324; hf]. Deep llama-arch, MQA (kv=1)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b", family="dense",
    num_layers=88, d_model=6144, num_heads=48, kv_heads=1,
    d_ff=24576, vocab=49152, head_dim=128, rope_theta=1e4,
)
