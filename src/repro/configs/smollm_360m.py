"""SmolLM-360M [hf:HuggingFaceTB/SmolLM-360M]. Llama-arch small."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, kv_heads=5,
    d_ff=2560, vocab=49152, head_dim=64, rope_theta=1e4,
)
