"""Jamba-v0.1 52B [arXiv:2403.19887]. 32 layers = 4 groups of
(1 attention + 7 mamba), 16-expert top-2 MoE every other layer."""
from repro.configs.base import ModelConfig, MambaConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    num_layers=32, d_model=4096, num_heads=32, kv_heads=8,
    d_ff=14336, vocab=65536, head_dim=128, rope_theta=1e4,
    attn_every=8,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff_expert=14336, every=2),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2, chunk=256),
)
