"""xLSTM-125M [arXiv:2405.04517]. 10 mLSTM + 2 sLSTM blocks (layers 0, 6);
no external FFN (internal up-projection, factor 2)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, kv_heads=4,
    d_ff=0, vocab=50304, head_dim=192,
    slstm_layers=(0, 6),
)
