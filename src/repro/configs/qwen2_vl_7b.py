"""Qwen2-VL-7B backbone [arXiv:2409.12191; hf]. M-RoPE, dynamic resolution
(frontend stubbed: input_specs feeds precomputed patch/text embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, kv_heads=4,
    d_ff=18944, vocab=152064, head_dim=128,
    qkv_bias=True, rope="mrope", rope_theta=1e6,
    mrope_sections=(16, 24, 24),
)
