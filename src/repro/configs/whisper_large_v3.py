"""Whisper-large-v3 backbone [arXiv:2212.04356]. Enc-dec, 32+32 layers,
learned absolute positions (no RoPE); conv frontend stubbed (input_specs
provides precomputed frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    num_layers=32, encoder_layers=32,
    d_model=1280, num_heads=20, kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64, rope="abs", qkv_bias=True,
)
