"""Granite-3.0-1B-A400M [hf:ibm-granite/granite-3.0-1b-a400m-base].
32-expert top-8 MoE every layer, no dense FFN."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, kv_heads=8,
    d_ff=0, vocab=49155, head_dim=64, rope_theta=1e4,
    moe=MoEConfig(num_experts=32, top_k=8, d_ff_expert=512, every=1),
)
