"""Architecture registry: --arch <id> resolves here."""
from repro.configs import (
    arctic_480b,
    granite_34b,
    granite_moe_1b_a400m,
    jamba_v0_1_52b,
    qwen1_5_0_5b,
    qwen2_5_3b,
    qwen2_vl_7b,
    smollm_360m,
    whisper_large_v3,
    xlstm_125m,
)
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    SHAPES,
    ShapeConfig,
    reduced,
    shape_applicable,
)

ARCHS = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen2_vl_7b, smollm_360m, qwen1_5_0_5b, granite_34b, qwen2_5_3b,
        arctic_480b, granite_moe_1b_a400m, whisper_large_v3, xlstm_125m,
        jamba_v0_1_52b,
    )
}
