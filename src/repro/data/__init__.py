from repro.data.synthetic import SyntheticTokens, make_batch_iter  # noqa: F401
