"""Deterministic synthetic token pipeline with host-side prefetch.

Batches are a pure function of (seed, step) so restarts resume the exact
data stream from the checkpointed step — the property fault-tolerant
training needs from its data layer. A background thread keeps a small
prefetch queue filled (the host->device overlap trick).
"""
from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTokens:
    """Markov-ish token stream: deterministic per (seed, step)."""

    def __init__(self, cfg, shape_cfg, seed: int = 0):
        self.cfg = cfg
        self.shape = shape_cfg
        self.seed = seed

    def batch(self, step: int) -> dict:
        cfg, sh = self.cfg, self.shape
        rng = np.random.default_rng((self.seed << 20) ^ step)
        B, S = sh.batch, sh.seq
        if cfg.family == "vlm":
            emb = rng.normal(0, 1, (B, S, cfg.d_model)).astype(np.float32)
            pos = np.broadcast_to(np.arange(S, dtype=np.int32),
                                  (3, B, S)).copy()
            lab = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)
            return {"embeds": emb, "positions": pos, "labels": lab}
        if cfg.family == "audio":
            emb = rng.normal(0, 1, (B, S, cfg.d_model)).astype(np.float32)
            tok = rng.integers(0, cfg.vocab, (B, S), dtype=np.int32)
            lab = np.roll(tok, -1, axis=1)
            return {"enc_embeds": emb, "dec_tokens": tok, "labels": lab}
        tok = rng.integers(0, cfg.vocab, (B, S + 1), dtype=np.int32)
        return {"tokens": tok[:, :-1].copy(), "labels": tok[:, 1:].copy()}


def make_batch_iter(source: SyntheticTokens, start_step: int = 0,
                    prefetch: int = 2):
    """Prefetching iterator over (step, batch)."""
    q: queue.Queue = queue.Queue(maxsize=prefetch)
    stop = threading.Event()

    def producer():
        step = start_step
        while not stop.is_set():
            q.put((step, source.batch(step)))
            step += 1

    t = threading.Thread(target=producer, daemon=True)
    t.start()

    class _Iter:
        def __iter__(self):
            return self

        def __next__(self):
            return q.get()

        def close(self):
            stop.set()
            try:
                q.get_nowait()
            except queue.Empty:
                pass

    return _Iter()
