"""Elastic re-meshing: continue training on the surviving devices.

On permanent pod loss the runtime (1) rebuilds the mesh from the surviving
device set, (2) re-lowers the train step for the new mesh, and (3) restores
the last checkpoint into the new sharding (checkpoints are stored as host
numpy, so resharding is a free device_put with the new NamedSharding).
The global batch is kept constant by raising per-pod microbatches.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class ElasticPlan:
    mesh_shape: tuple
    axis_names: tuple
    microbatch_scale: int


def remesh_plan(old_pods: int, lost_pods: int, base_shape=(16, 16),
                axis_names=("data", "model")) -> ElasticPlan:
    """Plan after losing ``lost_pods``: same per-pod mesh, scaled microbatches."""
    left = old_pods - lost_pods
    assert left >= 1, "no pods left"
    if left == 1:
        return ElasticPlan(base_shape, axis_names, old_pods)
    return ElasticPlan((left,) + base_shape, ("pod",) + axis_names,
                       old_pods // left if old_pods % left == 0 else old_pods)


def rebuild_mesh(plan: ElasticPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    need = int(np.prod(plan.mesh_shape))
    assert len(devices) >= need, (len(devices), need)
    return jax.make_mesh(plan.mesh_shape, plan.axis_names,
                         devices=devices[:need])
