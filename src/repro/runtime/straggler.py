"""Straggler detection/mitigation: EWMA step-time monitor.

At fleet scale, slow hosts show up as step-time inflation on their pod. The
monitor tracks an EWMA + variance of per-pod step times and emits a
mitigation decision when a pod's time exceeds ``z_thresh`` deviations: first
"rebalance" (shift microbatches away), then "evict" (drop the pod and
trigger elastic re-mesh, runtime/elastic.py) after ``evict_after``
consecutive flags.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class Decision:
    pod: int
    action: str          # ok | rebalance | evict
    ratio: float


class StragglerMonitor:
    def __init__(self, n_pods: int, alpha: float = 0.2,
                 z_thresh: float = 3.0, evict_after: int = 5):
        self.n = n_pods
        self.alpha = alpha
        self.z = z_thresh
        self.evict_after = evict_after
        self.mean = [None] * n_pods
        self.var = [0.0] * n_pods
        self.flags = [0] * n_pods

    def observe(self, pod: int, seconds: float) -> Decision:
        m = self.mean[pod]
        if m is None:
            self.mean[pod] = seconds
            return Decision(pod, "ok", 1.0)
        d = seconds - m
        sd = max(self.var[pod] ** 0.5, 0.02 * max(m, 1e-9))
        ratio = seconds / max(m, 1e-9)
        flagged = d > self.z * sd and ratio > 1.2
        if flagged:
            # do not fold anomalies into the baseline estimate
            self.flags[pod] += 1
            if self.flags[pod] >= self.evict_after:
                return Decision(pod, "evict", ratio)
            return Decision(pod, "rebalance", ratio)
        self.mean[pod] = m + self.alpha * d
        self.var[pod] = (1 - self.alpha) * (self.var[pod]
                                            + self.alpha * d * d)
        self.flags[pod] = 0
        return Decision(pod, "ok", ratio)
