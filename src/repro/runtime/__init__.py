from repro.runtime.carbon_gate import CarbonGate  # noqa: F401
from repro.runtime.fault import FailureInjector, run_with_restarts  # noqa: F401
from repro.runtime.straggler import StragglerMonitor  # noqa: F401
