"""CarbonGate: the paper's scheduler driving the training loop.

The training run is divided into fixed *step chunks*; each chunk is a task
of the paper's scheduling problem — the chunks on one pod form a chain (a
fixed mapping + total order, exactly the paper's setting), chunk duration
comes from the measured/estimated step time, and power draw is
``chips * chip_watts``. CaWoSched then assigns chunk start times inside the
green windows of the site's power profile, and the gate sleeps (simulated
or wall-clock) until each chunk's scheduled start.

Multi-pod runs build one chain per pod over the same profile; cross-pod
checkpoint barriers become chain-to-chain edges.

Forecasts are uncertain: the gate optionally plans against an ensemble of
perturbed profiles (``profiles=...``) through the multi-profile portfolio
engine — every variant scored against every member in one device launch —
and executes the robust (min-max) variant.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.api import Planner, PlanRequest
from repro.cluster import Platform
from repro.core.carbon import PowerProfile
from repro.core.dag import FixedMapping, Instance, build_instance
from repro.kernels.backend import resolve_engine
from repro.workflows.generators import Workflow


def fleet_platform(pods: int, chip_watts_idle: float, chip_watts_work: float,
                   chips_per_pod: int) -> Platform:
    """A Platform whose 'processors' are pods of an accelerator fleet."""
    speed = np.ones(pods, dtype=np.int64)
    p_idle = np.zeros(pods * pods, dtype=np.int64)
    p_work = np.zeros(pods * pods, dtype=np.int64)
    p_idle[:pods] = int(chip_watts_idle * chips_per_pod)
    p_work[:pods] = int(chip_watts_work * chips_per_pod)
    return Platform(speed=speed, p_idle=p_idle, p_work=p_work,
                    type_of=np.zeros(pods, dtype=np.int64))


def chunk_workflow(n_chunks_per_pod: list[int],
                   chunk_seconds: list[list[int]],
                   barriers: list[int] | None = None) -> tuple[Workflow, FixedMapping]:
    """Chains of step-chunks (one chain per pod) + optional barrier edges."""
    node_w = []
    edges = []
    proc = []
    order: list[list[int]] = []
    nid = 0
    chain_ids = []
    for p, n in enumerate(n_chunks_per_pod):
        ids = []
        for c in range(n):
            node_w.append(max(int(chunk_seconds[p][c]), 1))
            proc.append(p)
            if ids:
                edges.append((ids[-1], nid))
            ids.append(nid)
            nid += 1
        chain_ids.append(ids)
        order.append(ids)
    if barriers:
        # at barrier index k, all pods must have finished chunk k before any
        # pod starts chunk k+1 (checkpoint-consistency barrier)
        for k in barriers:
            for a in range(len(chain_ids)):
                for b in range(len(chain_ids)):
                    if a != b and k + 1 < len(chain_ids[b]) and k < len(chain_ids[a]):
                        edges.append((chain_ids[a][k], chain_ids[b][k + 1]))
    wf = Workflow(
        name="train-chunks",
        node_w=np.asarray(node_w, dtype=np.int64),
        edges=np.asarray(edges, dtype=np.int64).reshape(-1, 2),
        edge_w=np.ones(len(edges), dtype=np.int64))
    proc_arr = np.asarray(proc, dtype=np.int64)
    # cross-pod barrier edges become (cheap) sync communications on the
    # pod-to-pod links, ordered by source chunk index
    pods = len(n_chunks_per_pod)
    comm_order: dict[int, list[tuple[int, int]]] = {}
    for (u, v) in sorted(map(tuple, edges)):
        if proc_arr[u] != proc_arr[v]:
            a, b = int(proc_arr[u]), int(proc_arr[v])
            link = pods + a * (pods - 1) + (b if b < a else b - 1)
            comm_order.setdefault(link, []).append((int(u), int(v)))
    mapping = FixedMapping(
        proc=proc_arr,
        order=tuple(tuple(o) for o in order),
        comm_order={k: tuple(v) for k, v in comm_order.items()})
    return wf, mapping


@dataclasses.dataclass
class GatePlan:
    instance: Instance
    profile: PowerProfile
    start: np.ndarray           # scheduled chunk start times (seconds)
    cost: int                   # cost under the nominal profile
    asap_cost: int
    variant: str = ""           # the variant the plan executes
    robust_cost: int | None = None    # chosen variant's worst ensemble cost
    cost_matrix: np.ndarray | None = None   # [P, V] ensemble x variant costs
    variant_names: tuple = ()


class CarbonGate:
    """Plan + gate execution of training-step chunks into green windows.

    ``profiles`` optionally extends the nominal forecast with a
    perturbation ensemble (forecast-uncertainty members). Planning then
    runs the multi-profile portfolio engine — every variant against every
    member, one device launch under ``engine="jax"`` — and executes the
    *robust* variant: the one minimizing the worst-case cost across the
    ensemble. ``variant`` pins a specific heuristic instead ("auto" =
    robust pick; with a single profile "auto" simply picks the cheapest).
    """

    def __init__(self, profile: PowerProfile, platform: Platform,
                 variant: str = "pressWR-LS",
                 profiles: list[PowerProfile] | None = None,
                 engine: str = "auto"):
        self.profile = profile
        self.platform = platform
        self.variant = variant
        self.profiles = [profile] + [p for p in (profiles or [])
                                     if p is not profile]
        # engine="auto" centrally resolved: the device fan-out pays off as
        # soon as there is an ensemble to score (replanning loops amortize
        # the jit cache)
        self.engine = resolve_engine(engine, fanout=len(self.profiles))
        self.planner = Planner(platform, engine=self.engine)
        self.plan: GatePlan | None = None

    def _variants(self):
        return None if self.variant == "auto" \
            else tuple(dict.fromkeys(("asap", self.variant)))

    def make_plan(self, chunk_seconds: list[list[int]],
                  barriers: list[int] | None = None) -> GatePlan:
        wf, mapping = chunk_workflow(
            [len(c) for c in chunk_seconds], chunk_seconds, barriers)
        inst = build_instance(wf, mapping, self.platform,
                              dur=wf.node_w)
        res = self.planner.plan(PlanRequest(
            instances=inst, profiles=self.profiles,
            variants=self._variants(), robust=True))
        costs, names = res.cost_matrix(0)
        chosen, worst_cost = res.robust(0)
        nominal = res.results[0][0]
        self.plan = GatePlan(
            instance=inst, profile=self.profile,
            start=nominal[chosen].start, cost=nominal[chosen].cost,
            asap_cost=nominal["asap"].cost, variant=chosen,
            robust_cost=worst_cost, cost_matrix=costs,
            variant_names=names)
        return self.plan

    def replan_session(self, chunk_seconds: list[list[int]],
                       window_profiles, n_windows: int | None = None,
                       barriers: list[int] | None = None, lookahead: int = 1):
        """Async rolling-horizon replanning of this gate's chunk workflow.

        ``window_profiles`` is the per-window forecast source (callable
        ``k -> profiles`` or a sequence); every window's forecast must
        share one horizon so the chunk instance's PreparedGraph — and the
        jit cache under ``engine="jax"`` — is reused across windows.
        Returns a :class:`repro.api.PlanningSession` planning window k+1
        while window k executes.
        """
        wf, mapping = chunk_workflow(
            [len(c) for c in chunk_seconds], chunk_seconds, barriers)
        inst = build_instance(wf, mapping, self.platform, dur=wf.node_w)
        return self.planner.session(
            inst, window_profiles, n_windows=n_windows,
            variants=self._variants(), robust=True, lookahead=lookahead)

    def wait_time(self, pod: int, chunk: int, now: float) -> float:
        """Seconds to sleep before running this chunk (0 if already due)."""
        assert self.plan is not None
        chain = self.plan.instance.proc_chains[pod]
        task = chain[chunk]
        return max(float(self.plan.start[task]) - now, 0.0)
