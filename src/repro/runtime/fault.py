"""Fault tolerance: failure injection + checkpoint/restart driver.

Two injection surfaces share this module:

* :class:`FailureInjector` — the original per-step seam the training
  restart driver (:func:`run_with_restarts`) drills against;
* :class:`ServiceFaultInjector` — the planning-service chaos seam
  (:class:`repro.serve.service.PlanService` accepts one as
  ``injector=``): a deterministic script of :class:`FaultSpec`\\ s fired
  at solver-chain stages (crash, hang, device OOM, generic poison
  error) plus per-request profile corruption, so the chaos suite can
  drive every degradation path end-to-end with exact repeatability.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


class SimulatedFailure(RuntimeError):
    """A transient failure (retry-with-backoff is the right response)."""


class SimulatedOOM(MemoryError):
    """An injected device out-of-memory (blocked-LP retry is the right
    response — real dense ``longest_path_matrix`` overruns raise plain
    :class:`MemoryError`, which the service treats identically)."""


@dataclasses.dataclass
class FaultSpec:
    """One scripted fault: fire ``kind`` up to ``times`` times whenever a
    matching solver-chain ``stage`` solve is attempted.

    kinds:
      * ``"crash"``   — raise :class:`SimulatedFailure` (transient;
        exercises the retry/backoff path);
      * ``"hang"``    — sleep ``seconds`` inside the solve (exercises the
        deadline-budget watchdog);
      * ``"oom"``     — raise :class:`SimulatedOOM` (exercises the
        blocked-LP retry);
      * ``"error"``   — raise a generic :class:`ValueError` (a
        non-transient poison; exercises the quarantine bisect);
      * ``"corrupt"`` — consumed per *request* at batch assembly, not per
        solve: the service replaces that request's profiles with
        structurally corrupt ones (:func:`corrupt_profile`), exercising
        admission-side quarantine.
      * ``"worker-death"`` — consumed per drain-worker batch claim: the
        worker thread raises out of its drain loop and dies (exercises
        the supervisor's dead-worker restart + ticket requeue);
      * ``"wedge"`` — consumed per drain-worker batch claim: the worker
        stalls ``seconds`` without heartbeating (exercises the
        supervisor's wedged-worker deposition);
      * ``"kill"`` — consumed per batch claim: the whole service dies
        mid-burst (:meth:`repro.serve.service.PlanService.kill`),
        leaving admitted tickets in the journal (exercises restart
        replay).

    ``stage=None`` matches every chain stage. Specs are consumed in
    order, deterministically — no clock or RNG involvement unless
    ``ServiceFaultInjector(prob=...)`` is used.
    """

    kind: str
    stage: str | None = None
    times: int = 1
    seconds: float = 0.25

    KINDS = ("crash", "hang", "oom", "error", "corrupt",
             "worker-death", "wedge", "kill")

    def __post_init__(self):
        if self.kind not in self.KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")


def corrupt_profile(profile):
    """A structurally corrupt twin of ``profile``: its budget array loses
    one interval, so ``len(budget) != len(bounds) - 1`` — the invariant
    :func:`repro.api.request.validate_resolved` checks and every cost
    oracle relies on."""
    from repro.core.carbon import PowerProfile

    return PowerProfile(bounds=profile.bounds.copy(),
                        budget=profile.budget[:-1].copy(),
                        scenario=profile.scenario + "-corrupt")


class ServiceFaultInjector:
    """Deterministic chaos seam for :class:`~repro.serve.service
    .PlanService`.

    ``faults`` is a scripted list of :class:`FaultSpec`; ``prob``/``seed``
    add the legacy seeded-random mode on top (every solve attempt crashes
    with probability ``prob``, reproducible per seed). ``fired`` logs
    every injected event as ``(kind, stage)`` for test assertions.
    """

    def __init__(self, faults=(), prob: float = 0.0, seed: int = 0):
        self.faults = [dataclasses.replace(f) for f in faults]
        self.prob = float(prob)
        self.rng = np.random.default_rng(seed)
        self.fired: list[tuple[str, str | None]] = []

    def _take(self, kinds, stage: str | None) -> FaultSpec | None:
        for spec in self.faults:
            if spec.kind in kinds and spec.times > 0 and \
                    (spec.stage is None or spec.stage == stage):
                spec.times -= 1
                self.fired.append((spec.kind, stage))
                return spec
        return None

    def on_solve(self, stage: str, cancel=None) -> None:
        """Called by the service inside every chain-stage solve attempt
        (before the actual plan); may raise or stall.

        ``cancel`` is the solve's :class:`repro.core.cancel.CancelToken`:
        an injected ``"hang"`` sleeps in small slices polling it, so a
        watchdog-cancelled hang releases its solve-pool worker within
        ~10ms instead of holding it for the scripted duration (a real
        wedged solve behaves the same way once its own chunk boundary
        polls the token).
        """
        spec = self._take(("crash", "hang", "oom", "error"), stage)
        if spec is None:
            if self.prob and self.rng.random() < self.prob:
                self.fired.append(("crash", stage))
                raise SimulatedFailure(
                    f"injected random failure at stage {stage!r}")
            return
        if spec.kind == "crash":
            raise SimulatedFailure(f"injected crash at stage {stage!r}")
        if spec.kind == "oom":
            raise SimulatedOOM(f"injected device OOM at stage {stage!r}")
        if spec.kind == "error":
            raise ValueError(f"injected poison error at stage {stage!r}")
        deadline = time.monotonic() + spec.seconds     # "hang"
        while time.monotonic() < deadline:
            if cancel is not None:
                cancel.check()
            time.sleep(0.01)

    def on_worker(self) -> FaultSpec | None:
        """Called by each drain worker once per batch claim; returns the
        consumed worker-level :class:`FaultSpec` (kind
        ``"worker-death"``, ``"wedge"`` or ``"kill"``) or None. The
        service acts on the kind — raising out of the drain loop,
        stalling without heartbeating for ``spec.seconds``, or killing
        the whole service mid-burst."""
        return self._take(("worker-death", "wedge", "kill"), None)

    def corrupts_request(self) -> bool:
        """Called by the service once per admitted request at batch
        assembly; True consumes a ``"corrupt"`` spec and tells the
        service to poison that request's profiles."""
        return self._take(("corrupt",), None) is not None


class FailureInjector:
    """Deterministic pseudo-random failures for tests/drills."""

    def __init__(self, prob_per_step: float, seed: int = 0):
        self.prob = prob_per_step
        self.rng = np.random.default_rng(seed)

    def maybe_fail(self, step: int) -> None:
        if self.rng.random() < self.prob:
            raise SimulatedFailure(f"injected failure at step {step}")


def run_with_restarts(train_fn, ckpt_manager, init_state_fn, total_steps: int,
                      max_restarts: int = 10):
    """Run ``train_fn(state, start_step, stop_step)`` with restart-on-failure.

    ``train_fn`` must checkpoint through ``ckpt_manager`` and raise on
    failure; restarts resume from the latest manifest (the data pipeline is
    deterministic per step, so the stream resumes exactly).
    Returns (final_state, steps_done, n_restarts).
    """
    restarts = 0
    while True:
        state, step = ckpt_manager.restore_latest()
        if state is None:
            state, step = init_state_fn(), -1
        start = step + 1
        try:
            state = train_fn(state, start, total_steps)
            return state, total_steps, restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
