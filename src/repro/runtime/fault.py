"""Fault tolerance: failure injection + checkpoint/restart driver."""
from __future__ import annotations

import numpy as np


class SimulatedFailure(RuntimeError):
    pass


class FailureInjector:
    """Deterministic pseudo-random failures for tests/drills."""

    def __init__(self, prob_per_step: float, seed: int = 0):
        self.prob = prob_per_step
        self.rng = np.random.default_rng(seed)

    def maybe_fail(self, step: int) -> None:
        if self.rng.random() < self.prob:
            raise SimulatedFailure(f"injected failure at step {step}")


def run_with_restarts(train_fn, ckpt_manager, init_state_fn, total_steps: int,
                      max_restarts: int = 10):
    """Run ``train_fn(state, start_step, stop_step)`` with restart-on-failure.

    ``train_fn`` must checkpoint through ``ckpt_manager`` and raise on
    failure; restarts resume from the latest manifest (the data pipeline is
    deterministic per step, so the stream resumes exactly).
    Returns (final_state, steps_done, n_restarts).
    """
    restarts = 0
    while True:
        state, step = ckpt_manager.restore_latest()
        if state is None:
            state, step = init_state_fn(), -1
        start = step + 1
        try:
            state = train_fn(state, start, total_steps)
            return state, total_steps, restarts
        except SimulatedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
