"""AdamW with global-norm clipping and warmup+cosine schedule.

Optimizer states inherit the parameters' (TP+FSDP) sharding, so m/v are
fully sharded across the mesh (ZeRO-1/3 hybrid). An optional gradient-
compression hook casts the DP all-reduce to bf16 (distributed-optimization
trick; exact math is kept for the master update).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def lr_schedule(step, *, peak: float = 3e-4, warmup: int = 200,
                total: int = 10_000, floor: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_init(params, mixed_precision: bool = False):
    opt = {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }
    if mixed_precision:
        # f32 master copy; live params are bf16 (halves FSDP gather bytes)
        opt["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return opt


def cast_params(params, dtype=jnp.bfloat16):
    return jax.tree.map(lambda p: p.astype(dtype), params)


def adamw_update(params, grads, opt, lr, *, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8, wd: float = 0.1,
                 clip: float = 1.0):
    if "master" in opt:                 # mixed precision: update the master
        live_dtype = jax.tree.leaves(params)[0].dtype
        new_master, opt2, gnorm = adamw_update(
            opt["master"], grads,
            {"m": opt["m"], "v": opt["v"], "step": opt["step"]}, lr,
            b1=b1, b2=b2, eps=eps, wd=wd, clip=clip)
        new_params = jax.tree.map(lambda p: p.astype(live_dtype), new_master)
        opt2["master"] = new_master
        return new_params, opt2, gnorm
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-12))
    step = opt["step"] + 1
    t = step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1 ** t)
        vh = v / (1 - b2 ** t)
        new_p = p.astype(jnp.float32) - lr * (
            mh / (jnp.sqrt(vh) + eps) + wd * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["m"], opt["v"])
    new_params = jax.tree.map(lambda o: o[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm


def compress_grads(grads, enabled: bool = True):
    """bf16 gradient compression for the DP all-reduce (halves DP bytes)."""
    if not enabled:
        return grads
    return jax.tree.map(
        lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
