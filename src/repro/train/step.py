"""Train step: microbatched gradient accumulation + AdamW, pjit-ready."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding.ctx import shard
from repro.train.optimizer import adamw_init, adamw_update, compress_grads, lr_schedule


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: Any

    def tree(self):
        return {"params": self.params, "opt": self.opt}


def init_state(model, key, mixed_precision: bool = False):
    params = model.init(key)
    opt = adamw_init(params, mixed_precision=mixed_precision)
    if mixed_precision:
        import jax.numpy as _jnp
        params = jax.tree.map(lambda p: p.astype(_jnp.bfloat16), params)
    return {"params": params, "opt": opt}


def make_train_step(model, *, microbatches: int = 1, peak_lr: float = 3e-4,
                    total_steps: int = 10_000, warmup: int = 200,
                    grad_compress: bool = False):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def train_step(state, batch):
        params = state["params"]
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def split_mb(x):
                b = x.shape[0] if x.ndim >= 1 else 0
                # positions for vlm are [3,B,S]: split on axis 1
                if x.ndim == 3 and x.shape[0] == 3:
                    return x.reshape((3, microbatches, -1) + x.shape[2:]
                                     ).swapaxes(0, 1)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])

            mbs = jax.tree.map(split_mb, batch)

            def body(carry, mb):
                acc, lsum = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, lsum + loss), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        grads = compress_grads(grads, grad_compress)
        lr = lr_schedule(state["opt"]["step"] + 1, peak=peak_lr,
                         warmup=warmup, total=total_steps)
        new_params, new_opt, gnorm = adamw_update(
            params, grads, state["opt"], lr)
        metrics = {"loss": loss, "gnorm": gnorm, "lr": lr}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step
