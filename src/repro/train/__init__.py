from repro.train.optimizer import adamw_init, adamw_update, lr_schedule  # noqa: F401
from repro.train.step import TrainState, make_train_step  # noqa: F401
