"""Pipeline parallelism over the pod axis: GPipe schedule with shard_map.

The layer stack is split into ``n_stages`` contiguous stages (one per pod);
microbatches stream through with ``lax.ppermute`` boundary transfers. Used
by the granite-34b multi-pod §Perf exploration — the default plan keeps the
pod axis as pure DP, this module provides the alternative.

Bubble fraction = (S-1)/(M+S-1) for S stages and M microbatches, so the
driver should pick M >> S (the helper asserts M >= 4*S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def pipeline_apply(body_fn, stage_params, x_mb, *, axis_name: str = "pod"):
    """Run a GPipe pipeline inside ``shard_map`` over ``axis_name``.

    body_fn(params, x) -> x            one stage's computation
    stage_params: per-stage params (leading stage axis sharded over pods)
    x_mb: [M, mb, ...] microbatched activations (replicated over pods)

    Returns [M, mb, ...] outputs of the LAST stage (other pods produce
    zeros; caller reduces/selects).
    """
    n_stages = lax.psum(1, axis_name)
    stage = lax.axis_index(axis_name)
    # shard_map keeps the (now size-1) stage axis on the params block
    stage_params = jax.tree.map(lambda a: a[0], stage_params)
    M = x_mb.shape[0]
    assert M >= 4 * n_stages, "use >=4x microbatches per stage (bubble)"
    n_ticks = M + n_stages - 1

    def tick(carry, t):
        buf_in, outputs = carry
        # stage 0 injects microbatch t (if any); others take the permuted in
        inject = jnp.where(t < M, t, M - 1)
        x0 = x_mb[inject]
        x_in = jnp.where(stage == 0, x0, buf_in)
        y = body_fn(stage_params, x_in)
        # pass to the next stage
        buf_next = lax.ppermute(
            y, axis_name,
            perm=[(i, i + 1) for i in range(n_stages - 1)])
        # last stage writes its completed microbatch (t - (S-1))
        out_idx = t - (n_stages - 1)
        ok = (stage == n_stages - 1) & (out_idx >= 0)
        outputs = lax.cond(
            ok,
            lambda o: o.at[jnp.maximum(out_idx, 0)].set(y),
            lambda o: o,
            outputs)
        return (buf_next, outputs), None

    buf0 = jnp.zeros_like(x_mb[0])
    out0 = jnp.zeros_like(x_mb)
    (_, outputs), _ = lax.scan(tick, (buf0, out0), jnp.arange(n_ticks))
    # broadcast the last stage's outputs to all pods
    outputs = lax.psum(
        jnp.where(stage == n_stages - 1, outputs, jnp.zeros_like(outputs)),
        axis_name)
    return outputs


def make_pipelined_forward(body_fn, mesh, axis_name: str = "pod"):
    """Wrap pipeline_apply in shard_map for the given mesh."""
    from jax.experimental.shard_map import shard_map

    return shard_map(
        functools.partial(pipeline_apply, body_fn, axis_name=axis_name),
        mesh=mesh,
        in_specs=(P(axis_name), P()),
        out_specs=P(),
        check_rep=False,
    )
