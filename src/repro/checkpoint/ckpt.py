"""Sharded checkpoint save/restore (npz + json manifest, atomic rename).

Leaves are gathered to host (device_get) and stored flat-keyed; the manifest
records step, tree paths, shapes and dtypes so restores can validate against
the live model before overwriting anything. Writes go to ``<dir>.tmp`` and
are renamed only after fsync — a torn write never shadows a good checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten(tree, path=""):
    if isinstance(tree, dict):
        out = {}
        for k, v in tree.items():
            out.update(_flatten(v, f"{path}/{k}" if path else k))
        return out
    return {path: tree}


def _unflatten(flat):
    tree: dict = {}
    for path, v in flat.items():
        parts = path.split("/")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save_checkpoint(state, step: int, directory: str) -> str:
    os.makedirs(directory, exist_ok=True)
    dest = os.path.join(directory, f"ckpt_{step:08d}")
    tmp = dest + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(state)
    manifest = {"step": step, "leaves": {}}
    arrays = {}
    for i, (path, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        key = f"a{i}"
        arrays[key] = arr
        manifest["leaves"][path] = {
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(dest):
        shutil.rmtree(dest)
    os.rename(tmp, dest)
    return dest


def latest_checkpoint(directory: str) -> str | None:
    if not os.path.isdir(directory):
        return None
    cands = sorted(d for d in os.listdir(directory)
                   if d.startswith("ckpt_") and not d.endswith(".tmp"))
    return os.path.join(directory, cands[-1]) if cands else None


def load_checkpoint(path: str, like=None):
    """Returns (state, step). ``like`` (optional) validates shapes/dtypes."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for p, meta in manifest["leaves"].items():
        arr = data[meta["key"]]
        assert list(arr.shape) == meta["shape"]
        flat[p] = arr
    state = _unflatten(flat)
    if like is not None:
        ref = _flatten(like)
        assert set(ref) == set(flat), "checkpoint tree mismatch"
        for p in ref:
            assert tuple(ref[p].shape) == tuple(flat[p].shape), p
    return state, manifest["step"]
