"""Checkpoint rotation + async save thread."""
from __future__ import annotations

import os
import shutil
import threading

from repro.checkpoint.ckpt import latest_checkpoint, load_checkpoint, save_checkpoint


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, every: int = 50,
                 async_save: bool = False):
        self.directory = directory
        self.keep = keep
        self.every = every
        self.async_save = async_save
        self._thread: threading.Thread | None = None

    def maybe_save(self, state, step: int) -> bool:
        if step % self.every != 0:
            return False
        self.save(state, step)
        return True

    def save(self, state, step: int) -> None:
        if self.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._save_and_gc, args=(state, step), daemon=True)
            self._thread.start()
        else:
            self._save_and_gc(state, step)

    def _save_and_gc(self, state, step: int) -> None:
        save_checkpoint(state, step, self.directory)
        cands = sorted(d for d in os.listdir(self.directory)
                       if d.startswith("ckpt_") and not d.endswith(".tmp"))
        for d in cands[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, d))

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def restore_latest(self, like=None):
        self.wait()
        path = latest_checkpoint(self.directory)
        if path is None:
            return None, -1
        return load_checkpoint(path, like=like)
