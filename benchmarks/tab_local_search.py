"""Table 2: cost ratio of with-LS vs without-LS for the refined variants.

The with/without pairs share their greedy stage inside one
``schedule_portfolio`` pass (the -LS variant climbs from exactly the start
times its pair reports), so each case costs 4 greedy + 4 LS runs, not 8+4.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_matrix, emit, run_all_variants, write_csv

PAIRS = (("slackR-LS", "slackR"), ("slackWR-LS", "slackWR"),
         ("pressR-LS", "pressR"), ("pressWR-LS", "pressWR"))


def run(sizes=(200,), clusters=("small",), kinds=("atacseq", "bacass")):
    vals = {p: [] for p in PAIRS}
    t0 = time.perf_counter()
    n = 0
    for case in build_matrix(sizes=sizes, clusters=clusters, kinds=kinds):
        res = run_all_variants(
            case, variants=[a for p in PAIRS for a in p])
        for ls, nols in PAIRS:
            c_ls, c_no = res[ls][0], res[nols][0]
            if c_no == 0:
                vals[(ls, nols)].append(1.0 if c_ls == 0 else np.inf)
            else:
                vals[(ls, nols)].append(c_ls / c_no)
        n += 1
    dt = time.perf_counter() - t0
    rows = []
    for (ls, nols), rs in vals.items():
        rs = np.asarray([r for r in rs if np.isfinite(r)])
        rows.append([nols, rs.min(), rs.max(), f"{rs.mean():.4f}"])
    write_csv("tab2_local_search.csv", ["variant", "min", "max", "avg"], rows)
    avg = np.mean([float(r[3]) for r in rows])
    emit("tab2_local_search", dt / max(n, 1) * 1e6,
         f"avg_with/without={avg:.3f}")
    return rows


if __name__ == "__main__":
    run()
