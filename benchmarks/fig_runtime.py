"""Fig 8 (+ appendix 12/13): scheduler runtime vs workflow size and
deadline factor; also the Pallas-kernel-proposed batched LS runtime."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import build_matrix, emit, run_all_variants, write_csv
from repro.core.local_search_jax import local_search_batched
from repro.core.greedy import greedy_schedule


def run(sizes=(200, 1000, 4000), clusters=("small",)):
    rows = []
    t_all = {}
    t0 = time.perf_counter()
    n = 0
    for case in build_matrix(sizes=sizes, clusters=clusters,
                             factors=(1.5,), scenarios=("S1",)):
        res = run_all_variants(case)
        for v, (c, sec) in res.items():
            rows.append([case.name, case.inst.num_tasks, v, f"{sec:.4f}"])
            t_all.setdefault(v, []).append((case.inst.num_tasks, sec))
        n += 1
    # deadline sensitivity (paper: runtime driven by graph size, not T)
    for f in (1.0, 2.0, 3.0):
        for case in build_matrix(sizes=(1000,), clusters=clusters,
                                 factors=(f,), scenarios=("S1",),
                                 kinds=("atacseq",)):
            res = run_all_variants(case, variants=("pressWR-LS",))
            rows.append([f"deadline-{f}", case.inst.num_tasks, "pressWR-LS",
                         f"{res['pressWR-LS'][1]:.4f}"])
    # device LS (kernel-proposed) on the largest instance
    big = next(build_matrix(sizes=(sizes[-1],), clusters=clusters,
                            factors=(1.5,), scenarios=("S1",),
                            kinds=("atacseq",)))
    g = greedy_schedule(big.inst, big.profile, big.platform, score="press",
                        weighted=True, refined=True)
    t1 = time.perf_counter()
    local_search_batched(big.inst, big.profile, g, mu=10)
    t_dev = time.perf_counter() - t1
    rows.append(["batchedLS-" + big.name, big.inst.num_tasks,
                 "kernelLS", f"{t_dev:.4f}"])
    dt = time.perf_counter() - t0
    write_csv("fig8_runtime.csv", ["case", "n_tasks", "variant", "seconds"],
              rows)
    worst = max(sec for v, xs in t_all.items() for _, sec in xs)
    emit("fig8_runtime", dt / max(n, 1) * 1e6,
         f"max_variant_seconds={worst:.2f};kernelLS_s={t_dev:.2f}")
    return rows


if __name__ == "__main__":
    run()
