"""Fig 4/5/6: cost ratio vs the ASAP baseline — medians (overall and per
deadline factor) and boxplot statistics.

Costs come from one ``schedule_portfolio`` pass per case (bit-identical to
the per-variant loop; the asap baseline is the portfolio's free EST row)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    VARIANT_NAMES,
    build_matrix,
    emit,
    run_all_variants,
    write_csv,
)

LS_VARIANTS = tuple(v for v in VARIANT_NAMES if v.endswith("-LS"))


def run(sizes=(200,), clusters=("small",)):
    records = []        # (factor, scenario, cluster, variant, ratio)
    t0 = time.perf_counter()
    n = 0
    for case in build_matrix(sizes=sizes, clusters=clusters):
        res = run_all_variants(case, variants=LS_VARIANTS)
        base = res["asap"][0]
        for v in LS_VARIANTS:
            c = res[v][0]
            ratio = 0.0 if base == 0 and c == 0 else (
                c / base if base > 0 else np.inf)
            records.append((case.factor, case.scenario, v, ratio))
        n += 1
    dt = time.perf_counter() - t0

    med_rows, box_rows = [], []
    med_all = {}
    for v in LS_VARIANTS:
        rs = np.asarray([r for f, s, vv, r in records if vv == v])
        rs = rs[np.isfinite(rs)]
        med_all[v] = np.median(rs)
        q1, q2, q3 = np.percentile(rs, [25, 50, 75])
        box_rows.append(["all", v, rs.min(), q1, q2, q3, rs.max()])
        med_rows.append(["all", v, f"{np.median(rs):.4f}"])
        for f in (1.0, 1.5, 2.0, 3.0):
            rf = np.asarray([r for ff, s, vv, r in records
                             if vv == v and ff == f])
            rf = rf[np.isfinite(rf)]
            med_rows.append([f, v, f"{np.median(rf):.4f}"])
        for s in ("S1", "S2", "S3", "S4"):
            rscen = np.asarray([r for ff, ss, vv, r in records
                                if vv == v and ss == s])
            rscen = rscen[np.isfinite(rscen)]
            med_rows.append([s, v, f"{np.median(rscen):.4f}"])
    write_csv("fig4_cost_ratio_medians.csv", ["split", "variant", "median"],
              med_rows)
    write_csv("fig6_cost_ratio_box.csv",
              ["split", "variant", "min", "q1", "median", "q3", "max"],
              box_rows)
    best = min(med_all, key=med_all.get)
    loose = [r for f, s, vv, r in records if vv == best and f == 3.0
             and np.isfinite(r)]
    emit("fig4_cost_ratio", dt / max(n, 1) * 1e6,
         f"best_median={med_all[best]:.3f}({best})"
         f";median@3D={np.median(loose):.3f}")
    return records


if __name__ == "__main__":
    run()
