"""§Roofline table: read experiments/dryrun/*.json into the per-cell report."""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import emit, write_csv

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def run():
    t0 = time.perf_counter()
    rows = []
    n_cells = n_skip = 0
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*_single.json"))):
        d = json.load(open(path))
        if "skipped" in d:
            n_skip += 1
            rows.append([d["arch"], d["shape"], "SKIP", "", "", "", "", "",
                         d["skipped"][:50]])
            continue
        if "roofline" not in d:
            continue
        r, c = d["roofline"], d["cost"]
        rows.append([
            d["arch"], d["shape"], r["dominant"],
            f"{r['compute_s']:.4g}", f"{r['memory_s']:.4g}",
            f"{r['collective_s']:.4g}",
            f"{c['model_flops']:.3g}", f"{c['useful_ratio']:.3f}", "",
        ])
        n_cells += 1
    write_csv("roofline_table.csv",
              ["arch", "shape", "dominant", "compute_s", "memory_s",
               "collective_s", "model_flops", "useful_ratio", "note"], rows)
    dt = time.perf_counter() - t0
    emit("roofline_table", dt * 1e6 / max(n_cells, 1),
         f"cells={n_cells};skipped={n_skip}")
    return rows


if __name__ == "__main__":
    run()
