"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json (run after the sweep; §Perf entries are
maintained by hand in EXPERIMENTS.md)."""
from __future__ import annotations

import glob
import json
import os

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")


def _gb(x):
    return f"{x / 1e9:.2f}"


_VARIANT_MARKERS = ("_prepin", "_nofsdp", "_moesharded", "_mp.json",
                    "_cachepin", "_moeshardmap", "mp-nofsdp")


def _is_variant(path: str) -> bool:
    return any(m in os.path.basename(path) for m in _VARIANT_MARKERS)


def dryrun_table() -> str:
    lines = ["| arch | shape | mesh | compile | lower+compile s | "
             "arg bytes/dev | temp bytes/dev | collectives (once-counted) |",
             "|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        if _is_variant(p):
            continue
        d = json.load(open(p))
        if d.get("fsdp") is False or d.get("tag"):
            continue
        if "skipped" in d:
            lines.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
                         f"SKIP | — | — | — | {d['skipped'][:60]} |")
            continue
        if "hlo_once" not in d:
            continue
        mem = d.get("memory", {})
        co = d["hlo_once"]["collectives"]
        cstr = " ".join(f"{k.split('-')[-1]}:{co['counts'][k]}"
                        for k in co["counts"] if co["counts"][k])
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | OK | "
            f"{d.get('lower_s', 0) + d.get('compile_s', 0):.0f} | "
            f"{_gb(mem.get('argument_size_in_bytes', 0))} | "
            f"{_gb(mem.get('temp_size_in_bytes', 0))} | {cstr or '—'} |")
    return "\n".join(lines)


def roofline_table() -> str:
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL_FLOPS | useful ratio | bottleneck note |",
             "|---|---|---|---|---|---|---|---|---|"]
    notes = {
        ("collective",): "TP/MoE activation exchanges dominate",
        ("memory",): "HLO bytes (CPU-fusion overcount; see caveat)",
        ("compute",): "MXU-bound",
    }
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*_single.json"))):
        if _is_variant(p):
            continue
        d = json.load(open(p))
        if d.get("fsdp") is False or d.get("tag"):
            continue
        if "skipped" in d:
            lines.append(f"| {d['arch']} | {d['shape']} | — | — | — | SKIP "
                         f"| — | — | {d['skipped'][:50]} |")
            continue
        if "roofline" not in d:
            continue
        r, c = d["roofline"], d["cost"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {c['model_flops']:.2e} | "
            f"{c['useful_ratio']:.3f} | {notes[(r['dominant'],)]} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print("## Dry-run\n")
    print(dryrun_table())
    print("\n## Roofline\n")
    print(roofline_table())


def variants_table() -> str:
    """Tagged §Perf variants (the optimized framework), for comparison."""
    lines = ["| arch | shape | variant | compute s | memory s | "
             "collective s | bound s | useful |",
             "|---|---|---|---|---|---|---|---|"]
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*_single_*.json"))):
        if "_prepin" in p:
            continue
        d = json.load(open(p))
        if "roofline" not in d:
            continue
        tag = d.get("tag") or os.path.basename(p).rsplit("_", 1)[-1][:-5]
        if not d.get("fsdp", True) and not tag:
            tag = "nofsdp"
        r, c = d["roofline"], d["cost"]
        lines.append(
            f"| {d['arch']} | {d['shape']} | {tag} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.4f} | **{r['bound_s']:.3f}** | "
            f"{c['useful_ratio']:.3f} |")
    return "\n".join(lines)
