"""Shared benchmark machinery: the paper's instance matrix (§6.1), scaled
for this container; full-scale flags available on each module's CLI."""
from __future__ import annotations

import dataclasses
import os
import time

import numpy as np

from repro.cluster import (
    LARGE_CLUSTER_NODES_PER_TYPE,
    SMALL_CLUSTER_NODES_PER_TYPE,
    make_cluster,
)
from repro.core import (
    ALL_VARIANTS,
    build_instance,
    deadline_from_asap,
    generate_profile,
    heft_mapping,
    schedule_portfolio,
    schedule_reference,
)
from repro.workflows import WORKFLOW_KINDS, make_workflow, wfgen_scale

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

DEADLINE_FACTORS = (1.0, 1.5, 2.0, 3.0)
SCENARIOS = ("S1", "S2", "S3", "S4")
VARIANT_NAMES = tuple(v.name for v in ALL_VARIANTS)


@dataclasses.dataclass
class InstanceCase:
    name: str
    inst: object
    platform: object
    profile: object
    factor: float
    scenario: str


def build_matrix(sizes=(200,), clusters=("small",), kinds=WORKFLOW_KINDS,
                 factors=DEADLINE_FACTORS, scenarios=SCENARIOS,
                 J: int = 48, seed: int = 0):
    """Yield InstanceCases: kinds x sizes x clusters x scenarios x factors."""
    nodes = {"small": SMALL_CLUSTER_NODES_PER_TYPE // 4,
             "large": LARGE_CLUSTER_NODES_PER_TYPE // 4}
    # NOTE: /4 keeps HEFT fast on 1 CPU; pass clusters=("small-full",...) for
    # the paper's 72/144-node clusters.
    nodes["small-full"] = SMALL_CLUSTER_NODES_PER_TYPE
    nodes["large-full"] = LARGE_CLUSTER_NODES_PER_TYPE
    from repro.core.carbon import work_timeline
    from repro.core.estlst import asap_schedule

    for cl in clusters:
        plat = make_cluster(nodes[cl], seed=0)
        for kind in kinds:
            for size in sizes:
                wf = wfgen_scale(kind, size, seed=seed)
                mapping = heft_mapping(wf, plat)
                inst = build_instance(wf, mapping, plat)
                # calibrate green capacity to this workload's peak draw so
                # that scheduling decisions matter (paper §6.1 rationale)
                asap = asap_schedule(inst)
                D = deadline_from_asap(inst, 1.0)
                tl = work_timeline(inst, D, asap)
                # mean active draw: green can absorb at most ~80% of the
                # workload's average demand -> decisions matter at every
                # deadline factor (paper regime)
                peak = int(tl.mean())
                for scen in scenarios:
                    for f in factors:
                        T = deadline_from_asap(inst, f)
                        prof = generate_profile(scen, T, plat, J=J,
                                                seed=seed + 17,
                                                work_capacity=peak)
                        yield InstanceCase(
                            name=f"{kind}-{size}-{cl}-{scen}-D{f}",
                            inst=inst, platform=plat, profile=prof,
                            factor=f, scenario=scen)


def run_all_variants(case: InstanceCase, variants=None, mu: int = 10,
                     engine: str = "numpy"):
    """Returns {variant: (cost, seconds)} incl. the asap baseline.

    One amortized portfolio pass (bit-identical to looping ``schedule()``
    over the variants — the shared EST/LST/mask/budget precompute and the
    8 unique greedy runs are paid once per instance, not per variant).
    """
    names = ("asap",) + tuple(variants or VARIANT_NAMES)
    res = schedule_portfolio(case.inst, case.profile, case.platform,
                             variants=names, mu=mu, engine=engine)
    return {v: (r.cost, r.seconds) for v, r in res.items()}


def run_variant_loop(case: InstanceCase, variants=None, mu: int = 10):
    """The pre-portfolio seed-style path: one sequential-reference run per
    variant (``schedule_reference`` — ``schedule()`` itself is a Planner
    shim now, so the reference keeps this baseline honest)."""
    out = {}
    for v in ("asap",) + tuple(variants or VARIANT_NAMES):
        r = schedule_reference(case.inst, case.profile, case.platform, v,
                               mu=mu)
        out[v] = (r.cost, r.seconds)
    return out


def write_csv(name: str, header: list[str], rows: list[list]):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def emit(name: str, us_per_call: float, derived: str):
    """The harness contract: ``name,us_per_call,derived`` CSV on stdout."""
    print(f"{name},{us_per_call:.1f},{derived}")
