"""Fig 1: rank distribution of the 16 LS variants + ASAP across instances.

Each case runs through ``schedule_portfolio`` (via ``run_all_variants``):
one amortized pass per instance instead of 9 independent ``schedule()``
calls — identical costs, ~a portfolio-factor faster wall clock.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    VARIANT_NAMES,
    build_matrix,
    emit,
    run_all_variants,
    write_csv,
)

LS_VARIANTS = tuple(v for v in VARIANT_NAMES if v.endswith("-LS"))


def run(sizes=(200,), clusters=("small",)):
    algos = ("asap",) + LS_VARIANTS
    ranks = {a: np.zeros(len(algos), dtype=np.int64) for a in algos}
    worst = {a: 0 for a in algos}
    n_cases = 0
    t0 = time.perf_counter()
    for case in build_matrix(sizes=sizes, clusters=clusters):
        res = run_all_variants(case, variants=LS_VARIANTS)
        costs = {a: res[a][0] for a in algos}
        ordered = sorted(set(costs.values()))
        for a in algos:
            ranks[a][ordered.index(costs[a])] += 1
        wc = max(costs.values())
        for a in algos:
            if costs[a] == wc:
                worst[a] += 1
        n_cases += 1
    dt = time.perf_counter() - t0
    rows = [[a] + list(ranks[a]) + [worst[a]] for a in algos]
    write_csv("fig1_ranks.csv",
              ["algo"] + [f"rank{i+1}" for i in range(len(algos))] + ["worst"],
              rows)
    asap_worst_pct = 100.0 * worst["asap"] / max(n_cases, 1)
    best_rank1 = max(LS_VARIANTS, key=lambda a: ranks[a][0])
    emit("fig1_rank_distribution", dt / max(n_cases, 1) * 1e6,
         f"asap_worst={asap_worst_pct:.1f}%;rank1_leader={best_rank1}"
         f";rank1_share={100.0 * ranks[best_rank1][0] / max(n_cases, 1):.1f}%")
    return ranks, worst, n_cases


if __name__ == "__main__":
    run()
