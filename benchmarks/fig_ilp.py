"""Fig 7: heuristics vs exact ILP optimum on small instances.

Runs on the Planner's solver axis: one ``plan(solver="exact")`` per case
(the auto-dispatching DP/ILP oracle) against one heuristic plan, with
provenness certified by ``lower_bound == cost`` instead of a hand-rolled
status check.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import VARIANT_NAMES, build_matrix, emit, write_csv
from repro.api import Planner, PlanRequest

LS_VARIANTS = tuple(v for v in VARIANT_NAMES if v.endswith("-LS"))


def run(max_tasks: int = 70, time_limit: float = 90.0):
    rows = []
    ratios = {v: [] for v in LS_VARIANTS + ("asap",)}
    t0 = time.perf_counter()
    n = 0
    for case in build_matrix(sizes=(30,), clusters=("small",),
                             factors=(1.5,), scenarios=("S1", "S3"),
                             J=6):
        if case.inst.num_tasks > max_tasks or case.profile.T > 400:
            continue
        planner = Planner(case.platform, engine="numpy")
        req = dict(instances=case.inst, profiles=case.profile)
        try:
            exact = planner.plan(PlanRequest(
                **req, solver="exact",
                solver_options={"time_limit": time_limit}))
        except ValueError:
            continue        # no incumbent within the time limit
        opt = int(exact.costs[0, 0, 0])
        if int(exact.lower_bound[0, 0]) != opt:
            continue        # only PROVEN optima count (paper Fig 7)
        heur = planner.plan(PlanRequest(
            **req, variants=LS_VARIANTS + ("asap",)))
        for v in LS_VARIANTS + ("asap",):
            c = int(heur.result(variant=v).cost)
            r = 1.0 if (c == 0 and opt == 0) else (
                opt / c if c > 0 else 0.0)
            ratios[v].append(r)
            rows.append([case.name, v, c, f"{opt:.1f}", f"{r:.4f}"])
        n += 1
    dt = time.perf_counter() - t0
    write_csv("fig7_ilp_ratio.csv",
              ["case", "variant", "heur_cost", "ilp_cost", "ratio"], rows)
    med = {v: float(np.median(r)) if r else float("nan")
           for v, r in ratios.items()}
    best = max((v for v in LS_VARIANTS), key=lambda v: med[v])
    n_opt = sum(1 for v in LS_VARIANTS for r in ratios[v] if r >= 0.999)
    emit("fig7_ilp_comparison", dt / max(n, 1) * 1e6,
         f"median_ratio={med[best]:.3f}({best});asap={med['asap']:.3f}"
         f";optimal_hits={n_opt}")
    return med


if __name__ == "__main__":
    run()
