"""Benchmark harness: one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV lines (and per-figure CSVs in
benchmarks/out/). ``--full`` runs the paper-scale matrix (hours);
the default is a faithful scaled-down matrix that finishes in minutes.
"""
from __future__ import annotations

import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale matrix (34 workflows, 72/144 nodes)")
    ap.add_argument("--only", default=None,
                    help="comma list: rank,profile,ratio,ls,ilp,runtime,"
                         "roofline,portfolio")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, <60s; refresh BENCH_portfolio.json "
                         "(incl. its solver-quality `gaps` section) "
                         "cheaply in perf-touching PRs (tier-2: "
                         "`make bench-smoke`)")
    args = ap.parse_args()

    sizes = (200, 1000) if args.full else (200,)
    clusters = ("small-full", "large-full") if args.full else ("small",)
    want = set((args.only or
                "rank,profile,ratio,ls,ilp,runtime,roofline,portfolio"
                ).split(","))

    print("name,us_per_call,derived")
    if "rank" in want:
        from benchmarks.fig_rank import run as r1
        r1(sizes=sizes, clusters=clusters)
    if "profile" in want:
        from benchmarks.fig_perf_profile import run as r2
        r2(sizes=sizes, clusters=clusters)
    if "ratio" in want:
        from benchmarks.fig_cost_ratio import run as r3
        r3(sizes=sizes, clusters=clusters)
    if "ls" in want:
        from benchmarks.tab_local_search import run as r4
        r4(sizes=sizes, clusters=clusters)
    if "ilp" in want:
        from benchmarks.fig_ilp import run as r5
        r5(time_limit=20.0 if args.smoke else 90.0)
    if "runtime" in want:
        from benchmarks.fig_runtime import run as r6
        r6(sizes=(200, 1000, 4000) if args.full else (200, 1000))
    if "roofline" in want:
        from benchmarks.roofline_table import run as r7
        r7()
    if "portfolio" in want:
        from benchmarks.fig_portfolio import run as r8
        if args.smoke:
            r8(sizes=(60,), clusters=("small",), n_cases=2, n_profiles=4,
               smoke=True)
        else:
            r8(sizes=(200,), clusters=("small",))


if __name__ == "__main__":
    main()
