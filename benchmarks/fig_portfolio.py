"""Portfolio engine benchmark: seed-style per-variant loop vs one-pass
``schedule_portfolio`` on the 17-algorithm matrix, machine-readable.

Emits ``benchmarks/out/BENCH_portfolio.json``:
  * ``loop_us_per_instance`` / ``portfolio_us_per_instance`` — live
    measurements of the per-variant ``schedule()`` loop and the portfolio
    engine on the same instances (identical results, tested);
  * ``jax_fanout_us_per_instance`` — the vmapped device fan-out
    (``engine="jax"``), greedy stage bit-identical, batched -LS rounds;
  * ``seed_reference`` — the recorded wall clock of
    ``run.py --only rank,runtime`` at the seed commit vs this one (the
    acceptance trajectory; update SEED_REFERENCE when re-measuring on new
    hardware — run that matrix at the seed commit in a scratch worktree).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (
    OUT_DIR,
    build_matrix,
    emit,
    run_all_variants,
    run_variant_loop,
)

# wall clock of `run.py --only rank,runtime` (scaled-down matrix, this
# container), measured at the seed commit and after this PR's engine landed.
SEED_REFERENCE = {
    "matrix": "run.py --only rank,runtime (sizes=(200,)/(200,1000))",
    "seed_commit_seconds": 237.7,     # measured at seed commit, 1-CPU box
    "this_commit_seconds": 46.8,      # same box, portfolio engine (5.1x)
}


def run(sizes=(200,), clusters=("small",), n_cases: int = 6,
        with_jax: bool = True):
    cases = []
    for case in build_matrix(sizes=sizes, clusters=clusters,
                             factors=(1.0, 2.0), scenarios=("S1", "S3")):
        cases.append(case)
        if len(cases) >= n_cases:
            break

    t0 = time.perf_counter()
    loop_res = [run_variant_loop(c) for c in cases]
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    port_res = [run_all_variants(c) for c in cases]
    t_port = time.perf_counter() - t0

    for lr, pr in zip(loop_res, port_res):     # engine must be bit-identical
        for v, (cost, _) in lr.items():
            assert pr[v][0] == cost, v

    t_jax = None
    if with_jax:
        t0 = time.perf_counter()
        for c in cases:
            run_all_variants(c, engine="jax")
        t_jax = time.perf_counter() - t0

    n = len(cases)
    payload = {
        "n_instances": n,
        "variants_per_instance": 17,
        "loop_us_per_instance": t_loop / n * 1e6,
        "portfolio_us_per_instance": t_port / n * 1e6,
        "speedup_loop_over_portfolio": t_loop / t_port,
        "jax_fanout_us_per_instance": (t_jax / n * 1e6) if t_jax else None,
        "seed_reference": dict(SEED_REFERENCE),
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, "BENCH_portfolio.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    emit("portfolio_engine", t_port / n * 1e6,
         f"loop/portfolio={t_loop / t_port:.2f}x"
         f";jax_us={payload['jax_fanout_us_per_instance'] or 0:.0f}")
    return payload


if __name__ == "__main__":
    run()
