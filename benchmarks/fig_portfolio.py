"""Portfolio engine benchmark: seed-style per-variant loop vs one-pass
``schedule_portfolio`` vs the device fan-out, plus the multi-profile
replanning engine, machine-readable.

Emits ``benchmarks/out/BENCH_portfolio.json``:
  * ``loop_us_per_instance`` / ``portfolio_us_per_instance`` — live
    measurements of the per-variant ``schedule()`` loop and the portfolio
    engine on the same instances (identical results, tested);
  * ``jax_fanout_us_per_instance`` — the device engine (``engine="jax"``)
    in its replanning regime (steady-state: executables cached per shape
    bucket); ``jax_fanout_cold_us_per_instance`` includes the one-off
    bucket compiles; ``jax_fanout_us_per_instance_before`` is the recorded
    pre-fix number (per-shape retracing, level-relax scan core,
    interpreter-mode gain kernel);
  * ``multi_profile`` — ``schedule_portfolio_multi`` over an ensemble of
    perturbed profiles vs looping ``schedule_portfolio`` per profile;
  * ``planner`` — the Planner facade's overhead over the grid engine it
    wraps (request normalization + cache lookup + result assembly), the
    legacy-shim path for reference, and the combined instance x profile
    fan-out: cells, shape buckets, and the grid jit cache-miss counts
    proving one device launch per bucket (cold) and zero retracing
    (steady);
  * ``gaps`` — the solver-quality table: heuristics vs the exact oracle
    (``solver="exact"``: DP on a uniprocessor chain, ILP on a tiny
    multiprocessor DAG) on small instances, so the perf trajectory also
    tracks solution quality (a speedup that silently costs optimality
    shows up here);
  * ``service`` — serving-tier telemetry: a coalesced burst, forced
    degradations, and structured rejections through ``PlanService``,
    reported as queue depth, coalesce ratio, p50/p99 plan latency, and
    degradation counts; plus worker-pool scaling (the same burst through
    1 vs 4 drain workers) and the cooperative-cancellation latency (time
    for the pool to go idle after ``Ticket.cancel`` lands on a wedged
    solve);
  * ``obs`` — observability overhead, measured: disabled-tracer hot-path
    cost per ``obs.span`` call, spans-per-plan and the enabled-tracer
    wall clock on the same steady-state fan-out, the
    ``disabled_tracer_overhead_frac`` acceptance number (asserted < 2%),
    and the jax hook snapshot (compile events, jit cache entries);
  * ``mapping`` — joint mapping x scheduling vs schedule-only: per
    motif family, the best cost under the fixed HEFT mapping vs the
    candidate-mapping search on a scarce profile, the saving fraction,
    and candidate throughput (acceptance: search strictly wins on >= 3
    of the 4 families);
  * ``sharded`` — multi-device scaling data (:mod:`benchmarks
    .fig_sharded`): the grid launch timed per forced-host-device count
    in a subprocess (bitwise-verified against single-device) and the
    tiled Pallas gain kernel vs its jnp twin (measured honestly: on
    this CPU box the virtual devices share one core and the kernel runs
    interpreted, so ``speedup_vs_1`` ~ 1 and ``crossover_n`` is null —
    the section records real numbers, not extrapolations);
  * ``seed_reference`` — the recorded wall clock of
    ``run.py --only rank,runtime`` at the seed commit vs this one (the
    acceptance trajectory; update SEED_REFERENCE when re-measuring on new
    hardware — run that matrix at the seed commit in a scratch worktree).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (
    OUT_DIR,
    build_matrix,
    emit,
    run_all_variants,
    run_variant_loop,
)
from repro.core import generate_profile, schedule_portfolio, \
    schedule_portfolio_multi

# wall clock of `run.py --only rank,runtime` (scaled-down matrix, this
# container), measured at the seed commit and after PR1's engine landed.
SEED_REFERENCE = {
    "matrix": "run.py --only rank,runtime (sizes=(200,)/(200,1000))",
    "seed_commit_seconds": 237.7,     # measured at seed commit, 1-CPU box
    "this_commit_seconds": 46.8,      # same box, portfolio engine (5.1x)
}

# `engine="jax"` per instance before the fan-out fix (per-shape retracing
# of the nested level-relax scan + interpreter-mode gain kernel), recorded
# by this benchmark at the PR1 commit — ON THE REFERENCE MATRIX below.
# A --smoke run measures a different matrix, so the recorded baselines are
# withheld there (comparing live tiny-matrix numbers against recorded
# 200-size baselines would fabricate the speedup).
JAX_FANOUT_BEFORE_US = 2733936.2
REFERENCE_MATRIX = {"sizes": [200], "clusters": ["small"], "n_cases": 6}


def _gap_cases():
    """Tiny instances for the solver-quality table: one uniprocessor
    chain (``solver="exact"`` -> the §4.1 polynomial DP) and one
    multiprocessor DAG (-> the time-indexed ILP), both with budgets tight
    enough that scheduling decisions carry nonzero cost. Short durations
    keep the ILP's time-indexed model small (seconds, smoke-safe)."""
    from repro.cluster import make_cluster
    from repro.core import build_instance, deadline_from_asap
    from repro.core.carbon import PowerProfile
    from repro.core.dag import trivial_mapping
    from repro.workflows import layered_random

    plat = make_cluster(1, seed=0)
    out = []
    for name, by, seed in (("uniproc-chain", "single", 7),
                           ("multiproc-dag", "round_robin", 0)):
        rng = np.random.default_rng(seed)
        wf = layered_random(6, 3, seed=seed)
        dur = rng.integers(1, 6, size=wf.n)
        inst = build_instance(wf, trivial_mapping(wf, plat, by=by), plat,
                              dur=dur)
        T = deadline_from_asap(inst, 1.5)
        bounds = np.unique(np.round(np.linspace(0, T, 5)).astype(np.int64))
        budget = plat.idle_total + rng.integers(
            0, max(int(inst.task_work.max()) // 2, 2),
            size=len(bounds) - 1)
        out.append((name, plat, inst,
                    PowerProfile(bounds=bounds, budget=budget)))
    return out


def _gap_table(gap_time_limit: float) -> dict:
    """heuristics-vs-baseline-vs-exact on the tiny matrix, per case."""
    from repro.api import Planner, PlanRequest

    gaps = {"time_limit": gap_time_limit, "cases": []}
    for name, plat, inst, prof in _gap_cases():
        planner = Planner(plat, engine="numpy")
        req = dict(instances=inst, profiles=prof)
        exact = planner.plan(PlanRequest(
            **req, solver="exact",
            solver_options={"time_limit": gap_time_limit}))
        heur = planner.plan(PlanRequest(**req))
        base = planner.plan(PlanRequest(**req, solver="asap"))
        opt = int(exact.costs[0, 0, 0])
        lb = int(exact.lower_bound[0, 0])

        def ratio(c: int):
            return (c / opt) if opt > 0 else (1.0 if c == 0 else None)

        gaps["cases"].append({
            "case": name,
            "n_tasks": int(inst.num_tasks),
            "T": int(prof.T),
            "solver": exact.solver,
            "optimal": opt,
            "lower_bound": lb,
            "proven": lb == opt,
            "best_heuristic": int(heur.best_costs()[0, 0]),
            "gap_best": float(heur.gap(exact)[0, 0]),
            "gap_asap": ratio(int(base.costs[0, 0, 0])),
            "per_variant": {
                v: ratio(int(heur.costs[0, 0, i]))
                for i, v in enumerate(heur.variants)},
        })
    return gaps


def _lp_blocked_section(cases) -> dict:
    """Dense-vs-blocked longest-path engines on one small instance, plus
    an over-envelope instance only the blocked form can serve (its dense
    matrix raises MemoryError under the same lp budget), plus the
    steady-state jit cache-miss guarantee: the blocked path retraces
    nothing and adds no misses to the dense grid executable either."""
    from repro.cluster import make_cluster
    from repro.core import build_instance, deadline_from_asap, heft_mapping
    from repro.core.greedy_jax import (
        BlockedLP,
        _blocked_impl,
        _impl,
        longest_path_matrix,
        lp_block_bytes,
        lp_matrix_bytes,
        pad_dims,
    )
    from repro.core.portfolio import _COMBOS, prepare_graph, \
        schedule_portfolio_grid
    from repro.workflows import wfgen_scale

    V = len(_COMBOS)        # unique greedy orders the full grid fans out
    c = cases[0]
    inst, plat, prof = c.inst, c.platform, c.profile
    N = inst.num_tasks
    Np, _ = pad_dims(N, prof.T)

    def timed_grid(graph, reps=3):
        best = []
        for _ in range(reps):
            t0 = time.perf_counter()
            res = schedule_portfolio_grid([inst], [[prof]], plat,
                                          engine="jax", graphs=[graph])
            best.append(time.perf_counter() - t0)
        return res, float(np.median(best))

    g_dense = prepare_graph(inst, plat, prof.T)
    timed_grid(g_dense, reps=1)                    # warm the bucket
    res_dense, t_dense = timed_grid(g_dense)

    budget = lp_block_bytes(4, V, Np)
    if budget >= lp_matrix_bytes(N):               # tiny N: force the form
        budget = lp_block_bytes(1, V, Np)
    g_blk = prepare_graph(inst, plat, prof.T)
    g_blk._lp = BlockedLP(inst, budget_bytes=budget)
    timed_grid(g_blk, reps=1)                      # warm the chunk shape
    res_blk, t_blk = timed_grid(g_blk)
    for name, ref in res_dense[0][0].items():      # engines must agree
        assert res_blk[0][0][name].cost == ref.cost, name

    # steady state: re-running the blocked path must add zero jit cache
    # misses — neither to its own chunked executable nor to the dense grid
    grid_fn, blk_fn = _impl()["grid"], _blocked_impl()["multi"]
    before = grid_fn._cache_size() + blk_fn._cache_size()
    schedule_portfolio_grid([inst], [[prof]], plat, engine="jax",
                            graphs=[g_blk])
    misses_steady = grid_fn._cache_size() + blk_fn._cache_size() - before
    assert misses_steady == 0

    # over-envelope: an instance whose dense matrix exceeds the (reduced)
    # lp budget — longest_path_matrix refuses, the blocked form schedules
    wf = wfgen_scale("eager", 3 * N, seed=1)
    big = build_instance(wf, heft_mapping(wf, make_cluster(1, seed=1)),
                         make_cluster(1, seed=1))
    from repro.core import generate_profile
    bT = deadline_from_asap(big, 1.5)
    bprof = generate_profile("S1", bT, make_cluster(1, seed=1), J=16, seed=1)
    bNp, _ = pad_dims(big.num_tasks, bT)
    bbudget = max(lp_matrix_bytes(big.num_tasks) // 8,
                  lp_block_bytes(2, V, bNp))
    dense_raises = False
    try:
        longest_path_matrix(big, max_bytes=bbudget)
    except MemoryError:
        dense_raises = True
    g_big = prepare_graph(big, make_cluster(1, seed=1), bT,
                          lp_budget_bytes=bbudget)
    schedule_portfolio_grid([big], [[bprof]], make_cluster(1, seed=1),
                            engine="jax", graphs=[g_big])       # warm
    t0 = time.perf_counter()
    schedule_portfolio_grid([big], [[bprof]], make_cluster(1, seed=1),
                            engine="jax", graphs=[g_big])
    t_big = time.perf_counter() - t0

    return {
        "small": {
            "case": c.name,
            "n_tasks": N,
            "dense_us": t_dense * 1e6,
            "blocked_us": t_blk * 1e6,
            "blocked_over_dense": t_blk / t_dense,
            "budget_bytes": int(budget),
            "block_width": int(g_blk.lp().chunk_width(V, Np)),
        },
        "over_envelope": {
            "n_tasks": int(big.num_tasks),
            "lp_bytes": int(lp_matrix_bytes(big.num_tasks)),
            "budget_bytes": int(bbudget),
            "dense_raises": dense_raises,
            "blocked_us": t_big * 1e6,
            "block_width": int(g_big.lp().chunk_width(V, bNp)),
        },
        "jit_cache_misses_steady": int(misses_steady),
    }


def _service_section(cases) -> dict:
    """Serving-tier telemetry on a representative burst: a coalesced
    same-key burst (one combined launch serves every caller), two
    zero-budget requests that degrade down the ladder to ``asap``, one
    malformed request rejected at admission, and one load-shed
    :class:`~repro.serve.service.Overloaded` rejection — then the
    :meth:`PlanService.stats` snapshot (queue depth, coalesce ratio,
    p50/p99 plan latency, degradation counts) becomes the payload.

    Two robustness measurements ride along: ``workers_scaling`` times the
    same un-coalescable burst (``max_batch=1``) through a 1-worker and a
    4-worker pool, and ``cancel_latency_ms`` times how long the pool
    takes to go idle after :meth:`Ticket.cancel` lands on a solve wedged
    by an injected hang (the cooperative cancellation path end to end).
    Pure-python numpy solves hold the GIL, so the pool speedup on this
    engine measures dispatch overhead (~1x), not parallel solve
    throughput — the pool exists for isolation and supervision, and
    scales when solves release the GIL (ILP subprocesses, jax device
    launches).

    All services here run with ``compilation_cache=False`` — the bench
    must never flip the persistent jax cache on (see the NOTE in
    :func:`run`)."""
    from repro.api import Planner, PlanRequest
    from repro.runtime.fault import FaultSpec, ServiceFaultInjector
    from repro.serve import InvalidRequest, Overloaded, PlanService

    c = cases[0]
    burst = 6
    planner = Planner(c.platform, engine="numpy")
    req = PlanRequest(instances=c.inst, profiles=c.profile)
    with PlanService(planner, max_queue=burst + 2,
                     compilation_cache=False) as svc:
        svc.pause()                      # let the burst pile up: coalesce
        tickets = [svc.submit(req) for _ in range(burst)]
        svc.resume()
        for t in tickets:
            t.result(timeout=600)
        degraded = [svc.plan(req, budget=0.0) for _ in range(2)]
        try:                             # malformed: structured rejection
            svc.submit(PlanRequest(instances=c.inst, profiles=[]))
        except InvalidRequest:
            pass
        svc.pause()                      # overload: fill the queue, shed
        filler = []
        try:
            for _ in range(svc.max_queue + 1):
                filler.append(svc.submit(req))
        except Overloaded:
            pass
        svc.resume()
        for t in filler:
            t.result(timeout=600)
        stats = svc.stats()
    assert all(d.degraded and d.fallback_stage == "asap" for d in degraded)

    # Worker-count scaling: max_batch=1 defeats coalescing so the burst
    # is `burst` independent solves — the only speedup source is the pool.
    def _pool_burst_seconds(workers: int):
        pool_planner = Planner(c.platform, engine="numpy")
        with PlanService(pool_planner, workers=workers, max_batch=1,
                         max_queue=2 * burst,
                         compilation_cache=False) as pool:
            pool.pause()
            ts = [pool.submit(req) for _ in range(burst)]
            t0 = time.perf_counter()
            pool.resume()
            for t in ts:
                t.result(timeout=600)
            return time.perf_counter() - t0, pool.stats()

    seconds_w1, _ = _pool_burst_seconds(1)
    seconds_w4, stats_w4 = _pool_burst_seconds(4)

    # Cancellation latency: wedge the first solve with an injected hang,
    # cancel its ticket, and time the pool back to inflight_solves == 0 —
    # this is the watchdog->CancelToken->solver-checkpoint path, not a
    # queue drop.
    inj = ServiceFaultInjector(faults=[
        FaultSpec(kind="hang", stage="heuristic", times=1, seconds=60.0)])
    hang_planner = Planner(c.platform, engine="numpy")
    with PlanService(hang_planner, injector=inj,
                     compilation_cache=False) as hang_svc:
        ticket = hang_svc.submit(req)
        deadline = time.monotonic() + 30.0
        while (hang_svc.stats()["inflight_solves"] == 0
               and time.monotonic() < deadline):
            time.sleep(0.002)
        t0 = time.perf_counter()
        ticket.cancel("bench cancellation probe")
        while (hang_svc.stats()["inflight_solves"] > 0
               and time.monotonic() < deadline):
            time.sleep(0.002)
        cancel_latency_ms = (time.perf_counter() - t0) * 1e3
        cancel_stats = hang_svc.stats()

    return {
        "case": c.name,
        "burst": burst,
        "batches": stats["batches"],
        "coalesce_ratio": stats["coalesce_ratio"],
        "max_queue_depth": stats["max_queue_depth"],
        "completed": stats["completed"],
        "degraded": stats["degraded"],
        "rejected_invalid": stats["rejected_invalid"],
        "rejected_overloaded": stats["rejected_overloaded"],
        "stages": stats["stages"],
        "latency_p50_ms": stats["latency"]["p50_ms"],
        "latency_p99_ms": stats["latency"]["p99_ms"],
        "workers_scaling": {
            "burst": burst,
            "seconds_1_worker": seconds_w1,
            "seconds_4_workers": seconds_w4,
            "speedup": (seconds_w1 / seconds_w4
                        if seconds_w4 > 0 else None),
            "worker_restarts": stats_w4["worker_restarts"],
            "priority_inversions": stats_w4["priority_inversions"],
        },
        "cancel_latency_ms": cancel_latency_ms,
        "cancel_checks": cancel_stats["cancel_checks"],
        "cancelled_solves": cancel_stats["cancelled_solves"],
    }


def _obs_section(cases, with_jax: bool) -> dict:
    """Observability overhead, measured: the disabled-tracer hot-path
    cost (one global read + identity check per ``obs.span`` call), the
    span volume and wall-clock of the same steady-state fan-out with a
    live tracer, and the jax runtime hook snapshot (compile events,
    per-launcher jit cache entries, live arrays).

    ``disabled_tracer_overhead_frac`` is the acceptance number: the
    measured per-call disabled cost times the spans the plan would have
    emitted, as a fraction of the disabled-path plan time — asserted
    under 2% (spans are placed at launch/chunk granularity, never
    per-task, so the real figure is orders of magnitude below)."""
    import timeit

    from repro import obs
    from repro.obs import jax_hooks

    jax_hooks.install(obs.registry())
    c = cases[0]
    engine = "jax" if with_jax else "numpy"

    # the disabled span call, isolated: subtract the bare-lambda floor
    n = 200_000
    t_span = timeit.timeit(lambda: obs.span("x"), number=n) / n
    t_base = timeit.timeit(lambda: None, number=n) / n
    null_span_ns = max(t_span - t_base, 0.0) * 1e9

    run_all_variants(c, engine=engine)           # warm caches/executables
    reps = 7
    prev = obs.set_tracer(None)
    try:
        t_dis = []
        for _ in range(reps):
            t0 = time.perf_counter()
            run_all_variants(c, engine=engine)
            t_dis.append(time.perf_counter() - t0)
        t_disabled = float(np.median(t_dis))

        tr = obs.Tracer()
        obs.set_tracer(tr)
        t_en = []
        for _ in range(reps):
            tr.clear()
            t0 = time.perf_counter()
            run_all_variants(c, engine=engine)
            t_en.append(time.perf_counter() - t0)
        t_enabled = float(np.median(t_en))
        spans_per_plan = len(tr.finished())
    finally:
        obs.set_tracer(prev)

    overhead = spans_per_plan * null_span_ns * 1e-9 / t_disabled
    assert overhead < 0.02, (overhead, spans_per_plan, null_span_ns)

    return {
        "case": c.name,
        "engine": engine,
        "null_span_ns": null_span_ns,
        "spans_per_plan": spans_per_plan,
        "steady_plan_us_disabled": t_disabled * 1e6,
        "steady_plan_us_enabled": t_enabled * 1e6,
        "disabled_tracer_overhead_frac": overhead,
        "enabled_tracer_overhead_frac": t_enabled / t_disabled - 1.0,
        "jax": jax_hooks.snapshot(obs.registry()),
    }


def _mapping_section() -> dict:
    """Joint mapping x scheduling vs schedule-only, per motif family.

    For each of the paper's four workflow motifs: plan the same workflow
    with ``mapping="heft"`` (HEFT mapping, schedule-only optimization)
    and ``mapping="search"`` (the alternating candidate-mapping search),
    on a scarce profile where the green budget covers ~40 units of work
    per interval — the regime where the mapping choice actually moves
    carbon cost.  Reports per-motif best costs, the joint-mode saving,
    and candidate throughput; the acceptance bar is a strict search win
    on at least 3 of the 4 families."""
    from repro.api import Planner, PlanRequest
    from repro.cluster import make_cluster
    from repro.core import build_instance, deadline_from_asap, heft_mapping
    from repro.workflows import WORKFLOW_KINDS, make_workflow

    plat = make_cluster(1, seed=0)
    families = []
    wins = 0
    for kind in WORKFLOW_KINDS:
        wf = make_workflow(kind, 2, seed=1)
        inst_h = build_instance(wf, heft_mapping(wf, plat), plat)
        T = deadline_from_asap(inst_h, 3.0)
        prof = generate_profile("S3", T, plat, J=12, seed=2,
                                work_capacity=40)
        planner = Planner(plat, engine="numpy")
        res_h = planner.plan(PlanRequest(instances=wf, profiles=prof,
                                         mapping="heft"))
        t0 = time.perf_counter()
        res_s = planner.plan(PlanRequest(
            instances=wf, profiles=prof, mapping="search",
            mapping_options={"seeds": 6, "rounds": 3, "neighbors": 9,
                             "seed": 0}))
        t_search = time.perf_counter() - t0
        info = res_s.mapping_info[0]
        cost_h = int(res_h.best().cost)
        cost_s = int(res_s.best().cost)
        wins += cost_s < cost_h
        families.append({
            "family": kind,
            "n_tasks": int(wf.n),
            "T": int(T),
            "heft_cost": cost_h,
            "search_cost": cost_s,
            "saving_frac": (cost_h - cost_s) / cost_h if cost_h else 0.0,
            "winner_label": info.label,
            "candidates": info.candidates,
            "rounds": info.rounds,
            "candidates_per_sec": (info.candidates / t_search
                                   if t_search > 0 else None),
            "search_seconds": t_search,
        })
    return {"families": families, "search_wins": wins,
            "n_families": len(families)}


def run(sizes=(200,), clusters=("small",), n_cases: int = 6,
        with_jax: bool = True, n_profiles: int = 8,
        gap_time_limit: float = 20.0, smoke: bool = False):
    # NOTE: the persistent compilation cache
    # (repro.kernels.backend.enable_compilation_cache) is deliberately NOT
    # enabled here: the cold measurement must include the real bucket
    # compiles on every run, or cold-vs-steady comparisons across commits
    # would silently go warm after the first run on a machine.
    cases = []
    for case in build_matrix(sizes=sizes, clusters=clusters,
                             factors=(1.0, 2.0), scenarios=("S1", "S3")):
        cases.append(case)
        if len(cases) >= n_cases:
            break

    t0 = time.perf_counter()
    loop_res = [run_variant_loop(c) for c in cases]
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    port_res = [run_all_variants(c) for c in cases]
    t_port = time.perf_counter() - t0

    for lr, pr in zip(loop_res, port_res):     # engine must be bit-identical
        for v, (cost, _) in lr.items():
            assert pr[v][0] == cost, v

    t_jax = t_jax_cold = None
    multi = None
    planner_stats = None
    if with_jax:
        t0 = time.perf_counter()
        for c in cases:
            run_all_variants(c, engine="jax")
        t_jax_cold = time.perf_counter() - t0   # includes bucket compiles
        t0 = time.perf_counter()
        for c in cases:
            run_all_variants(c, engine="jax")
        t_jax = time.perf_counter() - t0        # replanning regime

        # multi-profile replanning: one instance x an ensemble of perturbed
        # forecasts; loop re-prepares and re-schedules per member, the
        # engine prepares the graph once and fans members x variants out
        # as one device launch
        c = cases[0]
        profs = [generate_profile(c.profile.scenario, c.profile.T,
                                  c.platform, J=48, seed=100 + s)
                 for s in range(n_profiles)]
        t0 = time.perf_counter()
        ref = [schedule_portfolio(c.inst, p, c.platform) for p in profs]
        t_mloop = time.perf_counter() - t0
        schedule_portfolio_multi(c.inst, profs, c.platform,
                                 engine="jax")   # warm the R-bucket shapes
        t0 = time.perf_counter()
        res = schedule_portfolio_multi(c.inst, profs, c.platform,
                                       engine="jax")
        t_multi = time.perf_counter() - t0
        # greedy rows must agree with the per-profile numpy loop
        for r, rr in zip(ref, res):
            for v in r:
                if not v.endswith("-LS"):
                    assert (r[v].start == rr[v].start).all(), v
        multi = {
            "n_profiles": n_profiles,
            "case": c.name,
            "loop_numpy_us_per_profile": t_mloop / n_profiles * 1e6,
            "multi_jax_us_per_profile": t_multi / n_profiles * 1e6,
            "speedup_multi_over_loop": t_mloop / t_multi,
        }

        # --- Planner facade: overhead over the grid engine it wraps, and
        # the combined instance x profile fan-out as ONE bucketed launch
        from repro.api import Planner, PlanRequest
        from repro.core.greedy_jax import _impl, pad_dims
        from repro.core.portfolio import schedule_portfolio_grid

        reps = 9       # wall-clock drift on the shared box swamps a
        # 5-rep median (single-sample swings of ±10% were observed);
        # 9 rotated reps keep the facade-overhead estimate honest
        planner = Planner(c.platform, engine="jax")
        req = PlanRequest(instances=c.inst, profiles=profs)
        planner.plan(req)                       # warm cache + executables
        graph = planner.prepared(c.inst, profs[0].T)
        contenders = {
            "facade": lambda: planner.plan(req),
            "grid": lambda: schedule_portfolio_grid(
                [c.inst], [profs], c.platform, engine="jax",
                graphs=[graph]),
            "legacy": lambda: schedule_portfolio_multi(     # graph seeded
                c.inst, profs, c.platform, engine="jax", graph=graph),
        }
        samples = {k: [] for k in contenders}
        keys = list(contenders)
        for rep in range(reps):                 # rotate order: de-bias
            for k in keys[rep % 3:] + keys[:rep % 3]:       # load drift
                t0 = time.perf_counter()
                contenders[k]()
                samples[k].append(time.perf_counter() - t0)
        t_facade, t_grid, t_legacy = (float(np.median(samples[k]))
                                      for k in keys)

        # combined grid: 2 instances sharing one shape bucket x ensemble
        # x 17 variants; the greedy fan-out must be ONE device launch per
        # bucket (verified by the jit cache-miss count)
        profs_b = [generate_profile(c.profile.scenario, c.profile.T,
                                    c.platform, J=48, seed=300 + s)
                   for s in range(n_profiles)]
        insts = [c.inst, c.inst]
        grid_req = PlanRequest(instances=insts,
                               profiles=[profs, profs_b])
        buckets = {pad_dims(i.num_tasks, profs[0].T) for i in insts}
        grid_fn = _impl()["grid"]
        before = grid_fn._cache_size()
        planner.plan(grid_req)                  # cold: compiles per bucket
        misses_cold = grid_fn._cache_size() - before
        before = grid_fn._cache_size()
        t0 = time.perf_counter()
        res = planner.plan(grid_req)
        t_combined = time.perf_counter() - t0
        misses_steady = grid_fn._cache_size() - before
        assert misses_cold == len(buckets), (misses_cold, buckets)
        assert misses_steady == 0               # steady: zero retracing
        n_cells = res.costs.size
        planner_stats = {
            "case": c.name,
            "facade_us": t_facade * 1e6,
            "grid_direct_us": t_grid * 1e6,
            "legacy_shim_us": t_legacy * 1e6,
            "facade_overhead_frac": t_facade / t_grid - 1.0,
            "combined_grid": {
                "n_instances": len(insts),
                "n_profiles": n_profiles,
                "n_variants": res.costs.shape[2],
                "cells": int(n_cells),
                "shape_buckets": len(buckets),
                "jit_cache_misses_cold": int(misses_cold),
                "jit_cache_misses_steady": int(misses_steady),
                "steady_us_per_cell": t_combined / n_cells * 1e6,
            },
        }

    lp_blocked = _lp_blocked_section(cases) if with_jax else None

    service = _service_section(cases)

    obs_stats = _obs_section(cases, with_jax=with_jax)

    gaps = _gap_table(gap_time_limit)

    mapping = _mapping_section()

    sharded = None
    if with_jax:
        from benchmarks.fig_sharded import section as sharded_section

        sharded = sharded_section(smoke=smoke)

    n = len(cases)
    matrix = {"sizes": list(sizes), "clusters": list(clusters),
              "n_cases": n, "n_profiles": n_profiles}
    on_reference = all(matrix[k] == v for k, v in REFERENCE_MATRIX.items())
    payload = {
        "matrix": matrix,
        "n_instances": n,
        "variants_per_instance": 17,
        "loop_us_per_instance": t_loop / n * 1e6,
        "portfolio_us_per_instance": t_port / n * 1e6,
        "speedup_loop_over_portfolio": t_loop / t_port,
        "jax_fanout_us_per_instance": (t_jax / n * 1e6) if t_jax else None,
        "jax_fanout_cold_us_per_instance":
            (t_jax_cold / n * 1e6) if t_jax_cold else None,
        # recorded-baseline fields only apply on the reference matrix
        "jax_fanout_us_per_instance_before":
            JAX_FANOUT_BEFORE_US if on_reference else None,
        "multi_profile": multi,
        "planner": planner_stats,
        "lp_blocked": lp_blocked,
        "service": service,
        "obs": obs_stats,
        "gaps": gaps,
        "mapping": mapping,
        "sharded": sharded,
        "seed_reference": dict(SEED_REFERENCE) if on_reference else None,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, "BENCH_portfolio.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    emit("portfolio_engine", t_port / n * 1e6,
         f"loop/portfolio={t_loop / t_port:.2f}x"
         f";jax_us={payload['jax_fanout_us_per_instance'] or 0:.0f}")
    if multi:
        emit("portfolio_multi", multi["multi_jax_us_per_profile"],
             f"multi/loop={multi['speedup_multi_over_loop']:.2f}x"
             f";profiles={n_profiles}")
    if planner_stats:
        g = planner_stats["combined_grid"]
        emit("planner_facade", planner_stats["facade_us"],
             f"overhead={planner_stats['facade_overhead_frac'] * 100:.1f}%"
             f";grid_cells={g['cells']}"
             f";buckets={g['shape_buckets']}"
             f";cold_misses={g['jit_cache_misses_cold']}")
    if lp_blocked:
        sm, ov = lp_blocked["small"], lp_blocked["over_envelope"]
        emit("portfolio_lp_blocked", sm["blocked_us"],
             f"blocked/dense={sm['blocked_over_dense']:.2f}x"
             f";over_envelope_n={ov['n_tasks']}"
             f";dense_raises={ov['dense_raises']}"
             f";steady_misses={lp_blocked['jit_cache_misses_steady']}")
    emit("planner_service", service["latency_p50_ms"] * 1e3,
         f"coalesce={service['coalesce_ratio']:.1f}x"
         f";p99_ms={service['latency_p99_ms']:.1f}"
         f";degraded={service['degraded']}/{service['completed']}"
         f";shed={service['rejected_overloaded']}")
    ws = service["workers_scaling"]
    emit("planner_service_pool", ws["seconds_4_workers"] * 1e6,
         f"speedup_4w={ws['speedup']:.2f}x"
         f";burst={ws['burst']}"
         f";cancel_ms={service['cancel_latency_ms']:.1f}"
         f";cancel_checks={service['cancel_checks']}")
    emit("planner_obs", obs_stats["null_span_ns"],
         f"disabled_overhead="
         f"{obs_stats['disabled_tracer_overhead_frac'] * 100:.4f}%"
         f";spans_per_plan={obs_stats['spans_per_plan']}"
         f";enabled_overhead="
         f"{obs_stats['enabled_tracer_overhead_frac'] * 100:.1f}%")
    cps = [f["candidates_per_sec"] for f in mapping["families"]
           if f["candidates_per_sec"]]
    emit("planner_mapping",
         float(np.median(cps)) if cps else 0.0,
         f"search_wins={mapping['search_wins']}/{mapping['n_families']}"
         f";median_saving="
         f"{np.median([f['saving_frac'] for f in mapping['families']]) * 100:.1f}%")
    if sharded:
        sw, gk = sharded["device_sweep"], sharded["gain_kernel"]
        top = sw["curve"][-1]
        emit("portfolio_sharded", top["steady_us"],
             f"devices={top['devices']}"
             f";speedup_vs_1={top['speedup_vs_1']:.2f}x"
             f";host_cpus={sw['host_cpus']}"
             f";bitwise={all(p['bitwise_identical'] for p in sw['curve'])}"
             f";gain_crossover_n={gk['crossover_n']}"
             f";gain_mode={gk['kernel_mode']}")
    for gc in gaps["cases"]:
        asap_s = ("n/a" if gc["gap_asap"] is None
                  else f"{gc['gap_asap']:.3f}")
        emit("portfolio_gap_" + gc["case"].replace("-", "_"),
             0.0,
             f"gap_best={gc['gap_best']:.3f}"
             f";gap_asap={asap_s}"
             f";optimal={gc['optimal']}"
             f";proven={gc['proven']}")
    return payload


if __name__ == "__main__":
    run()
