"""Portfolio engine benchmark: seed-style per-variant loop vs one-pass
``schedule_portfolio`` vs the device fan-out, plus the multi-profile
replanning engine, machine-readable.

Emits ``benchmarks/out/BENCH_portfolio.json``:
  * ``loop_us_per_instance`` / ``portfolio_us_per_instance`` — live
    measurements of the per-variant ``schedule()`` loop and the portfolio
    engine on the same instances (identical results, tested);
  * ``jax_fanout_us_per_instance`` — the device engine (``engine="jax"``)
    in its replanning regime (steady-state: executables cached per shape
    bucket); ``jax_fanout_cold_us_per_instance`` includes the one-off
    bucket compiles; ``jax_fanout_us_per_instance_before`` is the recorded
    pre-fix number (per-shape retracing, level-relax scan core,
    interpreter-mode gain kernel);
  * ``multi_profile`` — ``schedule_portfolio_multi`` over an ensemble of
    perturbed profiles vs looping ``schedule_portfolio`` per profile;
  * ``seed_reference`` — the recorded wall clock of
    ``run.py --only rank,runtime`` at the seed commit vs this one (the
    acceptance trajectory; update SEED_REFERENCE when re-measuring on new
    hardware — run that matrix at the seed commit in a scratch worktree).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (
    OUT_DIR,
    build_matrix,
    emit,
    run_all_variants,
    run_variant_loop,
)
from repro.core import generate_profile, schedule_portfolio, \
    schedule_portfolio_multi

# wall clock of `run.py --only rank,runtime` (scaled-down matrix, this
# container), measured at the seed commit and after PR1's engine landed.
SEED_REFERENCE = {
    "matrix": "run.py --only rank,runtime (sizes=(200,)/(200,1000))",
    "seed_commit_seconds": 237.7,     # measured at seed commit, 1-CPU box
    "this_commit_seconds": 46.8,      # same box, portfolio engine (5.1x)
}

# `engine="jax"` per instance before the fan-out fix (per-shape retracing
# of the nested level-relax scan + interpreter-mode gain kernel), recorded
# by this benchmark at the PR1 commit — ON THE REFERENCE MATRIX below.
# A --smoke run measures a different matrix, so the recorded baselines are
# withheld there (comparing live tiny-matrix numbers against recorded
# 200-size baselines would fabricate the speedup).
JAX_FANOUT_BEFORE_US = 2733936.2
REFERENCE_MATRIX = {"sizes": [200], "clusters": ["small"], "n_cases": 6}


def run(sizes=(200,), clusters=("small",), n_cases: int = 6,
        with_jax: bool = True, n_profiles: int = 8):
    # NOTE: the persistent compilation cache
    # (repro.kernels.backend.enable_compilation_cache) is deliberately NOT
    # enabled here: the cold measurement must include the real bucket
    # compiles on every run, or cold-vs-steady comparisons across commits
    # would silently go warm after the first run on a machine.
    cases = []
    for case in build_matrix(sizes=sizes, clusters=clusters,
                             factors=(1.0, 2.0), scenarios=("S1", "S3")):
        cases.append(case)
        if len(cases) >= n_cases:
            break

    t0 = time.perf_counter()
    loop_res = [run_variant_loop(c) for c in cases]
    t_loop = time.perf_counter() - t0

    t0 = time.perf_counter()
    port_res = [run_all_variants(c) for c in cases]
    t_port = time.perf_counter() - t0

    for lr, pr in zip(loop_res, port_res):     # engine must be bit-identical
        for v, (cost, _) in lr.items():
            assert pr[v][0] == cost, v

    t_jax = t_jax_cold = None
    multi = None
    if with_jax:
        t0 = time.perf_counter()
        for c in cases:
            run_all_variants(c, engine="jax")
        t_jax_cold = time.perf_counter() - t0   # includes bucket compiles
        t0 = time.perf_counter()
        for c in cases:
            run_all_variants(c, engine="jax")
        t_jax = time.perf_counter() - t0        # replanning regime

        # multi-profile replanning: one instance x an ensemble of perturbed
        # forecasts; loop re-prepares and re-schedules per member, the
        # engine prepares the graph once and fans members x variants out
        # as one device launch
        c = cases[0]
        profs = [generate_profile(c.profile.scenario, c.profile.T,
                                  c.platform, J=48, seed=100 + s)
                 for s in range(n_profiles)]
        t0 = time.perf_counter()
        ref = [schedule_portfolio(c.inst, p, c.platform) for p in profs]
        t_mloop = time.perf_counter() - t0
        schedule_portfolio_multi(c.inst, profs, c.platform,
                                 engine="jax")   # warm the R-bucket shapes
        t0 = time.perf_counter()
        res = schedule_portfolio_multi(c.inst, profs, c.platform,
                                       engine="jax")
        t_multi = time.perf_counter() - t0
        # greedy rows must agree with the per-profile numpy loop
        for r, rr in zip(ref, res):
            for v in r:
                if not v.endswith("-LS"):
                    assert (r[v].start == rr[v].start).all(), v
        multi = {
            "n_profiles": n_profiles,
            "case": c.name,
            "loop_numpy_us_per_profile": t_mloop / n_profiles * 1e6,
            "multi_jax_us_per_profile": t_multi / n_profiles * 1e6,
            "speedup_multi_over_loop": t_mloop / t_multi,
        }

    n = len(cases)
    matrix = {"sizes": list(sizes), "clusters": list(clusters),
              "n_cases": n, "n_profiles": n_profiles}
    on_reference = all(matrix[k] == v for k, v in REFERENCE_MATRIX.items())
    payload = {
        "matrix": matrix,
        "n_instances": n,
        "variants_per_instance": 17,
        "loop_us_per_instance": t_loop / n * 1e6,
        "portfolio_us_per_instance": t_port / n * 1e6,
        "speedup_loop_over_portfolio": t_loop / t_port,
        "jax_fanout_us_per_instance": (t_jax / n * 1e6) if t_jax else None,
        "jax_fanout_cold_us_per_instance":
            (t_jax_cold / n * 1e6) if t_jax_cold else None,
        # recorded-baseline fields only apply on the reference matrix
        "jax_fanout_us_per_instance_before":
            JAX_FANOUT_BEFORE_US if on_reference else None,
        "multi_profile": multi,
        "seed_reference": dict(SEED_REFERENCE) if on_reference else None,
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    out_path = os.path.join(OUT_DIR, "BENCH_portfolio.json")
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)

    emit("portfolio_engine", t_port / n * 1e6,
         f"loop/portfolio={t_loop / t_port:.2f}x"
         f";jax_us={payload['jax_fanout_us_per_instance'] or 0:.0f}")
    if multi:
        emit("portfolio_multi", multi["multi_jax_us_per_profile"],
             f"multi/loop={multi['speedup_multi_over_loop']:.2f}x"
             f";profiles={n_profiles}")
    return payload


if __name__ == "__main__":
    run()
