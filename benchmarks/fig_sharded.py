"""Sharded portfolio grid + tiled gain kernel: honest device-scaling data.

Feeds the ``sharded`` section of ``benchmarks/out/BENCH_portfolio.json``
(via :mod:`benchmarks.fig_portfolio`; also runnable standalone):

* ``device_sweep`` — the combined grid launch
  (``schedule_portfolio_grid(..., devices=d)``) timed at each device
  count over the SAME instance rows, bitwise-verified against the
  single-device launch.  The sweep runs in a subprocess so
  ``--xla_force_host_platform_device_count`` lands before the jax
  backend initializes; on this container every "device" is a slice of
  the same host CPU (``host_cpus`` is recorded next to the curve), so
  the numbers measure partitioning overhead, not parallel speedup —
  wall-clock scaling needs real accelerators, and the curve is recorded
  as measured rather than extrapolated.
* ``gain_kernel`` — the tiled Pallas ``gain_scan`` vs its jnp
  prefix-sum twin across task counts.  On CPU the kernel executes under
  the Pallas interpreter (orders of magnitude slower than compiled
  jnp), so ``crossover_n`` is honestly ``null`` here; the compiled
  TPU/GPU lowering is where the tile layout pays.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import numpy as np

_SWEEP_VARIANTS = ("asap", "pressW", "pressWR")


def _build_rows(n_inst: int):
    from repro.cluster import make_cluster
    from repro.core import (build_instance, deadline_from_asap,
                            generate_profile, heft_mapping)
    from repro.workflows import WORKFLOW_KINDS, make_workflow

    plat = make_cluster(1, seed=0)
    insts, rows = [], []
    for i in range(n_inst):
        wf = make_workflow(WORKFLOW_KINDS[i % len(WORKFLOW_KINDS)], 2,
                           seed=i)
        inst = build_instance(wf, heft_mapping(wf, plat), plat)
        T = deadline_from_asap(inst, 2.0)
        insts.append(inst)
        rows.append([generate_profile("S3", T, plat, J=8, seed=i)])
    return plat, insts, rows


def _child_sweep(devices: list[int], n_inst: int, reps: int) -> dict:
    """Runs INSIDE the forced-device-count subprocess: time the grid
    launch per device count and prove bitwise identity against the
    single-device baseline."""
    import jax

    from repro.core.portfolio import schedule_portfolio_grid

    plat, insts, rows = _build_rows(n_inst)

    def launch(d):
        return schedule_portfolio_grid(insts, rows, plat,
                                       variants=_SWEEP_VARIANTS,
                                       engine="jax", devices=d)

    base = launch(None)
    curve = []
    for d in devices:
        launch(d)                                   # compile this mesh
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            res = launch(d)
            ts.append(time.perf_counter() - t0)
        for i, row in enumerate(base):              # bitwise, every cell
            for p, cell in enumerate(row):
                for name, r in cell.items():
                    got = res[i][p][name]
                    assert np.array_equal(np.asarray(r.start),
                                          np.asarray(got.start)), \
                        (d, i, p, name)
                    assert r.cost == got.cost, (d, i, p, name)
        curve.append({"devices": d, "steady_us": float(np.median(ts)) * 1e6,
                      "bitwise_identical": True})
    one = curve[0]["steady_us"]
    for pt in curve:
        pt["speedup_vs_1"] = one / pt["steady_us"]
    return {
        "jax_devices": len(jax.devices()),
        "host_cpus": os.cpu_count(),
        "n_instances": n_inst,
        "n_profiles": len(rows[0]),
        "variants": list(_SWEEP_VARIANTS),
        "curve": curve,
        "note": ("virtual host devices share one CPU: the curve measures "
                 "shard_map partitioning overhead on this box, not "
                 "parallel speedup"),
    }


def device_sweep(devices=(1, 2, 8), n_inst: int = 8, reps: int = 3) -> dict:
    """Run :func:`_child_sweep` in a subprocess with the forced host
    device count, so the parent's already-initialized backend is moot."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count="
                        f"{max(devices)}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.fig_sharded", "--child",
         "--devices", ",".join(map(str, devices)),
         "--n-inst", str(n_inst), "--reps", str(reps)],
        env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"sharded sweep subprocess failed:\n"
                           f"{proc.stdout}\n{proc.stderr}")
    return json.loads(proc.stdout.splitlines()[-1])


def gain_kernel_crossover(sizes=(256, 1024), t: int = 512, mu: int = 21,
                          reps: int = 3) -> dict:
    """jnp prefix-sum twin vs the (interpreted-on-CPU) Pallas kernel."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.gain_scan import gain_scan

    backend = jax.default_backend()
    points = []
    for n in sizes:
        rng = np.random.default_rng(n)
        rem = jnp.asarray(rng.integers(-9, 9, t).astype(np.float32))
        dur = jnp.asarray(rng.integers(1, 9, n).astype(np.float32))
        start = jnp.asarray(rng.integers(0, t - 10, n).astype(np.float32))
        work = jnp.asarray(rng.integers(0, 7, n).astype(np.float32))
        lo = jnp.maximum(start - 30, 0)
        hi = start + 30

        def timed(interpret):
            gain_scan(rem, start, dur, work, lo, hi, mu=mu,
                      interpret=interpret).block_until_ready()   # warm
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                gain_scan(rem, start, dur, work, lo, hi, mu=mu,
                          interpret=interpret).block_until_ready()
                ts.append(time.perf_counter() - t0)
            return float(np.median(ts)) * 1e6

        # interpret=None auto-dispatches: the jnp twin on CPU (this box)
        points.append({"n_tasks": n, "t": t,
                       "jnp_twin_us": timed(None),
                       "kernel_us": timed(True)})
    faster = [p["n_tasks"] for p in points
              if p["kernel_us"] < p["jnp_twin_us"]]
    return {
        "backend": backend,
        "mu": mu,
        "kernel_mode": "interpret" if backend == "cpu" else "pallas",
        "points": points,
        # smallest N where the kernel wins; null on CPU, where the
        # interpreter (not the Mosaic/Triton lowering) runs the kernel
        "crossover_n": min(faster) if faster else None,
    }


def section(smoke: bool = False) -> dict:
    if smoke:
        sweep = device_sweep(devices=(1, 2, 8), n_inst=8, reps=3)
        kern = gain_kernel_crossover(sizes=(256, 1024))
    else:
        sweep = device_sweep(devices=(1, 2, 4, 8), n_inst=16, reps=5)
        kern = gain_kernel_crossover(sizes=(256, 1024, 4096))
    return {"device_sweep": sweep, "gain_kernel": kern}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--devices", default="1,2,8")
    ap.add_argument("--n-inst", type=int, default=8)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    if args.child:
        out = _child_sweep([int(d) for d in args.devices.split(",")],
                           args.n_inst, args.reps)
        print(json.dumps(out))
    else:
        print(json.dumps(section(smoke=args.smoke), indent=2))


if __name__ == "__main__":
    main()
