"""Fig 2/3: performance profiles (share of instances with ratio >= tau),
overall and split by deadline factor.

Costs come from one ``schedule_portfolio`` pass per case (bit-identical to
the per-variant loop)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import (
    VARIANT_NAMES,
    build_matrix,
    emit,
    run_all_variants,
    write_csv,
)

LS_VARIANTS = tuple(v for v in VARIANT_NAMES if v.endswith("-LS"))
TAUS = np.linspace(0.0, 1.0, 21)


def run(sizes=(200,), clusters=("small",)):
    records = []        # (factor, variant, ratio)
    t0 = time.perf_counter()
    n = 0
    for case in build_matrix(sizes=sizes, clusters=clusters):
        res = run_all_variants(case, variants=LS_VARIANTS)
        best = min(c for c, _ in res.values())
        for v in LS_VARIANTS:
            c = res[v][0]
            ratio = 1.0 if c == best == 0 else (
                best / c if c > 0 else 0.0)
            records.append((case.factor, v, ratio))
        n += 1
    dt = time.perf_counter() - t0

    rows = []
    summary = {}
    for split in ("all", 1.0, 1.5, 2.0, 3.0):
        for v in LS_VARIANTS:
            rs = np.asarray([r for f, vv, r in records
                             if vv == v and (split == "all" or f == split)])
            if len(rs) == 0:
                continue
            curve = [(rs >= t).mean() for t in TAUS]
            rows.append([split, v] + [f"{c:.4f}" for c in curve])
            if split == "all":
                summary[v] = curve[-1]      # share of instances at tau=1.0
    write_csv("fig2_perf_profiles.csv",
              ["split", "variant"] + [f"tau{t:.2f}" for t in TAUS], rows)
    leader = max(summary, key=summary.get)
    emit("fig2_perf_profile", dt / max(n, 1) * 1e6,
         f"tau1_leader={leader};share={summary[leader]:.3f}")
    return records


if __name__ == "__main__":
    run()
